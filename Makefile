# Tier-1 verify and convenience targets. PYTHONPATH=src mirrors ROADMAP.md.

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-smoke

# full tier-1 gate (what CI runs)
test:
	$(PY) -m pytest -x -q

# fast loop: skip the multi-minute @slow integration tests
test-fast:
	$(PY) -m pytest -q -m "not slow"

# full benchmark sweep (one bench per paper table/figure), with the
# machine-readable trajectory written to BENCH_<version>.json — the
# version lives in benchmarks/common.py (BENCH_VERSION); earlier
# BENCH_*.json files are committed history, never overwritten
bench:
	PYTHONPATH=src:. python -m benchmarks.run --json

# quick smoke: the mining-perf ladder (jnp vs pallas variants) plus the
# fused-superstep gate (syncs-per-step + speedup vs the PR-2 chunk loop),
# the checkpoint-overhead gate (<=5% of superstep wall time), the
# aggregation-bytes gate (device level 1 >=10x below B*24 per superstep),
# the graph-shard gate (per-device adjacency bytes <= 1/W at W=8,
# partitioned mining bit-identical to replicated), the observability
# gate (traced run ≤1% overhead + zero extra syncs, ≥95% phase coverage),
# and the fault-recovery gate (supervised crash recovery bit-identical,
# recovery overhead <=15% of the clean superstep wall)
bench-smoke:
	PYTHONPATH=src:. python -m benchmarks.run --smoke --json
