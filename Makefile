# Tier-1 verify and convenience targets. PYTHONPATH=src mirrors ROADMAP.md.

PY := PYTHONPATH=src python

.PHONY: test test-fast bench bench-smoke

# full tier-1 gate (what CI runs)
test:
	$(PY) -m pytest -x -q

# fast loop: skip the multi-minute @slow integration tests
test-fast:
	$(PY) -m pytest -q -m "not slow"

# full benchmark sweep (one bench per paper table/figure)
bench:
	PYTHONPATH=src:. python -m benchmarks.run

# quick smoke: just the mining-perf ladder (jnp vs pallas variants)
bench-smoke:
	PYTHONPATH=src:. python -m benchmarks.run --smoke
