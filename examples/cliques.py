"""Clique mining (paper Fig. 4c — the 19-line app) + validation against
networkx.

    PYTHONPATH=src python examples/cliques.py

Store knobs (DESIGN.md §7): ``EngineConfig(store="odag")`` keeps the
frontier ODAG-compressed between supersteps and re-applies the isClique
filter during extraction; ``device_budget_bytes=...`` mines in waves.
"""
import networkx as nx

from repro.core import EngineConfig, graph, run
from repro.core.apps import CliquesApp

g = graph.unlabeled_sn_like(scale=0.0002)
print(f"graph: {g.n} vertices, {g.m} edges")

res = run(g, CliquesApp(max_size=4), EngineConfig(chunk_size=8192,
                                                  initial_capacity=1 << 15))
for size, emb in sorted(res.embeddings.items()):
    print(f"  cliques of size {size}: {emb.shape[0]}")

# cross-check with networkx
gx = g.to_networkx()
counts = {}
for c in nx.enumerate_all_cliques(gx):
    if len(c) > 4:
        break
    counts[len(c)] = counts.get(len(c), 0) + 1
print("networkx:", counts)
assert all(res.embeddings[k].shape[0] == v for k, v in counts.items() if k in res.embeddings)
print("MATCH")
