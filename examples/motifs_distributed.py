"""Distributed motif counting over a device mesh (run with forced host
devices to see real sharding on CPU):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/motifs_distributed.py

Frontier-store knobs (DESIGN.md §7): ``DistConfig(store="raw")`` (default)
exchanges the frontier as a dense embedding list with even block slicing;
``store="odag"`` merges worker-local DenseODAGs with one OR-allreduce and
re-materialises cost-balanced per-worker slices (paper §5.2/§5.3) — see
``examples/motifs_odag_store.py`` for that variant with the live
compression numbers. ``DistConfig(checkpoint_dir=...)`` checkpoints every
sealed superstep; resuming on a mesh of a *different* worker count is
elastic by construction (DESIGN.md §9, ``examples/resume_after_crash.py``).
"""
import jax

from repro.core import graph
from repro.core.apps import MotifsApp
from repro.core.distributed import DistConfig, run_distributed

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
print(f"mesh: {n} workers")

g = graph.mico_like(scale=0.004)
res = run_distributed(g, MotifsApp(max_size=3), mesh, DistConfig())

print(f"motif counts over {res.stats.total_embeddings} embeddings:")
for code, count in sorted(res.patterns.items(), key=lambda kv: -kv[1]):
    print(f"  {code}: {count}")
print("\nper-step collective bytes (two-level aggregation):",
      [s.collective_bytes for s in res.stats.steps])
