"""Distributed motif counting over a device mesh (run with forced host
devices to see real sharding on CPU):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/motifs_distributed.py
"""
import jax

from repro.core import graph
from repro.core.apps import MotifsApp
from repro.core.distributed import DistConfig, run_distributed

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
print(f"mesh: {n} workers")

g = graph.mico_like(scale=0.004)
res = run_distributed(
    g, MotifsApp(max_size=3), mesh, DistConfig(use_odag_exchange=True)
)

print(f"motif counts over {res.stats.total_embeddings} embeddings:")
for code, count in sorted(res.patterns.items(), key=lambda kv: -kv[1]):
    print(f"  {code}: {count}")
print("\nper-step collective bytes (two-level aggregation):",
      [s.collective_bytes for s in res.stats.steps])
print("ODAG vs raw frontier bytes:",
      [(s.odag_bytes, s.frontier_bytes) for s in res.stats.steps])
