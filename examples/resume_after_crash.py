"""Checkpoint a mining run, kill it mid-flight, resume — same output.

    PYTHONPATH=src python examples/resume_after_crash.py

The walkthrough (DESIGN.md §9):

  1. mine the reference result uninterrupted;
  2. launch the SAME run in a child process with
     ``EngineConfig(checkpoint_dir=...)`` — every sealed superstep is
     persisted atomically — and hard-kill the child (``os._exit``) right
     after superstep 2's checkpoint lands, before the run can finish: what
     is left on disk is exactly what a SIGKILL / preemption at that seal
     boundary leaves;
  3. ``resume()`` from the surviving checkpoint and compare pattern
     dictionaries: identical.

Because the checkpoint payload is worker-count-free (the sealed frontier
store plus the superstep cursor), step 3 could equally hand the same
checkpoint to a ``ShardMapBackend`` over any mesh — see the elastic
restore tests in ``tests/test_checkpoint.py``.

This example doubles as the CI resume smoke (.github/workflows/ci.yml).
"""
import os
import subprocess
import sys
import tempfile
import textwrap

from repro.core import EngineConfig, graph, resume, run
from repro.core.apps import MotifsApp
from repro.core.runtime import latest_checkpoint

SCALE = 0.05          # CiteSeer-shaped, seconds per run
CRASH_AFTER_STEP = 2  # die once superstep 2's checkpoint is on disk

CHILD = textwrap.dedent(
    f"""
    import os, sys
    from repro.core import EngineConfig, graph, run
    from repro.core.apps import MotifsApp
    from repro.core.stats import StepStats

    ckpt_dir = sys.argv[1]
    # crash injection: hard-exit the moment superstep {CRASH_AFTER_STEP}'s
    # checkpoint has been written (StepStats.t_checkpoint is assigned right
    # after the atomic os.replace), leaving the run genuinely unfinished.
    t_ckpt_setter = StepStats.__setattr__
    def die_after_checkpoint(self, name, value):
        t_ckpt_setter(self, name, value)
        if name == "t_checkpoint" and value > 0 and self.step >= {CRASH_AFTER_STEP}:
            os._exit(17)
    StepStats.__setattr__ = die_after_checkpoint

    g = graph.citeseer_like(scale={SCALE})
    run(g, MotifsApp(max_size=3), EngineConfig(checkpoint_dir=ckpt_dir))
    os._exit(0)   # unreachable if the crash fired
    """
)


def main() -> None:
    g = graph.citeseer_like(scale=SCALE)
    app = MotifsApp(max_size=3)

    reference = run(g, app, EngineConfig())
    print(f"reference run: {len(reference.patterns)} patterns over "
          f"{len(reference.stats.steps)} supersteps")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep)
        )
        proc = subprocess.run(
            [sys.executable, "-c", CHILD, ckpt_dir], env=env
        )
        assert proc.returncode == 17, (
            f"child should have died mid-run (exit {proc.returncode})"
        )
        survivor = latest_checkpoint(ckpt_dir)
        print(f"child killed mid-run; survivor: {os.path.basename(survivor)}")

        resumed = resume(g, app, survivor)
        print(f"resumed run:   {len(resumed.patterns)} patterns over "
              f"{len(resumed.stats.steps)} supersteps "
              f"(replayed steps {[s.step for s in resumed.stats.steps[CRASH_AFTER_STEP:]]})")

        assert resumed.patterns == reference.patterns, "outputs diverged!"
        print("OK: resumed output identical to the uninterrupted run")


if __name__ == "__main__":
    main()
