"""Checkpoint a mining run, kill it mid-flight, resume — same output.

    PYTHONPATH=src python examples/resume_after_crash.py

The walkthrough (DESIGN.md §9 + §13):

  1. mine the reference result uninterrupted;
  2. launch the SAME run in a child process with
     ``EngineConfig(checkpoint_dir=...)`` — every sealed superstep is
     persisted atomically — and kill it with the §13 fault-injection
     layer: ``FaultPlan([FaultSpec("materialize", 3, "exit")])`` hard-
     exits (``os._exit``) the instant superstep 3 opens, right after
     superstep 2's checkpoint landed. What is left on disk is exactly
     what a SIGKILL / preemption at that boundary leaves;
  3. ``resume()`` from the surviving checkpoint and compare pattern
     dictionaries: identical;
  4. do it all again WITHOUT the manual resume: ``run_supervised`` with
     an injected crash retries from the last valid checkpoint by itself
     and reports what it did in ``result.recovery``.

Because the checkpoint payload is worker-count-free (the sealed frontier
store plus the superstep cursor), step 3 could equally hand the same
checkpoint to a ``ShardMapBackend`` over any mesh — see the elastic
restore tests in ``tests/test_checkpoint.py``, and the full
crash-at-every-phase kill matrix there for what this example smokes.

This example doubles as the CI resume smoke (.github/workflows/ci.yml).
"""
import os
import subprocess
import sys
import tempfile
import textwrap

from repro.core import EngineConfig, graph, resume, run, run_supervised
from repro.core.runtime import FaultPlan, FaultSpec, latest_checkpoint
from repro.core.runtime import faults as faults_lib
from repro.core.apps import MotifsApp

SCALE = 0.05      # CiteSeer-shaped, seconds per run
CRASH_STEP = 3    # die as superstep 3 opens: step 2's checkpoint survives

CHILD = textwrap.dedent(
    f"""
    import sys
    from repro.core import EngineConfig, graph, run
    from repro.core.apps import MotifsApp
    from repro.core.runtime import FaultPlan, FaultSpec

    # deterministic crash injection (DESIGN.md §13): kind "exit" calls
    # os._exit at the materialize boundary of superstep {CRASH_STEP} —
    # no atexit, no unwinding, the run is genuinely torn.
    plan = FaultPlan([FaultSpec("materialize", {CRASH_STEP}, "exit")])
    g = graph.citeseer_like(scale={SCALE})
    run(g, MotifsApp(max_size=3),
        EngineConfig(checkpoint_dir=sys.argv[1], faults=plan))
    raise SystemExit("unreachable: the injected exit never fired")
    """
)


def main() -> None:
    g = graph.citeseer_like(scale=SCALE)
    app = MotifsApp(max_size=3)

    reference = run(g, app, EngineConfig())
    print(f"reference run: {len(reference.patterns)} patterns over "
          f"{len(reference.stats.steps)} supersteps")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep)
        )
        proc = subprocess.run(
            [sys.executable, "-c", CHILD, ckpt_dir], env=env
        )
        assert proc.returncode == faults_lib.EXIT_CODE, (
            f"child should have died mid-run (exit {proc.returncode})"
        )
        survivor = latest_checkpoint(ckpt_dir)
        print(f"child killed mid-run; survivor: {os.path.basename(survivor)}")

        resumed = resume(g, app, survivor)
        print(f"resumed run:   {len(resumed.patterns)} patterns over "
              f"{len(resumed.stats.steps)} supersteps "
              f"(replayed steps "
              f"{[s.step for s in resumed.stats.steps[CRASH_STEP - 1:]]})")

        assert resumed.patterns == reference.patterns, "outputs diverged!"
        print("OK: resumed output identical to the uninterrupted run")

    # -- the supervised version: no manual resume step -------------------
    plan = FaultPlan([FaultSpec("expand", 2, "crash")])
    supervised = run_supervised(g, app, EngineConfig(faults=plan))
    rec = supervised.recovery
    print(f"run_supervised: crashed once, retried {rec['n_retries']}x, "
          f"resumed from step {rec['resumed_step']}, recovery "
          f"{rec['t_recovery'] * 1e3:.1f} ms")
    assert supervised.patterns == reference.patterns, "outputs diverged!"
    print("OK: supervised recovery identical to the uninterrupted run")


if __name__ == "__main__":
    main()
