"""End-to-end driver: frequent subgraph mining on a CiteSeer-scale graph,
reporting the paper's headline metrics (frequent patterns + supports,
quick-pattern reduction, per-step stats). This is the paper-kind end-to-end
run (a mining system's equivalent of a training run).

    PYTHONPATH=src python examples/fsm_end_to_end.py [--support 8] [--scale 0.3]

Pass ``--store odag`` to keep each superstep's frontier ODAG-compressed
between steps (paper §5.2, DESIGN.md §7) and print the live per-step
compression; ``EngineConfig(device_budget_bytes=...)`` additionally mines
frontiers larger than device memory in budget-sized waves.
"""
import argparse

from repro.core import EngineConfig, graph, run
from repro.core.apps import FSMApp
from repro.core.pattern import pattern_to_networkx


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--support", type=int, default=8)
    ap.add_argument("--max-size", type=int, default=3)
    ap.add_argument("--scale", type=float, default=0.3)
    ap.add_argument("--store", choices=["raw", "odag"], default="raw")
    args = ap.parse_args()

    g = graph.citeseer_like(scale=args.scale)
    print(f"graph: {g.n} vertices, {g.m} edges, {g.labels.max()+1} labels")
    res = run(
        g,
        FSMApp(support=args.support, max_size=args.max_size),
        EngineConfig(chunk_size=8192, initial_capacity=1 << 15,
                     store=args.store),
    )
    if args.store == "odag":
        print("frontier compression (raw -> odag bytes, Fig. 9):",
              {k: round(v, 1) for k, v in
               res.stats.compression_by_size().items()})

    print(f"\n{len(res.patterns)} frequent patterns (support >= {args.support}):")
    for code, sup in sorted(res.patterns.items(), key=lambda kv: -kv[1])[:10]:
        gx = pattern_to_networkx(code)
        labels = [d["label"] for _, d in gx.nodes(data=True)]
        print(f"  {gx.number_of_edges()} edges, labels={labels}: support={sup}")

    print("\nper-step stats (paper Table 4 shape):")
    print("step size frontier candidates canonical quick canon iso")
    for s in res.stats.steps:
        print(
            f"{s.step:4d} {s.size:4d} {s.n_frontier:9d} {s.n_generated:10d} "
            f"{s.n_canonical:9d} {s.n_quick_patterns:5d} "
            f"{s.n_canonical_patterns:5d} {s.n_iso_checks:4d}"
        )
    print(f"\nwall time: {res.stats.wall_time:.2f}s; "
          f"embeddings: {res.stats.total_embeddings}")


if __name__ == "__main__":
    main()
