"""Traced mining run: phase spans + Perfetto export (DESIGN.md §12).

    PYTHONPATH=src python examples/traced_run.py [--trace-dir traces]

Runs depth-3 motifs with ``RunConfig(trace=True, trace_dir=...)`` and
prints where the Chrome trace landed — open it at https://ui.perfetto.dev
(or ``chrome://tracing``) to see every superstep broken into
materialize / aggregate / alpha / expand / seal / checkpoint spans with
frontier sizes, bytes-to-host and host-sync counter tracks underneath.
``log_every=1`` also prints the one-line-per-superstep progress log.
CI runs this and validates the artifact with
``benchmarks/render_trace.py --check``.
"""
from __future__ import annotations

import argparse

from repro.core import RunConfig, SuperstepRuntime, graph, obs
from repro.core.apps import MotifsApp


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace-dir", default="traces")
    ap.add_argument("--scale", type=float, default=0.002)
    opts = ap.parse_args()

    g = graph.mico_like(scale=opts.scale)
    cfg = RunConfig(
        max_steps=3, trace=True, trace_dir=opts.trace_dir, log_every=1
    )
    result = SuperstepRuntime(g, MotifsApp(max_size=3), cfg).run()

    print(
        f"mined {result.stats.total_embeddings} embeddings "
        f"({len(result.patterns)} patterns) in "
        f"{result.stats.wall_time:.2f}s"
    )
    print(f"phase walls: {result.stats.phase_walls()}")
    print(f"trace: {result.trace_path}  (open in https://ui.perfetto.dev)")

    import json
    with open(result.trace_path) as f:
        doc = json.load(f)
    problems = obs.validate_chrome_trace(doc)
    cov = obs.phase_coverage(doc)
    assert not problems, problems
    print(f"trace valid; phase coverage {cov['coverage']:.2%}")


if __name__ == "__main__":
    main()
