"""Quickstart: mine motifs with the filter-process API in ~10 lines.

    PYTHONPATH=src python examples/quickstart.py

``EngineConfig`` knobs worth knowing: ``store="odag"`` keeps the frontier
ODAG-compressed between supersteps (paper §5.2), ``device_budget_bytes``
bounds the device-resident slice per wave (larger-than-memory mining) —
see DESIGN.md §7 and ``examples/motifs_odag_store.py``. The superstep
itself runs as the fused pipeline of DESIGN.md §8: ``async_chunks=True``
(default) dispatches chunks sync-free with child pattern codes computed
in the same device pass (``False`` = the PR-2 chunk loop, one host sync
per chunk), and ``compact_kernel`` routes compaction through the Pallas
stream-compaction kernel. ``cost_model="auto"`` (the default) resolves
every unset knob — pipeline shape, aggregation placement, kernel vs jnp,
sort vs radix bin — to the pilot-measured fastest choice for your
backend and graph, recorded in ``result.stats.cost_model``; pass
``cost_model="off"`` for the static defaults or ``cost_model_dir=...``
to skip the pilot on repeat runs (DESIGN.md §14).
``checkpoint_dir=...`` persists every sealed superstep so an interrupted
run resumes with identical output (DESIGN.md §9,
``examples/resume_after_crash.py``). ``trace=True, trace_dir="traces"``
exports a Perfetto-loadable trace of the run's phase spans — zero
overhead when off (DESIGN.md §12, ``examples/traced_run.py``).
"""
from repro.core import EngineConfig, graph, run
from repro.core.apps import MotifsApp
from repro.core.pattern import pattern_to_networkx

g = graph.citeseer_like(scale=0.05)                # CiteSeer-shaped graph
result = run(g, MotifsApp(max_size=3), EngineConfig())

print(f"explored {result.stats.total_embeddings} embeddings "
      f"in {result.stats.wall_time:.2f}s over {len(result.stats.steps)} steps")
top = sorted(result.patterns.items(), key=lambda kv: -kv[1])[:5]
for code, count in top:
    gx = pattern_to_networkx(code)
    print(f"  pattern nodes={gx.number_of_nodes()} edges={gx.number_of_edges()} "
          f"labels={[d['label'] for _, d in gx.nodes(data=True)]}: {count} embeddings")
