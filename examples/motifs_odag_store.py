"""Distributed motif counting with the ODAG frontier store (paper §5.2/§5.3):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python examples/motifs_odag_store.py

The ``store="odag"`` variant of ``examples/motifs_distributed.py``: between
BSP supersteps the frontier lives as a per-size ODAG instead of a dense
embedding list. Each worker's children are folded into a fixed-shape
DenseODAG, the worker bitmaps are merged with a bitwise OR (the paper's
§5.2 OR-allreduce, computed host-side in this single-process runtime), and
every worker re-materialises an approximately equal-cost slice via §5.3
cost-annotated partitioning — so exchange bytes scale with the ODAG, never
the embedding count. The printed per-step compression ratio is Fig. 9 from
a live engine run (``StepStats.compression``).

Other store knobs (DESIGN.md §7): the serial engine additionally accepts
``EngineConfig(store="odag", device_budget_bytes=...)`` to mine frontiers
larger than device memory in budget-sized waves (SpillStore).

Superstep knobs (DESIGN.md §8): ``async_chunks=True`` (default on both
``EngineConfig`` and ``DistConfig``) runs the fused pipeline — children,
counts, and quick-pattern codes in one device pass, at most two host
syncs per superstep; ``compact_kernel`` routes compaction through the
Pallas stream-compaction kernel (auto-on where Pallas compiles natively).
With ``store="odag"`` the carried-code shortcut is skipped (extraction
may resurrect rows) but the dispatch stays sync-free.
"""
import jax

from repro.core import graph
from repro.core.apps import MotifsApp
from repro.core.distributed import DistConfig, run_distributed

n = len(jax.devices())
mesh = jax.make_mesh((n,), ("data",))
print(f"mesh: {n} workers, frontier store: odag")

g = graph.mico_like(scale=0.004)
res = run_distributed(g, MotifsApp(max_size=3), mesh, DistConfig(store="odag"))

print(f"motif counts over {res.stats.total_embeddings} embeddings:")
for code, count in sorted(res.patterns.items(), key=lambda kv: -kv[1]):
    print(f"  {code}: {count}")

print("\nfrontier exchange, raw embedding list vs ODAG (Fig. 9):")
for s in res.stats.steps:
    if not s.odag_bytes:
        continue
    print(
        f"  size {s.size}: raw {s.frontier_bytes:>10,} B"
        f" -> odag {s.odag_bytes:>9,} B"
        f"  ({s.compression:.1f}x compression)"
    )
print("summary:", res.stats.summary())
