"""Train a ~130M-param LM (smollm-135m exact config) for a few hundred
steps on synthetic data with checkpointing — the model-zoo end-to-end
driver. On CPU this uses the reduced config by default; pass --full on a
real accelerator.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import subprocess
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-135m",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_ckpt",
    ]
    if args.full:
        cmd.append("--full")
    sys.exit(subprocess.call(cmd))
