"""Shared benchmark plumbing: CSV emission per the harness contract."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
