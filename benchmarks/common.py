"""Shared benchmark plumbing: CSV emission per the harness contract, plus
an in-process record of every emitted row so ``benchmarks/run.py --json``
can write the machine-readable perf trajectory (BENCH_<n>.json) that
future PRs gate against."""
from __future__ import annotations

import os
import sys
import time
from typing import Dict, List

#: THE bench-trajectory version: bump once per PR. ``run.py --json``,
#: the Makefile and CI all derive the output filename from here so the
#: three can never disagree again (PR 7 fixed a hardcoded stale default).
BENCH_VERSION = 10
DEFAULT_BENCH_JSON = f"BENCH_{BENCH_VERSION}.json"
PREV_BENCH_JSON = f"BENCH_{BENCH_VERSION - 1}.json"


def warn_missing_previous(root: str = ".") -> None:
    """Warn when the previous PR's trajectory file is absent — BENCH_7.json
    silently vanished in the PR-7 version rename; an explicit warning at
    ``--json`` time keeps the gap from recurring unnoticed."""
    if not os.path.exists(os.path.join(root, PREV_BENCH_JSON)):
        print(
            f"# WARNING: {PREV_BENCH_JSON} not found next to the new "
            f"trajectory — the bench history has a gap (commit the previous "
            f"version's file or note the break in CHANGES.md)",
            file=sys.stderr,
        )

#: every emit() of the process, in order — drained by run.py --json.
RECORDS: List[Dict] = []


def _parse_derived(derived: str) -> Dict:
    """Decode the ``k=v;k=v`` derived field into typed values (numbers
    where they parse, strings otherwise)."""
    out: Dict = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    RECORDS.append(
        {
            "name": name,
            "us_per_call": round(float(us_per_call), 1),
            "derived": _parse_derived(derived),
        }
    )


def timed(fn, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6
