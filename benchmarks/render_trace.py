"""Render / validate an exported superstep trace (DESIGN.md §12).

    PYTHONPATH=src python benchmarks/render_trace.py run.trace.json
    PYTHONPATH=src python benchmarks/render_trace.py --check run.trace.json

Default mode prints a per-phase summary of the run: span counts, total
wall per phase, the share of superstep wall the named phases cover, and
the counter tracks' final values. ``--check`` validates the Chrome
trace-event schema (every "X" event carries name/ph/ts/dur/pid/tid —
the subset Perfetto's importer needs) plus the §12 coverage gate
(phase spans account for >= 95% of superstep wall) and exits non-zero
on any problem — CI runs exactly this against the traced-run artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict

from repro.core import obs

#: the acceptance gate: named phase spans must account for this share of
#: the superstep wall (ISSUE 7 / DESIGN.md §12).
COVERAGE_GATE = 0.95


def summarize(doc) -> str:
    """Human-readable per-phase roll-up of one trace document."""
    by_name = defaultdict(lambda: [0, 0.0])   # name -> [count, total_us]
    supersteps = 0
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        rec = by_name[e["name"]]
        rec[0] += 1
        rec[1] += float(e["dur"])
        if e["name"] == "superstep":
            supersteps += 1
    other = doc.get("otherData", {})
    cov = obs.phase_coverage(doc)
    lines = [
        f"backend={other.get('backend', '?')}"
        f" wall={other.get('wall_time_s', '?')}s"
        f" supersteps={supersteps}"
        f" coverage={cov['coverage']:.4f}"
    ]
    for name, (count, total_us) in sorted(
        by_name.items(), key=lambda kv: -kv[1][1]
    ):
        lines.append(
            f"  {name:<16} n={count:<5} total={total_us / 1e6:.4f}s"
        )
    metrics = other.get("metrics", {})
    for kind in ("counters", "gauges"):
        for k, v in sorted(metrics.get(kind, {}).items()):
            lines.append(f"  [{kind[:-1]}] {k} = {v}")
    return "\n".join(lines)


def check(doc) -> list:
    """Schema + coverage problems of one trace document (empty == pass).

    A partial trace of an aborted run (``otherData.aborted``, written by
    the loop's exception path / supervisor-caught crash) must still parse
    and pass the schema check, but its interrupted superstep legitimately
    has uncovered wall — the coverage gate applies to clean runs only."""
    problems = obs.validate_chrome_trace(doc)
    if doc.get("otherData", {}).get("aborted"):
        return problems
    cov = obs.phase_coverage(doc)
    if cov["coverage"] < COVERAGE_GATE:
        problems.append(
            f"phase coverage {cov['coverage']:.4f} below the "
            f"{COVERAGE_GATE:.0%} gate "
            f"(covered {cov['covered_us']:.0f}us of {cov['total_us']:.0f}us)"
        )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", metavar="TRACE_JSON",
                    help="exported .trace.json file(s)")
    ap.add_argument("--check", action="store_true",
                    help="validate schema + coverage gate; exit 1 on problems")
    opts = ap.parse_args(argv)
    failures = 0
    for path in opts.paths:
        with open(path) as f:
            doc = json.load(f)
        problems = check(doc)
        if opts.check:
            if problems:
                failures += 1
                print(f"{path}: FAIL")
                for p in problems:
                    print(f"  - {p}")
            else:
                cov = obs.phase_coverage(doc)
                n = sum(
                    1 for e in doc["traceEvents"] if e.get("ph") == "X"
                )
                print(
                    f"{path}: OK ({n} spans, "
                    f"coverage={cov['coverage']:.4f})"
                )
        else:
            print(f"== {path}")
            print(summarize(doc))
            for p in problems:
                print(f"  !! {p}")
            failures += bool(problems)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
