"""Paper Fig. 12: CPU-time breakdown per phase (G+C = expand+canonical,
P = pattern aggregation, W+R = ODAG build/extract)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run, to_device
from repro.core import odag
from repro.core.apps import MotifsApp


def main():
    g = G.citeseer_like(scale=0.1)
    res = run(
        g, MotifsApp(max_size=4, collect_embeddings=True),
        EngineConfig(chunk_size=8192, initial_capacity=16384),
    )
    t_expand = sum(s.t_expand for s in res.stats.steps)
    t_agg = sum(s.t_aggregate for s in res.stats.steps)
    dg = to_device(g)
    emb = res.embeddings[max(res.embeddings)]
    o, us_w = timed(odag.build, emb)
    _, us_r = timed(odag.extract, dg, o)
    t_storage = (us_w + us_r) / 1e6
    total = t_expand + t_agg + t_storage
    emit(
        "fig12.breakdown_motifs",
        total * 1e6,
        f"GC={t_expand/total:.0%};P={t_agg/total:.0%};WR={t_storage/total:.0%}",
    )


if __name__ == "__main__":
    main()
