"""Paper Table 3 / Fig. 8: scalability with worker count. Workers are
forced host devices in subprocesses (1, 2, 4, 8); speedup is relative to 1
worker, like the paper's Fig. 8 normalises to 5 servers."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

SCRIPT = textwrap.dedent(
    """
    import json, time
    import jax
    from repro.core import graph as G
    from repro.core.apps import MotifsApp
    from repro.core.distributed import run_distributed, DistConfig

    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    g = G.mico_like(scale=0.004, seed=11)
    app = MotifsApp(max_size=3)
    # warmup (compile)
    run_distributed(g, app, mesh, DistConfig(initial_capacity=1 << 15))
    t0 = time.perf_counter()
    res = run_distributed(g, app, mesh, DistConfig(initial_capacity=1 << 15))
    dt = time.perf_counter() - t0
    print("RESULT" + json.dumps({"n": n, "time_s": dt,
                                 "emb": res.stats.total_embeddings}))
    """
)


def main():
    times = {}
    for n in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run(
            [sys.executable, "-W", "ignore", "-c", SCRIPT],
            env=env, capture_output=True, text=True, timeout=1200,
        )
        if proc.returncode != 0:
            emit(f"table3.motifs_{n}w", -1, "error")
            continue
        line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
        out = json.loads(line[len("RESULT"):])
        times[n] = out["time_s"]
        speedup = times[1] / out["time_s"] if 1 in times else 1.0
        emit(
            f"table3.motifs_{n}w",
            out["time_s"] * 1e6,
            f"speedup={speedup:.2f};emb={out['emb']}",
        )


if __name__ == "__main__":
    main()
