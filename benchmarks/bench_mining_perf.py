"""§Perf for the paper's own technique: the distributed mining step with
the paper's optimisations toggled.

  iteration 0 (naive):   per-embedding pattern exchange + per-embedding
                         graph-isomorphism canonicalisation (Fig 11 naive)
  iteration 1 (faithful): two-level aggregation — one domain-bitmap
                         collective, iso checks only per quick pattern
  iteration 2 (+ODAG):   frontier exchange compressed as DenseODAG

Reports wall time, collective bytes and iso-check counts per variant.

Plus the canonical-check kernel ladder on the serial engine (tentpole):

  jnp            pure-jnp Alg.-2 check (XLA streams the bitmap from HBM)
  pallas_interp  Pallas kernel forced through the interpreter
  pallas_auto    interpret=None — compiled (Mosaic/Triton) on TPU/GPU,
                 interpreter on CPU
  pallas_fused   fused expand_canonical kernel (validity + dedup + Alg.-2
                 in one VMEM pass)

Every rung must reproduce the jnp baseline's patterns exactly; the ladder
asserts that before emitting its timing row.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import graph as G
from repro.core.apps import FSMApp, MotifsApp
from repro.core.distributed import DistConfig, run_distributed
from repro.core.engine import EngineConfig, run


def _run(cfg_kwargs, g, mesh):
    app = FSMApp(support=4, max_size=3)
    t0 = time.perf_counter()
    res = run_distributed(g, app, mesh, DistConfig(**cfg_kwargs))
    dt = time.perf_counter() - t0
    coll = sum(s.collective_bytes for s in res.stats.steps)
    iso = sum(s.n_iso_checks for s in res.stats.steps)
    odag = sum(s.odag_bytes for s in res.stats.steps)
    raw = sum(s.frontier_bytes for s in res.stats.steps)
    return dt, coll, iso, odag, raw, len(res.patterns)


def _pallas_ladder():
    """jnp vs pallas-interpret vs pallas-auto(compiled) vs fused, serial.

    Correctness leg: every variant's end-to-end engine run must reproduce
    the jnp baseline's patterns. Timing leg: the module-level jitted
    ``explore.expand_and_compact`` (its jit cache persists across calls,
    unlike ``engine.run`` which builds a fresh closure per run), one
    warm-up call to compile then timed steady-state repeats — so the rows
    compare kernel throughput, not trace/compile time.
    """
    from repro.core import explore, to_device
    from repro.core.engine import _next_pow2

    g = G.citeseer_like(scale=0.06)
    rungs = [
        ("jnp", dict(use_pallas=False)),
        ("pallas_interp", dict(use_pallas=True, interpret=True)),
        ("pallas_auto", dict(use_pallas=True)),
        ("pallas_fused", dict(use_pallas=True, fused=True)),
    ]

    baseline = run(g, MotifsApp(max_size=3), EngineConfig(use_pallas=False))
    for name, kw in rungs[1:]:
        cfg = EngineConfig(
            use_pallas=kw["use_pallas"],
            fused_expand=kw.get("fused", False),
            pallas_interpret=kw.get("interpret"),
        )
        res = run(g, MotifsApp(max_size=3), cfg)
        assert res.patterns == baseline.patterns, f"{name} diverged from jnp"

    dg = to_device(g)
    # the pallas_* rows must actually time the kernels, not a silent
    # graph-size fallback to jnp — fail loudly if the graph outgrows VMEM
    from repro.kernels.canonical_check import ops as cc_ops
    assert cc_ops.fits_vmem(dg) and cc_ops.fits_vmem_fused(dg), (
        "ladder graph exceeds the kernel VMEM limits; pallas rows would "
        "silently time the jnp fallback"
    )
    # representative frontier: all size-2 embeddings, then time expanding it
    f1 = jnp.arange(dg.n, dtype=jnp.int32)[:, None]
    nv1 = jnp.ones((dg.n,), jnp.int32)
    children, count, _, _ = explore.expand_and_compact(
        dg, f1, nv1, "vertex", _next_pow2(4 * dg.m)
    )
    members = children[: int(count)]
    nv = jnp.full((members.shape[0],), 2, jnp.int32)
    cap = _next_pow2(32 * dg.m)  # roomy: timing must not truncate children

    repeat = 5
    for name, kw in rungs:
        step = lambda: explore.expand_and_compact(
            dg, members, nv, "vertex", cap, **kw
        )
        jax.block_until_ready(step())          # warm-up: trace + compile
        t0 = time.perf_counter()
        for _ in range(repeat):
            out = step()
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / repeat
        emit(
            f"perf_mining.ladder_{name}", dt * 1e6,
            f"frontier={int(members.shape[0])};children={int(out[1])};"
            f"backend={jax.default_backend()}",
        )

    # ---- ladder_auto: the cost-model-decided rung (DESIGN.md §14) ------
    # The BENCH_8 regression was ``auto`` (the old static heuristic)
    # picking a mode measured slower than jnp on CPU. The heuristic is
    # gone; the decision now comes from the same pilot ladder the engine
    # runs at bind time. Gate: the decided combo, re-timed back-to-back
    # against the jnp baseline (same process phase — absolute times drift
    # ~25% across this bench, so cross-phase ratios are meaningless), must
    # be within noise of it. If the pilot decides jnp, this is exact.
    from repro.core.runtime.costmodel import calibrate

    table = calibrate(dg, MotifsApp(max_size=3), EngineConfig(), "serial")

    def time_combo(up, ck):
        step = lambda: explore.expand_and_compact(
            dg, members, nv, "vertex", cap,
            use_pallas=up, compact_kernel=ck,
        )
        jax.block_until_ready(step())
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(step())
            best = min(best, time.perf_counter() - t0)
        return best

    t_auto = time_combo(table.use_pallas, table.compact_kernel)
    t_jnp_now = time_combo(False, False)
    vs_jnp = t_jnp_now / t_auto
    emit(
        "perf_mining.ladder_auto", t_auto * 1e6,
        f"use_pallas={table.use_pallas};compact={table.compact_kernel};"
        f"source={table.source};vs_jnp={vs_jnp:.2f}x",
    )
    assert vs_jnp >= 0.90, (
        f"cost-model ladder pick is {vs_jnp:.2f}x of the jnp baseline — "
        f"auto must never pick a mode the pilot measured slower"
    )


def main():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    g = G.citeseer_like(scale=0.12)

    _pallas_ladder()

    dt, coll, iso, _, raw, np_ = _run(dict(naive_aggregation=True), g, mesh)
    emit("perf_mining.iter0_naive", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};patterns={np_}")

    dt, coll, iso, _, raw, np_ = _run(dict(), g, mesh)
    emit("perf_mining.iter1_two_level", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};patterns={np_}")

    dt, coll, iso, odag, raw, np_ = _run(dict(store="odag"), g, mesh)
    emit("perf_mining.iter2_odag", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};"
         f"frontier_raw={raw};frontier_odag={odag}")


if __name__ == "__main__":
    main()
