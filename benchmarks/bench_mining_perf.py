"""§Perf for the paper's own technique: the distributed mining step with
the paper's optimisations toggled.

  iteration 0 (naive):   per-embedding pattern exchange + per-embedding
                         graph-isomorphism canonicalisation (Fig 11 naive)
  iteration 1 (faithful): two-level aggregation — one domain-bitmap
                         collective, iso checks only per quick pattern
  iteration 2 (+ODAG):   frontier exchange compressed as DenseODAG

Reports wall time, collective bytes and iso-check counts per variant.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit
from repro.core import graph as G
from repro.core.apps import FSMApp
from repro.core.distributed import DistConfig, run_distributed


def _run(cfg_kwargs, g, mesh):
    app = FSMApp(support=4, max_size=3)
    t0 = time.perf_counter()
    res = run_distributed(g, app, mesh, DistConfig(**cfg_kwargs))
    dt = time.perf_counter() - t0
    coll = sum(s.collective_bytes for s in res.stats.steps)
    iso = sum(s.n_iso_checks for s in res.stats.steps)
    odag = sum(s.odag_bytes for s in res.stats.steps)
    raw = sum(s.frontier_bytes for s in res.stats.steps)
    return dt, coll, iso, odag, raw, len(res.patterns)


def main():
    n = len(jax.devices())
    mesh = jax.make_mesh((n,), ("data",))
    g = G.citeseer_like(scale=0.12)

    dt, coll, iso, _, raw, np_ = _run(dict(naive_aggregation=True), g, mesh)
    emit("perf_mining.iter0_naive", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};patterns={np_}")

    dt, coll, iso, _, raw, np_ = _run(dict(), g, mesh)
    emit("perf_mining.iter1_two_level", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};patterns={np_}")

    dt, coll, iso, odag, raw, np_ = _run(dict(use_odag_exchange=True), g, mesh)
    emit("perf_mining.iter2_odag", dt * 1e6,
         f"coll_bytes={coll};iso_checks={iso};"
         f"frontier_raw={raw};frontier_odag={odag}")


if __name__ == "__main__":
    main()
