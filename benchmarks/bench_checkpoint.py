"""§Perf for superstep-granular checkpointing (DESIGN.md §9): overhead of
writing a checkpoint at every seal boundary, on the acceptance workload
(depth-3 motifs over ``mico_like(scale=0.005)``, the same graph the
fused-superstep gate uses).

Rows:

  * ``no_checkpoint`` — the plain fused run (baseline wall time);
  * ``every_superstep`` — ``checkpoint_dir=`` + ``checkpoint_every=1``:
    every sealed superstep lands on disk atomically. The per-step cost is
    measured directly (``StepStats.t_checkpoint`` wraps exactly the
    state-dict build + np.savez + os.replace) and gated;
  * ``resume_tail`` — resume from the FIRST checkpoint to completion,
    asserting the resumed pattern dictionary matches.

Hard gates:

  * checkpointing must not change results (pattern dicts identical, with
    and without, plus after resume);
  * checkpoint overhead ≤ 5% of superstep wall time
    (sum of ``t_checkpoint`` vs the run's non-checkpoint wall clock).
"""
from __future__ import annotations

import glob
import os
import tempfile
import time

from benchmarks.common import emit
from repro.core import graph as G, resume, run
from repro.core.apps import MotifsApp
from repro.core.engine import EngineConfig

SCALE = 0.005
OVERHEAD_GATE = 0.05


def main():
    g = G.mico_like(scale=SCALE)
    mk = lambda: MotifsApp(max_size=3)
    run(g, mk(), EngineConfig())          # warm the chunk-program cache

    t0 = time.perf_counter()
    base = run(g, mk(), EngineConfig())
    t_base = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        ck = run(g, mk(), EngineConfig(checkpoint_dir=td, checkpoint_every=1))
        files = sorted(glob.glob(os.path.join(td, "ckpt-step*.npz")))
        assert files, "no checkpoints written"
        assert ck.patterns == base.patterns, "checkpointing changed results"
        ckpt_bytes = sum(os.path.getsize(f) for f in files)

        t0 = time.perf_counter()
        resumed = resume(g, mk(), files[0])
        t_resume = time.perf_counter() - t0
        assert resumed.patterns == base.patterns, "resume diverged"

    t_ckpt = sum(s.t_checkpoint for s in ck.stats.steps)
    t_mining = max(ck.stats.wall_time - t_ckpt, 1e-9)
    overhead = t_ckpt / t_mining

    emit("checkpoint.no_checkpoint", t_base * 1e6,
         f"steps={len(base.stats.steps)};"
         f"embeddings={base.stats.total_embeddings}")
    emit("checkpoint.every_superstep", ck.stats.wall_time * 1e6,
         f"ckpts={len(files)};ckpt_bytes={ckpt_bytes};"
         f"t_ckpt_ms={t_ckpt * 1e3:.2f};overhead={overhead:.4f}")
    emit("checkpoint.resume_tail", t_resume * 1e6,
         f"from={os.path.basename(files[0])};"
         f"patterns={len(resumed.patterns)}")
    assert overhead <= OVERHEAD_GATE, (
        f"checkpoint overhead {overhead:.1%} > {OVERHEAD_GATE:.0%} gate "
        f"({t_ckpt * 1e3:.1f} ms of {t_mining * 1e3:.0f} ms superstep wall)"
    )


if __name__ == "__main__":
    main()
