"""§Perf for device-resident level-1 aggregation (DESIGN.md §10): host
bytes drained by pattern aggregation per superstep, device path vs the
host reference path.

Depth-3 motifs over ``mico_like(scale=0.005)`` (the acceptance workload —
labeled, so the final step has ~37k distinct quick patterns, the worst
realistic Q/B ratio). Two rows:

  * ``host_path`` — ``device_aggregate=False``: level 1 drains the whole
    frontier's (B, 3) int64 quick codes to the host every superstep
    (24 bytes per frontier row; plus the (B, 8) local-vertex table when
    domains are requested).
  * ``device_path`` — the default: level 1 folds on device and only O(Q)
    bytes cross (distinct codes packed to uint32 with unused label words
    dropped, counts narrowed to int32, one (6,) scalar drain).

Hard gates (enforced like bench_odag's compression gate):

  * identical pattern dictionaries (and per-step aggregate arrays) across
    the two paths;
  * per superstep, device-path ``bytes_to_host`` is >= 10x below
    ``B * ROW_CODE_BYTES`` (the per-row quick-code payload the host level-1
    used to drain — the "shipping the frontier to the host" this PR stops);
  * summed over the run, device-path bytes are >= 10x below the host
    path's MEASURED ``bytes_to_host``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import MotifsApp

SCALE = 0.005
CHUNK = 512
#: bytes of per-row aggregation payload the host path ships: one (3,)
#: int64 quick code per frontier row (serial.py's old per-wave
#: ``np.asarray(qp.codes)`` drain).
ROW_CODE_BYTES = 24
RATIO_GATE = 10.0


def _cfg(device_aggregate: bool) -> EngineConfig:
    return EngineConfig(
        device_aggregate=device_aggregate,
        chunk_size=CHUNK, initial_capacity=CHUNK,
    )


def main():
    g = G.mico_like(scale=SCALE)
    app = lambda: MotifsApp(max_size=3)     # noqa: E731
    # warm the chunk-program + canonicalisation caches so timings are
    # dataflow, not compiles (byte counts are deterministic either way)
    run(g, app(), _cfg(True))
    run(g, app(), _cfg(False))

    dev = run(g, app(), _cfg(True))
    host = run(g, app(), _cfg(False))

    assert dev.patterns == host.patterns, (
        "device aggregation diverged from the host reference path"
    )
    for a, b in zip(dev.aggregates, host.aggregates):
        np.testing.assert_array_equal(a.canon_codes, b.canon_codes)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.supports, b.supports)

    ratios = []
    for st in dev.stats.steps:
        if not st.n_quick_patterns:
            continue
        assert st.bytes_to_host > 0, "device path recorded no transfer"
        ratio = st.n_frontier * ROW_CODE_BYTES / st.bytes_to_host
        ratios.append(ratio)
        assert ratio >= RATIO_GATE, (
            f"step {st.step}: aggregation shipped {st.bytes_to_host} bytes "
            f"for a {st.n_frontier}-row frontier — only {ratio:.1f}x below "
            f"B*{ROW_CODE_BYTES}, gate is {RATIO_GATE}x"
        )
    assert ratios, "no aggregation steps measured"

    dev_bytes = dev.stats.total_bytes_to_host
    host_bytes = host.stats.total_bytes_to_host
    measured_ratio = host_bytes / max(dev_bytes, 1)
    assert measured_ratio >= RATIO_GATE, (
        f"device path shipped {dev_bytes} aggregation bytes vs the host "
        f"path's {host_bytes} — only {measured_ratio:.1f}x, gate is "
        f"{RATIO_GATE}x"
    )

    t_dev = sum(s.t_aggregate for s in dev.stats.steps)
    t_host = sum(s.t_aggregate for s in host.stats.steps)
    last = dev.stats.steps[-1]
    emit(
        "aggregate.host_path", t_host * 1e6,
        f"bytes={host_bytes};"
        f"bytes_by_step={'/'.join(str(s.bytes_to_host) for s in host.stats.steps)}",
    )
    emit(
        "aggregate.device_path", t_dev * 1e6,
        f"bytes={dev_bytes};"
        f"bytes_by_step={'/'.join(str(s.bytes_to_host) for s in dev.stats.steps)};"
        f"quick={last.n_quick_patterns};frontier={last.n_frontier};"
        f"min_row_ratio={min(ratios):.1f}x;vs_host_measured={measured_ratio:.1f}x",
    )


if __name__ == "__main__":
    main()
