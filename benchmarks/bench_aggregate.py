"""§Perf for device-resident level-1 aggregation (DESIGN.md §10): host
bytes drained by pattern aggregation per superstep, device path vs the
host reference path.

Depth-3 motifs over ``mico_like(scale=0.005)`` (the acceptance workload —
labeled, so the final step has ~37k distinct quick patterns, the worst
realistic Q/B ratio). Two rows:

  * ``host_path`` — ``device_aggregate=False``: level 1 drains the whole
    frontier's (B, 3) int64 quick codes to the host every superstep
    (24 bytes per frontier row; plus the (B, 8) local-vertex table when
    domains are requested).
  * ``device_path`` — the default: level 1 folds on device and only O(Q)
    bytes cross (distinct codes packed to uint32 with unused label words
    dropped, counts narrowed to int32, one (6,) scalar drain).

Hard gates (enforced like bench_odag's compression gate):

  * identical pattern dictionaries (and per-step aggregate arrays) across
    the two paths;
  * per superstep, device-path ``bytes_to_host`` is >= 10x below
    ``B * ROW_CODE_BYTES`` (the per-row quick-code payload the host level-1
    used to drain — the "shipping the frontier to the host" this PR stops);
  * summed over the run, device-path bytes are >= 10x below the host
    path's MEASURED ``bytes_to_host``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import MotifsApp

SCALE = 0.005
CHUNK = 512
#: bytes of per-row aggregation payload the host path ships: one (3,)
#: int64 quick code per frontier row (serial.py's old per-wave
#: ``np.asarray(qp.codes)`` drain).
ROW_CODE_BYTES = 24
RATIO_GATE = 10.0
#: auto placement must be within 5% of the faster forced path.
AUTO_GATE = 0.95
#: radix bin vs lax.sort bin on the large-batch ladder: must not lose.
RADIX_GATE = 1.0
BIN_ROWS = 350_000


def _cfg(device_aggregate: bool) -> EngineConfig:
    # cost_model="off" keeps these two rows measuring the same static
    # paths BENCH_8 did; the auto row below exercises the calibrated
    # dispatch (DESIGN.md §14).
    return EngineConfig(
        device_aggregate=device_aggregate,
        chunk_size=CHUNK, initial_capacity=CHUNK,
        cost_model="off",
    )


def main():
    g = G.mico_like(scale=SCALE)
    app = lambda: MotifsApp(max_size=3)     # noqa: E731
    # warm the chunk-program + canonicalisation caches so timings are
    # dataflow, not compiles (byte counts are deterministic either way)
    run(g, app(), _cfg(True))
    run(g, app(), _cfg(False))

    def timed_run(cfg, repeat=3):
        """Best-of-``repeat`` summed aggregate-phase time (single runs are
        ~15% noisy on the CPU scheduler, enough to trip the 0.95x gate —
        and best-of-2 still was: an A/B interleave of the identical host
        path measured 0.83–1.20x run-to-run)."""
        best_t, res = None, None
        for _ in range(repeat):
            r = run(g, app(), cfg)
            t = sum(s.t_aggregate for s in r.stats.steps)
            if best_t is None or t < best_t:
                best_t, res = t, r
        return res, best_t

    dev, t_dev = timed_run(_cfg(True))
    host, t_host = timed_run(_cfg(False))

    assert dev.patterns == host.patterns, (
        "device aggregation diverged from the host reference path"
    )
    for a, b in zip(dev.aggregates, host.aggregates):
        np.testing.assert_array_equal(a.canon_codes, b.canon_codes)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.supports, b.supports)

    ratios = []
    for st in dev.stats.steps:
        if not st.n_quick_patterns:
            continue
        assert st.bytes_to_host > 0, "device path recorded no transfer"
        ratio = st.n_frontier * ROW_CODE_BYTES / st.bytes_to_host
        ratios.append(ratio)
        assert ratio >= RATIO_GATE, (
            f"step {st.step}: aggregation shipped {st.bytes_to_host} bytes "
            f"for a {st.n_frontier}-row frontier — only {ratio:.1f}x below "
            f"B*{ROW_CODE_BYTES}, gate is {RATIO_GATE}x"
        )
    assert ratios, "no aggregation steps measured"

    dev_bytes = dev.stats.total_bytes_to_host
    host_bytes = host.stats.total_bytes_to_host
    measured_ratio = host_bytes / max(dev_bytes, 1)
    assert measured_ratio >= RATIO_GATE, (
        f"device path shipped {dev_bytes} aggregation bytes vs the host "
        f"path's {host_bytes} — only {measured_ratio:.1f}x, gate is "
        f"{RATIO_GATE}x"
    )

    last = dev.stats.steps[-1]
    emit(
        "aggregate.host_path", t_host * 1e6,
        f"bytes={host_bytes};"
        f"bytes_by_step={'/'.join(str(s.bytes_to_host) for s in host.stats.steps)}",
    )
    emit(
        "aggregate.device_path", t_dev * 1e6,
        f"bytes={dev_bytes};"
        f"bytes_by_step={'/'.join(str(s.bytes_to_host) for s in dev.stats.steps)};"
        f"quick={last.n_quick_patterns};frontier={last.n_frontier};"
        f"min_row_ratio={min(ratios):.1f}x;vs_host_measured={measured_ratio:.1f}x",
    )

    # ---- cost-model auto row (DESIGN.md §14) ---------------------------
    # auto must land on (or within noise of) the faster of the two forced
    # placements — the BENCH_8 regression this PR closes was device
    # aggregation losing wall time on CPU while staying the default.
    auto_cfg = EngineConfig(chunk_size=CHUNK, initial_capacity=CHUNK)
    run(g, app(), auto_cfg)          # warm: calibration pilot + compiles
    auto, t_auto = timed_run(auto_cfg)
    assert auto.patterns == host.patterns, "auto cost model diverged"
    cm = auto.stats.cost_model
    auto_vs_forced = min(t_dev, t_host) / max(t_auto, 1e-9)
    emit(
        "aggregate.auto_costmodel", t_auto * 1e6,
        f"source={cm['source']};devagg={cm['device_aggregate']};"
        f"bin={cm['aggregate_bin']};bytes={auto.stats.total_bytes_to_host};"
        f"vs_best_forced={auto_vs_forced:.2f}x",
    )
    assert auto_vs_forced >= AUTO_GATE, (
        f"auto aggregation placement is {auto_vs_forced:.2f}x of the best "
        f"forced path (gate {AUTO_GATE}x)"
    )

    _bin_ladder_350k()


def _bin_ladder_350k():
    """Radix/bucket bin vs the ``lax.sort`` bin on a ≥350k-row batch —
    the input size where BENCH_8 measured the sort bin at ~290 ms on CPU.
    Gate: radix must not lose to sort (it is only ever *chosen* by the
    cost model where the pilot measured it faster)."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels.aggregate import bin_rows

    rng = np.random.default_rng(17)
    b = BIN_ROWS
    bits = rng.integers(0, 1 << 12, b).astype(np.int64)
    w1 = rng.integers(0, 1 << 16, b).astype(np.int64)
    codes = jnp.asarray(
        np.stack([3 | (bits << 4), w1, np.zeros(b, np.int64)], axis=1)
    )
    valid = jnp.asarray(rng.random(b) < 0.9)
    cap = 1 << 16
    bf = jax.jit(
        bin_rows, static_argnums=(2,),
        static_argnames=("use_kernel", "block", "interpret", "method"),
    )

    def best_of(method, repeat=3):
        jax.block_until_ready(bf(codes, valid, cap, method=method))
        best = float("inf")
        for _ in range(repeat):
            t0 = time.perf_counter()
            jax.block_until_ready(bf(codes, valid, cap, method=method))
            best = min(best, time.perf_counter() - t0)
        return best

    t_sort = best_of("sort")
    t_radix = best_of("radix")
    speedup = t_sort / t_radix
    emit("aggregate.bin_sort_350k", t_sort * 1e6, f"rows={b};cap={cap}")
    emit(
        "aggregate.bin_radix_350k", t_radix * 1e6,
        f"rows={b};cap={cap};speedup_vs_sort={speedup:.2f}x",
    )
    assert speedup >= RADIX_GATE, (
        f"radix bin {speedup:.2f}x vs sort on {b} rows (gate {RADIX_GATE}x)"
    )


if __name__ == "__main__":
    main()
