"""Render EXPERIMENTS.md from the dry-run / perf-ladder artifacts.

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import json
import os

from repro.roofline.analysis import Roofline

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "dryrun_results.json")
LADDER = os.path.join(HERE, "perf_ladder.json")
OUT = os.path.join(HERE, "..", "EXPERIMENTS.md")


def _roof(v):
    r = v["roofline"]
    return Roofline(
        flops=r["flops"], hbm_bytes=r["hbm_bytes"], coll_bytes=r["coll_bytes"],
        chips=r["chips"], model_flops=r["model_flops"],
    )


def dryrun_table(res, mesh_tag):
    rows = []
    for key in sorted(res):
        v = res[key]
        if not key.endswith(mesh_tag) or key.startswith("mining"):
            continue
        arch, shape, _ = key.split("|")
        if v.get("status") == "skipped":
            rows.append(f"| {arch} | {shape} | skipped | {v['reason'][:60]} |  |  |")
            continue
        if v.get("status") != "ok":
            rows.append(f"| {arch} | {shape} | ERROR | {v.get('error','')[:60]} |  |  |")
            continue
        m = v.get("memory_analysis", {})
        per_dev = (m.get("argument_bytes", 0) + m.get("temp_bytes", 0)) / 2**30
        coll = v.get("collectives", {})
        sched = ",".join(k for k, b in coll.items() if b > 0) or "-"
        rows.append(
            f"| {arch} | {shape} | ok ({v['compile_s']}s) | "
            f"{per_dev:.1f} GiB | {v['roofline']['flops']:.2e} | {sched} |"
        )
    return rows


def roofline_table(res):
    rows = []
    for key in sorted(res):
        v = res[key]
        if not key.endswith("|single") or v.get("status") != "ok" or key.startswith("mining"):
            continue
        arch, shape, _ = key.split("|")
        r = _roof(v)
        rows.append(
            f"| {arch} | {shape} | {r.t_compute:.3f} | {r.t_memory:.3f} | "
            f"{r.t_collective:.3f} | {r.bottleneck} | {r.model_flops:.2e} | "
            f"{r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} |"
        )
    return rows


def main():
    res = json.load(open(RESULTS)) if os.path.exists(RESULTS) else {}
    ladder = json.load(open(LADDER)) if os.path.exists(LADDER) else {}

    lines = []
    add = lines.append
    add("# EXPERIMENTS")
    add("")
    add("Artifacts: `benchmarks/dryrun_results.json` (every cell, raw + derived),")
    add("`benchmarks/perf_ladder.json` (§Perf), `bench_output.txt` (paper tables).")
    add("All FLOP/byte figures are PER-DEVICE (verified: jax cost_analysis reports")
    add("the SPMD per-device module); MODEL_FLOPS is global.")
    add("")

    # ---------------- paper validation ---------------------------------
    add("## §Paper-claims validation (the faithful baseline)")
    add("")
    add("| Paper claim | Reproduction | Result |")
    add("|---|---|---|")
    add("| Completeness (Thm 4): engine visits exactly the valid embeddings | engine vs brute-force oracle sets, vertex+edge modes (tests/test_apps_vs_oracle.py) | exact match, 0 duplicates |")
    add("| Canonicality uniqueness/extendibility (Thm 2/3) | hypothesis property tests over random graphs (tests/test_property_canonical.py) | exactly 1 canonical order per embedding; == greedy construction |")
    add("| FSM min-image supports | vs all-isomorphism oracle | exact equality across seeds/supports |")
    add("| Motif counts / clique counts | vs networkx-assisted oracles | exact equality |")
    add("| Fig 2 example: one (blue,yellow) edge pattern, support 2 | tests | reproduced |")
    add("| Table 4: quick patterns << embeddings | bench_two_level | e.g. motifs-MiCo(scaled): reduction ~1e3-1e4x (#iso checks == #quick patterns) |")
    add("| Fig 11: >10x slowdown without two-level aggregation | bench_mining_perf iter0 vs iter1 | 6.4x wall (76.0s -> 12.0s), iso checks 102,132 -> 4,472 (22.8x), collective bytes 2.88MB -> 0.43MB (6.7x) |")
    add("| Fig 9: ODAG orders-of-magnitude compression | bench_odag + bench_mining_perf iter2 | frontier exchange 1.20MB -> 0.11MB (11x) at depth 3; 85x at depth 4 (denser graphs) |")
    add("| Fig 7: TLV 2 orders of magnitude slower, TLP load-imbalance bound | bench_paradigms | TLV message blowup + hot vertices; TLP speedup bound << #workers |")
    add("| Fig 8/Table 3: near-linear scaling | bench_scalability (1..8 forced host devices) | speedup reported in bench_output.txt |")
    add("")

    # ---------------- dry-run -------------------------------------------
    n_ok = sum(1 for v in res.values() if v.get("status") == "ok")
    n_skip = sum(1 for v in res.values() if v.get("status") == "skipped")
    n_err = sum(1 for v in res.values() if v.get("status") == "error")
    add("## §Dry-run")
    add("")
    add(f"**{n_ok} cells compiled ok, {n_skip} documented skips, {n_err} errors** "
        "(40 arch x shape cells x 2 meshes + mining cells). Every cell is "
        "`jax.jit(step).lower(ShapeDtypeStructs).compile()` on the production "
        "mesh — 16x16=256 chips single-pod and 2x16x16=512 chips multi-pod "
        "(the `pod` axis shards data-parallel batch + ZeRO state).")
    add("")
    add("Skips (per assignment): long_500k for the 8 pure-full-attention "
        "archs (quadratic 512k decode excluded); run for zamba2 (Mamba2 + "
        "windowed shared-attention) and xlstm (O(1)-state).")
    add("")
    add("### Single-pod (16x16, 256 chips)")
    add("")
    add("| arch | shape | status (compile) | per-dev bytes (args+temp) | per-dev FLOPs | collective schedule |")
    add("|---|---|---|---|---|---|")
    lines += dryrun_table(res, "|single")
    add("")
    add("### Multi-pod (2x16x16, 512 chips)")
    add("")
    add("| arch | shape | status (compile) | per-dev bytes (args+temp) | per-dev FLOPs | collective schedule |")
    add("|---|---|---|---|---|---|")
    lines += dryrun_table(res, "|multi")
    add("")
    for key in ("mining|single", "mining|multi"):
        if key in res and res[key].get("status") == "ok":
            v = res[key]
            add(f"**Mining step ({key.split('|')[1]}-pod)**: compiled ok in "
                f"{v['compile_s']}s on {v['chips']} chips; frontier 2^20 "
                f"embeddings sharded over the dp axes, adjacency bitmap "
                f"sharded over 'model'; collective schedule: "
                + ", ".join(f"{k}={b/1e6:.1f}MB" for k, b in v["collectives"].items() if b)
                + ".")
            add("")

    # ---------------- roofline ------------------------------------------
    add("## §Roofline (single-pod, per assignment)")
    add("")
    add("Hardware model: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI "
        "per chip. Terms are seconds per step, per device. Costs use the "
        "depth-extrapolation (two small unrolled depths -> affine in L; "
        "lax.scan bodies are otherwise counted once by cost_analysis — "
        "verified in tests/test_roofline.py).")
    add("")
    add("Known CPU-lowering artifacts (documented, not correctable without "
        "real hardware): XLA-CPU upcasts bf16 matmuls/collectives to f32 "
        "(~2x on memory/collective bytes) and fuses less than the TPU "
        "backend, so t_memory is an upper bound; relative deltas between "
        "iterations remain meaningful.")
    add("")
    add("| arch | shape | t_compute | t_memory | t_collective | bottleneck | MODEL_FLOPS | useful ratio | roofline frac |")
    add("|---|---|---|---|---|---|---|---|---|")
    lines += roofline_table(res)
    add("")
    add("Per-cell one-line 'what would move the dominant term':")
    add("")
    add("- *train cells (collective-bound)*: overlap the Megatron TP "
        "all-reduces with the next matmul (collective-matmul / async "
        "collectives) and move cross-pod grad reduce to bf16 — both standard; "
        "the remaining gap is the f32-on-CPU artifact.")
    add("- *prefill cells (memory-bound)*: the Pallas flash_attention kernel "
        "(kernels/) removes the blocked-softmax HBM round-trips that "
        "dominate t_memory; on TPU the (B,H,QB,S) temps live in VMEM.")
    add("- *decode cells (memory-bound)*: weights+KV streaming is the "
        "roofline floor; MLA's compressed cache (deepseek) is the win that "
        "matters — its t_memory/token is ~5x smaller than yi-34b's at the "
        "same batch.")
    add("- *long_500k (SSM)*: state is O(1); the term is dominated by "
        "streaming params for batch=1 — batching or speculative decode is "
        "the only lever.")
    add("")

    # ---------------- perf ----------------------------------------------
    add("## §Perf")
    add("")
    add("### LM stack: hypothesis -> change -> measure ladder")
    add("")
    add("Three pairs hillclimbed (worst fraction / most collective-bound / "
        "prefill-representative). 'baseline' = paper-agnostic naive GSPMD "
        "layout (weights FSDP+TP sharded, no activation constraints, dense "
        "attention); 'opt' = iterations 1-3 applied.")
    add("")
    if ladder:
        add("| pair | layout | t_compute | t_memory | t_collective | bottleneck | roofline frac |")
        add("|---|---|---|---|---|---|---|")
        for key in sorted(ladder):
            v = ladder[key]
            if "error" in v:
                add(f"| {key} |  | ERROR {v['error'][:50]} |  |  |  |  |")
                continue
            arch, shape, layout = key.split("|")
            add(
                f"| {arch} {shape} | {layout} | {v['t_compute_s']:.2f} | "
                f"{v['t_memory_s']:.2f} | {v['t_collective_s']:.2f} | "
                f"{v['bottleneck']} | {v['roofline_fraction']:.3f} |"
            )
        add("")
    add("Iteration log (hypothesis -> change -> before/after -> verdict):")
    add("")
    add("1. **Hypothesis**: GSPMD all-reduces (B,H,S,S) attention-score "
        "partials because wk/wv specs shard kv_heads*dh over model=16 while "
        "yi-34b has only 8 kv heads (dh gets sharded; contraction goes "
        "partial). Napkin: scores f32 = 16x8x7x4096x4096x4B ~ 7.5 GB/layer. "
        "**Change**: pin q/k/v to head-sharded-only layouts + residual to "
        "(dp,None,None) (with_sharding_constraint). **Measured** "
        "(yi-34b train_4k): t_collective 70.4s -> 43.4s (-38%), frac "
        "0.059 -> 0.096. **CONFIRMED** (the 7.5 GB/layer score all-reduce "
        "disappeared from the HLO).")
    add("")
    add("2. **Hypothesis**: FSDP-sharding weight contracting dims over "
        "'data' makes GSPMD regather ~1.9 GB of weights-or-activations per "
        "matmul per layer; ZeRO-1 (weights TP-only + optimizer-state "
        "data-sharded) moves params across 'data' once per step instead. "
        "**Change**: spec_for ZeRO-1 layout + opt_state_specs extension. "
        "**Measured**: t_collective 43.4 -> 43.1s (-0.7%). **REFUTED** (for "
        "this cell the regathers were NOT weight gathers — they are "
        "remat-era activation regathers; lesson: read the HLO before "
        "trusting the FSDP intuition; kept anyway for the memory win: "
        "per-dev optimizer state 12 bytes/param -> 12/256).")
    add("")
    add("3. **Hypothesis**: the dense (S,S) score materialisation dominates "
        "t_memory at train_4k/prefill_32k (CPU backend cannot flash-fuse). "
        "Napkin (yi, per device): 16x56x4096x4096xf32 ~ 240 GB of "
        "score traffic vs ~60 GB of everything else. **Change**: blocked "
        "attention (512-query chunks, lax.map; python-unrolled under the "
        "cost ladder). **Measured** (1-layer yi): hbm_bytes 0.612 TB -> "
        "0.386 TB (-37%). **CONFIRMED** (remaining gap = weight reads + "
        "residuals; the Pallas kernel is the TPU-native version).")
    add("")
    add("4. **Hypothesis**: the same constraint layout helps MoE trains "
        "too. **Measured** (deepseek-v2 train_4k): frac 0.015 -> 0.005 — "
        "**REFUTED, regression**: pinning the residual to (dp,None,None) "
        "makes the globally-argsorted MoE dispatch gather the full token "
        "matrix per layer (the sort's indices are global; GSPMD resolves "
        "the sharded gather by all-gathering the operand). Lesson: read "
        "the HLO — token-choice MoE needs group-local routing before "
        "activation constraints pay off.")
    add("")
    add("5. **Hypothesis**: grouped (GShard-schedule) dispatch — split "
        "tokens into dp-aligned groups, vmap the sort/scatter per group "
        "(zero cross-group coordination), and let the (G,E,C,d) layout "
        "change G:'data' -> E:'model' be the expert-parallel all-to-all — "
        "removes the gather entirely. Napkin: all-to-all payload = "
        "cap*E*d*2B per group ~ dispatch tensor itself, ~0.3 GB/device vs "
        "the ~10 GB/layer gather. **Change**: layers.moe grouped dispatch "
        "(iteration-5). **Measured** (deepseek-v2 train_4k): frac 0.005 -> "
        "**0.042** (vs 0.015 baseline, +180%); t_collective 190 -> 52.5s; "
        "bottleneck flips collective -> memory. llama4 train multi 0.028 -> "
        "0.049. **CONFIRMED**.")
    add("")
    add("6. Stop criterion: remaining deltas on the dominant term came from "
        "the f32-upcast CPU artifact (uniform 2x) and XLA-CPU fusion "
        "limits; three consecutive candidate changes (seq-parallel "
        "constraints, bf16 pod-reduce, score-block retiling) each predicted "
        "<5% on the dominant term under this backend.")
    add("")
    add("**Summary (roofline fraction, baseline -> optimized)**: "
        "yi-34b train_4k 0.059 -> 0.097 (+64%); deepseek-v2-236b train_4k "
        "0.015 -> 0.042 (+180%); qwen2.5-14b prefill_32k 0.012 -> 0.019 "
        "(+58%, collective- -> memory-bound). The paper-faithful mining "
        "engine's own ladder is below.")
    add("")
    add("### Paper technique (the faithful reproduction + its own ladder)")
    add("")
    add("Distributed FSM, 1-device mesh, citeseer-like graph "
        "(bench_mining_perf):")
    add("")
    add("| iteration | wall | collective bytes | iso checks | frontier exchange |")
    add("|---|---|---|---|---|")
    add("| 0: naive per-embedding aggregation | 76.0s | 2.88 MB | 102,132 | raw lists |")
    add("| 1: two-level pattern aggregation (paper §5.4) | 12.0s (6.4x) | 0.43 MB (6.7x) | 4,472 (22.8x) | raw lists |")
    add("| 2: + DenseODAG exchange (paper §5.2) | 12.0s | 0.43 MB | 4,472 | 1.20 MB -> 0.11 MB (11x) |")
    add("")
    add("The paper-faithful configuration (iterations 1+2) IS the optimised "
        "one for the mining engine — the paper's own optimisations are what "
        "the ladder climbs, which is the reproduction's §Perf story; the "
        "beyond-paper additions (bitmap-domain psum aggregation as a single "
        "collective, VMEM-resident canonicality kernel) are what the TPU "
        "port contributes on top.")
    add("")

    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(lines)} lines)")


if __name__ == "__main__":
    main()
