"""§Perf for the fault-tolerance subsystem (DESIGN.md §13): what a crash
costs on the acceptance workload (depth-3 motifs over
``mico_like(scale=0.005)``, the same graph the fused-superstep and
checkpoint gates use).

Rows:

  * ``supervised_clean`` — ``run_supervised`` with no faults: the
    supervisor wrapper + private checkpoint cadence on a healthy run
    (the ``faults=None`` fast path is a single attribute read per
    phase boundary);
  * ``injected_crash`` — a deterministic ``FaultPlan`` crash at the
    expand boundary of superstep 2; the supervisor reloads the last
    valid cut and re-runs. Recovery time is measured directly
    (``StepStats.t_recovery`` on the retry attempt's first step) and
    gated;
  * ``corrupt_rollback`` — the newest checkpoint is tampered (stale
    SHA-256) before a crash: the supervisor must detect the mismatch
    and roll back one cut further.

Hard gates:

  * every supervised run's pattern dict matches the clean baseline —
    recovery must not change results;
  * recovery overhead ≤ 15% of the baseline superstep wall
    (sum of ``t_recovery`` vs the clean run's wall clock).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import graph as G, run, run_supervised
from repro.core.apps import MotifsApp
from repro.core.engine import EngineConfig
from repro.core.runtime import FaultPlan, FaultSpec

SCALE = 0.005
RECOVERY_GATE = 0.15


def _t_recovery(res):
    return sum(s.t_recovery for s in res.stats.steps)


def main():
    g = G.mico_like(scale=SCALE)
    mk = lambda: MotifsApp(max_size=3)
    base = run(g, mk(), EngineConfig())   # warm the chunk-program cache

    t0 = time.perf_counter()
    clean = run_supervised(g, mk(), EngineConfig())
    t_clean = time.perf_counter() - t0
    assert clean.patterns == base.patterns, "supervisor changed results"
    assert clean.recovery is None

    plan = FaultPlan([FaultSpec("expand", 2, "crash")])
    t0 = time.perf_counter()
    crashed = run_supervised(g, mk(), EngineConfig(faults=plan))
    t_crash = time.perf_counter() - t0
    assert crashed.patterns == base.patterns, "recovered run diverged"
    assert crashed.recovery["n_retries"] == 1
    t_rec = _t_recovery(crashed)
    overhead = t_rec / max(t_clean, 1e-9)

    plan = FaultPlan([
        FaultSpec("checkpoint", 1, "corrupt"),
        FaultSpec("expand", 2, "crash"),
    ])
    t0 = time.perf_counter()
    rolled = run_supervised(g, mk(), EngineConfig(faults=plan))
    t_roll = time.perf_counter() - t0
    assert rolled.patterns == base.patterns, "rollback run diverged"
    assert rolled.recovery["rolled_back"] >= 1, "corrupt cut not skipped"

    emit("faults.supervised_clean", t_clean * 1e6,
         f"steps={len(clean.stats.steps)};"
         f"embeddings={clean.stats.total_embeddings}")
    emit("faults.injected_crash", t_crash * 1e6,
         f"t_recovery_ms={t_rec * 1e3:.2f};overhead={overhead:.4f};"
         f"resumed_step={crashed.recovery['resumed_step']}")
    emit("faults.corrupt_rollback", t_roll * 1e6,
         f"t_recovery_ms={_t_recovery(rolled) * 1e3:.2f};"
         f"rolled_back={rolled.recovery['rolled_back']};"
         f"resumed_step={rolled.recovery['resumed_step']}")
    assert overhead <= RECOVERY_GATE, (
        f"recovery overhead {overhead:.1%} > {RECOVERY_GATE:.0%} gate "
        f"({t_rec * 1e3:.1f} ms of {t_clean * 1e3:.0f} ms clean wall)"
    )


if __name__ == "__main__":
    main()
