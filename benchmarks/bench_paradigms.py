"""Paper Fig. 7 / §6.2: TLE (Arabesque) vs TLV vs TLP paradigms.

Reports wall time, message counts (TLV's killer) and the TLP speedup bound
from pattern-partitioned load imbalance.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import FSMApp, MotifsApp
from repro.core.baselines.tlp import run_tlp_fsm
from repro.core.baselines.tlv import run_tlv


def main():
    g = G.citeseer_like(scale=0.06)

    # TLE (this paper)
    res, us = timed(
        run, g, MotifsApp(max_size=3), EngineConfig(chunk_size=4096, initial_capacity=8192)
    )
    emit("fig7.tle_motifs_ms3", us, f"embeddings={res.stats.total_embeddings}")

    tlv = run_tlv(g, max_size=3)
    emit(
        "fig7.tlv_motifs_ms3",
        tlv.wall_time * 1e6,
        f"messages={tlv.n_messages};max_load={tlv.max_vertex_load};"
        f"mean_load={tlv.mean_vertex_load:.1f}",
    )

    res_fsm, us_fsm = timed(
        run, g, FSMApp(support=5, max_size=3), EngineConfig(chunk_size=4096, initial_capacity=8192)
    )
    emit("fig7.tle_fsm_s5", us_fsm, f"frequent={len(res_fsm.patterns)}")

    tlp = run_tlp_fsm(g, support=5, max_size=3)
    for w in (5, 10, 20):
        emit(
            f"fig7.tlp_fsm_speedup_bound_{w}w",
            tlp.wall_time * 1e6,
            f"bound={tlp.speedup_bound(w):.2f}x_of_{w}w",
        )


if __name__ == "__main__":
    main()
