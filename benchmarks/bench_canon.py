"""§Perf for level-2 canonical placement (DESIGN.md §15): the last host
phase of the superstep — canonicalising the O(Q) distinct quick-pattern
table — measured per placement, with the overlap and auto-dispatch gates.

Depth-4 motifs over ``mico_like(scale=0.002)``: labeled (29 labels), so
the depth-3 level already has ~20k distinct quick patterns and the
terminal depth-4 level ~775k — the worst realistic level-2 load. Rows
(level-2 CRITICAL-PATH wall = summed ``StepStats.t_canon``; the memo is
cleared before every timed run so each one pays the cold
canonicalisation):

  * ``canon.host``       — forced synchronous host batch (the reference);
  * ``canon.device``     — forced batched device refine + in-program
    canonical re-bin (``kernels/canonical_refine.py``);
  * ``canon.host_async`` — forced background thread joined at the seal
    boundary: only the residual wait is on the critical path;
  * ``canon.auto``       — ``cost_model="auto"`` picks the placement from
    the calibration probe (DESIGN.md §14, probe 5).

Hard gates:

  * identical pattern dictionaries across ALL placements (bit-identical
    canonical codes and counts — the refactor's correctness contract);
  * ``auto`` critical-path level-2 wall within ``AUTO_GATE`` (0.95x) of
    the best FORCED placement — the cost model must not pick a loser;
  * overlapped steps (every step with a next superstep to hide behind):
    ``host_async`` critical-path level-2 wall >= ``OVERLAP_GATE`` (5x)
    below the synchronous host wall on the same steps — t_canon is off
    the critical path. (The terminal step joins on the done path with
    nothing to overlap, so it is excluded by construction.)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import EngineConfig, graph as G, pattern as pattern_lib, run
from repro.core.apps import MotifsApp

SCALE = 0.002
MAX_SIZE = 4
#: auto placement's level-2 wall must be within 5% of the best forced one.
AUTO_GATE = 0.95
#: overlapped (non-terminal) level-2 wall: host sync vs host_async join.
OVERLAP_GATE = 5.0
#: clock floor for the overlap ratio (the async residual wait routinely
#: measures 0.0 at perf_counter resolution).
EPS = 1e-4


def _cfg(placement, cost_model="off"):
    # device_aggregate pinned ON: the placement dispatch lives on the
    # device-aggregation path (host_async NEEDS its deferrable O(Q) table;
    # the CPU cost model would otherwise choose the host level-1 reference
    # and every row would measure the same code). canonical_placement=None
    # under cost_model="auto" is the calibrated row.
    return EngineConfig(
        canonical_placement=placement,
        device_aggregate=True,
        cost_model=cost_model,
    )


def _timed(g, cfg, repeat=2):
    """Best-of-``repeat`` level-2 critical-path wall, memo-cold each run."""
    best, res = None, None
    for _ in range(repeat):
        pattern_lib.clear_memo()
        r = run(g, MotifsApp(max_size=MAX_SIZE), cfg)
        t = sum(s.t_canon for s in r.stats.steps)
        if best is None or t < best:
            best, res = t, r
    return res, best


def main():
    g = G.mico_like(scale=SCALE)
    # warm every compiled program once (timings are dataflow, not compiles)
    run(g, MotifsApp(max_size=MAX_SIZE), _cfg("device"))

    # best-of-3 on the gated rows: the ~7 s terminal batch is identical
    # code under every sync-host-dominated placement, and single runs are
    # ~5% noisy on the CPU scheduler — exactly the AUTO_GATE margin
    host, t_host = _timed(g, _cfg("host"), repeat=3)
    device, t_device = _timed(g, _cfg("device"), repeat=1)
    overlap, t_async = _timed(g, _cfg("host_async"), repeat=3)
    auto, t_auto = _timed(g, _cfg(None, cost_model="auto"), repeat=3)

    rows = {
        "canon.host": (host, t_host),
        "canon.device": (device, t_device),
        "canon.host_async": (overlap, t_async),
        "canon.auto": (auto, t_auto),
    }
    n_quick = max(s.n_quick_patterns for s in host.stats.steps)
    for name, (res, t) in rows.items():
        assert res.patterns == host.patterns, (
            f"{name} diverged from the host reference placement"
        )
        for a, b in zip(res.aggregates, host.aggregates):
            np.testing.assert_array_equal(a.canon_codes, b.canon_codes)
            np.testing.assert_array_equal(a.counts, b.counts)
        emit(
            name, t * 1e6,
            f"n_quick={n_quick};wall_s={round(res.stats.wall_time, 2)}",
        )

    best_forced = min(t_host, t_device, t_async)
    ratio_auto = best_forced / max(t_auto, EPS)
    # overlapped steps only: the terminal level-2 batch joins on the done
    # path (no next superstep underneath) for EVERY placement alike
    o_host = sum(s.t_canon for s in host.stats.steps[:-1])
    o_async = sum(s.t_canon for s in overlap.stats.steps[:-1])
    ratio_overlap = o_host / max(o_async, EPS)
    emit(
        "canon.gates", 0.0,
        f"auto_vs_best_forced={round(ratio_auto, 3)};"
        f"overlap_speedup={round(ratio_overlap, 1)};"
        f"best_forced_ms={round(best_forced * 1e3, 1)}",
    )
    assert ratio_auto >= AUTO_GATE, (
        f"auto placement lost to the best forced one: {t_auto:.3f}s vs "
        f"{best_forced:.3f}s ({ratio_auto:.2f}x < {AUTO_GATE}x)"
    )
    assert ratio_overlap >= OVERLAP_GATE, (
        f"host_async left level-2 on the critical path: {o_async:.4f}s vs "
        f"host {o_host:.4f}s ({ratio_overlap:.1f}x < {OVERLAP_GATE}x)"
    )


if __name__ == "__main__":
    main()
