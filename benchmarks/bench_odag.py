"""Paper Fig. 9 (ODAG compression per depth) and Fig. 10 (cost of the ODAG
store/extract cycle vs the raw embedding list), end-to-end from live engine
runs: the frontier store (DESIGN.md §7) records per-step
``frontier_bytes`` (raw embedding-list baseline) vs ``odag_bytes`` (what
actually lived between supersteps), so the compression column is measured
on the real execution path, not an offline re-encode.

Acceptance gate: >= 5x frontier-exchange byte reduction at depth >= 3 on
``mico_like``.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import FSMApp, MotifsApp


def main():
    g = G.mico_like(scale=0.005)
    app = lambda: MotifsApp(max_size=3)
    cfg = lambda **kw: EngineConfig(
        chunk_size=8192, initial_capacity=16384, **kw
    )

    # Fig 9: per-depth compression from the ODAG store's live byte stats
    res, us_odag = timed(run, g, app(), cfg(store="odag"))
    depth3_ok = False
    for s in res.stats.steps:
        if not s.odag_bytes:
            continue
        emit(
            f"fig9.odag_depth{s.size}",
            s.t_storage * 1e6,
            f"raw_bytes={s.frontier_bytes};odag_bytes={s.odag_bytes};"
            f"compression={s.compression:.1f}x",
        )
        if s.size >= 3 and s.compression >= 5.0:
            depth3_ok = True
    if not depth3_ok:
        raise AssertionError(
            "ODAG store did not reach 5x frontier-byte reduction at depth>=3: "
            f"{res.stats.compression_by_size()}"
        )

    # Fig 10: whole-run cost of the ODAG store/extract cycle vs RawStore
    _, us_raw = timed(run, g, app(), cfg())
    total_raw = sum(s.frontier_bytes for s in res.stats.steps)
    total_odag = sum(s.odag_bytes or s.frontier_bytes for s in res.stats.steps)
    emit(
        "fig10.odag_cycle_vs_raw",
        us_odag,
        f"raw_store_us={us_raw:.0f};bytes_saved={total_raw - total_odag};"
        f"slowdown={us_odag / max(us_raw, 1):.2f}x",
    )

    # larger-than-memory: SpillStore waves under a device budget smaller
    # than the peak frontier must reproduce the same mining volume
    budget = max(s.frontier_bytes for s in res.stats.steps) // 4
    res_sp, us_sp = timed(
        run, g, app(), cfg(store="odag", device_budget_bytes=budget)
    )
    assert res_sp.patterns == res.patterns
    emit(
        "fig10.spill_waves",
        us_sp,
        f"device_budget_bytes={budget};"
        f"steps={len(res_sp.stats.steps)};match=1",
    )

    # edge-mode ODAG store (FSM frontier)
    res_e = run(
        G.citeseer_like(scale=0.12),
        FSMApp(support=2, max_size=3),
        cfg(store="odag"),
    )
    for s in res_e.stats.steps:
        if s.odag_bytes:
            emit(
                f"fig9.odag_edge_depth{s.size}",
                s.t_storage * 1e6,
                f"raw_bytes={s.frontier_bytes};odag_bytes={s.odag_bytes};"
                f"compression={s.compression:.1f}x",
            )


if __name__ == "__main__":
    main()
