"""Paper Fig. 9 (ODAG compression per depth) and Fig. 10 (slowdown when
storing full embedding lists vs ODAGs: here the inverse — cost of the ODAG
build/extract cycle vs its byte savings)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run, to_device
from repro.core import odag
from repro.core.apps import FSMApp, MotifsApp


def main():
    g = G.citeseer_like(scale=0.12)
    dg = to_device(g)
    app = MotifsApp(max_size=4, collect_embeddings=True)
    res = run(g, app, EngineConfig(chunk_size=8192, initial_capacity=16384))

    for size, emb in sorted(res.embeddings.items()):
        if size < 2:
            continue
        o, us_build = timed(odag.build, emb)
        raw = emb.size * 4
        emit(
            f"fig9.odag_depth{size}",
            us_build,
            f"raw_bytes={raw};odag_bytes={o.n_bytes};compression={raw / max(o.n_bytes,1):.1f}x",
        )

    # Fig 10: full exchange-cycle cost with vs without ODAG at max depth
    emb = res.embeddings[max(res.embeddings)]
    o = odag.build(emb)
    _, us_extract = timed(odag.extract, dg, o)
    _, us_raw = timed(lambda e: np.array(e, copy=True), emb)
    emit(
        "fig10.odag_cycle_vs_raw",
        us_build + us_extract,
        f"raw_copy_us={us_raw:.0f};bytes_saved={emb.size*4 - o.n_bytes}",
    )

    # edge-mode ODAG (FSM frontier)
    res_e = run(
        g, FSMApp(support=2, max_size=3, collect_embeddings=True),
        EngineConfig(chunk_size=8192, initial_capacity=16384),
    )
    if res_e.embeddings:
        emb_e = res_e.embeddings[max(res_e.embeddings)]
        o_e, us_e = timed(odag.build, emb_e)
        emit(
            "fig9.odag_edge_mode",
            us_e,
            f"raw_bytes={emb_e.size*4};odag_bytes={o_e.n_bytes}",
        )


if __name__ == "__main__":
    main()
