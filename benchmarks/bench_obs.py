"""§12 gate for the observability subsystem: tracing must not change the run.

Depth-3 motifs over ``mico_like(scale=0.005)`` (the acceptance workload),
mined four ways: untraced and traced on the serial backend, untraced and
traced on the shard-map backend. Hard gates:

  * **identity** — the traced run's pattern dictionary and every per-step
    counter stat (frontier, children, chunks, host syncs, bytes-to-host,
    collective bytes, generated/canonical counts) are bit-identical to
    the untraced run's: ``obs.count``/``obs.set_stat`` perform the exact
    arithmetic the raw ``st.x += v`` sites did;
  * **zero extra syncs** — ``trace=True`` (without ``trace_sync``) adds
    no host syncs: per-step ``n_host_syncs`` equal across the pair, and
    the fused-pipeline contract (<= 2 per superstep) still holds;
  * **coverage** — the exported Chrome trace is schema-valid
    (``render_trace.py --check``) and the named phase spans account for
    >= 95% of superstep wall on BOTH backends;
  * the traced-vs-untraced wall ratio rides along as an informational
    ``overhead`` field (compile caches are shared, so the pairs are
    measured after a warm-up run).
"""
from __future__ import annotations

import json
import os
import tempfile

import jax

from benchmarks import render_trace
from benchmarks.common import emit
from repro.core import RunConfig, SuperstepRuntime, graph as G, obs
from repro.core.apps import MotifsApp
from repro.core.runtime.shard import ShardMapBackend

SCALE = 0.005
CHUNK = 512
COVERAGE_GATE = 0.95

#: per-step counter stats that must be bit-identical traced vs untraced.
COUNTER_STATS = (
    "n_frontier", "n_children", "n_chunks", "n_host_syncs",
    "bytes_to_host", "collective_bytes", "n_generated", "n_canonical",
    "n_quick_patterns", "n_canonical_patterns",
)


def _run(g, trace_dir=None, backend=None):
    cfg = RunConfig(
        chunk_size=CHUNK, initial_capacity=CHUNK, max_steps=3,
        trace=trace_dir is not None, trace_dir=trace_dir,
    )
    return SuperstepRuntime(g, MotifsApp(max_size=3), cfg, backend).run()


def _gate_pair(name: str, ref, traced):
    assert traced.patterns == ref.patterns, (
        f"{name}: tracing changed the mined patterns "
        f"({len(traced.patterns)} vs {len(ref.patterns)})"
    )
    for a, b in zip(ref.stats.steps, traced.stats.steps):
        for k in COUNTER_STATS:
            va, vb = getattr(a, k), getattr(b, k)
            assert va == vb, (
                f"{name} step {a.step}: {k} diverged under tracing "
                f"({va} untraced vs {vb} traced)"
            )
    doc = json.load(open(traced.trace_path))
    problems = render_trace.check(doc)
    assert not problems, f"{name}: trace failed validation: {problems}"
    return obs.phase_coverage(doc)["coverage"]


def main():
    g = G.mico_like(scale=SCALE)
    td = tempfile.mkdtemp(prefix="bench_obs_")
    mesh = jax.make_mesh((min(2, len(jax.devices())),), ("data",))

    for name, backend in (
        ("serial", lambda: None),
        ("shard", lambda: ShardMapBackend(mesh)),
    ):
        _run(g, backend=backend())                       # warm compile caches
        ref = _run(g, backend=backend())
        traced = _run(g, trace_dir=td, backend=backend())
        cov = _gate_pair(name, ref, traced)
        # fused-pipeline sync contract survives with tracing off AND on
        for r in (ref, traced):
            for st in r.stats.steps:
                assert st.n_host_syncs <= 2, (
                    f"{name}: {st.n_host_syncs} syncs in step {st.step}"
                )
        overhead = traced.stats.wall_time / max(ref.stats.wall_time, 1e-9)
        emit(
            f"obs.{name}", ref.stats.wall_time * 1e6,
            f"traced_us={traced.stats.wall_time * 1e6:.0f};"
            f"overhead={overhead:.3f};"
            f"coverage={cov:.4f};"
            f"patterns={len(ref.patterns)};"
            f"trace_bytes={os.path.getsize(traced.trace_path)}",
        )


if __name__ == "__main__":
    main()
