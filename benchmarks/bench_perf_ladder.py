"""§Perf hillclimb measurement: for the three chosen (arch x shape) pairs,
lower the BASELINE layout (paper-faithful weights-only sharding, iteration
0) and the OPTIMIZED layout (activation constraints + ZeRO-1 + blocked
attention) and report both roofline term sets.

Run standalone (it forces 512 host devices):
    PYTHONPATH=src python -m benchmarks.bench_perf_ladder
Writes benchmarks/perf_ladder.json.
"""
import os

os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import dataclasses
import json

PAIRS = [
    # worst roofline fraction among big trains + collective-bound
    ("yi-34b", "train_4k"),
    # most collective-bound MoE (expert-parallel all-to-all path)
    ("deepseek-v2-236b", "train_4k"),
    # prefill: attention-quadratic dominant, memory/collective mix
    ("qwen2.5-14b", "prefill_32k"),
]

OUT = os.path.join(os.path.dirname(__file__), "perf_ladder.json")


def measure(arch, shape_name, act_constraints):
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_arch
    from repro.launch.dryrun import _depth_ladder, _lower_program, _raw_costs
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis

    cfg = get_arch(arch)
    shape = SHAPE_BY_NAME[shape_name]
    mesh = make_production_mesh()
    c1, c2, l1, l2, lreal = _depth_ladder(cfg)
    k1 = _raw_costs(
        _lower_program(c1, shape, mesh, act_constraints=act_constraints)[0].compile()
    )
    k2 = _raw_costs(
        _lower_program(c2, shape, mesh, act_constraints=act_constraints)[0].compile()
    )
    costs = {}
    for key in ("flops", "hbm_bytes", "coll_bytes"):
        slope = (k2[key] - k1[key]) / (l2 - l1)
        costs[key] = k1[key] + slope * (lreal - l1)
    model = None
    from repro.models import build_model

    model = build_model(cfg)
    import jax

    ps = model.init_shapes(jax.random.PRNGKey(0))
    mf = analysis.model_flops_for(cfg, shape, ps)
    roof = analysis.Roofline(chips=256, model_flops=mf, **costs)
    return roof.to_dict()


def main():
    results = {}
    if os.path.exists(OUT):
        with open(OUT) as f:
            results = json.load(f)
    for arch, shape in PAIRS:
        for layout in ("baseline", "opt"):
            key = f"{arch}|{shape}|{layout}"
            if key in results:
                continue
            print(f"[perf] {key} ...", flush=True)
            try:
                results[key] = measure(arch, shape, act_constraints=(layout == "opt"))
                r = results[key]
                print(
                    f"  tc={r['t_compute_s']:.2f} tm={r['t_memory_s']:.2f} "
                    f"tx={r['t_collective_s']:.2f} frac={r['roofline_fraction']:.3f}",
                    flush=True,
                )
            except Exception as e:
                results[key] = {"error": f"{type(e).__name__}: {e}"}
                print("  ERROR", e, flush=True)
            with open(OUT, "w") as f:
                json.dump(results, f, indent=1, default=float)
    for k, v in results.items():
        if "error" not in v:
            print(f"{k}: frac={v['roofline_fraction']:.3f} "
                  f"bottleneck={v['bottleneck']}")


if __name__ == "__main__":
    main()
