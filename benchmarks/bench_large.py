"""Paper Table 5: large-graph stress scaled to the container — densest
generator, deepest exploration that stays in memory; reports embeddings
processed and peak frontier footprint."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import CliquesApp, MotifsApp


def main():
    sn = G.unlabeled_sn_like(scale=0.0004)
    res, us = timed(
        run, sn, MotifsApp(max_size=3),
        EngineConfig(chunk_size=16384, initial_capacity=1 << 16),
    )
    peak = max(s.frontier_bytes for s in res.stats.steps)
    emit(
        "table5.motifs_sn_ms3",
        us,
        f"embeddings={res.stats.total_embeddings};peak_frontier_bytes={peak}",
    )

    res, us = timed(
        run, sn, CliquesApp(max_size=4, collect_embeddings=False),
        EngineConfig(chunk_size=16384, initial_capacity=1 << 16),
    )
    emit(
        "table5.cliques_sn_ms4",
        us,
        f"embeddings={res.stats.total_embeddings}",
    )


if __name__ == "__main__":
    main()
