"""Benchmark driver: one bench per paper table/figure (DESIGN.md §6).

Prints ``name,us_per_call,derived`` CSV rows. The dry-run/roofline tables
(assignment §Dry-run/§Roofline) live in dryrun_results.json, produced by
``python -m repro.launch.dryrun``; ``bench_roofline`` summarises them here.

``--smoke`` runs the mining-perf ladder plus the fused-superstep,
checkpoint-overhead, aggregation-bytes, graph-shard, observability,
and fault-recovery gates — the quick sanity sweep behind
``make bench-smoke``.
``--json [PATH]`` additionally writes every emitted row (us_per_call +
parsed derived stats) as machine-readable JSON — the default path is
``benchmarks.common.DEFAULT_BENCH_JSON`` (``BENCH_<version>.json``, one
constant shared with the Makefile and CI) — the perf trajectory future
PRs gate against instead of an empty history.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import traceback

from benchmarks.common import DEFAULT_BENCH_JSON


def main(argv=None) -> None:
    args = argparse.ArgumentParser(description=__doc__)
    args.add_argument(
        "--smoke", action="store_true",
        help="run only the fast mining-perf ladder + superstep gate",
    )
    args.add_argument(
        "--json", nargs="?", const=DEFAULT_BENCH_JSON, default=None,
        metavar="PATH",
        help=f"write emitted rows as JSON (default: {DEFAULT_BENCH_JSON})",
    )
    opts = args.parse_args(argv)
    from benchmarks import (
        bench_aggregate,
        bench_breakdown,
        bench_canon,
        bench_checkpoint,
        bench_faults,
        bench_graphshard,
        bench_large,
        bench_mining_perf,
        bench_obs,
        bench_odag,
        bench_paradigms,
        bench_roofline,
        bench_single_thread,
        bench_scalability,
        bench_superstep,
        bench_two_level,
    )

    benches = [
        ("paradigms(fig7)", bench_paradigms.main),
        ("single_thread(table2)", bench_single_thread.main),
        ("scalability(table3/fig8)", bench_scalability.main),
        ("odag(fig9/10)", bench_odag.main),
        ("two_level(table4/fig11)", bench_two_level.main),
        ("breakdown(fig12)", bench_breakdown.main),
        ("large(table5)", bench_large.main),
        ("mining_perf(§Perf)", bench_mining_perf.main),
        ("superstep(§8)", bench_superstep.main),
        ("checkpoint(§9)", bench_checkpoint.main),
        ("aggregate(§10)", bench_aggregate.main),
        ("graphshard(§11)", bench_graphshard.main),
        ("obs(§12)", bench_obs.main),
        ("faults(§13)", bench_faults.main),
        ("canon(§15)", bench_canon.main),
        ("roofline(dry-run)", bench_roofline.main),
    ]
    if opts.smoke:
        benches = [
            ("mining_perf(§Perf)", bench_mining_perf.main),
            ("superstep(§8)", bench_superstep.main),
            ("checkpoint(§9)", bench_checkpoint.main),
            ("aggregate(§10)", bench_aggregate.main),
            ("graphshard(§11)", bench_graphshard.main),
            ("obs(§12)", bench_obs.main),
            ("faults(§13)", bench_faults.main),
        ]
    failures = 0
    for name, fn in benches:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if opts.json:
        import jax

        from benchmarks.common import RECORDS, warn_missing_previous

        warn_missing_previous()

        with open(opts.json, "w") as f:
            json.dump(
                {
                    "benches": RECORDS,
                    "failures": failures,
                    "backend": jax.default_backend(),
                    "python": platform.python_version(),
                },
                f,
                indent=2,
            )
        print(f"# wrote {len(RECORDS)} rows to {opts.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
