"""§11 gate for partitioned graph storage: per-device adjacency bytes.

The replicated ``DeviceGraph`` pins the full CSR + packed adjacency bitmap
on every device; the partitioned layout (``PartitionedGraph``, DESIGN.md
§11) gives each of W workers one vertex-range shard plus a halo tile
fetched per superstep. This bench measures what PR 6 promises:

  * **memory**: with W=8 shards, the per-device share of the adjacency
    structures (CSR rows + edge-id rows + degrees + bitmap tile) is
    <= 1/W of the replicated bytes plus the halo slack — one chunk's
    worth of gathered neighbour rows (the halo capacity is a static
    function of the chunk shape, ``explore.halo_cap``);
  * **exactness**: mining over the partitioned layout is bit-identical to
    the replicated reference — same pattern dictionary, same counts —
    for depth-3 motifs on the gate graph (vertex-mode halo) and FSM on a
    labeled graph (edge-mode halo; a smaller graph, since depth-3 FSM on
    mico is a multi-minute run and exactness is scale-independent).

Rows: replicated vs partitioned bytes (vertex balancing, the layout the
gate holds for), a degree-balanced row for the load-balance trade-off
(padded tile rows may inflate its bytes on skewed graphs — informational),
and the partitioned mining wall time next to the replicated baseline.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import RunConfig, SuperstepRuntime, graph as G
from repro.core.apps import FSMApp, MotifsApp
from repro.core.explore import halo_cap

W = 8
SCALE = 0.005
CHUNK = 512


def _halo_slack_bytes(g: G.DeviceGraph, chunk: int, size: int, mode: str) -> int:
    """One chunk's halo tile: the only adjacency bytes a worker holds
    beyond its own shard (gathered rows + bitmap tile / edge-id rows)."""
    cap = halo_cap((chunk, size), mode, int(g.labels.shape[0]))
    d = int(g.nbr.shape[1])
    words = int(g.adj_bits.shape[1])
    row = 2 * d * 4 if mode == "edge" else (d + words) * 4
    return cap * (row + 4)  # + the halo vertex ids themselves


def main():
    g = G.mico_like(scale=SCALE)
    dg = G.to_device(g)
    repl = G.replicated_adjacency_bytes(dg)

    pg = G.to_partitioned(g, W, balance="vertex")
    per_dev = pg.per_device_adjacency_bytes
    slack = _halo_slack_bytes(dg, CHUNK, 3, "vertex")
    assert per_dev <= repl / W + slack, (
        f"partitioned layout holds {per_dev} adjacency bytes per device — "
        f"more than 1/{W} of the replicated {repl} (+{slack} halo slack)"
    )

    pg_deg = G.to_partitioned(g, W, balance="degree")

    cfg = dict(chunk_size=CHUNK, initial_capacity=CHUNK)
    runs = [
        ("motifs", g, lambda: MotifsApp(max_size=3)),
        ("fsm", G.random_labeled(120, 600, 4, seed=9),
         lambda: FSMApp(support=3, max_size=3)),
    ]
    for name, gr, mk in runs:
        mode = "edge" if name == "fsm" else "vertex"
        ref = SuperstepRuntime(gr, mk(), RunConfig(**cfg)).run()
        part = SuperstepRuntime(
            gr, mk(), RunConfig(graph_partition=W, **cfg)
        ).run()
        assert part.patterns == ref.patterns, (
            f"{name}: partitioned mining diverged from replicated "
            f"({len(part.patterns)} vs {len(ref.patterns)} patterns)"
        )
        emit(
            f"graphshard.{name}", part.stats.wall_time * 1e6,
            f"replicated_us={ref.stats.wall_time * 1e6:.0f};"
            f"patterns={len(ref.patterns)};"
            f"halo_slack={_halo_slack_bytes(dg, CHUNK, 3, mode)}",
        )

    emit(
        "graphshard.bytes", 0.0,
        f"replicated={repl};per_device_w{W}={per_dev};"
        f"share={per_dev * W / repl:.2f}x_of_replicated_total;"
        f"halo_slack={slack};"
        f"per_device_degree_balanced={pg_deg.per_device_adjacency_bytes}",
    )


if __name__ == "__main__":
    main()
