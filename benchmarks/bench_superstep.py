"""§Perf for the fused superstep pipeline (DESIGN.md §8): host syncs per
superstep and wall-clock vs the PR-2 chunk loop.

Depth-3 motifs over ``mico_like(scale=0.005)`` (the acceptance workload).
Three rows:

  * ``pr2_chunk_loop`` — a faithful reimplementation of the PR-2 engine's
    superstep against the SAME device chunk programs: per-chunk host
    slice/pad/upload, one blocking ``int(count)`` sync per chunk, a
    separate eager quick-pattern pass (second wave upload), and PR-2's
    host level 2 (Python-loop canonicalisation per quick pattern, orbits
    always). This is the measured baseline the acceptance criteria gate
    against.
  * ``legacy_path`` — ``async_chunks=False`` today: the PR-2 chunk-loop
    *dataflow* riding this PR's shared aggregation improvements
    (vectorised/memoised level 2, lexsort unique). Shows the pipeline-only
    delta; still O(chunks) host syncs.
  * ``fused_pipeline`` — ``async_chunks=True``: pilot-calibrated sync-free
    dispatch, single count drain, carried child codes.

Hard gates (enforced like bench_odag's compression gate):

  * identical pattern dictionaries across all three;
  * fused host syncs per superstep O(1) (≤ 2: pilot + drain) while both
    baselines pay O(chunks);
  * fused wall-clock ≥ 1.3x faster than the PR-2 chunk loop.

Cost-model rows (DESIGN.md §14): ``force_device``/``force_host`` pin the
placement extremes and ``auto_costmodel`` is the new default — gated to
be within 5% of the fastest forced config (auto must never pick a mode
the pilot measured slower) and a real win over the old static default.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import aggregation, graph as G, pattern as pattern_lib, to_device
from repro.core.apps import MotifsApp
from repro.core.engine import (
    EngineConfig,
    _make_expand_fn,
    _next_pow2,
    _quick_patterns,
    run,
)

SCALE = 0.005
CHUNK = 512
REPEAT = 2
SPEEDUP_GATE = 1.3
#: auto must be within 5% of the fastest forced placement (noise floor).
AUTO_GATE = 0.95
#: and a real win over the old fused-everywhere static default — measured
#: ~2.4x on CPU; gated conservatively against scheduler noise.
AUTO_STATIC_GATE = 1.3


# ---------------------------------------------------------------------------
# the PR-2 superstep, reproduced for measurement
# ---------------------------------------------------------------------------

def _pr2_build_table(unique_quick: np.ndarray) -> pattern_lib.PatternTable:
    """PR-2's level 2: one Python ``canonicalize_one`` per quick pattern,
    automorphism orbits for every canonical pattern, void-dtype row
    unique — the host loop this PR batched and memoised."""
    q = len(unique_quick)
    canon = np.zeros((q, 3), dtype=np.int64)
    sigma = np.zeros((q, pattern_lib.MAX_PATTERN_VERTICES), dtype=np.int32)
    for i in range(q):
        key, sg = pattern_lib.canonicalize_one(unique_quick[i])
        canon[i] = key
        sigma[i] = sg
    uniq_canon, inv = np.unique(canon, axis=0, return_inverse=True)
    orbits = np.stack(
        [pattern_lib.automorphism_orbits(c) for c in uniq_canon], axis=0
    ) if len(uniq_canon) else np.zeros((0, 8), np.int32)
    return pattern_lib.PatternTable(
        quick_codes=unique_quick,
        canon_codes=uniq_canon,
        quick_to_canon=inv.astype(np.int32),
        sigma=sigma,
        canon_n_verts=(uniq_canon[:, 0] & 0xF).astype(np.int32),
        canon_orbits=orbits,
        n_iso_checks=q,
    )


def _pr2_run(g, dg, expand_fn, max_size=3, chunk_size=CHUNK, cap0=CHUNK):
    """PR-2's ``engine.run`` dataflow for motifs on the raw store, against
    the same jitted chunk program the current engine uses. Returns
    (patterns, syncs, chunks)."""
    patterns = {}
    syncs = chunks = 0
    frontier = np.arange(dg.n, dtype=np.int32)[:, None]
    size = 1
    while True:
        b = len(frontier)
        if b == 0:
            break
        # separate quick-pattern pass: second upload of the wave
        qp = _quick_patterns(
            dg, "vertex", jnp.asarray(frontier),
            jnp.full((b,), size, dtype=jnp.int32),
        )
        codes = np.asarray(qp.codes)
        uniq, inv = np.unique(codes, axis=0, return_inverse=True)
        table = _pr2_build_table(uniq)
        counts = np.bincount(
            table.quick_to_canon[inv], minlength=len(table.canon_codes)
        )
        for pc, n in enumerate(counts):
            code = tuple(int(x) for x in table.canon_codes[pc])
            patterns[code] = patterns.get(code, 0) + int(n)
        if size >= max_size:
            break
        # chunked expansion: host slice/pad/upload + int(count) per chunk
        children_blocks = []
        cap = cap0
        for lo in range(0, b, chunk_size):
            chunk = np.asarray(frontier[lo : lo + chunk_size])
            cb = int(chunk.shape[0])
            bucket = min(chunk_size, _next_pow2(max(cb, 1)))
            pad = bucket - cb
            if pad:
                chunk = np.concatenate(
                    [chunk, np.full((pad, size), -1, np.int32)], axis=0
                )
            n_valid = jnp.concatenate(
                [jnp.full((cb,), size, jnp.int32), jnp.zeros((pad,), jnp.int32)]
            )
            chunk = jnp.asarray(chunk)
            chunks += 1
            while True:
                children, count, _, _, _, _ = expand_fn(
                    dg, chunk, n_valid, out_cap=cap
                )
                count = int(count)
                syncs += 1
                if count <= cap:
                    break
                cap = _next_pow2(count)
            if count:
                children_blocks.append(np.asarray(children[:count]))
        frontier = (
            np.concatenate(children_blocks)
            if children_blocks
            else np.zeros((0, size + 1), np.int32)
        )
        size += 1
    return patterns, syncs, chunks


def _cfg(async_chunks: bool) -> EngineConfig:
    # cost_model="off" pins the pre-calibration static defaults so these
    # rows keep measuring the same dataflow BENCH_8 did; the cost-model
    # rows below measure the new auto/forced dispatch against them.
    return EngineConfig(
        async_chunks=async_chunks, chunk_size=CHUNK, initial_capacity=CHUNK,
        cost_model="off",
    )


def _cm_cfg(mode: str) -> EngineConfig:
    return EngineConfig(
        chunk_size=CHUNK, initial_capacity=CHUNK, cost_model=mode
    )


def _best(fn):
    best, out = None, None
    for _ in range(REPEAT):
        t0 = time.perf_counter()
        r = fn()
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best, out = dt, r
    return out, best


def main():
    g = G.mico_like(scale=SCALE)
    dg = to_device(g)
    app = MotifsApp(max_size=3)
    expand_fn = _make_expand_fn(app, "vertex")   # PR-2's chunk program
    # warm the shared chunk-program cache so every variant times dataflow,
    # not trace/compile
    for ac in (False, True):
        run(g, MotifsApp(max_size=3), _cfg(ac))
    _pr2_run(g, dg, expand_fn)

    (pr2_patterns, pr2_syncs, pr2_chunks), t_pr2 = _best(
        lambda: _pr2_run(g, dg, expand_fn)
    )
    legacy, t_legacy = _best(lambda: run(g, MotifsApp(max_size=3), _cfg(False)))
    fused, t_fused = _best(lambda: run(g, MotifsApp(max_size=3), _cfg(True)))

    assert fused.patterns == legacy.patterns == pr2_patterns, (
        "fused diverged from the PR-2 loop"
    )

    exp_legacy = [s for s in legacy.stats.steps if s.n_chunks]
    exp_fused = [s for s in fused.stats.steps if s.n_chunks]
    max_fused_syncs = max(s.n_host_syncs for s in exp_fused)
    assert any(s.n_chunks > 1 for s in exp_legacy), (
        "bench too small: the chunk loop never went multi-chunk"
    )
    assert pr2_syncs >= pr2_chunks > 1, "PR-2 loop should sync per chunk"
    for s in exp_legacy:
        assert s.n_host_syncs >= s.n_chunks, "legacy path should sync per chunk"
    assert max_fused_syncs <= 2, (
        f"fused pipeline syncs per superstep not O(1): {max_fused_syncs}"
    )

    speedup = t_pr2 / t_fused
    speedup_legacy = t_legacy / t_fused
    emit(
        "superstep.pr2_chunk_loop", t_pr2 * 1e6,
        f"chunks={pr2_chunks};syncs={pr2_syncs};"
        f"embeddings={legacy.stats.total_embeddings}",
    )
    emit(
        "superstep.legacy_path", t_legacy * 1e6,
        f"chunks={sum(s.n_chunks for s in exp_legacy)};"
        f"syncs={legacy.stats.total_host_syncs};"
        f"syncs_per_step_max={max(s.n_host_syncs for s in exp_legacy)}",
    )
    emit(
        "superstep.fused_pipeline", t_fused * 1e6,
        f"chunks={sum(s.n_chunks for s in exp_fused)};"
        f"syncs={fused.stats.total_host_syncs};"
        f"syncs_per_step_max={max_fused_syncs};"
        f"compiled_programs={fused.stats.n_compiles};"
        f"speedup_vs_pr2={speedup:.2f}x;speedup_vs_legacy={speedup_legacy:.2f}x",
    )
    assert speedup >= SPEEDUP_GATE, (
        f"fused superstep speedup {speedup:.2f}x < {SPEEDUP_GATE}x gate "
        f"(PR-2 {t_pr2 * 1e3:.0f} ms vs fused {t_fused * 1e3:.0f} ms)"
    )

    # ---- cost-model dispatch rows (DESIGN.md §14) ----------------------
    # Warm-up runs pay calibration (auto) and compiles once; the timed
    # runs then hit the process-wide decision-table cache, so the rows
    # measure dispatch quality, not the pilot.
    for mode in ("auto", "force_device", "force_host"):
        run(g, MotifsApp(max_size=3), _cm_cfg(mode))
    auto, t_auto = _best(lambda: run(g, MotifsApp(max_size=3), _cm_cfg("auto")))
    fdev, t_fdev = _best(
        lambda: run(g, MotifsApp(max_size=3), _cm_cfg("force_device"))
    )
    fhost, t_fhost = _best(
        lambda: run(g, MotifsApp(max_size=3), _cm_cfg("force_host"))
    )
    assert auto.patterns == fdev.patterns == fhost.patterns == pr2_patterns, (
        "cost-model modes diverged"
    )
    auto_syncs = max(
        s.n_host_syncs for s in auto.stats.steps if s.n_chunks
    )
    assert auto_syncs <= 2, (
        f"auto cost model broke the O(1)-sync contract: {auto_syncs}"
    )
    cm = auto.stats.cost_model
    t_best_forced = min(t_fdev, t_fhost)
    auto_vs_forced = t_best_forced / t_auto
    auto_vs_static = t_fused / t_auto
    emit("superstep.force_device", t_fdev * 1e6,
         f"syncs={fdev.stats.total_host_syncs}")
    emit("superstep.force_host", t_fhost * 1e6,
         f"syncs={fhost.stats.total_host_syncs}")
    emit(
        "superstep.auto_costmodel", t_auto * 1e6,
        f"source={cm['source']};async={cm['async_chunks']};"
        f"devagg={cm['device_aggregate']};bin={cm['aggregate_bin']};"
        f"syncs_per_step_max={auto_syncs};"
        f"vs_best_forced={auto_vs_forced:.2f}x;"
        f"speedup_vs_static_default={auto_vs_static:.2f}x",
    )
    assert auto_vs_forced >= AUTO_GATE, (
        f"auto config is {auto_vs_forced:.2f}x of the fastest forced config "
        f"(gate {AUTO_GATE}x): auto picked a mode the pilot measured slower"
    )
    assert auto_vs_static >= AUTO_STATIC_GATE, (
        f"auto config only {auto_vs_static:.2f}x vs the static fused default "
        f"(gate {AUTO_STATIC_GATE}x): calibration stopped paying for itself"
    )


if __name__ == "__main__":
    main()
