"""Paper Table 2: single-worker Arabesque vs centralized baseline (here:
the brute-force enumerator in the role of the specialized C/Java tools)."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.baselines import bruteforce as bf


def main():
    mico = G.mico_like(scale=0.004)
    cite = G.citeseer_like(scale=0.06)
    cfg = EngineConfig(chunk_size=8192, initial_capacity=16384)

    res, us = timed(run, mico, MotifsApp(max_size=3), cfg)
    emit("table2.arabesque_motifs_ms3_mico", us, f"emb={res.stats.total_embeddings}")
    _, us_b = timed(bf.motif_counts, mico, 3)
    emit("table2.centralized_motifs_ms3_mico", us_b, "")

    res, us = timed(run, mico, CliquesApp(max_size=4), cfg)
    emit("table2.arabesque_cliques_ms4_mico", us, f"emb={res.stats.total_embeddings}")
    _, us_b = timed(bf.clique_counts, mico, 4)
    emit("table2.centralized_cliques_ms4_mico", us_b, "")

    res, us = timed(run, cite, FSMApp(support=10, max_size=3), cfg)
    emit("table2.arabesque_fsm_s10_citeseer", us, f"freq={len(res.patterns)}")
    _, us_b = timed(bf.fsm_supports, cite, 3, 10)
    emit("table2.centralized_fsm_s10_citeseer", us_b, "")


if __name__ == "__main__":
    main()
