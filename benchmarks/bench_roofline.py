"""Summarise the dry-run roofline table (assignment §Roofline) from
dryrun_results.json. Derived metrics are recomputed from the raw per-device
FLOPs/bytes so the formulas can evolve without recompiling 80 cells."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.roofline.analysis import Roofline

RESULTS = os.path.join(os.path.dirname(__file__), "dryrun_results.json")


def load():
    with open(RESULTS) as f:
        return json.load(f)


def recompute(entry):
    r = entry["roofline"]
    return Roofline(
        flops=r["flops"],
        hbm_bytes=r["hbm_bytes"],
        coll_bytes=r["coll_bytes"],
        chips=r["chips"],
        model_flops=r["model_flops"],
        per_device_hbm=r.get("per_device_hbm"),
    )


def main():
    if not os.path.exists(RESULTS):
        emit("roofline.missing", -1, "run python -m repro.launch.dryrun --all first")
        return
    res = load()
    n_ok = n_skip = n_err = 0
    for key in sorted(res):
        v = res[key]
        if v.get("status") == "skipped":
            n_skip += 1
            continue
        if v.get("status") != "ok":
            n_err += 1
            emit(f"dryrun.{key}", -1, f"error={v.get('error','')[:60]}")
            continue
        n_ok += 1
        if "roofline" not in v:
            continue
        roof = recompute(v)
        step_time = max(roof.t_compute, roof.t_memory, roof.t_collective)
        emit(
            f"roofline.{key}",
            step_time * 1e6,
            f"bottleneck={roof.bottleneck};frac={roof.roofline_fraction:.3f};"
            f"useful={roof.useful_flops_ratio:.2f};"
            f"tc={roof.t_compute:.4f};tm={roof.t_memory:.4f};tx={roof.t_collective:.4f}",
        )
    emit("dryrun.summary", 0.0, f"ok={n_ok};skipped={n_skip};errors={n_err}")


if __name__ == "__main__":
    main()
