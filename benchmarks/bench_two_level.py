"""Paper Table 4 / Fig. 11: two-level pattern aggregation.

Reports #embeddings vs #quick patterns vs #canonical patterns (the
reduction factor), and times pattern aggregation with the optimisation vs
the naive scheme (canonical-form computation for EVERY embedding)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import EngineConfig, graph as G, run
from repro.core import pattern as pl
from repro.core.apps import FSMApp, MotifsApp


def main():
    g = G.mico_like(scale=0.004)
    res = run(
        g, MotifsApp(max_size=3), EngineConfig(chunk_size=8192, initial_capacity=16384)
    )
    st = res.stats.steps[-1]
    emit(
        "table4.motifs_mico_ms3",
        0.0,
        f"embeddings={st.n_frontier};quick={st.n_quick_patterns};"
        f"canonical={st.n_canonical_patterns};"
        f"reduction={st.n_frontier / max(st.n_quick_patterns,1):.0f}x",
    )

    cite = G.citeseer_like(scale=0.06)
    res = run(
        cite, FSMApp(support=5, max_size=3),
        EngineConfig(chunk_size=8192, initial_capacity=16384),
    )
    st = res.stats.steps[-1]
    emit(
        "table4.fsm_citeseer_s5",
        0.0,
        f"embeddings={st.n_frontier};quick={st.n_quick_patterns};"
        f"canonical={st.n_canonical_patterns}",
    )

    # Fig 11: time the level-2 canonicalisation per QUICK pattern vs per
    # EMBEDDING (the naive path the optimisation eliminates)
    quick = np.unique(
        np.random.default_rng(0).integers(0, 2, size=(64, 3)).astype(np.int64), axis=0
    )
    # realistic codes: take actual aggregates
    agg = res.aggregates[-1]
    codes = agg.canon_codes if len(agg.canon_codes) else quick
    _, us_once = timed(pl.build_pattern_table, codes)
    n_emb = max(st.n_frontier, 1)
    per_quick_us = us_once / max(len(codes), 1)
    emit(
        "fig11.two_level_saving",
        us_once,
        f"naive_est_us={per_quick_us * n_emb:.0f};"
        f"speedup={n_emb / max(len(codes),1):.0f}x",
    )


if __name__ == "__main__":
    main()
