"""The exploration driver — Algorithm 1, BFS level-synchronous.

Each exploration step is one (chunked) jitted device program; the host loop
only orchestrates capacities and the pattern dictionary, mirroring the
paper's BSP supersteps. Frontier arrays are bucketed to power-of-two
capacities so XLA recompiles only per bucket.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph, Graph, to_device
from repro.core.stats import RunStats, StepStats, Timer
from repro.kernels.dispatch import default_use_pallas


@dataclasses.dataclass
class EngineConfig:
    chunk_size: int = 4096        # frontier rows per expansion program
    initial_capacity: int = 4096  # starting output-capacity bucket
    max_steps: int = 16           # hard cap on exploration depth
    #: route the Alg.-2 canonicality check through the Pallas kernel
    #: (VMEM-sized graphs, vertex mode). None -> auto: on for backends with
    #: a native Pallas lowering (TPU/GPU), off on CPU.
    use_pallas: Optional[bool] = None
    #: with use_pallas, also fuse candidate validity + dedup + Alg.-2 into
    #: the single-pass expand_canonical kernel (vertex mode).
    fused_expand: bool = False
    #: Pallas interpret override; None -> auto per backend (compiled on
    #: TPU/GPU, interpreter on CPU).
    pallas_interpret: Optional[bool] = None

    def resolve_use_pallas(self) -> bool:
        return default_use_pallas() if self.use_pallas is None else self.use_pallas


@dataclasses.dataclass
class MiningResult:
    patterns: Dict[tuple, int]                    # canon code -> count/support
    aggregates: List[aggregation.StepAggregates]
    stats: RunStats
    embeddings: Dict[int, np.ndarray]             # size -> (B, size) arrays

    def pattern_count(self, code) -> int:
        return self.patterns.get(tuple(int(x) for x in code), 0)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _make_expand_fn(app: MiningApp, mode: str, use_pallas: bool = False,
                    fused: bool = False, interpret=None):
    """Per-run jitted chunk program: expand + canonicality + app filter +
    compaction. Recompiled per (width, capacity) bucket."""

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def fn(g: DeviceGraph, members, n_valid, out_cap: int):
        if mode == "vertex":
            exp = explore.expand_vertex(
                g, members, n_valid,
                use_pallas=use_pallas, fused=fused, interpret=interpret,
            )
        else:
            exp = explore.expand_edge(
                g, members, n_valid, use_pallas=use_pallas, interpret=interpret
            )
        keep = exp.keep & app.filter(g, members, n_valid, exp.rows, exp.cand)
        children, count = explore.compact(members, exp, keep, out_cap)
        return children, count, exp.n_generated, exp.n_canonical

    return fn


def _initial_frontier(g: DeviceGraph, mode: str) -> jnp.ndarray:
    n0 = g.n if mode == "vertex" else g.m
    return jnp.arange(n0, dtype=jnp.int32)[:, None]


def _quick_patterns(g: DeviceGraph, mode: str, members, n_valid):
    if mode == "vertex":
        return pattern_lib.quick_pattern_vertex(g, members, n_valid)
    return pattern_lib.quick_pattern_edge(g, members, n_valid)


def run(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    config: Optional[EngineConfig] = None,
) -> MiningResult:
    config = config or EngineConfig()
    g = to_device(graph) if isinstance(graph, Graph) else graph
    mode = app.mode
    expand_fn = _make_expand_fn(
        app, mode,
        use_pallas=config.resolve_use_pallas(),
        fused=config.fused_expand,
        interpret=config.pallas_interpret,
    )

    result = MiningResult(patterns={}, aggregates=[], stats=RunStats(), embeddings={})
    t_start = time.perf_counter()

    frontier = _initial_frontier(g, mode)  # (B, size) int32, all rows valid
    size = 1

    for step in range(1, config.max_steps + 1):
        b = int(frontier.shape[0])
        if b == 0:
            break
        st = StepStats(step=step, size=size, n_frontier=b)
        st.frontier_bytes = int(frontier.size) * 4
        timer = Timer()

        # ---- pattern aggregation of this step's embeddings (end of the
        # step that generated them, per Algorithm 1) ----------------------
        canon_slot = None
        agg = None
        if app.wants_patterns:
            n_valid = jnp.full((b,), size, dtype=jnp.int32)
            qp = _quick_patterns(g, mode, frontier, n_valid)
            agg, canon_slot, _ = aggregation.aggregate_step(
                g.n, qp, jnp.ones((b,), dtype=bool), app.wants_domains
            )
            result.aggregates.append(agg)
            st.n_quick_patterns = agg.n_quick
            st.n_canonical_patterns = agg.n_canonical
            st.n_iso_checks = agg.n_iso_checks
        st.t_aggregate = timer.lap()

        # ---- alpha: aggregation filter on the frontier -------------------
        if app.wants_patterns and agg is not None:
            alpha = app.aggregation_filter(canon_slot, agg)
            # beta / outputs: record aggregates of surviving patterns
            surviving = np.unique(canon_slot[alpha]) if alpha.any() else []
            for pc in surviving:
                code = tuple(int(x) for x in agg.canon_codes[pc])
                value = int(
                    agg.supports[pc] if app.wants_domains else agg.counts[pc]
                )
                result.patterns[code] = result.patterns.get(code, 0) + value

            if not alpha.all():
                frontier = frontier[np.asarray(alpha)]
                b = int(frontier.shape[0])
        if app.collect_embeddings and b:
            result.embeddings[size] = np.asarray(frontier)

        # ---- termination ---------------------------------------------------
        if app.termination_filter(size) or b == 0 or step == config.max_steps:
            result.stats.steps.append(st)
            break

        # ---- expansion (chunked, capacity-bucketed) ----------------------
        children_parts = []
        cap = max(config.initial_capacity, 1)
        for lo in range(0, b, config.chunk_size):
            chunk = frontier[lo : lo + config.chunk_size]
            cb = int(chunk.shape[0])
            bucket = min(config.chunk_size, _next_pow2(max(cb, 1)))
            pad = bucket - cb
            if pad:
                chunk = jnp.concatenate(
                    [chunk, jnp.full((pad, size), -1, jnp.int32)], axis=0
                )
            n_valid = jnp.concatenate(
                [jnp.full((cb,), size, jnp.int32), jnp.zeros((pad,), jnp.int32)]
            )

            while True:
                children, count, ngen, ncanon = expand_fn(g, chunk, n_valid, out_cap=cap)
                count = int(count)
                if count <= cap:
                    break
                cap = _next_pow2(count)
            st.n_generated += int(ngen)
            st.n_canonical += int(ncanon)
            if count:
                children_parts.append(children[:count])

        st.t_expand = timer.lap()
        st.n_children = sum(int(c.shape[0]) for c in children_parts)
        result.stats.steps.append(st)

        if not children_parts:
            break
        frontier = jnp.concatenate(children_parts, axis=0)
        size += 1

    result.stats.wall_time = time.perf_counter() - t_start
    return result
