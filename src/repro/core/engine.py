"""The exploration driver — Algorithm 1, BFS level-synchronous.

Each exploration step is one (chunked) jitted device program; the host loop
only orchestrates capacities and the pattern dictionary, mirroring the
paper's BSP supersteps. Frontier arrays are bucketed to power-of-two
capacities so XLA recompiles only per bucket.

Between supersteps the frontier is owned by a pluggable
:mod:`repro.core.store` (DESIGN.md §7): the engine appends child blocks
while expanding, ``seal``s at the superstep boundary, and mines the next
step wave-by-wave from ``store.chunks()`` — with ``store="odag"`` the
frontier lives ODAG-compressed (paper §5.2) and ``device_budget_bytes``
bounds how many rows are device-resident at once (larger-than-memory
mining, paper §5.3 cost-balanced waves).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph, Graph, to_device
from repro.core.stats import RunStats, StepStats, Timer
from repro.core.store import make_store
from repro.kernels.dispatch import default_use_pallas


@dataclasses.dataclass
class EngineConfig:
    chunk_size: int = 4096        # frontier rows per expansion program
    initial_capacity: int = 4096  # starting output-capacity bucket
    max_steps: int = 16           # hard cap on exploration depth
    #: route the Alg.-2 canonicality check through the Pallas kernel
    #: (VMEM-sized graphs, vertex mode). None -> auto: on for backends with
    #: a native Pallas lowering (TPU/GPU), off on CPU.
    use_pallas: Optional[bool] = None
    #: with use_pallas, also fuse candidate validity + dedup + Alg.-2 into
    #: the single-pass expand_canonical kernel (vertex mode).
    fused_expand: bool = False
    #: Pallas interpret override; None -> auto per backend (compiled on
    #: TPU/GPU, interpreter on CPU).
    pallas_interpret: Optional[bool] = None
    #: how the frontier lives between supersteps: "raw" keeps the dense
    #: embedding list, "odag" stores per-size ODAGs (paper §5.2) and
    #: re-materialises via cost-balanced extraction (§5.3).
    store: str = "raw"
    #: device byte budget for one materialised frontier wave; when set, the
    #: frontier store is wrapped in a SpillStore and each superstep is mined
    #: in waves of at most this many bytes of embedding rows (frontiers
    #: larger than device memory). None -> one wave per step.
    device_budget_bytes: Optional[int] = None

    def resolve_use_pallas(self) -> bool:
        return default_use_pallas() if self.use_pallas is None else self.use_pallas


@dataclasses.dataclass
class MiningResult:
    patterns: Dict[tuple, int]                    # canon code -> count/support
    aggregates: List[aggregation.StepAggregates]
    stats: RunStats
    embeddings: Dict[int, np.ndarray]             # size -> (B, size) arrays

    def pattern_count(self, code) -> int:
        return self.patterns.get(tuple(int(x) for x in code), 0)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


def _make_expand_fn(app: MiningApp, mode: str, use_pallas: bool = False,
                    fused: bool = False, interpret=None):
    """Per-run jitted chunk program: expand + canonicality + app filter +
    compaction. Recompiled per (width, capacity) bucket."""

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def fn(g: DeviceGraph, members, n_valid, out_cap: int):
        if mode == "vertex":
            exp = explore.expand_vertex(
                g, members, n_valid,
                use_pallas=use_pallas, fused=fused, interpret=interpret,
            )
        else:
            exp = explore.expand_edge(
                g, members, n_valid, use_pallas=use_pallas, interpret=interpret
            )
        keep = exp.keep & app.filter(g, members, n_valid, exp.rows, exp.cand)
        children, count = explore.compact(members, exp, keep, out_cap)
        return children, count, exp.n_generated, exp.n_canonical

    return fn


def _initial_frontier(g: DeviceGraph, mode: str) -> np.ndarray:
    n0 = g.n if mode == "vertex" else g.m
    return np.arange(n0, dtype=np.int32)[:, None]


def _quick_patterns(g: DeviceGraph, mode: str, members, n_valid):
    if mode == "vertex":
        return pattern_lib.quick_pattern_vertex(g, members, n_valid)
    return pattern_lib.quick_pattern_edge(g, members, n_valid)


def store_app_filter(app: MiningApp, g: DeviceGraph):
    """Adapt ``app.filter`` to the per-candidate signature ODAG extraction
    re-applies (DESIGN.md §7): extraction rows are already one member-set per
    candidate, so the parent-row indirection is the identity. Returns None
    for the base accept-all filter (nothing to re-apply)."""
    if type(app).filter is MiningApp.filter:
        return None

    def phi(mem, nv, cnd):
        rows = jnp.arange(int(mem.shape[0]), dtype=jnp.int32)
        return app.filter(g, mem, nv, rows, cnd)

    return phi


def run(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    config: Optional[EngineConfig] = None,
) -> MiningResult:
    config = config or EngineConfig()
    g = to_device(graph) if isinstance(graph, Graph) else graph
    mode = app.mode
    use_pallas = config.resolve_use_pallas()
    expand_fn = _make_expand_fn(
        app, mode,
        use_pallas=use_pallas,
        fused=config.fused_expand,
        interpret=config.pallas_interpret,
    )
    store = make_store(
        config.store, g,
        mode=mode,
        app_filter=store_app_filter(app, g),
        use_pallas=use_pallas,
        interpret=config.pallas_interpret,
        device_budget_bytes=config.device_budget_bytes,
    )

    result = MiningResult(patterns={}, aggregates=[], stats=RunStats(), embeddings={})
    t_start = time.perf_counter()

    store.append(_initial_frontier(g, mode))
    store.seal(1)
    size = 1

    for step in range(1, config.max_steps + 1):
        b = store.n_rows
        if b == 0:
            break
        st = StepStats(step=step, size=size, n_frontier=b)
        st.frontier_bytes = store.raw_bytes
        if store.kind == "odag":
            st.odag_bytes = store.stored_bytes
        timer = Timer()

        # ---- re-materialise the frontier in device-budget waves ----------
        waves = list(store.chunks())
        # extraction may resurrect pattern-pruned rows (a superset of the
        # appended rows; see ODAGStore) — stats count what is actually mined
        st.n_frontier = sum(len(w) for w in waves)
        st.t_storage = timer.lap()

        # ---- pattern aggregation of this step's embeddings (end of the
        # step that generated them, per Algorithm 1): quick patterns per
        # wave on device, level-1 merge on host ---------------------------
        canon_slot = None
        agg = None
        if app.wants_patterns:
            codes_parts, lv_parts = [], []
            for w in waves:
                qp = _quick_patterns(
                    g, mode, jnp.asarray(w),
                    jnp.full((len(w),), size, dtype=jnp.int32),
                )
                codes_parts.append(np.asarray(qp.codes))
                lv_parts.append(np.asarray(qp.local_verts))
            codes = (
                np.concatenate(codes_parts)
                if codes_parts else np.zeros((0, 3), np.int64)
            )
            lv = (
                np.concatenate(lv_parts)
                if lv_parts
                else np.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), np.int32)
            )
            agg, canon_slot = aggregation.aggregate_rows(
                g.n, codes, lv, app.wants_domains
            )
            result.aggregates.append(agg)
            st.n_quick_patterns = agg.n_quick
            st.n_canonical_patterns = agg.n_canonical
            st.n_iso_checks = agg.n_iso_checks
        st.t_aggregate = timer.lap()

        # ---- alpha: aggregation filter on the frontier -------------------
        if app.wants_patterns and agg is not None:
            alpha = app.aggregation_filter(canon_slot, agg)
            # beta / outputs: record aggregates of surviving patterns
            surviving = np.unique(canon_slot[alpha]) if alpha.any() else []
            for pc in surviving:
                code = tuple(int(x) for x in agg.canon_codes[pc])
                value = int(
                    agg.supports[pc] if app.wants_domains else agg.counts[pc]
                )
                result.patterns[code] = result.patterns.get(code, 0) + value

            if not alpha.all():
                off, pruned = 0, []
                for w in waves:
                    pruned.append(w[alpha[off : off + len(w)]])
                    off += len(w)
                waves = pruned
        b_live = sum(len(w) for w in waves)
        if app.collect_embeddings and b_live:
            live = [w for w in waves if len(w)]
            result.embeddings[size] = (
                np.asarray(live[0])
                if len(live) == 1
                else np.concatenate(live, axis=0)
            )

        # ---- termination ---------------------------------------------------
        if app.termination_filter(size) or b_live == 0 or step == config.max_steps:
            result.stats.steps.append(st)
            break

        # ---- expansion (chunked, capacity-bucketed), children appended to
        # the store as they are produced ----------------------------------
        cap = max(config.initial_capacity, 1)
        for w in waves:
            for lo in range(0, len(w), config.chunk_size):
                chunk = np.asarray(w[lo : lo + config.chunk_size])
                cb = int(chunk.shape[0])
                bucket = min(config.chunk_size, _next_pow2(max(cb, 1)))
                pad = bucket - cb
                if pad:
                    chunk = np.concatenate(
                        [chunk, np.full((pad, size), -1, np.int32)], axis=0
                    )
                n_valid = jnp.concatenate(
                    [jnp.full((cb,), size, jnp.int32), jnp.zeros((pad,), jnp.int32)]
                )
                chunk = jnp.asarray(chunk)

                while True:
                    children, count, ngen, ncanon = expand_fn(
                        g, chunk, n_valid, out_cap=cap
                    )
                    count = int(count)
                    if count <= cap:
                        break
                    cap = _next_pow2(count)
                st.n_generated += int(ngen)
                st.n_canonical += int(ncanon)
                if count:
                    store.append(np.asarray(children[:count]))
                    st.n_children += count

        st.t_expand = timer.lap()
        store.seal(size + 1)
        st.t_storage += timer.lap()
        result.stats.steps.append(st)

        if store.n_rows == 0:
            break
        size += 1

    result.stats.wall_time = time.perf_counter() - t_start
    return result
