"""Serial mining entry point — a thin wrapper over the unified runtime.

The exploration driver this module used to implement (Algorithm 1 as a
BFS level-synchronous loop of jitted chunk programs, DESIGN.md §8) now
lives ONCE in :mod:`repro.core.runtime`: :class:`SuperstepRuntime` owns
the superstep loop, :class:`SerialBackend` owns the fused pilot +
stacked-drain chunk pipeline (and the PR-2 ``async_chunks=False``
baseline), and :class:`RunConfig` owns every knob. ``run`` and
``EngineConfig`` are kept as the stable public names — ``EngineConfig`` is
a deprecation shim over :class:`RunConfig` (same fields, same defaults,
same ``resolve_*`` behaviour; tested in ``tests/test_runtime.py``).

Checkpoint/resume (DESIGN.md §9): pass ``EngineConfig(checkpoint_dir=...)``
to persist every sealed superstep, and continue an interrupted run with
:func:`repro.core.runtime.resume`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph, Graph
from repro.core.runtime import (
    MiningResult,
    RunConfig,
    SerialBackend,
    SuperstepRuntime,
)
from repro.core.runtime.config import next_pow2 as _next_pow2  # noqa: F401
from repro.core.runtime.programs import (  # noqa: F401  (compat re-exports)
    make_expand_fn as _make_expand_fn,
    quick_patterns as _quick_patterns,
    retire as _retire,
    store_app_filter,
)

__all__ = ["EngineConfig", "MiningResult", "run"]


@dataclasses.dataclass
class EngineConfig(RunConfig):
    """Deprecated alias of :class:`repro.core.runtime.RunConfig`.

    Kept as an empty subclass so every pre-runtime call site (and kwarg)
    keeps working; new code should construct ``RunConfig`` directly."""


def run(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    config: Optional[RunConfig] = None,
) -> MiningResult:
    """Mine ``graph`` with ``app`` on the serial backend (one device)."""
    return SuperstepRuntime(graph, app, config, SerialBackend()).run()
