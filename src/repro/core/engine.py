"""The exploration driver — Algorithm 1, BFS level-synchronous.

Each exploration step is one (chunked) jitted device program; the host loop
only orchestrates capacities and the pattern dictionary, mirroring the
paper's BSP supersteps. Frontier arrays are bucketed to power-of-two
capacities so XLA recompiles only per bucket.

Between supersteps the frontier is owned by a pluggable
:mod:`repro.core.store` (DESIGN.md §7): the engine appends child blocks
while expanding, ``seal``s at the superstep boundary, and mines the next
step wave-by-wave from ``store.chunks()`` — with ``store="odag"`` the
frontier lives ODAG-compressed (paper §5.2) and ``device_budget_bytes``
bounds how many rows are device-resident at once (larger-than-memory
mining, paper §5.3 cost-balanced waves).

The superstep itself runs as a *fused, device-resident pipeline*
(DESIGN.md §8, ``async_chunks``): every wave is uploaded once and sliced
into chunks on device, each chunk program returns children + counts +
child quick-pattern codes in one pass, counts stay device-resident while
chunks dispatch back-to-back, and the host drains all control values once
per superstep — O(1) host syncs instead of the O(chunks) of the PR-2 loop
(kept as ``async_chunks=False``, the benchmark baseline).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph, Graph, to_device
from repro.core.stats import RunStats, StepStats, Timer
from repro.core.store import make_store
from repro.kernels.dispatch import default_use_pallas


@dataclasses.dataclass
class EngineConfig:
    chunk_size: int = 4096        # frontier rows per expansion program
    initial_capacity: int = 4096  # starting output-capacity bucket
    max_steps: int = 16           # hard cap on exploration depth
    #: route the Alg.-2 canonicality check through the Pallas kernel
    #: (VMEM-sized graphs, vertex mode). None -> auto: on for backends with
    #: a native Pallas lowering (TPU/GPU), off on CPU.
    use_pallas: Optional[bool] = None
    #: with use_pallas, also fuse candidate validity + dedup + Alg.-2 into
    #: the single-pass expand_canonical kernel (vertex mode).
    fused_expand: bool = False
    #: Pallas interpret override; None -> auto per backend (compiled on
    #: TPU/GPU, interpreter on CPU).
    pallas_interpret: Optional[bool] = None
    #: how the frontier lives between supersteps: "raw" keeps the dense
    #: embedding list, "odag" stores per-size ODAGs (paper §5.2) and
    #: re-materialises via cost-balanced extraction (§5.3).
    store: str = "raw"
    #: device byte budget for one materialised frontier wave; when set, the
    #: frontier store is wrapped in a SpillStore and each superstep is mined
    #: in waves of at most this many bytes of embedding rows (frontiers
    #: larger than device memory). None -> one wave per step.
    device_budget_bytes: Optional[int] = None
    #: fused superstep pipeline (DESIGN.md §8): chunk programs return
    #: children + counts + child quick-pattern codes in one device pass,
    #: counts stay device-resident and the host drains them ONCE per
    #: superstep (O(1) host syncs instead of O(chunks); with a device
    #: budget, once per budget wave so only one wave is ever resident);
    #: chunk buffers are retired as they fold into the store to cut peak
    #: HBM. False = the PR-2 chunk loop (one host sync per chunk, separate
    #: quick-pattern pass over every wave) — kept as the measured baseline.
    async_chunks: bool = True
    #: route chunk compaction through the Pallas stream-compaction kernel
    #: (block prefix-sum + scatter, ``kernels/compact.py``) instead of the
    #: jnp nonzero gather. None -> auto: on where Pallas compiles to
    #: native code (TPU), off on CPU where the interpreter would lose.
    compact_kernel: Optional[bool] = None

    def resolve_use_pallas(self) -> bool:
        return default_use_pallas() if self.use_pallas is None else self.use_pallas

    def resolve_compact_kernel(self) -> bool:
        return (
            default_use_pallas()
            if self.compact_kernel is None
            else self.compact_kernel
        )


@dataclasses.dataclass
class MiningResult:
    patterns: Dict[tuple, int]                    # canon code -> count/support
    aggregates: List[aggregation.StepAggregates]
    stats: RunStats
    embeddings: Dict[int, np.ndarray]             # size -> (B, size) arrays

    def pattern_count(self, code) -> int:
        return self.patterns.get(tuple(int(x) for x in code), 0)


def _next_pow2(x: int) -> int:
    return 1 << max(0, (int(x) - 1).bit_length())


#: process-wide jitted chunk programs, keyed by (app identity, flags).
#: Re-running an engine with an equivalent app config reuses the compiled
#: programs instead of re-tracing per run — the jit cache is what the pow2
#: bucketing bounds (DESIGN.md §8), so it should be shared, not rebuilt.
_CHUNK_PROGRAM_CACHE: Dict[tuple, object] = {}


def _app_cache_key(app: MiningApp):
    """Hashable identity of an app's *traced* behaviour (class + dataclass
    fields), or None when the app carries unhashable state."""
    try:
        fields = tuple(
            (f.name, getattr(app, f.name)) for f in dataclasses.fields(app)
        )
        key = (type(app).__module__, type(app).__qualname__, fields)
        hash(key)
        return key
    except (TypeError, ValueError):
        return None


def _make_expand_fn(app: MiningApp, mode: str, use_pallas: bool = False,
                    fused: bool = False, interpret=None,
                    compact_kernel: bool = False, with_patterns: bool = False,
                    with_local_verts: bool = True):
    """Jitted chunk program of the superstep pipeline: expand + canonicality
    + app filter + compaction (+ child quick patterns when the pipeline is
    fused). Recompiled per (width, capacity) pow2 bucket; cached across
    runs for hashable app configs."""
    app_key = _app_cache_key(app)
    key = None
    if app_key is not None:
        key = (app_key, mode, use_pallas, fused, interpret,
               compact_kernel, with_patterns, with_local_verts)
        cached = _CHUNK_PROGRAM_CACHE.get(key)
        if cached is not None:
            return cached

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def fn(g: DeviceGraph, members, n_valid, out_cap: int):
        return explore.fused_chunk_step(
            g, members, n_valid, out_cap,
            mode=mode,
            app=app,
            with_patterns=with_patterns,
            with_local_verts=with_local_verts,
            use_pallas=use_pallas,
            fused=fused,
            compact_kernel=compact_kernel,
            interpret=interpret,
        )

    if key is not None:
        _CHUNK_PROGRAM_CACHE[key] = fn
    return fn


def _jit_cache_size(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except Exception:  # pragma: no cover - older/newer jax internals
        return None


def _initial_frontier(g: DeviceGraph, mode: str) -> np.ndarray:
    n0 = g.n if mode == "vertex" else g.m
    return np.arange(n0, dtype=np.int32)[:, None]


def _quick_patterns(g: DeviceGraph, mode: str, members, n_valid):
    if mode == "vertex":
        return pattern_lib.quick_pattern_vertex(g, members, n_valid)
    return pattern_lib.quick_pattern_edge(g, members, n_valid)


def _device_chunk(wave_dev, lo: int, cb: int, bucket: int, k: int):
    """Slice chunk ``[lo, lo+cb)`` out of a device-resident wave and pad it
    to its pow2 ``bucket`` on device — no host round-trip per chunk (the
    PR-2 loop re-built every chunk from the host wave)."""
    chunk = jax.lax.slice_in_dim(wave_dev, lo, lo + cb)
    n_valid = jnp.full((cb,), k, jnp.int32)
    if bucket > cb:
        chunk = jnp.concatenate(
            [chunk, jnp.full((bucket - cb, k), -1, jnp.int32)]
        )
        n_valid = jnp.concatenate(
            [n_valid, jnp.zeros((bucket - cb,), jnp.int32)]
        )
    return chunk, n_valid


def _retire(*buffers) -> None:
    """Best-effort immediate deletion of drained device buffers (instead of
    waiting for GC) — the fused pipeline's peak-HBM control."""
    for b in buffers:
        if hasattr(b, "delete"):
            try:
                b.delete()
            except Exception:
                pass


def store_app_filter(app: MiningApp, g: DeviceGraph):
    """Adapt ``app.filter`` to the per-candidate signature ODAG extraction
    re-applies (DESIGN.md §7): extraction rows are already one member-set per
    candidate, so the parent-row indirection is the identity. Returns None
    for the base accept-all filter (nothing to re-apply)."""
    if type(app).filter is MiningApp.filter:
        return None

    def phi(mem, nv, cnd):
        rows = jnp.arange(int(mem.shape[0]), dtype=jnp.int32)
        return app.filter(g, mem, nv, rows, cnd)

    return phi


def run(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    config: Optional[EngineConfig] = None,
) -> MiningResult:
    config = config or EngineConfig()
    g = to_device(graph) if isinstance(graph, Graph) else graph
    mode = app.mode
    use_pallas = config.resolve_use_pallas()
    compact_kernel = config.resolve_compact_kernel()
    fused_pipe = config.async_chunks
    store = make_store(
        config.store, g,
        mode=mode,
        app_filter=store_app_filter(app, g),
        use_pallas=use_pallas,
        interpret=config.pallas_interpret,
        device_budget_bytes=config.device_budget_bytes,
    )
    # child codes computed in the chunk program are only reusable when the
    # next superstep re-materialises exactly the appended rows in order —
    # true for the raw store (also under a spill budget), not for ODAG
    # extraction (which may resurrect pattern-pruned rows).
    with_patterns = fused_pipe and app.wants_patterns and store.kind == "raw"
    expand_fn = _make_expand_fn(
        app, mode,
        use_pallas=use_pallas,
        fused=config.fused_expand,
        interpret=config.pallas_interpret,
        compact_kernel=compact_kernel,
        with_patterns=with_patterns,
        with_local_verts=app.wants_domains,
    )
    cache_before = _jit_cache_size(expand_fn)

    result = MiningResult(patterns={}, aggregates=[], stats=RunStats(), embeddings={})
    t_start = time.perf_counter()

    store.append(_initial_frontier(g, mode))
    store.seal(1)
    size = 1
    #: fused mode: (codes, local_verts) of the sealed frontier, carried from
    #: the previous superstep's chunk programs — the next aggregation pass
    #: is pure host work, no re-upload, no second device pass.
    carried: Optional[tuple] = None
    #: fused mode: the output-capacity bucket persists across supersteps so
    #: one overflow re-dispatch per run (not per step) is the common case.
    cap = max(config.initial_capacity, 1)
    signatures = set()

    for step in range(1, config.max_steps + 1):
        b = store.n_rows
        if b == 0:
            break
        st = StepStats(step=step, size=size, n_frontier=b)
        st.frontier_bytes = store.raw_bytes
        if store.kind == "odag":
            st.odag_bytes = store.stored_bytes
        timer = Timer()

        # ---- re-materialise the frontier in device-budget waves ----------
        waves = list(store.chunks())
        wave_dev: List[Optional[jnp.ndarray]] = [None] * len(waves)
        # extraction may resurrect pattern-pruned rows (a superset of the
        # appended rows; see ODAGStore) — stats count what is actually mined
        st.n_frontier = sum(len(w) for w in waves)
        st.t_storage = timer.lap()

        # ---- pattern aggregation of this step's embeddings (end of the
        # step that generated them, per Algorithm 1): quick patterns either
        # carried from the chunk programs that produced the rows (fused,
        # raw store) or computed per wave on the one device-resident upload
        # the expansion below reuses; level-1 merge on host ----------------
        canon_slot = None
        agg = None
        if app.wants_patterns:
            if carried is not None and len(carried[0]) == st.n_frontier:
                codes, lv = carried
            else:
                codes_parts, lv_parts = [], []
                for wi, w in enumerate(waves):
                    wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
                    qp = _quick_patterns(
                        g, mode, wave_dev[wi],
                        jnp.full((len(w),), size, dtype=jnp.int32),
                    )
                    codes_parts.append(np.asarray(qp.codes))
                    lv_parts.append(np.asarray(qp.local_verts))
                    if config.device_budget_bytes is not None:
                        # SpillStore contract: one budget wave resident at
                        # a time — expansion re-uploads its own wave
                        _retire(wave_dev[wi])
                        wave_dev[wi] = None
                codes = (
                    np.concatenate(codes_parts)
                    if codes_parts else np.zeros((0, 3), np.int64)
                )
                lv = (
                    np.concatenate(lv_parts)
                    if lv_parts
                    else np.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), np.int32)
                )
            agg, canon_slot = aggregation.aggregate_rows(
                g.n, codes, lv, app.wants_domains
            )
            result.aggregates.append(agg)
            st.n_quick_patterns = agg.n_quick
            st.n_canonical_patterns = agg.n_canonical
            st.n_iso_checks = agg.n_iso_checks
        carried = None
        st.t_aggregate = timer.lap()

        # ---- alpha: aggregation filter on the frontier -------------------
        if app.wants_patterns and agg is not None:
            alpha = app.aggregation_filter(canon_slot, agg)
            # beta / outputs: record aggregates of surviving patterns
            surviving = np.unique(canon_slot[alpha]) if alpha.any() else []
            for pc in surviving:
                code = tuple(int(x) for x in agg.canon_codes[pc])
                value = int(
                    agg.supports[pc] if app.wants_domains else agg.counts[pc]
                )
                result.patterns[code] = result.patterns.get(code, 0) + value

            if not alpha.all():
                off, pruned = 0, []
                for w in waves:
                    pruned.append(w[alpha[off : off + len(w)]])
                    off += len(w)
                waves = pruned
                # pruned rows invalidate the device-resident waves
                _retire(*[wd for wd in wave_dev if wd is not None])
                wave_dev = [None] * len(waves)
        b_live = sum(len(w) for w in waves)
        if app.collect_embeddings and b_live:
            live = [w for w in waves if len(w)]
            result.embeddings[size] = (
                np.asarray(live[0])
                if len(live) == 1
                else np.concatenate(live, axis=0)
            )

        # ---- termination ---------------------------------------------------
        if app.termination_filter(size) or b_live == 0 or step == config.max_steps:
            result.stats.steps.append(st)
            break

        # ---- expansion (chunked, capacity-bucketed), children appended to
        # the store as they are produced ----------------------------------
        if fused_pipe:
            if config.device_budget_bytes is not None and len(waves) > 1:
                # SpillStore contract (DESIGN.md §7): at most one budget
                # wave device-resident at a time — pipeline and drain one
                # wave per pass (syncs O(waves), i.e. O(frontier/budget),
                # still independent of the chunk count) and retire each
                # wave's buffers before the next is uploaded.
                parts = []
                for wi in range(len(waves)):
                    sub_dev = [wave_dev[wi]]
                    c, cap = _expand_fused(
                        g, expand_fn, store, config, [waves[wi]], sub_dev,
                        size, cap, st, signatures, with_patterns,
                    )
                    _retire(sub_dev[0])
                    wave_dev[wi] = None
                    if c is not None:
                        parts.append(c)
                carried = (
                    (
                        np.concatenate([p[0] for p in parts]),
                        np.concatenate([p[1] for p in parts]),
                    )
                    if parts
                    else None
                )
            else:
                carried, cap = _expand_fused(
                    g, expand_fn, store, config, waves, wave_dev, size, cap,
                    st, signatures, with_patterns,
                )
        else:
            _expand_legacy(g, expand_fn, store, config, waves, size, st,
                           signatures)

        # every chunk has been drained — the step's device waves are dead
        _retire(*[wd for wd in wave_dev if wd is not None])
        st.t_expand = timer.lap()
        store.seal(size + 1)
        st.t_storage += timer.lap()
        result.stats.steps.append(st)

        if store.n_rows == 0:
            break
        size += 1

    result.stats.wall_time = time.perf_counter() - t_start
    result.stats.chunk_signatures = sorted(signatures)
    cache_after = _jit_cache_size(expand_fn)
    result.stats.n_compiles = (
        cache_after - cache_before
        if cache_before is not None and cache_after is not None
        else len(signatures)
    )
    return result


def _iter_chunks(waves, wave_dev, chunk_size: int, size: int):
    """Yield device-sliced, pow2-padded chunks over all waves, uploading
    each wave at most once (reusing the aggregation pass's upload)."""
    for wi, w in enumerate(waves):
        if not len(w):
            continue
        if wave_dev[wi] is None:
            wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
        wd = wave_dev[wi]
        for lo in range(0, len(w), chunk_size):
            cb = min(chunk_size, len(w) - lo)
            bucket = min(chunk_size, _next_pow2(max(cb, 1)))
            chunk, n_valid = _device_chunk(wd, lo, cb, bucket, size)
            yield wi, lo, cb, bucket, chunk, n_valid


#: chunk programs in flight between drains: bounds how many capacity-
#: padded output buffers are device-resident at once (peak HBM is
#: O(window * step_cap), not O(step output)) while keeping host syncs at
#: O(chunks / window) per superstep — 1 + pilot for any step under ~32
#: chunks.
_DRAIN_WINDOW = 32


def _expand_fused(g, expand_fn, store, config, waves, wave_dev, size, cap,
                  st, signatures, with_patterns):
    """The fused superstep expansion (DESIGN.md §8).

    One *pilot* chunk calibrates the step's output-capacity bucket (sync 1
    — the PR-2 loop instead discovers capacity growth once per chunk); the
    remaining chunks dispatch back-to-back with counts left on device and
    drain in stacked reads of ``_DRAIN_WINDOW`` chunks (one more sync per
    window, a single one for typical steps). Compaction counts are exact
    (never clamped to the capacity), so overshot chunks are re-dispatched
    at their exact pow2 bucket without any further sync. As a window
    drains, its children fold into the store via device-side prefix
    slices (only valid rows cross to the host), its pattern codes are
    collected for the next step's aggregation, and every buffer of the
    window is retired."""
    chunks = list(_iter_chunks(waves, wave_dev, config.chunk_size, size))
    st.n_chunks += len(chunks)
    if not chunks:
        return None, cap

    # ---- pilot: sync 1 calibrates the capacity bucket for the step ------
    _, _, cb0, bucket0, chunk0, n_valid0 = chunks[0]
    signatures.add((size, bucket0, cap))
    out = expand_fn(g, chunk0, n_valid0, out_cap=cap)
    c0 = int(out[1])
    st.n_host_syncs += 1
    if c0 > cap:
        _retire(out[0], out[2], out[3])
        cap = _next_pow2(c0)
        signatures.add((size, bucket0, cap))
        out = expand_fn(g, chunk0, n_valid0, out_cap=cap)  # count known exact
    # scale the pilot count to a full bucket for the remaining chunks; a
    # chunk that still overshoots is re-dispatched individually below
    est = -((-c0 * bucket0) // max(cb0, 1))        # ceil(c0 * bucket0 / cb0)
    step_cap = max(_next_pow2(max(est, 1)), 64)

    codes_parts, lv_parts = [], []

    def drain(pending):
        """One stacked control sync for a window of dispatched chunks,
        exact-cap overflow retries, then fold + retire."""
        meta = np.asarray(
            jnp.stack([s for p in pending for s in (p[9], p[10], p[11])])
        ).reshape(-1, 3)
        st.n_host_syncs += 1
        counts = meta[:, 0]
        st.n_generated += int(meta[:, 1].sum())
        st.n_canonical += int(meta[:, 2].sum())
        for i, p in enumerate(pending):
            if counts[i] <= p[12]:
                continue
            _retire(p[6], p[7], p[8])          # oversubscribed outputs
            retry_cap = _next_pow2(int(counts[i]))
            signatures.add((size, p[3], retry_cap))
            children, _, codes, lv, _, _ = expand_fn(
                g, p[4], p[5], out_cap=retry_cap
            )
            p[6], p[7], p[8] = children, codes, lv
        for i, p in enumerate(pending):
            cnt = int(counts[i])
            _retire(p[4], p[5])                # chunk inputs are dead now
            if cnt:
                # device-side prefix slices: the padding never crosses to
                # the host (same contract as store.resolve_rows)
                store.append(np.asarray(p[6][:cnt], dtype=np.int32))
                st.n_children += cnt
                if with_patterns:
                    codes_parts.append(np.asarray(p[7][:cnt]))
                    lv_parts.append(np.asarray(p[8][:cnt]))
            _retire(p[6], p[7], p[8])

    # [wi, lo, cb, bucket, chunk, n_valid, children, codes, lv,
    #  count, ngen, ncanon, used_cap]
    pending = [list(chunks[0]) + [out[0], out[2], out[3],
                                  out[1], out[4], out[5], cap]]
    for ch in chunks[1:]:
        _, _, _, bucket_i, chunk_i, n_valid_i = ch
        signatures.add((size, bucket_i, step_cap))
        children, count, codes, lv, ngen, ncanon = expand_fn(
            g, chunk_i, n_valid_i, out_cap=step_cap
        )
        pending.append(
            list(ch) + [children, codes, lv, count, ngen, ncanon, step_cap]
        )
        if len(pending) >= _DRAIN_WINDOW:
            drain(pending)
            pending = []
    if pending:
        drain(pending)
    cap = max(cap, step_cap)

    carried = None
    if with_patterns and codes_parts:
        carried = (np.concatenate(codes_parts), np.concatenate(lv_parts))
    return carried, cap


def _expand_legacy(g, expand_fn, store, config, waves, size, st, signatures):
    """The PR-2 chunk loop, preserved bit-for-bit as the measured baseline
    (``benchmarks/bench_superstep.py``): every chunk is sliced and padded
    on the host and re-uploaded (even when aggregation already uploaded
    the wave — the double upload the fused pipeline removes), one blocking
    ``int(count)`` host sync per chunk plus one per capacity retry, the
    capacity bucket reset every superstep, children forced through
    ``np.asarray`` per chunk."""
    cap = max(config.initial_capacity, 1)
    for w in waves:
        for lo in range(0, len(w), config.chunk_size):
            chunk = np.asarray(w[lo : lo + config.chunk_size])
            cb = int(chunk.shape[0])
            bucket = min(config.chunk_size, _next_pow2(max(cb, 1)))
            pad = bucket - cb
            if pad:
                chunk = np.concatenate(
                    [chunk, np.full((pad, size), -1, np.int32)], axis=0
                )
            n_valid = jnp.concatenate(
                [jnp.full((cb,), size, jnp.int32), jnp.zeros((pad,), jnp.int32)]
            )
            chunk = jnp.asarray(chunk)
            st.n_chunks += 1
            while True:
                signatures.add((size, bucket, cap))
                children, count, _, _, ngen, ncanon = expand_fn(
                    g, chunk, n_valid, out_cap=cap
                )
                count = int(count)
                st.n_host_syncs += 1
                if count <= cap:
                    break
                _retire(children)
                cap = _next_pow2(count)
            st.n_generated += int(ngen)
            st.n_canonical += int(ncanon)
            if count:
                store.append(np.asarray(children[:count]))
                st.n_children += count
