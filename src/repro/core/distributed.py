"""Distributed TLE exploration on a device mesh (paper §5.1/§5.3 on JAX).

The Giraph BSP superstep becomes one jitted ``shard_map`` program per
exploration step:

  * expansion + canonicality is *coordination-free* (paper §5.1): each worker
    expands its frontier slice with zero communication;
  * pattern aggregation is ONE collective: per-pattern counts and FSM domain
    bitmaps are ``psum``/OR-allreduced (two-level aggregation: bytes scale
    with #patterns, never #embeddings — Table 4 as collective-bytes);
  * the frontier between supersteps is owned by a pluggable
    :mod:`repro.core.store` (DESIGN.md §7). With ``store="raw"`` the
    re-balancing is broadcast-then-partition (paper §5.3): an all-gather of
    the frontier followed by deterministic block slicing, so every worker
    ends with |F|/W embeddings. With ``store="odag"`` each worker's children
    are folded into a fixed-shape DenseODAG and the worker bitmaps are
    merged with a bitwise OR — host-side in this single-process runtime,
    bit-for-bit the §5.2 "merge and broadcast" OR-allreduce of a multi-host
    mesh — and every worker re-materialises its slice via cost-annotated
    partitioning + extraction (§5.3). Exchange bytes (``collective_bytes``)
    then scale with the ODAG, never the embedding list.

The superstep body is the fused pipeline of DESIGN.md §8
(``DistConfig.async_chunks``): every worker's shard runs the same
``explore.fused_chunk_step`` program the serial engine jits — expansion +
canonicality + app filter + stream compaction + (raw store) the children's
quick-pattern codes in one device pass — children land in the store as
device arrays, and the host takes ONE control sync per superstep on the
exact (unclamped) child counts.

``run_distributed`` mirrors ``engine.run`` and must produce identical
results (integration-tested); ``mining_step_for_dryrun`` is the fixed-shape
program the multi-pod dry-run lowers on the 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4/0.5: experimental namespace
    from jax.experimental.shard_map import shard_map


def _shard_map_pallas_ok(f, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled: pallas_call has no
    replication rule, so worker bodies that may contain a kernel need
    check_rep=False (renamed check_vma in newer jax)."""
    try:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    except TypeError:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )

from repro.core import aggregation, explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.engine import (
    EngineConfig,
    MiningResult,
    _next_pow2,
    _retire,
    store_app_filter,
)
from repro.core.graph import DeviceGraph, Graph, to_device
from repro.core.stats import RunStats, StepStats, Timer
from repro.core.store import make_store
from repro.kernels.dispatch import default_use_pallas


def _mesh_axis_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def pad_parts(parts, k: int):
    """Pad variable-length per-worker row blocks to one dense
    ``(W, per, k)`` int32 array (pad value -1) + per-worker counts — THE
    shard-padding convention, shared by the even block split below and the
    store-provided (cost-balanced) parts in ``run_distributed``."""
    n = len(parts)
    per = max(max((len(p) for p in parts), default=0), 1)
    padded = np.full((n, per, k), -1, dtype=np.int32)
    counts = np.zeros(n, dtype=np.int32)
    for s, p in enumerate(parts):
        padded[s, : len(p)] = p
        counts[s] = len(p)
    return padded, counts


def partition_frontier(frontier: np.ndarray, n_shards: int):
    """Broadcast-then-partition (paper §5.3): even block split, padded."""
    b, k = frontier.shape
    per = -(-b // n_shards) if b else 1
    return pad_parts(
        [frontier[s * per : (s + 1) * per] for s in range(n_shards)], k
    )


def make_sharded_expand(app: MiningApp, mesh: Mesh, axes=("data",),
                        use_pallas: bool = False, interpret=None,
                        compact_kernel: bool = False,
                        with_patterns: bool = False):
    """One BSP superstep: coordination-free expand over the mesh.

    The worker body is the SAME fused chunk program the serial engine jits
    (``explore.fused_chunk_step``, DESIGN.md §8): expansion + canonicality
    + app filter + stream compaction, and — with ``with_patterns`` — the
    children's quick-pattern codes in the same device pass, so the next
    superstep's aggregation needs no second upload of the frontier.
    """

    mode = app.mode
    spec_in = P(axes)

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def step(g: DeviceGraph, members, n_valid, out_cap: int):
        def worker(g, members, n_valid):
            m = members[0]          # shard_map adds the leading shard dim
            nv = n_valid[0]
            children, count, codes, lv, ngen, ncanon = explore.fused_chunk_step(
                g, m, nv, out_cap,
                mode=mode,
                app=app,
                with_patterns=with_patterns,
                use_pallas=use_pallas,
                compact_kernel=compact_kernel,
                interpret=interpret,
            )
            outs = (children[None], count[None], ngen[None], ncanon[None])
            if with_patterns:
                outs += (codes[None], lv[None])
            return outs

        mapper = (
            _shard_map_pallas_ok if (use_pallas or compact_kernel) else shard_map
        )
        n_out = 6 if with_patterns else 4
        return mapper(
            functools.partial(worker, g),
            mesh=mesh,
            in_specs=(spec_in, spec_in),
            out_specs=(spec_in,) * n_out,
        )(members, n_valid)

    return step


def make_sharded_aggregate(mesh: Mesh, axes=("data",)):
    """Two-level aggregation's global reduce as ONE collective: counts psum +
    domain-bitmap OR(max)-allreduce over the mesh axes."""

    spec = P(axes)

    @functools.partial(jax.jit, static_argnames=("n_canon", "n_vertices"))
    def agg(canon_slot, verts_canon, valid, n_canon: int, n_vertices: int):
        def worker(canon_slot, verts_canon, valid):
            slot = canon_slot[0]
            counts = jax.ops.segment_sum(
                valid[0].astype(jnp.int64),
                jnp.where(valid[0], slot, n_canon),
                n_canon + 1,
            )[:n_canon]
            bitmaps = aggregation.domain_bitmaps(
                slot, verts_canon[0], valid[0], n_canon, n_vertices
            )
            # THE collective: bytes ∝ #patterns, not #embeddings (Table 4)
            counts = jax.lax.psum(counts, axes)
            bitmaps = jax.lax.pmax(bitmaps.astype(jnp.int32), axes) > 0
            return counts[None], bitmaps[None]

        counts, bitmaps = shard_map(
            worker,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=(spec, spec),
        )(canon_slot, verts_canon, valid)
        return counts[0], bitmaps[0]

    return agg


@dataclasses.dataclass
class DistConfig:
    axes: tuple = ("data",)
    initial_capacity: int = 4096     # per-shard children capacity bucket
    max_steps: int = 16
    #: frontier store between supersteps: "raw" = broadcast-then-partition
    #: block slicing of the dense embedding list; "odag" = worker-local
    #: DenseODAGs merged with a bitwise OR (the §5.2 OR-allreduce, computed
    #: host-side here), per-worker slices re-materialised via §5.3
    #: cost-balanced extraction.
    store: str = "raw"
    #: disable two-level aggregation (§Perf baseline): every worker
    #: all-gathers all embeddings' quick codes and canonicalises each
    #: embedding's pattern itself — the paper's Fig.11 naive scheme.
    naive_aggregation: bool = False
    #: route the Alg.-2 check through the Pallas kernel inside each
    #: worker's shard (same dispatch rules as EngineConfig.use_pallas).
    use_pallas: Optional[bool] = None
    #: Pallas interpret override; None -> auto per backend.
    pallas_interpret: Optional[bool] = None
    #: fused superstep pipeline (DESIGN.md §8), mirroring
    #: ``EngineConfig.async_chunks``: with ``store="raw"`` the sharded
    #: expand also emits the children's quick-pattern codes, so the next
    #: superstep's aggregation runs from carried codes instead of
    #: re-uploading the frontier for a second device pass; children are
    #: appended to the store as device arrays (no forced host transfer).
    async_chunks: bool = True
    #: route worker-shard compaction through the Pallas stream-compaction
    #: kernel (``kernels/compact.py``); None -> auto, on where Pallas
    #: compiles natively (same rule as EngineConfig.compact_kernel).
    compact_kernel: Optional[bool] = None

    def resolve_use_pallas(self) -> bool:
        return default_use_pallas() if self.use_pallas is None else self.use_pallas

    def resolve_compact_kernel(self) -> bool:
        return (
            default_use_pallas()
            if self.compact_kernel is None
            else self.compact_kernel
        )


def run_distributed(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    mesh: Mesh,
    config: Optional[DistConfig] = None,
) -> MiningResult:
    """Distributed mirror of ``engine.run`` (same MiningResult contract)."""
    config = config or DistConfig()
    g = to_device(graph) if isinstance(graph, Graph) else graph
    n_shards = _mesh_axis_size(mesh, config.axes)
    resolved_pallas = config.resolve_use_pallas()
    fused_pipe = config.async_chunks
    # carried child codes need the next frontier to be exactly the appended
    # rows in order — raw store only (ODAG extraction resurrects rows), and
    # the naive-aggregation baseline deliberately re-derives everything.
    with_patterns = (
        fused_pipe
        and app.wants_patterns
        and config.store == "raw"
        and not config.naive_aggregation
    )
    expand = make_sharded_expand(
        app, mesh, config.axes,
        use_pallas=resolved_pallas,
        interpret=config.pallas_interpret,
        compact_kernel=config.resolve_compact_kernel(),
        with_patterns=with_patterns,
    )
    aggregate = make_sharded_aggregate(mesh, config.axes)
    store = make_store(
        config.store, g,
        mode=app.mode,
        app_filter=store_app_filter(app, g),
        use_pallas=resolved_pallas,
        interpret=config.pallas_interpret,
        dense_exchange=True,
    )

    result = MiningResult(patterns={}, aggregates=[], stats=RunStats(), embeddings={})
    t_start = time.perf_counter()

    n0 = g.n if app.mode == "vertex" else g.m
    store.append(np.arange(n0, dtype=np.int32)[:, None])
    store.seal(1)
    size = 1
    cap = config.initial_capacity
    #: fused mode: (codes, local_verts) of the sealed frontier, emitted by
    #: the previous superstep's sharded expand (DESIGN.md §8)
    carried = None

    for step_i in range(1, config.max_steps + 1):
        if store.n_rows == 0:
            break
        st = StepStats(step=step_i, size=size, n_frontier=store.n_rows)
        st.frontier_bytes = store.raw_bytes
        if store.kind == "odag":
            st.odag_bytes = store.stored_bytes
        timer = Timer()

        # ---- re-materialise per-worker slices from the store -------------
        # raw: deterministic block split (broadcast-then-partition); odag:
        # §5.3 cost-annotated partitions, one extraction per worker.
        parts = store.worker_parts(n_shards)
        frontier = (
            np.concatenate(parts, axis=0)
            if any(len(p) for p in parts)
            else np.zeros((0, size), np.int32)
        )
        b = len(frontier)
        # extraction may resurrect pattern-pruned rows (a superset of the
        # appended rows; see ODAGStore) — stats count what is actually mined
        st.n_frontier = b
        st.t_storage = timer.lap()

        # ---- pattern aggregation (collective) ---------------------------
        canon_slot = None
        agg_out = None
        if app.wants_patterns:
            if carried is not None and len(carried[0]) == b:
                # fused pipeline: codes were computed by the sharded expand
                # that produced these rows — no re-upload, no second pass
                codes_np, lv_np = carried
            else:
                n_valid_h = jnp.full((b,), size, dtype=jnp.int32)
                qp = (
                    pattern_lib.quick_pattern_vertex(
                        g, jnp.asarray(frontier), n_valid_h
                    )
                    if app.mode == "vertex"
                    else pattern_lib.quick_pattern_edge(
                        g, jnp.asarray(frontier), n_valid_h
                    )
                )
                codes_np = np.asarray(qp.codes)
                lv_np = np.asarray(qp.local_verts)
            if config.naive_aggregation:
                # naive scheme: exchange per-EMBEDDING codes (an all-gather
                # of B x 24 bytes x workers) and run pattern canonicalisation
                # once per embedding instead of once per quick pattern.
                st.collective_bytes += int(codes_np.size * 8) * n_shards
                for row in codes_np:
                    pattern_lib.canonicalize_one(row)       # B iso checks
            uniq, inv = aggregation.quick_slot_ids(codes_np, np.ones(b, bool))
            table = pattern_lib.build_pattern_table(
                uniq, with_orbits=app.wants_domains
            )
            pc = len(table.canon_codes)
            canon_slot, verts_canon = aggregation.map_to_canonical_positions(
                table, inv, lv_np
            )
            # shard the level-1 inputs, reduce with the collective
            slot_sh, slot_counts = partition_frontier(canon_slot[:, None], n_shards)
            vc_sh, _ = partition_frontier(np.asarray(verts_canon), n_shards)
            per = slot_sh.shape[1]
            valid_sh = (
                np.arange(per)[None, :] < slot_counts[:, None]
            )
            counts, bitmaps = aggregate(
                jnp.asarray(slot_sh[:, :, 0]),
                jnp.asarray(vc_sh.reshape(n_shards, per, -1)),
                jnp.asarray(valid_sh),
                n_canon=max(pc, 1),
                n_vertices=g.n,
            )
            counts = np.asarray(counts[:pc])
            if app.wants_domains:
                supports = aggregation.min_image_support(
                    bitmaps[:pc], table.canon_n_verts, table.canon_orbits
                )
            else:
                supports = counts.copy()
            agg_out = aggregation.StepAggregates(
                canon_codes=table.canon_codes,
                counts=counts.astype(np.int64),
                supports=np.asarray(supports).astype(np.int64),
                n_quick=len(uniq),
                n_canonical=pc,
                n_iso_checks=table.n_iso_checks,
            )
            result.aggregates.append(agg_out)
            st.n_quick_patterns = agg_out.n_quick
            st.n_canonical_patterns = agg_out.n_canonical
            st.n_iso_checks = b if config.naive_aggregation else agg_out.n_iso_checks
            st.collective_bytes += counts.nbytes + (
                int(np.asarray(bitmaps[:pc]).size) // 8 if app.wants_domains else 0
            )
        carried = None
        st.t_aggregate = timer.lap()

        # ---- alpha + outputs --------------------------------------------
        if agg_out is not None:
            alpha = app.aggregation_filter(canon_slot, agg_out)
            for pcs in (np.unique(canon_slot[alpha]) if alpha.any() else []):
                code = tuple(int(x) for x in agg_out.canon_codes[pcs])
                value = int(
                    agg_out.supports[pcs] if app.wants_domains else agg_out.counts[pcs]
                )
                result.patterns[code] = result.patterns.get(code, 0) + value
            if not alpha.all():
                off, pruned = 0, []
                for p in parts:
                    pruned.append(p[alpha[off : off + len(p)]])
                    off += len(p)
                parts = pruned
                frontier = frontier[alpha]
                b = len(frontier)
        if app.collect_embeddings and b:
            result.embeddings[size] = frontier.copy()

        if app.termination_filter(size) or b == 0 or step_i == config.max_steps:
            result.stats.steps.append(st)
            break

        # ---- coordination-free sharded expansion over the (§5.3
        # cost-balanced) per-worker slices ---------------------------------
        shards, counts_sh = pad_parts(parts, size)
        per = shards.shape[1]
        n_valid = (np.arange(per)[None, :] < counts_sh[:, None]) * size
        members_dev = jnp.asarray(shards)
        n_valid_dev = jnp.asarray(n_valid.astype(np.int32))
        while True:
            outs = expand(g, members_dev, n_valid_dev, out_cap=cap)
            children, ccount = outs[0], outs[1]
            ccount = np.asarray(ccount)     # THE per-step control sync
            st.n_host_syncs += 1
            st.n_chunks += 1
            if int(ccount.max()) <= cap:
                break
            # counts are exact (unclamped compaction), so exactly one
            # re-dispatch at the next pow2 bucket suffices
            _retire(*outs)
            cap = _next_pow2(int(ccount.max()))
        st.n_generated = int(np.asarray(outs[2]).sum())
        st.n_canonical = int(np.asarray(outs[3]).sum())

        # ---- frontier exchange: worker-local children into the store as
        # device arrays (resolved at seal; odag: DenseODAG OR-allreduce,
        # §5.2); with the fused pipeline the children's pattern codes are
        # carried to the next superstep's aggregation -----------------------
        for s in range(n_shards):
            store.append(children[s], worker=s, count=int(ccount[s]))
        if with_patterns:
            codes_all = np.asarray(outs[4])
            lv_all = np.asarray(outs[5])
            carried = (
                np.concatenate(
                    [codes_all[s, : ccount[s]] for s in range(n_shards)]
                ),
                np.concatenate(
                    [lv_all[s, : ccount[s]] for s in range(n_shards)]
                ),
            )
        st.t_expand = timer.lap()
        store.seal(size + 1)
        st.t_storage += timer.lap()
        st.n_children = store.n_rows
        # frontier exchange: what a worker ships (raw rows, or the merged
        # ODAG with store="odag") rides the same collective accounting as
        # the aggregation reduce
        st.collective_bytes += store.exchange_bytes
        result.stats.steps.append(st)

        if store.n_rows == 0:
            break
        size += 1

    result.stats.wall_time = time.perf_counter() - t_start
    return result


# ---------------------------------------------------------------------------
# Fixed-shape mining step for the multi-pod dry-run
# ---------------------------------------------------------------------------

def mining_step_for_dryrun(mesh: Mesh, axes=("pod", "data"),
                           use_pallas: Optional[bool] = None, interpret=None):
    """A single fully fixed-shape distributed exploration step suitable for
    AOT lowering on the production mesh: expand + canonicality + quick
    patterns + domain-bitmap psum. Pattern dictionary capacity is static.

    ``use_pallas=None`` resolves against the *lowering host's* backend
    (same rule as the engines). NB: the AOT dry-run harness forces CPU
    host devices, so it models the jnp check path by default — pass
    ``use_pallas=True`` explicitly to lower/inspect the kernel path the
    TPU engine defaults to.
    """
    resolved_pallas = default_use_pallas() if use_pallas is None else use_pallas

    def step(g: DeviceGraph, members, n_valid, quick_dict):
        """members: (B, k) sharded over `axes`; quick_dict: (Q, 3) replicated."""

        def worker(g, quick_dict, members, n_valid):
            m, nv = members[0], n_valid[0]
            exp = explore.expand_vertex(
                g, m, nv, use_pallas=resolved_pallas, interpret=interpret
            )
            out_cap = m.shape[0]  # fixed children capacity = shard size
            children, count = explore.compact(m, exp, exp.keep, out_cap)
            child_nv = jnp.where(
                jnp.arange(out_cap) < count, jnp.max(nv) + 1, 0
            ).astype(jnp.int32)
            qp = pattern_lib.quick_pattern_vertex(g, children, child_nv)
            # static-capacity dictionary match (searchsorted on w0 then
            # verify all three words)
            q = quick_dict.shape[0]
            eq = (qp.codes[:, None, :] == quick_dict[None, :, :]).all(-1)
            slot = jnp.where(eq.any(1), jnp.argmax(eq, axis=1), q)
            counts = jax.ops.segment_sum(
                (child_nv > 0).astype(jnp.int32), slot, q + 1
            )[:q]
            counts = jax.lax.psum(counts, axes)
            return children[None], count[None], counts[None]

        spec = P(axes)
        mapper = _shard_map_pallas_ok if resolved_pallas else shard_map
        return mapper(
            functools.partial(worker, g, quick_dict),
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec, spec),
        )(members, n_valid)

    return step
