"""Distributed mining entry point — a thin wrapper over the unified runtime.

The shard-map superstep this module used to implement (paper §5.1/§5.3 as
one jitted ``shard_map`` program per exploration step, with the two-level
aggregation collective and the §5.2 DenseODAG OR-merge exchange) now lives
ONCE in :mod:`repro.core.runtime.shard` behind the
:class:`~repro.core.runtime.backend.ExecutionBackend` protocol; the BSP
loop around it is the same :class:`~repro.core.runtime.SuperstepRuntime`
the serial engine drives. ``run_distributed`` and ``DistConfig`` are kept
as the stable public names — ``DistConfig`` is a deprecation shim over
:class:`RunConfig` (the shard-map backend reads ``axes`` /
``naive_aggregation`` from it and ignores the serial-only knobs).

``run_distributed`` mirrors ``engine.run`` and must produce identical
results (integration-tested); ``mining_step_for_dryrun`` is the fixed-shape
program the multi-pod dry-run lowers on the 512-chip mesh.

Checkpoint/resume (DESIGN.md §9): ``DistConfig(checkpoint_dir=...)``
persists every sealed superstep; resuming with a mesh of a *different*
worker count is elastic by construction — per-worker slices are
re-partitioned from the restored store at extraction time.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph, Graph
from repro.core.runtime import (
    MiningResult,
    RunConfig,
    ShardMapBackend,
    SuperstepRuntime,
)
from repro.core.runtime.shard import (  # noqa: F401  (canonical home)
    make_sharded_aggregate,
    make_sharded_expand,
    mesh_axis_size as _mesh_axis_size,
    pad_parts,
    partition_frontier,
    shard_map,
    shard_map_pallas_ok as _shard_map_pallas_ok,
)
__all__ = ["DistConfig", "run_distributed", "mining_step_for_dryrun"]


@dataclasses.dataclass
class DistConfig(RunConfig):
    """Deprecated alias of :class:`repro.core.runtime.RunConfig`.

    Kept as an empty subclass so every pre-runtime call site (and kwarg)
    keeps working; new code should construct ``RunConfig`` directly."""


def run_distributed(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    mesh: Mesh,
    config: Optional[RunConfig] = None,
) -> MiningResult:
    """Mine ``graph`` with ``app`` sharded over ``mesh`` (same
    ``MiningResult`` contract as ``engine.run``)."""
    return SuperstepRuntime(graph, app, config, ShardMapBackend(mesh)).run()


# ---------------------------------------------------------------------------
# Fixed-shape mining step for the multi-pod dry-run
# ---------------------------------------------------------------------------

def mining_step_for_dryrun(mesh: Mesh, axes=("pod", "data"),
                           use_pallas: Optional[bool] = None, interpret=None):
    """A single fully fixed-shape distributed exploration step suitable for
    AOT lowering on the production mesh: expand + canonicality + quick
    patterns + domain-bitmap psum. Pattern dictionary capacity is static.

    ``use_pallas=None`` resolves against the *lowering host's* backend
    (same rule as the engines). NB: the AOT dry-run harness forces CPU
    host devices, so it models the jnp check path by default — pass
    ``use_pallas=True`` explicitly to lower/inspect the kernel path the
    TPU engine defaults to.
    """
    resolved_pallas = RunConfig(use_pallas=use_pallas).resolve_use_pallas()

    def step(g: DeviceGraph, members, n_valid, quick_dict):
        """members: (B, k) sharded over `axes`; quick_dict: (Q, 3) replicated."""

        def worker(g, quick_dict, members, n_valid):
            m, nv = members[0], n_valid[0]
            exp = explore.expand_vertex(
                g, m, nv, use_pallas=resolved_pallas, interpret=interpret
            )
            out_cap = m.shape[0]  # fixed children capacity = shard size
            children, count = explore.compact(m, exp, exp.keep, out_cap)
            child_nv = jnp.where(
                jnp.arange(out_cap) < count, jnp.max(nv) + 1, 0
            ).astype(jnp.int32)
            qp = pattern_lib.quick_pattern_vertex(g, children, child_nv)
            # static-capacity dictionary match (searchsorted on w0 then
            # verify all three words)
            q = quick_dict.shape[0]
            eq = (qp.codes[:, None, :] == quick_dict[None, :, :]).all(-1)
            slot = jnp.where(eq.any(1), jnp.argmax(eq, axis=1), q)
            counts = jax.ops.segment_sum(
                (child_nv > 0).astype(jnp.int32), slot, q + 1
            )[:q]
            counts = jax.lax.psum(counts, axes)
            return children[None], count[None], counts[None]

        spec = P(axes)
        mapper = _shard_map_pallas_ok if resolved_pallas else shard_map
        return mapper(
            functools.partial(worker, g, quick_dict),
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec, spec, spec),
        )(members, n_valid)

    return step
