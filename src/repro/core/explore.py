"""Vectorised embedding expansion — the inner loop of Algorithm 1.

One exploration step takes a frontier of canonical embeddings (each a row of
vertex ids or edge ids in visit order) and produces every canonical child
obtained by adding one incident vertex/edge, already deduplicated (within the
parent) and filtered by the embedding-canonicality check.

TPU adaptation (see DESIGN.md §2): instead of per-embedding adjacency-list
walks, we materialise a dense padded candidate tensor ``(C, k, D)`` /
``(C, 2k, D)`` from the padded neighbour table and evaluate *all* pruning
rules as fused mask expressions. The engine chunks the frontier so this
tensor stays bounded.

:func:`fused_chunk_step` is the single device pass of the fused superstep
pipeline (DESIGN.md §8): expansion + canonicality + app filter + stream
compaction + the children's quick-pattern codes, so the engines never
upload a wave twice or sync per chunk.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bitset, canonical, pattern as pattern_lib
from repro.core.graph import DeviceGraph, PartitionedGraph
from repro.kernels import aggregate as aggregate_kernel_lib
from repro.kernels import compact as compact_kernel_lib
from repro.kernels import gather as gather_kernel_lib
from repro.kernels.canonical_check import ops as cc_ops


class TileView(NamedTuple):
    """One chunk's gathered halo of a :class:`PartitionedGraph`
    (DESIGN.md §11): the ascending unique *member* vertices (vertex mode)
    or member-edge endpoints (edge mode) with their neighbour / incident-
    edge / packed-adjacency rows gathered into dense tiles, plus the
    replicated id/label payload. Everything downstream of expansion
    (canonicality, app filters, the children's quick patterns) consumes
    this view instead of a whole-graph table.

    Rows are *tile-local*; columns of ``adj_t`` stay global, so one
    resident endpoint resolves any pairwise adjacency query —
    :meth:`is_edge` tries both sides, and every pair the fused pipeline
    asks about (member↔candidate, child-embedding pairs) has at most one
    non-member vertex."""

    uniq: jnp.ndarray         # (U,) int32 ascending halo ids, pad sentinel n
    labels: jnp.ndarray       # (n,) int32 — replicated
    edge_uv: jnp.ndarray      # (m, 2) int32 — replicated
    edge_labels: jnp.ndarray  # (m,) int32 — replicated
    nbr_t: jnp.ndarray        # (U, D) int32 gathered neighbour rows, pad -1
    nbr_eid_t: jnp.ndarray    # (U, D) int32 incident-edge rows ((U, 0) unused)
    adj_t: jnp.ndarray        # (U, W) uint32 gathered adjacency rows

    @property
    def n(self) -> int:
        return self.labels.shape[0]

    @property
    def m(self) -> int:
        return self.edge_uv.shape[0]

    @property
    def max_degree(self) -> int:
        return self.nbr_t.shape[1]

    def rank(self, v):
        """(tile row of each global id, hit mask). ``uniq`` is ascending
        with sentinel-``n`` padding, so translation is one searchsorted;
        misses return a clipped-safe row with ``hit=False``."""
        v = jnp.asarray(v)
        r = jnp.searchsorted(self.uniq, jnp.clip(v, 0, self.n)).astype(jnp.int32)
        r = jnp.minimum(r, self.uniq.shape[0] - 1)
        return r, (self.uniq[r] == v) & (v >= 0)

    def is_edge(self, u, v):
        """Symmetric O(1) edge query resolved from whichever endpoint is
        tile-resident (False when neither is, or for out-of-range ids) —
        the total-graph contract every generic caller (quick patterns,
        app phi filters) relies on."""
        ru, hu = self.rank(u)
        rv, hv = self.rank(v)
        return (
            bitset.test_bit(self.adj_t, jnp.where(hu, ru, -1), v)
            | bitset.test_bit(self.adj_t, jnp.where(hv, rv, -1), u)
        )


def halo_cap(members_shape, mode: str, n: int) -> int:
    """Static tile capacity for a chunk: the distinct halo can never exceed
    min(member-vertex slots, n), so the pow2 of that bound makes tile
    overflow impossible by construction — no new host syncs, no retry."""
    c, k = members_shape
    slots = c * k * (2 if mode == "edge" else 1)
    # pow2 bucket (config.next_pow2 inlined: runtime.config imports would
    # cycle through the runtime package __init__)
    return 1 << max(0, (max(min(slots, int(n)), 1) - 1).bit_length())


def halo_vertices(g, members, n_valid, mode: str):
    """Flat (possibly duplicated) halo vertex ids of a chunk: the members
    themselves (vertex mode) or the member edges' endpoints (edge mode);
    invalid slots -1."""
    c, k = members.shape
    valid = jnp.arange(k)[None, :] < n_valid[:, None]
    if mode == "vertex":
        return jnp.where(valid, members, -1).reshape(-1)
    verts = g.edge_uv[jnp.maximum(members, 0)].reshape(c, 2 * k)
    return jnp.where(jnp.repeat(valid, 2, axis=1), verts, -1).reshape(-1)


def build_tile_view(
    g: PartitionedGraph,
    members: jnp.ndarray,
    n_valid: jnp.ndarray,
    mode: str,
    *,
    use_pallas: bool = False,
    compact_kernel: bool = False,
    interpret=None,
) -> TileView:
    """The tile-gather stage of the fused pipeline on one process: halo
    unique (presence bitmap + stream compaction, ``kernels/gather.py``)
    followed by row gathers from the shard-stacked tables through the
    global->flat translation of ``PartitionedGraph.flat_index``. The
    shard-map backend builds the same view per worker with collectives in
    place of the flat gather (``runtime/shard.py``)."""
    cap = halo_cap(members.shape, mode, g.n)
    verts = halo_vertices(g, members, n_valid, mode)
    uniq, _ = gather_kernel_lib.halo_unique(
        verts, g.n, cap, use_kernel=compact_kernel, interpret=interpret
    )
    fi, ok = g.flat_index(uniq)
    fi = jnp.where(ok, fi, -1)
    d, w = g.max_degree, g.adj_sh.shape[2]
    nbr_t = gather_kernel_lib.gather_rows(
        g.nbr_sh.reshape(-1, d), fi, -1,
        use_kernel=use_pallas, interpret=interpret,
    )
    if mode == "edge":
        nbr_eid_t = gather_kernel_lib.gather_rows(
            g.nbr_eid_sh.reshape(-1, d), fi, -1,
            use_kernel=use_pallas, interpret=interpret,
        )
        adj_t = jnp.zeros((cap, 1), jnp.uint32)   # edge mode never reads adj
    else:
        nbr_eid_t = jnp.zeros((cap, 0), jnp.int32)
        adj_t = gather_kernel_lib.gather_rows(
            g.adj_sh.reshape(-1, w), fi, 0,
            use_kernel=use_pallas, interpret=interpret,
        )
    return TileView(
        uniq=uniq,
        labels=g.labels,
        edge_uv=g.edge_uv,
        edge_labels=g.edge_labels,
        nbr_t=nbr_t,
        nbr_eid_t=nbr_eid_t,
        adj_t=adj_t,
    )


class Expansion(NamedTuple):
    """Flattened candidate set for one frontier chunk (before compaction)."""

    rows: jnp.ndarray        # (Ncand,) int32 parent row in the chunk
    cand: jnp.ndarray        # (Ncand,) int32 extension vertex / edge id
    keep: jnp.ndarray        # (Ncand,) bool — canonical, deduped, valid
    n_generated: jnp.ndarray  # () int32 raw candidate slots that were valid
    n_canonical: jnp.ndarray  # () int32 survivors of the canonicality check


def expand_vertex(
    g: DeviceGraph,
    members: jnp.ndarray,   # (C, k) int32, pad -1
    n_valid: jnp.ndarray,   # (C,) int32
    *,
    use_pallas: bool = False,
    fused: bool = False,
    interpret=None,
) -> Expansion:
    """Candidates for vertex-induced exploration.

    A candidate slot (c, i, j) is neighbour j of member i of embedding c.
    Kept iff: slot valid; vertex not already a member; this is the *first*
    occurrence (no earlier member is adjacent to it — neighbour lists are
    sorted-unique so within one member's list it appears once); and the
    extended embedding passes the incremental canonicality check.

    ``use_pallas`` routes the canonicality check through the VMEM-resident
    Pallas kernel (``repro.kernels.canonical_check``); ``fused`` addition-
    ally evaluates the validity masks inside the same kernel pass
    (``expand_canonical``), skipping the ``(C, k, k, D)`` HBM intermediate.
    Both fall back to this jnp path when the graph exceeds the VMEM limits.
    """
    if use_pallas and fused and cc_ops.fits_vmem_fused(g):
        return _expand_vertex_fused(g, members, n_valid, interpret)
    c, k = members.shape
    d = g.max_degree
    pos = jnp.arange(k)[None, :]
    member_ok = pos < n_valid[:, None]                      # (C, k)

    tiled = isinstance(g, TileView)
    if tiled:
        # partitioned path: members are halo-resident by construction, so
        # every member-rooted lookup goes through tile ranks while ids stay
        # global (columns of adj_t are global; see TileView)
        ranks, in_tile = g.rank(members)
        mrow = jnp.where(member_ok & in_tile, ranks, -1)     # (C, k)
        cand = jnp.where(
            (member_ok & in_tile)[:, :, None], g.nbr_t[ranks], -1
        )                                                    # (C, k, D)
    else:
        safe = jnp.maximum(members, 0)
        cand = jnp.where(member_ok[:, :, None], g.nbr[safe], -1)  # (C, k, D)
    slot_ok = cand >= 0

    # not already a member of the embedding
    is_member = (cand[:, :, :, None] == members[:, None, None, :]).any(-1)

    # first-occurrence dedup: drop if an *earlier* member is adjacent to cand.
    if tiled:
        adj_em = bitset.test_bit(
            g.adj_t, mrow[:, :, None, None], cand[:, None, :, :]
        )
    else:
        adj_em = g.is_edge(members[:, :, None, None], cand[:, None, :, :])
    adj_em = adj_em & member_ok[:, :, None, None]           # (C, k_m, k_i, D)
    earlier = (
        jnp.arange(k)[None, :, None, None] < jnp.arange(k)[None, None, :, None]
    )
    seen_earlier = (adj_em & earlier).any(axis=1)           # (C, k_i, D)

    valid = slot_ok & ~is_member & ~seen_earlier

    flat_cand = cand.reshape(c * k * d)
    flat_rows = jnp.repeat(jnp.arange(c, dtype=jnp.int32), k * d)
    flat_valid = valid.reshape(c * k * d)

    if tiled:
        canon = cc_ops.canonical_check_tiles(
            members[flat_rows], mrow[flat_rows], n_valid[flat_rows],
            flat_cand, g.adj_t,
            use_pallas=use_pallas, interpret=interpret,
        )
    elif use_pallas:
        canon = cc_ops.canonical_check(
            g, members[flat_rows], n_valid[flat_rows], flat_cand,
            mode="vertex", interpret=interpret,
        )
    else:
        canon = canonical.vertex_check(
            g, members[flat_rows], n_valid[flat_rows], flat_cand
        )
    keep = flat_valid & canon
    return Expansion(
        rows=flat_rows,
        cand=flat_cand,
        keep=keep,
        n_generated=flat_valid.sum().astype(jnp.int32),
        n_canonical=keep.sum().astype(jnp.int32),
    )


def _expand_vertex_fused(g, members, n_valid, interpret=None) -> Expansion:
    """Vertex expansion through the fused ``expand_canonical`` kernel:
    validity + dedup + Alg.-2 in one VMEM pass, flattened to the same
    Expansion contract as the jnp path."""
    c, k = members.shape
    d = g.max_degree
    cand, valid, keep = cc_ops.expand_canonical(
        g, members, n_valid, interpret=interpret
    )
    return Expansion(
        rows=jnp.repeat(jnp.arange(c, dtype=jnp.int32), k * d),
        cand=cand.reshape(c * k * d),
        keep=keep.reshape(c * k * d),
        n_generated=valid.sum().astype(jnp.int32),
        n_canonical=keep.sum().astype(jnp.int32),
    )


def expand_edge(
    g: DeviceGraph,
    members: jnp.ndarray,   # (C, k) int32 edge ids, pad -1
    n_valid: jnp.ndarray,   # (C,) int32
    *,
    use_pallas: bool = False,
    interpret=None,
) -> Expansion:
    """Candidates for edge-induced exploration.

    Endpoint slots: member i contributes endpoints (2i, 2i+1). A candidate
    edge is drawn from the incident-edge list of an endpoint vertex; it is
    kept only at its first producing slot: dropped if an earlier endpoint
    slot holds the same vertex (whole incident list already enumerated) or
    the candidate's other endpoint (edge enumerated from the other side).
    """
    c, k = members.shape
    d = g.max_degree
    k2 = 2 * k
    safe = jnp.maximum(members, 0)
    pos = jnp.arange(k)[None, :]
    member_ok = pos < n_valid[:, None]                       # (C, k)

    verts = g.edge_uv[safe].reshape(c, k2)                   # (C, 2k)
    vert_ok = jnp.repeat(member_ok, 2, axis=1)               # (C, 2k)
    verts = jnp.where(vert_ok, verts, -1)

    if isinstance(g, TileView):
        # partitioned path: member-edge endpoints are the halo, so their
        # incident-edge / neighbour rows come from the gathered tiles
        ranks, in_tile = g.rank(verts)
        ok3 = (vert_ok & in_tile)[:, :, None]
        cand = jnp.where(ok3, g.nbr_eid_t[ranks], -1)        # (C, 2k, D)
        other = jnp.where(ok3, g.nbr_t[ranks], -1)           # (C, 2k, D)
    else:
        safe_v = jnp.maximum(verts, 0)
        cand = jnp.where(vert_ok[:, :, None], g.nbr_eid[safe_v], -1)
        other = jnp.where(vert_ok[:, :, None], g.nbr[safe_v], -1)
    slot_ok = cand >= 0

    is_member = (cand[:, :, :, None] == members[:, None, None, :]).any(-1)

    slot_idx = jnp.arange(k2)
    earlier = slot_idx[None, :, None, None] < slot_idx[None, None, :, None]
    same_vertex = verts[:, :, None, None] == verts[:, None, :, None]
    hits_other = verts[:, :, None, None] == other[:, None, :, :]
    dup = (same_vertex & earlier).any(axis=1) | (hits_other & earlier).any(axis=1)

    valid = slot_ok & ~is_member & ~dup

    flat_cand = cand.reshape(c * k2 * d)
    flat_rows = jnp.repeat(jnp.arange(c, dtype=jnp.int32), k2 * d)
    flat_valid = valid.reshape(c * k2 * d)

    if use_pallas:
        # routed through the kernel dispatch even though edge mode currently
        # always resolves to the jnp check (see ops.py dispatch rules).
        canon = cc_ops.canonical_check(
            g, members[flat_rows], n_valid[flat_rows], flat_cand,
            mode="edge", interpret=interpret,
        )
    else:
        canon = canonical.edge_check(
            g, members[flat_rows], n_valid[flat_rows], flat_cand
        )
    keep = flat_valid & canon
    return Expansion(
        rows=flat_rows,
        cand=flat_cand,
        keep=keep,
        n_generated=flat_valid.sum().astype(jnp.int32),
        n_canonical=keep.sum().astype(jnp.int32),
    )


def compact(
    members: jnp.ndarray,   # (C, k) parents of the chunk
    exp: Expansion,
    keep: jnp.ndarray,      # (Ncand,) final keep mask (after app filter)
    out_cap: int,
    *,
    use_kernel: bool = False,
    interpret=None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather kept candidates into a dense (out_cap, k+1) child frontier.

    Returns (children, count). ``count`` may exceed ``out_cap``: the caller
    must then retry with a larger capacity (bucketed recompilation).
    ``use_kernel`` routes the keep-mask compaction through the Pallas
    stream-compaction kernel (``kernels/compact.py``, DESIGN.md §8)
    instead of the jnp nonzero gather; both honour the same contract.
    Capacities whose index window exceeds the kernel's VMEM limit fall
    back to the jnp gather (same rule as the canonical-check bitmap).
    """
    c, k = members.shape
    if use_kernel and compact_kernel_lib.fits_vmem(out_cap):
        idx, count = compact_kernel_lib.stream_compact_pallas(
            keep, out_cap, interpret=interpret
        )
    else:
        idx, count = compact_kernel_lib.stream_compact_ref(keep, out_cap)
    rows = exp.rows[idx]
    cand = exp.cand[idx]
    children = jnp.concatenate([members[rows], cand[:, None]], axis=1)
    slot_valid = jnp.arange(out_cap) < count
    children = jnp.where(slot_valid[:, None], children, -1)
    return children, count


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "out_cap", "use_pallas", "fused", "interpret", "compact_kernel"
    ),
)
def expand_and_compact(
    g: DeviceGraph,
    members: jnp.ndarray,
    n_valid: jnp.ndarray,
    mode: str,
    out_cap: int,
    use_pallas: bool = False,
    fused: bool = False,
    interpret=None,
    compact_kernel: bool = False,
):
    """Fused expand + canonicality + compaction (no app filter) — used by
    benchmarks and the distributed runtime where the app filter is fused in
    separately."""
    if isinstance(g, PartitionedGraph):
        g = build_tile_view(
            g, members, n_valid, mode,
            use_pallas=use_pallas, compact_kernel=compact_kernel,
            interpret=interpret,
        )
    if mode == "vertex":
        exp = expand_vertex(
            g, members, n_valid,
            use_pallas=use_pallas, fused=fused, interpret=interpret,
        )
    else:
        exp = expand_edge(
            g, members, n_valid, use_pallas=use_pallas, interpret=interpret
        )
    children, count = compact(
        members, exp, exp.keep, out_cap,
        use_kernel=compact_kernel, interpret=interpret,
    )
    return children, count, exp.n_generated, exp.n_canonical


def fused_chunk_step(
    g: DeviceGraph,
    members: jnp.ndarray,   # (C, k) int32 frontier chunk, pad -1
    n_valid: jnp.ndarray,   # (C,) int32
    out_cap: int,
    *,
    mode: str,
    app=None,
    with_patterns: bool = False,
    with_aggregates: bool = False,
    agg_qcap: int = 4096,
    with_local_verts: bool = True,
    use_pallas: bool = False,
    fused: bool = False,
    compact_kernel: bool = False,
    aggregate_kernel: bool = False,
    aggregate_bin: str = "sort",
    interpret=None,
):
    """ONE device pass of the fused superstep pipeline (DESIGN.md §8):
    expansion + canonicality + the app's phi filter + stream compaction +
    (optionally) the children's quick-pattern codes.

    Returns ``(children, count, codes, local_verts, n_generated,
    n_canonical)``. ``count`` is the unclamped kept total (host overflow
    decisions need no recomputation); with ``with_patterns`` the codes/
    local-vertex tables are ``(out_cap, 3)`` / ``(out_cap, 8)`` aligned
    with ``children`` (pad slots inert), else both are 0-row placeholders.

    ``with_aggregates`` (DESIGN.md §10, mutually exclusive with
    ``with_patterns``) additionally bins the children's quick codes into a
    per-chunk level-1 PARTIAL in the same pass and returns the 7-tuple
    ``(children, count, uniq (acap, 3), ucounts (acap,) int32, n_uniq,
    n_generated, n_canonical)`` where ``acap = min(out_cap, agg_qcap)`` —
    the raw code array never leaves the program; the engine folds the
    partials across the stacked-drain window
    (``aggregation.DeviceLevel1``). Bounding the partial at ``agg_qcap``
    keeps the cross-chunk merges O(Q)-sized instead of O(children);
    ``n_uniq`` is unclamped, so a chunk whose distinct count overflows
    ``acap`` is detected at the fold (device-side flag, no extra sync) and
    the engine re-bins from the frontier waves instead.

    Shared by the serial engine's jitted chunk program and the distributed
    worker body under ``shard_map`` — the same program in both runtimes.

    With a :class:`PartitionedGraph` the pass opens with the tile-gather
    stage (DESIGN.md §11): the chunk's halo tiles are gathered once
    (``build_tile_view``) and every downstream consumer — expansion,
    canonicality, the app filter, the children's quick patterns — runs on
    the :class:`TileView`. The tile capacity is a static function of the
    chunk shape, so the output contract (and the engines' drain protocol)
    is unchanged. A pre-built ``TileView`` is also accepted (the shard-map
    worker builds its own view with collectives)."""
    if isinstance(g, PartitionedGraph):
        g = build_tile_view(
            g, members, n_valid, mode,
            use_pallas=use_pallas, compact_kernel=compact_kernel,
            interpret=interpret,
        )
    if mode == "vertex":
        exp = expand_vertex(
            g, members, n_valid,
            use_pallas=use_pallas, fused=fused, interpret=interpret,
        )
    else:
        exp = expand_edge(
            g, members, n_valid, use_pallas=use_pallas, interpret=interpret
        )
    keep = exp.keep
    if app is not None:
        keep = keep & app.filter(g, members, n_valid, exp.rows, exp.cand)
    children, count = compact(
        members, exp, keep, out_cap,
        use_kernel=compact_kernel, interpret=interpret,
    )
    if with_patterns or with_aggregates:
        child_k = members.shape[1] + 1
        child_nv = jnp.where(
            jnp.arange(out_cap) < count, child_k, 0
        ).astype(jnp.int32)
        qp = (
            pattern_lib.quick_pattern_vertex(g, children, child_nv)
            if mode == "vertex"
            else pattern_lib.quick_pattern_edge(g, children, child_nv)
        )
        if with_aggregates:
            uniq, ucounts, _, n_uniq, _ = aggregate_kernel_lib.bin_rows(
                qp.codes, child_nv > 0, min(out_cap, agg_qcap),
                use_kernel=aggregate_kernel, interpret=interpret,
                method=aggregate_bin,
            )
            # the partial crosses chunks as int32: SATURATE at the I32_SAT
            # sentinel instead of wrapping — fold_partial detects the
            # sentinel and the step re-folds wide (DESIGN.md §13)
            ucounts32 = jnp.minimum(
                ucounts, jnp.int64(aggregate_kernel_lib.I32_SAT)
            ).astype(jnp.int32)
            return (children, count, uniq, ucounts32,
                    n_uniq, exp.n_generated, exp.n_canonical)
        codes = qp.codes
        # only FSM's min-image domains read the local-vertex table; when
        # unused, dropping it from the outputs lets XLA DCE its scatter
        local_verts = (
            qp.local_verts
            if with_local_verts
            else jnp.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), jnp.int32)
        )
    else:
        codes = jnp.zeros((0, 3), jnp.int64)
        local_verts = jnp.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), jnp.int32)
    return children, count, codes, local_verts, exp.n_generated, exp.n_canonical
