"""Exact brute-force oracles (host, networkx) for validating the engine.

These enumerate *all* connected vertex- or edge-induced embeddings by
recursive expansion with set-dedup (no canonicality tricks), then compute
pattern counts and min-image supports independently of every device code
path. Only usable on tiny graphs; that is their job.
"""
from __future__ import annotations

import itertools
from collections import defaultdict

import numpy as np

from repro.core.graph import Graph
from repro.core import pattern as pattern_lib


def _adj_sets(g: Graph):
    adj = [set() for _ in range(g.n)]
    for u, v in g.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    return adj


def enumerate_vertex_embeddings(g: Graph, max_size: int) -> dict[int, set]:
    """All connected vertex sets of size 1..max_size, as frozensets."""
    adj = _adj_sets(g)
    levels: dict[int, set] = {1: {frozenset([v]) for v in range(g.n)}}
    for k in range(2, max_size + 1):
        nxt = set()
        for emb in levels[k - 1]:
            border = set().union(*(adj[v] for v in emb)) - set(emb)
            for v in border:
                nxt.add(emb | {v})
        levels[k] = nxt
    return levels


def enumerate_edge_embeddings(g: Graph, max_size: int) -> dict[int, set]:
    """All connected edge-id sets of size 1..max_size."""
    incident = [set() for _ in range(g.n)]
    for eid, (u, v) in enumerate(g.edges):
        incident[int(u)].add(eid)
        incident[int(v)].add(eid)
    levels: dict[int, set] = {1: {frozenset([e]) for e in range(g.m)}}
    for k in range(2, max_size + 1):
        nxt = set()
        for emb in levels[k - 1]:
            verts = set()
            for e in emb:
                verts.update(g.edges[e])
            border = set().union(*(incident[v] for v in verts)) - set(emb)
            for e in border:
                nxt.add(emb | {e})
        levels[k] = nxt
    return levels


def _vertex_embedding_code(g: Graph, emb: frozenset):
    """Canonical pattern code of a vertex-induced embedding (host path,
    independent of the device quick-pattern code)."""
    vs = sorted(emb)
    nv = len(vs)
    idx = {v: i for i, v in enumerate(vs)}
    adj = np.zeros((nv, nv), dtype=bool)
    es = set(map(tuple, np.sort(g.edges, axis=1).tolist()))
    for a, b in itertools.combinations(vs, 2):
        if (a, b) in es or (b, a) in es:
            adj[idx[a], idx[b]] = adj[idx[b], idx[a]] = True
    labels = g.labels[vs]
    quick = pattern_lib.encode(nv, adj, labels)
    code, _ = pattern_lib.canonicalize_one(quick)
    return code


def _edge_embedding_code_and_vertmaps(g: Graph, emb: frozenset):
    """Canonical code + *all* {canonical position -> graph vertex} maps of an
    edge-induced embedding (one per isomorphism pattern->embedding; the
    paper's domain definition ranges over all of them)."""
    eids = sorted(emb)
    vs = sorted({int(x) for e in eids for x in g.edges[e]})
    nv = len(vs)
    idx = {v: i for i, v in enumerate(vs)}
    adj = np.zeros((nv, nv), dtype=bool)
    for e in eids:
        u, v = (int(x) for x in g.edges[e])
        adj[idx[u], idx[v]] = adj[idx[v], idx[u]] = True
    labels = g.labels[vs]
    quick = pattern_lib.encode(nv, adj, labels)
    code, _ = pattern_lib.canonicalize_one(quick)
    # every permutation achieving the canonical code is an isomorphism
    maps = []
    for perm in itertools.permutations(range(nv)):
        perm = np.array(perm)
        padj = adj[np.ix_(perm, perm)]
        plab = labels[perm]
        if pattern_lib.encode(nv, padj, plab) == code:
            # canonical position i corresponds to local vertex perm[i]
            maps.append({i: vs[perm[i]] for i in range(nv)})
    return code, maps


def motif_counts(g: Graph, max_size: int) -> dict[tuple, int]:
    """Pattern -> #vertex-induced embeddings, sizes 1..max_size."""
    counts: dict[tuple, int] = defaultdict(int)
    levels = enumerate_vertex_embeddings(g, max_size)
    for k in range(1, max_size + 1):
        for emb in levels[k]:
            counts[_vertex_embedding_code(g, emb)] += 1
    return dict(counts)


def clique_counts(g: Graph, max_size: int) -> dict[int, int]:
    """size -> #cliques (vertex-induced complete subgraphs)."""
    adj = _adj_sets(g)
    levels = enumerate_vertex_embeddings(g, max_size)
    out = {}
    for k in range(1, max_size + 1):
        cnt = 0
        for emb in levels[k]:
            if all(b in adj[a] for a, b in itertools.combinations(emb, 2)):
                cnt += 1
        out[k] = cnt
    return out


def fsm_supports(g: Graph, max_size: int, support: int) -> dict[tuple, int]:
    """Frequent edge-induced patterns with min-image supports, honouring
    anti-monotonic level-wise pruning exactly as the engine does (embeddings
    of infrequent patterns are not expanded)."""
    incident = [set() for _ in range(g.n)]
    for eid, (u, v) in enumerate(g.edges):
        incident[int(u)].add(eid)
        incident[int(v)].add(eid)

    frequent: dict[tuple, int] = {}
    frontier = {frozenset([e]) for e in range(g.m)}
    for k in range(1, max_size + 1):
        if not frontier:
            break
        domains: dict[tuple, dict[int, set]] = defaultdict(lambda: defaultdict(set))
        by_pattern: dict[tuple, list] = defaultdict(list)
        for emb in frontier:
            code, vmaps = _edge_embedding_code_and_vertmaps(g, emb)
            by_pattern[code].append(emb)
            for vmap in vmaps:
                for pos, vert in vmap.items():
                    domains[code][pos].add(vert)
        survivors = set()
        for code, embs in by_pattern.items():
            sup = min(len(s) for s in domains[code].values())
            if sup >= support:
                frequent[code] = sup
                survivors.update(embs)
        nxt = set()
        if k < max_size:
            for emb in survivors:
                verts = set()
                for e in emb:
                    verts.update(int(x) for x in g.edges[e])
                border = set().union(*(incident[v] for v in verts)) - set(emb)
                for e in border:
                    nxt.add(emb | {e})
        frontier = nxt
    return frequent
