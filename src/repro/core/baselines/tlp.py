"""Think-Like-a-Pattern baseline (paper §3.2, Fig. 7; GRAMI-style).

Pattern-centric FSM: state is kept per *pattern*; embeddings are re-computed
on the fly by subgraph-isomorphism search instead of being materialised.
Parallelism = partitioning patterns over workers, which is exactly what the
paper shows cannot scale: there are few frequent patterns and their
embedding counts are highly skewed. We report the per-worker load imbalance
that caps TLP speedup, plus wall time.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import pattern as pattern_lib
from repro.core.baselines import bruteforce as bf
from repro.core.graph import Graph


@dataclasses.dataclass
class TLPReport:
    n_patterns: int
    pattern_work: dict          # canonical code -> #embeddings visited
    wall_time: float

    def speedup_bound(self, n_workers: int) -> float:
        """Best-case speedup with patterns partitioned over workers (LPT
        bound): total work / max worker work."""
        works = sorted(self.pattern_work.values(), reverse=True)
        if not works:
            return 1.0
        workers = [0] * n_workers
        for w in works:
            workers[int(np.argmin(workers))] += w
        total = sum(works)
        return total / max(max(workers), 1)


def run_tlp_fsm(g: Graph, support: int, max_size: int) -> TLPReport:
    """Level-wise pattern-centric FSM: per pattern, embeddings are recomputed
    (we reuse the oracle enumerator as the isomorphism search) and work is
    attributed to the pattern's worker."""
    t0 = time.perf_counter()
    levels = bf.enumerate_edge_embeddings(g, max_size)
    work: dict[tuple, int] = {}
    for k in range(1, max_size + 1):
        for emb in levels[k]:
            code, _ = _code_of(g, emb)
            work[code] = work.get(code, 0) + 1
    # keep only frequent ones at each level (the others are pruned, but TLP
    # still *visited* their embeddings to count them — work stays attributed)
    freq = bf.fsm_supports(g, max_size, support)
    return TLPReport(
        n_patterns=len(freq),
        pattern_work={c: w for c, w in work.items()},
        wall_time=time.perf_counter() - t0,
    )


def _code_of(g: Graph, emb):
    eids = sorted(emb)
    vs = sorted({int(x) for e in eids for x in g.edges[e]})
    nv = len(vs)
    idx = {v: i for i, v in enumerate(vs)}
    adj = np.zeros((nv, nv), dtype=bool)
    for e in eids:
        u, v = (int(x) for x in g.edges[e])
        adj[idx[u], idx[v]] = adj[idx[v], idx[u]] = True
    labels = g.labels[vs]
    quick = pattern_lib.encode(nv, adj, labels)
    return pattern_lib.canonicalize_one(quick)
