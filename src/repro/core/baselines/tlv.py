"""Think-Like-a-Vertex baseline (paper §3.2, Fig. 7).

Faithful cost model of TLV embedding exploration on a Pregel-style system:
each vertex holds local embeddings; to expand, an embedding is *sent* to
every border vertex (a message per border vertex), which extends it with its
own neighbours. We reuse the same canonicality pruning as Arabesque (the
paper's TLV implementation did too), so the comparison isolates the
paradigm's communication/imbalance cost, not algorithmic differences.

This is a host simulation that reports the metrics Fig. 7 is about:
messages exchanged, per-vertex load imbalance, wall time.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class TLVReport:
    n_messages: int
    n_embeddings: int
    max_vertex_load: int
    mean_vertex_load: float
    wall_time: float


def _canonical_extend_ok(adj, emb, v):
    """Alg. 2 on host (same pruning as the engine)."""
    if v in emb:
        return False
    if emb[0] > v:
        return False
    found = False
    for u in emb:
        if not found and v in adj[u]:
            found = True
        elif found and u > v:
            return False
    return found


def run_tlv(g: Graph, max_size: int) -> TLVReport:
    t0 = time.perf_counter()
    adj = [set() for _ in range(g.n)]
    for u, v in g.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))

    n_messages = 0
    n_embeddings = g.n
    load = np.zeros(g.n, dtype=np.int64)

    # inbox[v] = embeddings v must try to expand with its own neighbours
    inbox = defaultdict(list)
    for v in range(g.n):
        inbox[v].append((v,))
        load[v] += 1

    for _size in range(1, max_size):
        outbox = defaultdict(list)
        level = set()
        for v, embs in inbox.items():
            for emb in embs:
                # v extends emb with each of its neighbours
                for w in adj[v]:
                    if _canonical_extend_ok(adj, emb, w):
                        child = emb + (w,)
                        level.add(child)
                        # child must be sent to all its border vertices
                        for b in child:
                            outbox[b].append(child)
                            n_messages += 1
                            load[b] += 1
        n_embeddings += len(level)
        # dedup per vertex: the same child reaches a border vertex once per
        # producer; a real TLV system pays the messages, then dedups.
        inbox = {
            v: list({e: None for e in embs}.keys()) for v, embs in outbox.items()
        }

    return TLVReport(
        n_messages=n_messages,
        n_embeddings=n_embeddings,
        max_vertex_load=int(load.max()),
        mean_vertex_load=float(load.mean()),
        wall_time=time.perf_counter() - t0,
    )
