"""Quick patterns and canonical patterns (paper §5.4, two-level aggregation).

Level 1 (device, per embedding, linear time): a *quick pattern* is the
order-dependent encoding of an embedding's structure — local vertex labels in
visit order plus the adjacency bits among local positions. Automorphic *and*
isomorphic embeddings may map to different quick patterns, but the number of
distinct quick patterns is orders of magnitude smaller than the number of
embeddings (paper Table 4).

Level 2 (once per distinct quick pattern): canonicalisation — the minimum
encoding over all vertex-position permutations. This replaces the paper's
use of the ``bliss`` canonical-labeling library; pattern orders are small
(k ≤ 8) so brute-force minimisation over k! permutations is exact and cheap
*because* it only runs on quick patterns, never on embeddings — the paper's
entire argument for the two-level scheme.

This module is the *host memo/decode layer*: the pure canonical math lives
in :mod:`repro.core.canon_math` (shared with the batched device kernel
``kernels/canonical_refine.py``), and every name is re-exported here for
back-compat. The process-wide quick→canonical memo is thread-safe (the
``host_async`` placement canonicalises on a background thread) and bounded
(LRU cap, ``RunConfig.canonical_memo_cap`` / :func:`set_memo_cap` —
unbounded growth was a real leak on labeled graphs: mico has 37k distinct
size-3 quick patterns *per scale step*).

Encoding (3 × int64 per pattern):
  w0 = n_vertices | adj_bits << 4     (pair (a<b) -> bit b*(b-1)/2 + a)
  w1 = labels[0..3], 8 bits each      (labels must be < 256)
  w2 = labels[4..7], 8 bits each
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import DeviceGraph
from repro.core.canon_math import (  # noqa: F401  (re-exported, back-compat)
    MAX_PATTERN_VERTICES,
    _canonicalize_batch,
    _decode_batch,
    _encode_batch,
    _lex_less,
    _pair_bit,
    _perms,
    automorphism_orbits,
    canonicalize_one,
    decode,
    encode,
    n_pair_bits,
    perm_tables,
)


class QuickPatterns(NamedTuple):
    codes: jnp.ndarray        # (B, 3) int64 quick-pattern code per embedding
    local_verts: jnp.ndarray  # (B, 8) int32 graph vertex at local position, pad -1
    n_verts: jnp.ndarray      # (B,) int32


def quick_pattern_vertex(
    g: DeviceGraph, members: jnp.ndarray, n_valid: jnp.ndarray
) -> QuickPatterns:
    """Quick patterns of vertex-induced embeddings: local positions are the
    members in visit order; adjacency = all graph edges among members."""
    b, k = members.shape
    pos = jnp.arange(k)
    valid = pos[None, :] < n_valid[:, None]
    mem = jnp.where(valid, members, -1)

    adj = g.is_edge(mem[:, :, None], mem[:, None, :])            # (B, k, k)
    bits = jnp.zeros((b,), dtype=jnp.int64)
    for a in range(k):
        for c in range(a + 1, k):
            bits = bits | (adj[:, a, c].astype(jnp.int64) << _pair_bit(a, c))

    labels = jnp.where(valid, g.labels[jnp.maximum(mem, 0)], 0)  # (B, k)
    w1 = jnp.zeros((b,), dtype=jnp.int64)
    w2 = jnp.zeros((b,), dtype=jnp.int64)
    for i in range(min(k, 4)):
        w1 = w1 | (labels[:, i].astype(jnp.int64) << (8 * i))
    for i in range(4, min(k, 8)):
        w2 = w2 | (labels[:, i].astype(jnp.int64) << (8 * (i - 4)))

    w0 = n_valid.astype(jnp.int64) | (bits << 4)
    codes = jnp.stack([w0, w1, w2], axis=1)
    lv = jnp.full((b, MAX_PATTERN_VERTICES), -1, dtype=jnp.int32)
    lv = lv.at[:, :k].set(jnp.where(valid, mem, -1))
    return QuickPatterns(codes=codes, local_verts=lv, n_verts=n_valid)


def quick_pattern_edge(
    g: DeviceGraph, members: jnp.ndarray, n_valid: jnp.ndarray
) -> QuickPatterns:
    """Quick patterns of edge-induced embeddings.

    Local vertices = endpoint sequence deduplicated in first-appearance
    order; adjacency bits cover *member edges only* (edge-induced semantics:
    non-member graph edges between the same vertices are excluded).
    """
    b, k = members.shape
    k2 = 2 * k
    pos = jnp.arange(k)
    valid_e = pos[None, :] < n_valid[:, None]                    # (B, k)
    safe = jnp.maximum(members, 0)
    verts = g.edge_uv[safe].reshape(b, k2)                       # (B, 2k)
    vert_ok = jnp.repeat(valid_e, 2, axis=1)
    verts = jnp.where(vert_ok, verts, -1)

    # first-appearance local ids
    t = jnp.arange(k2)
    same = (verts[:, :, None] == verts[:, None, :]) & vert_ok[:, :, None] & vert_ok[:, None, :]
    first_idx = jnp.argmax(same, axis=1)                         # (B, 2k): min t' with equal vertex
    is_first = (first_idx == t[None, :]) & vert_ok
    rank = jnp.cumsum(is_first, axis=1) - 1                      # local id at first slots
    local_id = jnp.take_along_axis(rank, first_idx, axis=1)      # (B, 2k)
    local_id = jnp.where(vert_ok, local_id, -1)
    n_verts = is_first.sum(axis=1).astype(jnp.int32)

    # local vertex table: scatter first-appearance vertices to their rank
    lv = jnp.full((b, MAX_PATTERN_VERTICES), -1, dtype=jnp.int32)
    scatter_pos = jnp.where(is_first, rank, MAX_PATTERN_VERTICES)  # dump slot 8
    lv_ext = jnp.full((b, MAX_PATTERN_VERTICES + 1), -1, dtype=jnp.int32)
    lv = lv_ext.at[jnp.arange(b)[:, None], scatter_pos].set(
        jnp.where(is_first, verts, -1)
    )[:, :MAX_PATTERN_VERTICES]

    # adjacency bits from member edges
    a_id = local_id[:, 0::2]                                     # (B, k)
    b_id = local_id[:, 1::2]
    lo = jnp.minimum(a_id, b_id)
    hi = jnp.maximum(a_id, b_id)
    bit = jnp.where(valid_e, (hi * (hi - 1)) // 2 + lo, 0)
    bits = jnp.zeros((b,), dtype=jnp.int64)
    for j in range(k):
        contrib = jnp.where(valid_e[:, j], jnp.int64(1) << bit[:, j].astype(jnp.int64), 0)
        bits = bits | contrib

    labels = jnp.where(lv >= 0, g.labels[jnp.maximum(lv, 0)], 0)  # (B, 8)
    w1 = jnp.zeros((b,), dtype=jnp.int64)
    w2 = jnp.zeros((b,), dtype=jnp.int64)
    for i in range(4):
        w1 = w1 | (labels[:, i].astype(jnp.int64) << (8 * i))
        w2 = w2 | (labels[:, i + 4].astype(jnp.int64) << (8 * i))
    w0 = n_verts.astype(jnp.int64) | (bits << 4)
    return QuickPatterns(
        codes=jnp.stack([w0, w1, w2], axis=1), local_verts=lv, n_verts=n_verts
    )


# ---------------------------------------------------------------------------
# Process-wide quick -> canonical memo (thread-safe, bounded LRU)
# ---------------------------------------------------------------------------

#: default LRU cap: generous (a million distinct patterns ≈ 50 MB of memo)
#: but finite — labeled-graph workloads otherwise grow the memo without
#: bound for the lifetime of the process.
DEFAULT_MEMO_CAP = 1 << 20

_MEMO_LOCK = threading.Lock()
#: quick code-row bytes -> (canon (3,) int64, sigma (8,) int32). Quick
#: patterns recur across supersteps and runs (the paper's engine accumulates
#: exactly this map), so level 2 pays the permutation search once per
#: distinct pattern per process, not per step.
_CANON_CACHE: "OrderedDict[bytes, tuple]" = OrderedDict()
#: canonical code tuple -> orbit representatives (8,) int32 (FSM domains).
_ORBIT_CACHE: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
_MEMO_CAP = DEFAULT_MEMO_CAP


def set_memo_cap(cap: Optional[int]) -> int:
    """Set the LRU cap of the canonical/orbit memos; returns the old cap.

    ``None`` restores :data:`DEFAULT_MEMO_CAP`. Shrinking evicts
    least-recently-used entries immediately.
    """
    global _MEMO_CAP
    with _MEMO_LOCK:
        old = _MEMO_CAP
        _MEMO_CAP = DEFAULT_MEMO_CAP if cap is None else max(1, int(cap))
        while len(_CANON_CACHE) > _MEMO_CAP:
            _CANON_CACHE.popitem(last=False)
        while len(_ORBIT_CACHE) > _MEMO_CAP:
            _ORBIT_CACHE.popitem(last=False)
    return old


def clear_memo() -> None:
    """Drop every memoised canonicalisation (benchmarks: cold timing)."""
    with _MEMO_LOCK:
        _CANON_CACHE.clear()
        _ORBIT_CACHE.clear()


def memo_sizes() -> tuple[int, int]:
    """(canon entries, orbit entries) currently memoised."""
    with _MEMO_LOCK:
        return len(_CANON_CACHE), len(_ORBIT_CACHE)


def _memo_get_canon(keys: list) -> dict:
    """Snapshot memo hits for ``keys`` (marks them recently used)."""
    out = {}
    with _MEMO_LOCK:
        for k in keys:
            got = _CANON_CACHE.get(k)
            if got is not None:
                _CANON_CACHE.move_to_end(k)
                out[k] = got
    return out


def _memo_put_canon(items) -> None:
    with _MEMO_LOCK:
        for k, v in items:
            _CANON_CACHE[k] = v
            _CANON_CACHE.move_to_end(k)
        while len(_CANON_CACHE) > _MEMO_CAP:
            _CANON_CACHE.popitem(last=False)


class PatternTable(NamedTuple):
    """Mapping of the step's unique quick patterns to canonical patterns."""

    quick_codes: np.ndarray      # (Q, 3) int64 unique quick codes
    canon_codes: np.ndarray      # (Pc, 3) int64 unique canonical codes
    quick_to_canon: np.ndarray   # (Q,) int32 canonical slot per quick slot
    sigma: np.ndarray            # (Q, 8) int32 local pos -> canonical pos
    canon_n_verts: np.ndarray    # (Pc,) int32
    canon_orbits: np.ndarray     # (Pc, 8) int32 orbit representative per pos
    n_iso_checks: int            # == Q: graph-isomorphism invocations (Table 4)


def build_pattern_table(
    unique_quick: np.ndarray,
    with_orbits: bool = True,
    canon_fn: Optional[Callable[[np.ndarray], tuple]] = None,
) -> PatternTable:
    """Level 2 for one step's distinct quick patterns, batched + memoised.

    Uncached codes are canonicalised in vectorised per-``n_verts`` batches
    (:func:`canon_math._canonicalize_batch`) and remembered process-wide, so
    the permutation search runs once per distinct pattern per process —
    across supersteps AND runs (the superstep pipeline's aggregation is
    host-bound exactly here, DESIGN.md §8). ``n_iso_checks`` stays the
    *conceptual* per-step invocation count (Table 4 semantics), not the
    cache-miss count.

    ``canon_fn`` (optional) replaces the host permutation search for the
    cache *misses*: it receives the (M, 3) int64 miss codes (mixed nv) and
    must return ``(canon (M, 3) int64, sigma (M, 8) int32)`` under the
    exact :func:`canonicalize_one` contract — the hook the device placement
    (``kernels/canonical_refine``) plugs into. Memoisation still applies.

    ``with_orbits=False`` skips the automorphism-orbit search (only FSM's
    min-image domains consume orbits) and returns identity representatives.
    """
    q = len(unique_quick)
    canon = np.zeros((q, 3), dtype=np.int64)
    sigma = np.zeros((q, MAX_PATTERN_VERTICES), dtype=np.int32)
    rows64 = np.ascontiguousarray(unique_quick, dtype=np.int64)
    keys = [row.tobytes() for row in rows64]
    # hits snapshotted into a local dict so concurrent eviction can never
    # drop an entry between the miss pass and the fill loop below.
    local = _memo_get_canon(keys)
    misses = [i for i, k in enumerate(keys) if k not in local]
    if misses:
        miss_codes = rows64[misses]
        if canon_fn is not None:
            ck, sg = canon_fn(miss_codes)
            fresh = [(keys[misses[j]], (ck[j], sg[j])) for j in range(len(misses))]
        else:
            fresh = []
            by_nv: dict[int, list] = {}
            for j, i in enumerate(misses):
                by_nv.setdefault(int(miss_codes[j, 0]) & 0xF, []).append(j)
            for nv, js in by_nv.items():
                ck, sg = _canonicalize_batch(miss_codes[js])
                for row, j in enumerate(js):
                    fresh.append((keys[misses[j]], (ck[row], sg[row])))
        local.update(fresh)
        _memo_put_canon(fresh)
    for i, k in enumerate(keys):
        canon[i], sigma[i] = local[k]
    uniq_canon, inv = np.unique(canon.reshape(q, 3), axis=0, return_inverse=True)
    if with_orbits and len(uniq_canon):
        orbits = np.stack([_orbits_cached(c) for c in uniq_canon], axis=0)
    else:
        orbits = np.tile(
            np.arange(MAX_PATTERN_VERTICES, dtype=np.int32),
            (len(uniq_canon), 1),
        )
    return PatternTable(
        quick_codes=unique_quick,
        canon_codes=uniq_canon,
        quick_to_canon=inv.astype(np.int32),
        sigma=sigma,
        canon_n_verts=(uniq_canon[:, 0] & 0xF).astype(np.int32),
        canon_orbits=orbits,
        n_iso_checks=q,
    )


def _orbits_cached(code: np.ndarray) -> np.ndarray:
    key = tuple(int(x) for x in code)
    with _MEMO_LOCK:
        got = _ORBIT_CACHE.get(key)
        if got is not None:
            _ORBIT_CACHE.move_to_end(key)
            return got
    got = automorphism_orbits(code)
    with _MEMO_LOCK:
        _ORBIT_CACHE[key] = got
        while len(_ORBIT_CACHE) > _MEMO_CAP:
            _ORBIT_CACHE.popitem(last=False)
    return got


def seed_memo(quick_codes: np.ndarray, canon: np.ndarray, sigma: np.ndarray,
              canon_codes: Optional[np.ndarray] = None,
              orbits: Optional[np.ndarray] = None) -> None:
    """Warm the memo with externally computed (device) canonicalisations so
    later host passes over the same patterns are cache hits."""
    rows64 = np.ascontiguousarray(quick_codes, dtype=np.int64)
    _memo_put_canon(
        (rows64[i].tobytes(), (canon[i], sigma[i])) for i in range(len(rows64))
    )
    if canon_codes is not None and orbits is not None:
        with _MEMO_LOCK:
            for i in range(len(canon_codes)):
                key = tuple(int(x) for x in canon_codes[i])
                _ORBIT_CACHE[key] = np.asarray(orbits[i], dtype=np.int32)
            while len(_ORBIT_CACHE) > _MEMO_CAP:
                _ORBIT_CACHE.popitem(last=False)


def pattern_to_networkx(code):
    import networkx as nx

    nv, adj, labels = decode(np.asarray(code))
    g = nx.Graph()
    for i in range(nv):
        g.add_node(i, label=int(labels[i]))
    for i in range(nv):
        for j in range(i + 1, nv):
            if adj[i, j]:
                g.add_edge(i, j)
    return g
