"""Quick patterns and canonical patterns (paper §5.4, two-level aggregation).

Level 1 (device, per embedding, linear time): a *quick pattern* is the
order-dependent encoding of an embedding's structure — local vertex labels in
visit order plus the adjacency bits among local positions. Automorphic *and*
isomorphic embeddings may map to different quick patterns, but the number of
distinct quick patterns is orders of magnitude smaller than the number of
embeddings (paper Table 4).

Level 2 (host, once per distinct quick pattern): canonicalisation — the
minimum encoding over all vertex-position permutations. This replaces the
paper's use of the ``bliss`` canonical-labeling library; pattern orders are
small (k ≤ 8) so brute-force minimisation over k! permutations is exact and
cheap *because* it only runs on quick patterns, never on embeddings — the
paper's entire argument for the two-level scheme.

Encoding (3 × int64 per pattern):
  w0 = n_vertices | adj_bits << 4     (pair (a<b) -> bit b*(b-1)/2 + a)
  w1 = labels[0..3], 8 bits each      (labels must be < 256)
  w2 = labels[4..7], 8 bits each
"""
from __future__ import annotations

import itertools
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import DeviceGraph

MAX_PATTERN_VERTICES = 8


def _pair_bit(a, b):
    """Bit index for unordered position pair (a < b)."""
    return (b * (b - 1)) // 2 + a


class QuickPatterns(NamedTuple):
    codes: jnp.ndarray        # (B, 3) int64 quick-pattern code per embedding
    local_verts: jnp.ndarray  # (B, 8) int32 graph vertex at local position, pad -1
    n_verts: jnp.ndarray      # (B,) int32


def quick_pattern_vertex(
    g: DeviceGraph, members: jnp.ndarray, n_valid: jnp.ndarray
) -> QuickPatterns:
    """Quick patterns of vertex-induced embeddings: local positions are the
    members in visit order; adjacency = all graph edges among members."""
    b, k = members.shape
    pos = jnp.arange(k)
    valid = pos[None, :] < n_valid[:, None]
    mem = jnp.where(valid, members, -1)

    adj = g.is_edge(mem[:, :, None], mem[:, None, :])            # (B, k, k)
    bits = jnp.zeros((b,), dtype=jnp.int64)
    for a in range(k):
        for c in range(a + 1, k):
            bits = bits | (adj[:, a, c].astype(jnp.int64) << _pair_bit(a, c))

    labels = jnp.where(valid, g.labels[jnp.maximum(mem, 0)], 0)  # (B, k)
    w1 = jnp.zeros((b,), dtype=jnp.int64)
    w2 = jnp.zeros((b,), dtype=jnp.int64)
    for i in range(min(k, 4)):
        w1 = w1 | (labels[:, i].astype(jnp.int64) << (8 * i))
    for i in range(4, min(k, 8)):
        w2 = w2 | (labels[:, i].astype(jnp.int64) << (8 * (i - 4)))

    w0 = n_valid.astype(jnp.int64) | (bits << 4)
    codes = jnp.stack([w0, w1, w2], axis=1)
    lv = jnp.full((b, MAX_PATTERN_VERTICES), -1, dtype=jnp.int32)
    lv = lv.at[:, :k].set(jnp.where(valid, mem, -1))
    return QuickPatterns(codes=codes, local_verts=lv, n_verts=n_valid)


def quick_pattern_edge(
    g: DeviceGraph, members: jnp.ndarray, n_valid: jnp.ndarray
) -> QuickPatterns:
    """Quick patterns of edge-induced embeddings.

    Local vertices = endpoint sequence deduplicated in first-appearance
    order; adjacency bits cover *member edges only* (edge-induced semantics:
    non-member graph edges between the same vertices are excluded).
    """
    b, k = members.shape
    k2 = 2 * k
    pos = jnp.arange(k)
    valid_e = pos[None, :] < n_valid[:, None]                    # (B, k)
    safe = jnp.maximum(members, 0)
    verts = g.edge_uv[safe].reshape(b, k2)                       # (B, 2k)
    vert_ok = jnp.repeat(valid_e, 2, axis=1)
    verts = jnp.where(vert_ok, verts, -1)

    # first-appearance local ids
    t = jnp.arange(k2)
    same = (verts[:, :, None] == verts[:, None, :]) & vert_ok[:, :, None] & vert_ok[:, None, :]
    first_idx = jnp.argmax(same, axis=1)                         # (B, 2k): min t' with equal vertex
    is_first = (first_idx == t[None, :]) & vert_ok
    rank = jnp.cumsum(is_first, axis=1) - 1                      # local id at first slots
    local_id = jnp.take_along_axis(rank, first_idx, axis=1)      # (B, 2k)
    local_id = jnp.where(vert_ok, local_id, -1)
    n_verts = is_first.sum(axis=1).astype(jnp.int32)

    # local vertex table: scatter first-appearance vertices to their rank
    lv = jnp.full((b, MAX_PATTERN_VERTICES), -1, dtype=jnp.int32)
    scatter_pos = jnp.where(is_first, rank, MAX_PATTERN_VERTICES)  # dump slot 8
    lv_ext = jnp.full((b, MAX_PATTERN_VERTICES + 1), -1, dtype=jnp.int32)
    lv = lv_ext.at[jnp.arange(b)[:, None], scatter_pos].set(
        jnp.where(is_first, verts, -1)
    )[:, :MAX_PATTERN_VERTICES]

    # adjacency bits from member edges
    a_id = local_id[:, 0::2]                                     # (B, k)
    b_id = local_id[:, 1::2]
    lo = jnp.minimum(a_id, b_id)
    hi = jnp.maximum(a_id, b_id)
    bit = jnp.where(valid_e, (hi * (hi - 1)) // 2 + lo, 0)
    bits = jnp.zeros((b,), dtype=jnp.int64)
    for j in range(k):
        contrib = jnp.where(valid_e[:, j], jnp.int64(1) << bit[:, j].astype(jnp.int64), 0)
        bits = bits | contrib

    labels = jnp.where(lv >= 0, g.labels[jnp.maximum(lv, 0)], 0)  # (B, 8)
    w1 = jnp.zeros((b,), dtype=jnp.int64)
    w2 = jnp.zeros((b,), dtype=jnp.int64)
    for i in range(4):
        w1 = w1 | (labels[:, i].astype(jnp.int64) << (8 * i))
        w2 = w2 | (labels[:, i + 4].astype(jnp.int64) << (8 * i))
    w0 = n_verts.astype(jnp.int64) | (bits << 4)
    return QuickPatterns(
        codes=jnp.stack([w0, w1, w2], axis=1), local_verts=lv, n_verts=n_verts
    )


# ---------------------------------------------------------------------------
# Host-side decode / canonicalisation (level 2)
# ---------------------------------------------------------------------------

def decode(code) -> tuple[int, np.ndarray, np.ndarray]:
    """(n_vertices, dense adjacency (nv, nv) bool, labels (nv,))."""
    w0, w1, w2 = (int(x) for x in code)
    nv = w0 & 0xF
    bits = w0 >> 4
    adj = np.zeros((nv, nv), dtype=bool)
    for bb in range(1, nv):
        for aa in range(bb):
            if (bits >> _pair_bit(aa, bb)) & 1:
                adj[aa, bb] = adj[bb, aa] = True
    labels = np.array([(w1 >> (8 * i)) & 0xFF for i in range(4)]
                      + [(w2 >> (8 * i)) & 0xFF for i in range(4)])[:nv]
    return nv, adj, labels.astype(np.int32)


def encode(nv: int, adj: np.ndarray, labels: np.ndarray) -> tuple[int, int, int]:
    bits = 0
    for bb in range(1, nv):
        for aa in range(bb):
            if adj[aa, bb]:
                bits |= 1 << _pair_bit(aa, bb)
    w0 = nv | (bits << 4)
    w1 = w2 = 0
    for i in range(min(nv, 4)):
        w1 |= int(labels[i]) << (8 * i)
    for i in range(4, min(nv, 8)):
        w2 |= int(labels[i]) << (8 * (i - 4))
    return w0, w1, w2


_PERMS_CACHE: dict[int, np.ndarray] = {}

#: process-wide quick->canonical memo: code-row bytes -> (canon (3,) int64,
#: sigma (8,) int32). Quick patterns recur across supersteps and runs (the
#: paper's engine accumulates exactly this map), so level 2 pays the
#: permutation search once per distinct pattern per process, not per step.
_CANON_CACHE: dict[bytes, tuple] = {}
#: canonical code -> orbit representatives (8,) int32 (FSM domains only).
_ORBIT_CACHE: dict[tuple, np.ndarray] = {}


def _perms(nv: int) -> np.ndarray:
    if nv not in _PERMS_CACHE:
        _PERMS_CACHE[nv] = np.array(list(itertools.permutations(range(nv))), np.int32)
    return _PERMS_CACHE[nv]


def _decode_batch(codes: np.ndarray, nv: int):
    """Vectorised :func:`decode` over (Q, 3) codes sharing ``n_verts``."""
    w0, w1, w2 = codes[:, 0], codes[:, 1], codes[:, 2]
    bits = w0 >> 4
    adj = np.zeros((len(codes), nv, nv), dtype=bool)
    for bb in range(1, nv):
        for aa in range(bb):
            on = ((bits >> _pair_bit(aa, bb)) & 1).astype(bool)
            adj[:, aa, bb] = adj[:, bb, aa] = on
    labels = np.zeros((len(codes), nv), dtype=np.int64)
    for i in range(min(nv, 4)):
        labels[:, i] = (w1 >> (8 * i)) & 0xFF
    for i in range(4, min(nv, 8)):
        labels[:, i] = (w2 >> (8 * (i - 4))) & 0xFF
    return adj, labels


def _encode_batch(adj: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Vectorised :func:`encode`: (Q, nv, nv) + (Q, nv) -> (Q, 3) int64."""
    q, nv = labels.shape
    bits = np.zeros(q, dtype=np.int64)
    for bb in range(1, nv):
        for aa in range(bb):
            bits |= adj[:, aa, bb].astype(np.int64) << _pair_bit(aa, bb)
    w0 = nv | (bits << 4)
    w1 = np.zeros(q, dtype=np.int64)
    w2 = np.zeros(q, dtype=np.int64)
    for i in range(min(nv, 4)):
        w1 |= labels[:, i] << (8 * i)
    for i in range(4, min(nv, 8)):
        w2 |= labels[:, i] << (8 * (i - 4))
    return np.stack([w0, w1, w2], axis=1)


def _lex_less(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-wise lexicographic a < b over (Q, 3) code triples."""
    return (
        (a[:, 0] < b[:, 0])
        | ((a[:, 0] == b[:, 0]) & (a[:, 1] < b[:, 1]))
        | ((a[:, 0] == b[:, 0]) & (a[:, 1] == b[:, 1]) & (a[:, 2] < b[:, 2]))
    )


def _canonicalize_batch(codes: np.ndarray):
    """Batched :func:`canonicalize_one` over (Q, 3) codes sharing
    ``n_verts``: one vectorised pass per permutation instead of a Python
    loop per pattern. Identical tie-breaking (first minimal permutation
    wins), hence bit-identical canon codes and sigmas."""
    q = len(codes)
    nv = int(codes[0, 0]) & 0xF
    sigma = np.tile(np.arange(MAX_PATTERN_VERTICES, dtype=np.int32), (q, 1))
    if nv <= 1:
        return codes.astype(np.int64, copy=True), sigma
    adj, labels = _decode_batch(codes, nv)
    perms = _perms(nv)
    best = None
    best_pi = np.zeros(q, dtype=np.int64)
    for pi, perm in enumerate(perms):
        key = _encode_batch(adj[:, perm][:, :, perm], labels[:, perm])
        if best is None:
            best = key
        else:
            better = _lex_less(key, best)
            best = np.where(better[:, None], key, best)
            best_pi = np.where(better, pi, best_pi)
    chosen = perms[best_pi]                       # (Q, nv): canon pos -> local
    rows = np.arange(q)[:, None]
    sigma[rows, chosen] = np.arange(nv, dtype=np.int32)[None, :]
    return best, sigma


def canonicalize_one(code) -> tuple[tuple[int, int, int], np.ndarray]:
    """Canonical code of one quick pattern + the permutation sigma with
    sigma[local_pos] = canonical_pos achieving it (graph-isomorphism
    canonical form; exact, replaces bliss)."""
    nv, adj, labels = decode(code)
    if nv <= 1:
        return encode(nv, adj, labels), np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    perms = _perms(nv)                        # (p!, nv): perm[i] = new position? see below
    best_key, best_sigma = None, None
    for perm in perms:
        # perm maps canonical position -> local position (a relabeling order)
        padj = adj[np.ix_(perm, perm)]
        plab = labels[perm]
        key = encode(nv, padj, plab)
        if best_key is None or key < best_key:
            best_key = key
            sigma = np.empty(nv, dtype=np.int32)
            sigma[perm] = np.arange(nv, dtype=np.int32)  # local -> canonical
            best_sigma = sigma
    full = np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    full[:nv] = best_sigma
    return best_key, full


def automorphism_orbits(code) -> np.ndarray:
    """Orbit representative per vertex position of a (canonical) pattern.

    Min-image domains are defined over mappings from *any* automorphism of
    an embedding (paper §4.2); with a single fixed isomorphism per embedding
    (our sigma), the full domain of position p is the union of the
    single-isomorphism domains over p's orbit under Aut(pattern). Positions
    sharing a representative must have their domains OR-ed.
    """
    nv, adj, labels = decode(np.asarray(code))
    rep = np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    if nv <= 1:
        return rep
    base = encode(nv, adj, labels)
    for perm in _perms(nv):
        padj = adj[np.ix_(perm, perm)]
        plab = labels[perm]
        if encode(nv, padj, plab) == base:
            # perm maps new position i -> old position perm[i]; i and
            # perm[i] are in the same orbit.
            for i in range(nv):
                a, b = rep[i], rep[perm[i]]
                if a != b:
                    lo, hi = (a, b) if a < b else (b, a)
                    rep[rep == hi] = lo
    return rep


class PatternTable(NamedTuple):
    """Mapping of the step's unique quick patterns to canonical patterns."""

    quick_codes: np.ndarray      # (Q, 3) int64 unique quick codes
    canon_codes: np.ndarray      # (Pc, 3) int64 unique canonical codes
    quick_to_canon: np.ndarray   # (Q,) int32 canonical slot per quick slot
    sigma: np.ndarray            # (Q, 8) int32 local pos -> canonical pos
    canon_n_verts: np.ndarray    # (Pc,) int32
    canon_orbits: np.ndarray     # (Pc, 8) int32 orbit representative per pos
    n_iso_checks: int            # == Q: graph-isomorphism invocations (Table 4)


def build_pattern_table(
    unique_quick: np.ndarray, with_orbits: bool = True
) -> PatternTable:
    """Level 2 for one step's distinct quick patterns, batched + memoised.

    Uncached codes are canonicalised in vectorised per-``n_verts`` batches
    (:func:`_canonicalize_batch`) and remembered process-wide, so the
    permutation search runs once per distinct pattern per process — across
    supersteps AND runs (the superstep pipeline's aggregation is host-bound
    exactly here, DESIGN.md §8). ``n_iso_checks`` stays the *conceptual*
    per-step invocation count (Table 4 semantics), not the cache-miss count.

    ``with_orbits=False`` skips the automorphism-orbit search (only FSM's
    min-image domains consume orbits) and returns identity representatives.
    """
    q = len(unique_quick)
    canon = np.zeros((q, 3), dtype=np.int64)
    sigma = np.zeros((q, MAX_PATTERN_VERTICES), dtype=np.int32)
    rows64 = np.ascontiguousarray(unique_quick, dtype=np.int64)
    keys = [row.tobytes() for row in rows64]
    misses = [i for i, k in enumerate(keys) if k not in _CANON_CACHE]
    if misses:
        miss_codes = unique_quick[misses].astype(np.int64)
        by_nv: dict[int, list] = {}
        for j, i in enumerate(misses):
            by_nv.setdefault(int(miss_codes[j, 0]) & 0xF, []).append(j)
        for nv, js in by_nv.items():
            ck, sg = _canonicalize_batch(miss_codes[js])
            for row, j in enumerate(js):
                _CANON_CACHE[keys[misses[j]]] = (ck[row], sg[row])
    for i, k in enumerate(keys):
        canon[i], sigma[i] = _CANON_CACHE[k]
    uniq_canon, inv = np.unique(canon.reshape(q, 3), axis=0, return_inverse=True)
    if with_orbits and len(uniq_canon):
        orbits = np.stack([_orbits_cached(c) for c in uniq_canon], axis=0)
    else:
        orbits = np.tile(
            np.arange(MAX_PATTERN_VERTICES, dtype=np.int32),
            (len(uniq_canon), 1),
        )
    return PatternTable(
        quick_codes=unique_quick,
        canon_codes=uniq_canon,
        quick_to_canon=inv.astype(np.int32),
        sigma=sigma,
        canon_n_verts=(uniq_canon[:, 0] & 0xF).astype(np.int32),
        canon_orbits=orbits,
        n_iso_checks=q,
    )


def _orbits_cached(code: np.ndarray) -> np.ndarray:
    key = tuple(int(x) for x in code)
    got = _ORBIT_CACHE.get(key)
    if got is None:
        got = _ORBIT_CACHE[key] = automorphism_orbits(code)
    return got


def pattern_to_networkx(code):
    import networkx as nx

    nv, adj, labels = decode(np.asarray(code))
    g = nx.Graph()
    for i in range(nv):
        g.add_node(i, label=int(labels[i]))
    for i in range(nv):
        for j in range(i + 1, nv):
            if adj[i, j]:
                g.add_edge(i, j)
    return g
