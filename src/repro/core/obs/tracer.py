"""Low-overhead span tracer of the observability layer (DESIGN.md §12).

One :class:`Tracer` collects host-side *spans* — named, nested, attributed
wall-time intervals (``superstep`` > ``expand`` > ...) — from every layer
of the runtime through the module-level helpers in ``repro.core.obs``.
Design constraints, in order:

  * **zero new device syncs when disabled** (the default): the module-level
    ``span()`` helper returns a shared ``nullcontext`` when no tracer is
    installed, and ``fence()`` is a no-op unless the installed tracer was
    built with ``sync=True``. The disabled path performs one global read
    and no allocation.
  * **honest phase boundaries are opt-in**: JAX dispatch is asynchronous,
    so a host-side ``perf_counter`` lap at a phase boundary measures
    *dispatch*, not device completion. ``Tracer(sync=True)``
    (``RunConfig.trace_sync``) makes ``fence(*trees)`` block on the passed
    arrays at phase boundaries — the documented contract: blocking
    ``block_until_ready`` boundaries exist ONLY under ``trace_sync=True``.
  * **thread safety**: span stacks are thread-local (nesting is
    per-thread, matching Chrome trace ``tid`` semantics) and the event
    list is lock-guarded, so a future background-canonicalisation thread
    can trace into the same run.

Timestamps are microseconds since the tracer's epoch (``perf_counter``
based — monotonic, sub-µs resolution), the unit Chrome trace events use
natively.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class Span:
    """One closed span: a Chrome-trace complete ("X") event's worth."""

    name: str
    ts: float                 # µs since the tracer epoch
    dur: float                # µs
    tid: int                  # small per-tracer thread index
    depth: int                # nesting depth on its thread (0 = root)
    parent: Optional[str]     # enclosing span's name (None at depth 0)
    args: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CounterSample:
    """One point of a named counter track (Chrome "C" event)."""

    name: str
    ts: float                 # µs since the tracer epoch
    values: Dict[str, float]


class Tracer:
    """Collects spans + counter samples for one (or more) mining runs."""

    def __init__(self, sync: bool = False,
                 on_close: Optional[Callable[[Span], None]] = None) -> None:
        self.sync = bool(sync)
        self.on_close = on_close
        self.epoch = time.perf_counter()
        self.spans: List[Span] = []
        self.counters: List[CounterSample] = []
        #: fences that actually blocked — the overhead-guard observable
        self.n_fences = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._tids: Dict[int, int] = {}

    # -- internals -----------------------------------------------------------
    def _now(self) -> float:
        return (time.perf_counter() - self.epoch) * 1e6

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    # -- recording -----------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs):
        stack = self._stack()
        parent = stack[-1] if stack else None
        stack.append(name)
        t0 = self._now()
        try:
            yield
        finally:
            t1 = self._now()
            stack.pop()
            sp = Span(
                name=name, ts=t0, dur=t1 - t0,
                tid=self._tid(), depth=len(stack), parent=parent,
                args=attrs,
            )
            with self._lock:
                self.spans.append(sp)
            if self.on_close is not None:
                self.on_close(sp)

    def counter(self, name: str, **values) -> None:
        sample = CounterSample(
            name=name, ts=self._now(),
            values={k: float(v) for k, v in values.items()},
        )
        with self._lock:
            self.counters.append(sample)

    def fence(self, *trees) -> None:
        """Block until the passed pytrees are device-complete — ONLY when
        this tracer was built with ``sync=True`` (``trace_sync``). The
        accurate-phase-boundary knob; never implied by plain tracing."""
        if not self.sync:
            return
        import jax

        blocked = False
        for tree in trees:
            if tree is None:
                continue
            jax.block_until_ready(tree)
            blocked = True
        if blocked:
            self.n_fences += 1


# -- the installed tracer (module-level, what the runtime layers talk to) ----

_TRACER: Optional[Tracer] = None
#: shared reusable no-op context — the whole disabled-path cost of span()
_NULL = contextlib.nullcontext()


def install(tracer: Optional[Tracer]) -> None:
    """Make ``tracer`` the process's current tracer (None uninstalls).
    Last-install-wins: concurrent *traced* runs in one process would
    interleave into whichever tracer is current (untraced runs are
    unaffected — they never install)."""
    global _TRACER
    _TRACER = tracer


def current() -> Optional[Tracer]:
    return _TRACER


def span(name: str, **attrs):
    """A tracer span when tracing is on; a shared nullcontext otherwise."""
    t = _TRACER
    if t is None:
        return _NULL
    return t.span(name, **attrs)


def fence(*trees) -> None:
    """Phase-boundary device fence: blocks only under an installed
    ``sync=True`` tracer (the ``trace_sync`` contract); no-op — and no
    device touch — in every other configuration."""
    t = _TRACER
    if t is not None and t.sync:
        t.fence(*trees)


def sync_active() -> bool:
    """True iff an installed tracer asked for blocking phase boundaries."""
    t = _TRACER
    return t is not None and t.sync


def probe_time(fn, *args) -> float:
    """Run a jitted probe twice — once to warm the compile cache, once
    timed to completion — and return the timed seconds. Used by the
    ``trace_sync`` gather/halo probes (``StepStats.t_gather``/
    ``t_exchange``): those stages run *inside* the fused program, so
    separating them costs a probe dispatch, which only the diagnostic
    sync mode pays."""
    import jax

    jax.block_until_ready(fn(*args))       # compile + warm, untimed
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def annotate(name: str):
    """A ``jax.profiler.TraceAnnotation`` aligning the device profiler's
    timeline with the host span taxonomy — created only while a tracer is
    installed (the disabled path must not touch profiler machinery)."""
    if _TRACER is None:
        return _NULL
    import jax

    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - profiler backend unavailable
        return _NULL
