"""Metrics registry + the single write path for StepStats counters.

Every ``st.bytes_to_host += ...`` / ``st.n_host_syncs += 1`` site the
backends grew now routes through :func:`count` / :func:`set_stat`, which

  * perform **exactly** the arithmetic the inline mutation did
    (``setattr(st, name, getattr(st, name) + value)``), so every existing
    bench gate built on ``StepStats`` stays bit-identical whether or not
    anything is observing, and
  * mirror the update into the installed :class:`MetricsRegistry` (when
    one is installed) as named counters/gauges — the machine-readable
    stream the exporters render.

The disabled path is one module-level read + the unchanged setattr.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple


class MetricsRegistry:
    """Named counters, gauges, and distributions for one traced run.

    ``counters``  accumulate (run totals per name);
    ``gauges``    keep the last value and the max watermark;
    ``dists``     keep (count, sum, min, max) summaries.
    Thread-safe — same contract as the tracer.
    """

    def __init__(self) -> None:
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.gauge_max: Dict[str, float] = {}
        self.dists: Dict[str, Tuple[int, float, float, float]] = {}
        #: per-step counter history: name -> [(step, value), ...]
        self.by_step: Dict[str, List[Tuple[int, float]]] = {}
        self._lock = threading.Lock()

    def count(self, name: str, value, step: Optional[int] = None) -> None:
        v = float(value)
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + v
            if step is not None:
                self.by_step.setdefault(name, []).append((int(step), v))

    def gauge(self, name: str, value, step: Optional[int] = None) -> None:
        v = float(value)
        with self._lock:
            self.gauges[name] = v
            if v > self.gauge_max.get(name, float("-inf")):
                self.gauge_max[name] = v
            if step is not None:
                self.by_step.setdefault(name, []).append((int(step), v))

    def observe(self, name: str, value) -> None:
        v = float(value)
        with self._lock:
            n, s, lo, hi = self.dists.get(name, (0, 0.0, v, v))
            self.dists[name] = (n + 1, s + v, min(lo, v), max(hi, v))

    def snapshot(self) -> Dict:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "gauge_max": dict(self.gauge_max),
                "dists": {
                    k: {"count": n, "sum": s, "min": lo, "max": hi}
                    for k, (n, s, lo, hi) in self.dists.items()
                },
            }


_REGISTRY: Optional[MetricsRegistry] = None


def install(registry: Optional[MetricsRegistry]) -> None:
    global _REGISTRY
    _REGISTRY = registry


def current() -> Optional[MetricsRegistry]:
    return _REGISTRY


def count(st, name: str, value) -> None:
    """THE counter write path: ``st.<name> += value``, bit-identical to the
    inline mutation it replaced, mirrored into the registry when one is
    installed."""
    setattr(st, name, getattr(st, name) + value)
    reg = _REGISTRY
    if reg is not None:
        reg.count(name, value, step=getattr(st, "step", None))


def set_stat(st, name: str, value) -> None:
    """Assignment-style stats (``st.<name> = value``) through the same
    observation funnel."""
    setattr(st, name, value)
    reg = _REGISTRY
    if reg is not None:
        reg.gauge(name, value, step=getattr(st, "step", None))


def gauge(name: str, value, step: Optional[int] = None) -> None:
    """Registry-only gauge (no StepStats field) — e.g. device memory."""
    reg = _REGISTRY
    if reg is not None:
        reg.gauge(name, value, step=step)


def sample_device_memory() -> Optional[int]:
    """Device bytes-in-use of the default device, or None where the
    backend exposes no allocator stats (CPU jax commonly doesn't). Never
    raises and never syncs — ``memory_stats`` reads allocator counters."""
    try:
        import jax

        stats = jax.devices()[0].memory_stats()
        if not stats:
            return None
        v = stats.get("bytes_in_use")
        return int(v) if v is not None else None
    except Exception:
        return None
