"""Structured observability for the superstep runtime (DESIGN.md §12).

The façade every runtime layer imports as ``from repro.core import obs``:

  * ``obs.span("expand", step=k, ...)`` — nested host phase spans
    (a shared nullcontext when no tracer is installed: zero allocation,
    zero device syncs on the disabled path);
  * ``obs.count(st, "bytes_to_host", n)`` / ``obs.set_stat(...)`` — THE
    write path for StepStats counters, bit-identical to the inline
    mutations it replaced, mirrored into the metrics registry while
    observing;
  * ``obs.fence(*trees)`` — blocking phase boundaries, ONLY under
    ``trace_sync=True``;
  * ``obs.annotate("fused_chunk")`` — ``jax.profiler.TraceAnnotation``
    device/host timeline alignment while traced;
  * :class:`RunObserver` — the per-run bundle the loop drives (install,
    per-step counters + progress log, Chrome-trace/JSONL export).

Knobs: ``RunConfig.trace`` / ``trace_dir`` / ``trace_sync`` /
``log_every``.
"""
from repro.core.obs.export import (              # noqa: F401
    PHASES,
    RunObserver,
    chrome_trace_events,
    phase_coverage,
    step_log_line,
    validate_chrome_trace,
    write_chrome_trace,
)
from repro.core.obs.metrics import (             # noqa: F401
    MetricsRegistry,
    count,
    gauge,
    sample_device_memory,
    set_stat,
)
from repro.core.obs.metrics import (             # noqa: F401
    current as current_metrics,
)
from repro.core.obs.metrics import (             # noqa: F401
    install as install_metrics,
)
from repro.core.obs.tracer import (              # noqa: F401
    Span,
    Tracer,
    annotate,
    fence,
    probe_time,
    span,
    sync_active,
)
from repro.core.obs.tracer import (              # noqa: F401
    current as current_tracer,
)
from repro.core.obs.tracer import (              # noqa: F401
    install as install_tracer,
)
