"""Exporters of the observability layer: Chrome trace JSON, JSONL, step log.

Three renderings of one traced run (DESIGN.md §12):

  * :func:`write_chrome_trace` — the Chrome trace-event format
    (``{"traceEvents": [...]}``), loadable directly in Perfetto /
    ``chrome://tracing``: every closed span becomes a complete ("X")
    event, counters become "C" tracks, plus "M" metadata naming the
    process/threads.
  * a JSONL event stream (one JSON object per closed span / step record,
    flushed at superstep boundaries) for live ``tail -f`` while a run
    mines.
  * :func:`step_log_line` — the per-superstep one-line structured progress
    log (frontier size, chunks, syncs, compression, bytes-to-host, phase
    walls) behind ``RunConfig.log_every``.

:class:`RunObserver` is the loop-facing bundle: it owns the tracer +
registry for one run, installs them for the run's duration, and writes
the export files at the end.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, List, Optional

from repro.core.obs import metrics as metrics_lib
from repro.core.obs import tracer as tracer_lib

#: the host phase-span taxonomy (children of "superstep"; DESIGN.md §12)
PHASES = (
    "materialize", "aggregate", "alpha", "expand", "seal", "checkpoint",
)

_PID = os.getpid()
_SEQ_LOCK = threading.Lock()
_SEQ = [0]


def _next_seq() -> int:
    with _SEQ_LOCK:
        _SEQ[0] += 1
        return _SEQ[0]


# -- Chrome trace-event rendering ---------------------------------------------

def chrome_trace_events(tracer: tracer_lib.Tracer) -> List[Dict]:
    """Render a tracer's spans + counters as Chrome trace events."""
    events: List[Dict] = [
        {
            "ph": "M", "name": "process_name", "pid": _PID, "tid": 0,
            "args": {"name": "repro-arabesque superstep runtime"},
        }
    ]
    for tid in sorted({sp.tid for sp in tracer.spans} | {0}):
        events.append({
            "ph": "M", "name": "thread_name", "pid": _PID, "tid": tid,
            "args": {"name": "main" if tid == 0 else f"thread-{tid}"},
        })
    for sp in tracer.spans:
        args = {k: _jsonable(v) for k, v in sp.args.items()}
        args["depth"] = sp.depth
        if sp.parent is not None:
            args["parent"] = sp.parent
        events.append({
            "ph": "X", "name": sp.name,
            "ts": round(sp.ts, 3), "dur": round(sp.dur, 3),
            "pid": _PID, "tid": sp.tid, "cat": "host",
            "args": args,
        })
    for cs in tracer.counters:
        events.append({
            "ph": "C", "name": cs.name, "ts": round(cs.ts, 3),
            "pid": _PID, "tid": 0, "args": dict(cs.values),
        })
    return events


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return str(v)


def write_chrome_trace(path: str, tracer: tracer_lib.Tracer,
                       registry: Optional[metrics_lib.MetricsRegistry] = None,
                       meta: Optional[Dict] = None) -> str:
    doc = {
        "traceEvents": chrome_trace_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": dict(meta or {}),
    }
    if registry is not None:
        doc["otherData"]["metrics"] = registry.snapshot()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def validate_chrome_trace(doc) -> List[str]:
    """Schema check of an exported trace: returns the list of problems
    (empty == valid). Enforced fields per event kind: "X" spans need
    ``name/ph/ts/dur/pid/tid``, "M"/"C" need ``name/ph/pid/tid`` (+ ts for
    counters) — the subset Perfetto's importer requires."""
    problems: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a Chrome trace: missing top-level 'traceEvents'"]
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["empty traceEvents"]
    if not any(e.get("ph") == "X" for e in events):
        problems.append("no complete ('X') span events")
    for i, e in enumerate(events):
        ph = e.get("ph")
        if ph not in ("X", "M", "C"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        need = ("name", "ph", "ts", "dur", "pid", "tid") if ph == "X" else (
            ("name", "ph", "ts", "pid", "tid") if ph == "C"
            else ("name", "ph", "pid", "tid")
        )
        for k in need:
            if k not in e:
                problems.append(f"event {i} ({ph}/{e.get('name')}): no {k!r}")
        if ph == "X" and "dur" in e and float(e["dur"]) < 0:
            problems.append(f"event {i} ({e.get('name')}): negative dur")
    return problems


def phase_coverage(doc) -> Dict[str, float]:
    """How much of the superstep wall the named phase spans account for:
    ``covered`` = Σ dur of PHASES spans whose parent is "superstep",
    ``total`` = Σ dur of "superstep" spans, ``coverage`` their ratio
    (1.0 when there are no supersteps — nothing to cover)."""
    total = covered = 0.0
    for e in doc.get("traceEvents", []):
        if e.get("ph") != "X":
            continue
        if e["name"] == "superstep":
            total += float(e["dur"])
        elif (
            e["name"] in PHASES
            and e.get("args", {}).get("parent") == "superstep"
        ):
            covered += float(e["dur"])
    return {
        "total_us": total,
        "covered_us": covered,
        "coverage": (covered / total) if total > 0 else 1.0,
    }


# -- per-superstep progress log -----------------------------------------------

def step_log_line(st) -> str:
    """One structured line per superstep (``RunConfig.log_every``)."""
    return (
        f"step={st.step} size={st.size} frontier={st.n_frontier}"
        f" children={st.n_children} chunks={st.n_chunks}"
        f" syncs={st.n_host_syncs} compression={st.compression:.1f}"
        f" bytes_to_host={st.bytes_to_host}"
        f" collective_bytes={st.collective_bytes}"
        f" t_storage={st.t_storage:.4f} t_aggregate={st.t_aggregate:.4f}"
        f" t_expand={st.t_expand:.4f} t_gather={st.t_gather:.4f}"
        f" t_exchange={st.t_exchange:.4f} t_checkpoint={st.t_checkpoint:.4f}"
    )


def _step_record(st) -> Dict:
    return {
        "event": "superstep",
        "step": st.step, "size": st.size,
        "n_frontier": st.n_frontier, "n_children": st.n_children,
        "n_chunks": st.n_chunks, "n_host_syncs": st.n_host_syncs,
        "compression": round(st.compression, 3),
        "bytes_to_host": st.bytes_to_host,
        "collective_bytes": st.collective_bytes,
        "t_storage": st.t_storage, "t_aggregate": st.t_aggregate,
        "t_expand": st.t_expand, "t_gather": st.t_gather,
        "t_exchange": st.t_exchange, "t_checkpoint": st.t_checkpoint,
        "n_retries": st.n_retries, "t_recovery": st.t_recovery,
    }


def _span_record(sp: tracer_lib.Span) -> Dict:
    return {
        "event": "span", "name": sp.name, "ts_us": round(sp.ts, 3),
        "dur_us": round(sp.dur, 3), "tid": sp.tid, "depth": sp.depth,
        "parent": sp.parent,
        "args": {k: _jsonable(v) for k, v in sp.args.items()},
    }


class _JsonlWriter:
    """Append-only JSONL sink, opened lazily.

    Span records are buffered raw (no serialisation on the write path);
    superstep records serialise + flush everything accumulated so far —
    so ``tail -f`` sees whole supersteps as they complete, while closing
    a span inside the loop costs a list append, not ``json.dumps`` or
    file I/O (both showed up as >5% of sub-millisecond supersteps' wall,
    failing the coverage gate on warm tiny runs)."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._f = None
        self._buf: List[Dict] = []
        self._lock = threading.Lock()

    def write(self, obj: Dict, flush: bool = False) -> None:
        with self._lock:
            self._buf.append(obj)
            if flush:
                self._flush_locked()

    def _flush_locked(self) -> None:
        if self._f is None:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._f = open(self.path, "w")
        for obj in self._buf:
            if isinstance(obj, tracer_lib.Span):
                obj = _span_record(obj)
            self._f.write(json.dumps(obj) + "\n")
        self._buf.clear()
        self._f.flush()

    def close(self) -> None:
        with self._lock:
            if self._buf:
                self._flush_locked()
            if self._f is not None:
                self._f.close()
                self._f = None


# -- the loop-facing bundle ---------------------------------------------------

class RunObserver:
    """Owns the tracer/registry/exporters for ONE mining run.

    Built unconditionally by the runtime loop; every method is a cheap
    no-op when neither ``trace`` nor ``log_every`` asked for anything —
    the observability layer's disabled cost is this object's allocation
    per run."""

    def __init__(self, config, backend_name: str = "") -> None:
        self.config = config
        self.backend_name = backend_name
        self.enabled = bool(config.trace)
        self.log_every = int(config.log_every or 0)
        self.tracer: Optional[tracer_lib.Tracer] = None
        self.registry: Optional[metrics_lib.MetricsRegistry] = None
        self.trace_path: Optional[str] = None
        self._jsonl: Optional[_JsonlWriter] = None
        self._finished = False
        if self.enabled:
            self.registry = metrics_lib.MetricsRegistry()
            on_close = None
            if config.trace_dir is not None:
                base = os.path.join(
                    config.trace_dir, f"run-{_PID}-{_next_seq():04d}"
                )
                self.trace_path = base + ".trace.json"
                self._jsonl = _JsonlWriter(base + ".events.jsonl")
                on_close = self._span_closed
            self.tracer = tracer_lib.Tracer(
                sync=bool(config.trace_sync), on_close=on_close
            )

    def _span_closed(self, sp: tracer_lib.Span) -> None:
        # hot path (fires inside the superstep span): a buffered append —
        # the JSON rendering is deferred to the next step-boundary flush
        self._jsonl.write(sp)

    # -- run lifecycle --------------------------------------------------------
    def start(self) -> None:
        if self.enabled:
            tracer_lib.install(self.tracer)
            metrics_lib.install(self.registry)

    def step_done(self, st) -> None:
        """Called once per appended StepStats: counter tracks + progress log."""
        if self.tracer is not None:
            self.tracer.counter(
                "frontier", rows=st.n_frontier, children=st.n_children
            )
            self.tracer.counter(
                "bytes", to_host=st.bytes_to_host,
                collective=st.collective_bytes,
            )
            self.tracer.counter("host_syncs", syncs=st.n_host_syncs)
            mem = metrics_lib.sample_device_memory()
            if mem is not None:
                metrics_lib.gauge("device_bytes_in_use", mem, step=st.step)
                self.tracer.counter("device_memory", bytes_in_use=mem)
        if self._jsonl is not None:
            self._jsonl.write(_step_record(st), flush=True)
        if self.log_every and st.step % self.log_every == 0:
            print(f"[obs] {step_log_line(st)}", flush=True)

    def finish(
        self, wall_time: float = 0.0, aborted: bool = False
    ) -> Optional[str]:
        """Uninstall + export. Returns the written trace path (or None).
        Idempotent — the loop's finally block may call it after a normal
        finish (no-op) or on an exception (exports the partial trace).
        ``aborted=True`` marks the export as a partial trace of a run
        that died mid-superstep (``otherData["aborted"]``): the spans that
        closed by exception unwinding are all flushed, and
        ``render_trace.py --check`` skips the phase-coverage gate for it
        (an aborted superstep legitimately has uncovered wall)."""
        if not self.enabled or self._finished:
            return self.trace_path if self.enabled else None
        self._finished = True
        if tracer_lib.current() is self.tracer:
            tracer_lib.install(None)
        if metrics_lib.current() is self.registry:
            metrics_lib.install(None)
        if self._jsonl is not None:
            if aborted:
                self._jsonl.write({"event": "aborted"}, flush=True)
            self._jsonl.close()
        if self.trace_path is not None:
            meta = {
                "backend": self.backend_name,
                "wall_time_s": round(float(wall_time), 6),
                "trace_sync": bool(self.config.trace_sync),
            }
            if aborted:
                meta["aborted"] = True
            write_chrome_trace(
                self.trace_path, self.tracer, self.registry, meta=meta
            )
        return self.trace_path
