"""The filter-process programming model (paper §3, §4.1).

Applications implement the paper's user-defined functions. The one TPU
adaptation: functions are *vectorised* — they receive a batch of embeddings
as arrays and return boolean masks, instead of being called per embedding.
Automorphism invariance and anti-monotonicity (paper §3.1 "Guarantees and
requirements") are still the application's obligation; the property tests
check them for the bundled apps.

Mapping to the paper's API (Figure 3):
  filter              -> :meth:`MiningApp.filter`           (phi)
  process             -> engine output collection + :meth:`process_outputs`
  aggregationFilter   -> :meth:`MiningApp.aggregation_filter` (alpha)
  aggregationProcess  -> :meth:`MiningApp.aggregation_process` (beta)
  terminationFilter   -> :meth:`MiningApp.termination_filter`
  map/reduce          -> pattern-keyed aggregation in the engine (§5.4)
  readAggregate       -> the ``agg`` argument handed to alpha/beta
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import DeviceGraph


@dataclasses.dataclass
class MiningApp:
    """Base class: explores everything up to ``max_size`` (no pruning)."""

    #: 'vertex' (vertex-induced) or 'edge' (edge-induced) exploration (§3.1)
    mode: str = "vertex"
    #: stop after embeddings reach this many vertices (vertex mode) or edges
    #: (edge mode); the terminationFilter optimisation of §4.1.
    max_size: Optional[int] = None
    #: run pattern aggregation each step (two-level, §5.4)
    wants_patterns: bool = True
    #: compute FSM-style min-image domains during aggregation
    wants_domains: bool = False
    #: keep explored embeddings in the result (paper ``output(e)``)
    collect_embeddings: bool = False

    # -- phi: candidate filter, vectorised ---------------------------------
    def filter(
        self,
        g: DeviceGraph,
        members: jnp.ndarray,   # (C, k) parent embeddings of the chunk
        n_valid: jnp.ndarray,   # (C,)
        rows: jnp.ndarray,      # (Ncand,) parent row per candidate
        cand: jnp.ndarray,      # (Ncand,) extension vertex/edge id
    ) -> jnp.ndarray:
        """Anti-monotonic candidate predicate; default: accept all."""
        return jnp.ones(rows.shape, dtype=bool)

    # -- alpha: aggregation filter, pattern-granular -----------------------
    def pattern_filter(self, agg) -> Optional[np.ndarray]:
        """Per-PATTERN keep mask ``(Pc,) bool`` over ``agg.canon_codes``,
        or None for keep-all (the default alpha). This is the granularity
        the device-resident aggregation evaluates alpha at (DESIGN.md §10):
        per-row masks are derived on device from per-pattern verdicts, so
        no per-row state has to cross to the host unless pruning actually
        fires. Apps that genuinely need per-*row* alpha override
        :meth:`aggregation_filter` instead (and the engine falls back to
        the host aggregation path for them)."""
        return None

    # -- alpha: aggregation filter on the frontier, host-side --------------
    def aggregation_filter(
        self,
        canon_slot: np.ndarray,     # (B,) canonical-pattern slot per frontier row
        agg,                        # StepAggregates from the generating step
    ) -> np.ndarray:
        """Prune frontier rows using aggregates of their generating step;
        default: broadcast :meth:`pattern_filter` to rows (keep all when it
        returns None — paper: alpha defaults to true)."""
        pk = self.pattern_filter(agg)
        if pk is None:
            return np.ones(canon_slot.shape, dtype=bool)
        pk = np.asarray(pk, dtype=bool)
        return np.where(
            canon_slot >= 0, pk[np.maximum(canon_slot, 0)], False
        )

    # -- beta: aggregation process (outputs keyed by pattern) --------------
    def aggregation_process(self, agg) -> Optional[dict]:
        """Return the per-pattern outputs for this step (or None)."""
        return None

    # -- termination filter -------------------------------------------------
    def termination_filter(self, size_after_step: int) -> bool:
        """True -> stop expanding after this size (default: max_size)."""
        return self.max_size is not None and size_after_step >= self.max_size
