"""Per-step execution statistics (feeds paper Figs 9/12, Tables 3/4)."""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List


@dataclasses.dataclass
class StepStats:
    step: int = 0
    size: int = 0                    # embedding size at this step's frontier
    n_frontier: int = 0              # embeddings expanded
    n_generated: int = 0             # valid candidate slots
    n_canonical: int = 0             # survivors of the canonicality check
    n_children: int = 0              # survivors of the app filter
    n_quick_patterns: int = 0
    n_canonical_patterns: int = 0
    n_iso_checks: int = 0
    n_chunks: int = 0                # chunk programs dispatched this step
    #: host→device control syncs: times the host *blocked on a device
    #: value to decide control flow* (capacity retries, chunk loops).
    #: The PR-2 chunk loop pays one per chunk; the fused pipeline
    #: (DESIGN.md §8) drains all counts once — O(1) per superstep.
    n_host_syncs: int = 0
    frontier_bytes: int = 0          # raw embedding-list bytes (Fig 9 baseline)
    odag_bytes: int = 0              # ODAG-compressed bytes (Fig 9)
    collective_bytes: int = 0        # bytes exchanged in the distributed step
    #: device→host bytes drained by PATTERN AGGREGATION this superstep:
    #: distinct codes + counts + domain bitmaps + alpha row masks under the
    #: device-resident path (O(#patterns), DESIGN.md §10), or the full
    #: frontier's quick codes / local-vertex tables under the host
    #: reference path (O(frontier)). ``bench_aggregate.py`` gates the
    #: device path at >=10x below the per-row code payload.
    bytes_to_host: int = 0
    t_expand: float = 0.0            # G+C phases of Fig 12
    t_aggregate: float = 0.0         # P phase
    #: seconds of level-2 canonicalisation on the CRITICAL PATH
    #: (DESIGN.md §15): the host batch or device refine under sync
    #: placements, but only the residual join wait under ``host_async`` —
    #: the overlap win is exactly the sync placement's value minus this.
    #: ``bench_canon.py`` gates host_async at <=1/5 of the host wall.
    t_canon: float = 0.0
    t_storage: float = 0.0           # W+R phases (ODAG build/extract)
    #: tile-gather seconds of the partitioned layout (DESIGN.md §11/§12):
    #: ``build_tile_view`` runs INSIDE the fused chunk program, so the
    #: split is measured by a dedicated probe dispatch ONLY under
    #: ``trace_sync=True`` (serial backend, partitioned graphs); 0.0
    #: otherwise — the cost then rides ``t_expand``, as before.
    t_gather: float = 0.0
    #: halo-exchange seconds of the partitioned shard-map superstep
    #: (request/response ``all_to_all`` or ragged all-gather): probe-
    #: measured under ``trace_sync=True`` only, else folded in
    #: ``t_expand``. The exchange's WIRE bytes are always accounted
    #: (``collective_bytes``), independent of this timing.
    t_exchange: float = 0.0
    #: seconds writing this step's superstep checkpoint (DESIGN.md §9);
    #: 0.0 when checkpointing is off or the cadence skipped the step.
    #: ``bench_checkpoint.py`` gates the sum at ≤5% of superstep wall time.
    t_checkpoint: float = 0.0
    #: supervisor retries that preceded this step's (re-)execution
    #: (DESIGN.md §13): stamped by ``run_supervised`` on the first step of
    #: each recovery attempt, 0 everywhere else.
    n_retries: int = 0
    #: seconds the supervisor spent RECOVERING before this step re-ran:
    #: checkpoint reload + validation + backend rebuild + backoff sleep —
    #: the pure fault-tolerance tax, excluding re-mined supersteps.
    #: ``bench_faults.py`` gates the sum at ≤15% of superstep wall.
    t_recovery: float = 0.0

    @property
    def compression(self) -> float:
        """Fig. 9 per-step ratio: raw embedding-list bytes over what the
        frontier store actually held between supersteps (1.0 for RawStore
        or an empty frontier)."""
        if self.odag_bytes <= 0 or self.frontier_bytes <= 0:
            return 1.0
        return self.frontier_bytes / self.odag_bytes


@dataclasses.dataclass
class RunStats:
    steps: List[StepStats] = dataclasses.field(default_factory=list)
    wall_time: float = 0.0
    #: chunk programs compiled during this run (jit cache growth); the
    #: pow2 bucketing of chunk widths and output capacities bounds this to
    #: O(log) entries per embedding size (DESIGN.md §8).
    n_compiles: int = 0
    #: the distinct (embedding_size, chunk_width, out_cap) signatures
    #: actually dispatched — width and capacity must be powers of two
    #: (tested); each signature compiles at most one chunk program.
    chunk_signatures: List[tuple] = dataclasses.field(default_factory=list)
    #: the effective cost-model decision table of this run (DESIGN.md §14):
    #: every resolved knob + probe timings + provenance ("static" /
    #: "calibrated" / "cached" / "forced:<mode>") — placement decisions
    #: must be observable after the fact, not inferred from timings.
    cost_model: Dict = dataclasses.field(default_factory=dict)

    @property
    def total_embeddings(self) -> int:
        return sum(s.n_children for s in self.steps) + (
            self.steps[0].n_frontier if self.steps else 0
        )

    @property
    def total_host_syncs(self) -> int:
        return sum(s.n_host_syncs for s in self.steps)

    @property
    def total_bytes_to_host(self) -> int:
        return sum(s.bytes_to_host for s in self.steps)

    def phase_walls(self) -> Dict[str, float]:
        """Per-phase wall totals over the run (Fig. 12's split, seconds)."""
        out: Dict[str, float] = {}
        for name in (
            "t_expand", "t_aggregate", "t_canon", "t_storage",
            "t_gather", "t_exchange", "t_checkpoint",
        ):
            out[name] = round(sum(getattr(s, name) for s in self.steps), 4)
        return out

    def summary(self) -> Dict:
        return {
            "steps": len(self.steps),
            "total_embeddings": self.total_embeddings,
            "total_iso_checks": sum(s.n_iso_checks for s in self.steps),
            "wall_time_s": round(self.wall_time, 4),
            "max_compression": round(
                max((s.compression for s in self.steps), default=1.0), 1
            ),
            "host_syncs": self.total_host_syncs,
            "total_bytes_to_host": self.total_bytes_to_host,
            "phase_walls_s": self.phase_walls(),
            "chunk_programs": self.n_compiles,
        }

    def compression_by_size(self) -> Dict[int, float]:
        """Per-step Fig. 9 curve: embedding size -> frontier compression."""
        return {s.size: s.compression for s in self.steps if s.odag_bytes > 0}


class Timer:
    def __init__(self):
        self.t0 = time.perf_counter()

    def lap(self) -> float:
        t = time.perf_counter()
        dt = t - self.t0
        self.t0 = t
        return dt
