"""ODAG — Overapproximating Directed Acyclic Graph (paper §5.2).

An ODAG stores a set of same-size canonical embeddings as k per-position
domains plus connectivity bitmaps between consecutive positions: a prefix
tree with all equal-id nodes of a level collapsed. It encodes a *superset*
of the stored embeddings; extraction re-applies the same filters as
Algorithm 1 (validity + canonicality + app filter), which by completeness
removes exactly the spurious paths.

Size: O(k * N^2) bits worst-case vs O(N^k) embeddings — the paper's
several-orders-of-magnitude compression (Fig. 9), reproduced by
``benchmarks/bench_odag.py``.

Two representations:
  * :class:`ODAG` — exact, ragged (host build): per-pattern storage and the
    byte accounting used for Fig. 9.
  * :class:`DenseODAG` — fixed-shape bitmaps over the full vertex space,
    merged across workers with a single OR-allreduce: the distributed
    exchange format (paper §5.2 "merge and broadcast" as one collective).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import canonical
from repro.core.graph import DeviceGraph
from repro.kernels.canonical_check import ops as cc_ops


@dataclasses.dataclass
class ODAG:
    """Exact ragged ODAG for one pattern's embeddings of size k."""

    k: int
    domains: List[np.ndarray]        # level i: (Di,) int32 sorted unique ids
    conn: List[np.ndarray]           # level i: (Di, D_{i+1}) bool

    @property
    def n_bytes(self) -> int:
        b = sum(d.size * 4 for d in self.domains)
        b += sum((c.size + 7) // 8 for c in self.conn)
        return b

    def counts(self) -> List[int]:
        return [len(d) for d in self.domains]

    def path_upper_bound(self) -> int:
        """#paths encoded (incl. spurious): the §5.3 cost estimate."""
        if not self.domains:
            return 0
        cost = np.ones(len(self.domains[-1]), dtype=np.int64)
        for c in reversed(self.conn):
            cost = c @ cost
        return int(cost.sum())


def build(members: np.ndarray, k: Optional[int] = None) -> ODAG:
    """Build the ODAG of a set of size-k embeddings (ids in visit order)."""
    members = np.asarray(members)
    k = k or members.shape[1]
    members = members[:, :k]
    domains, index = [], []
    for i in range(k):
        d = np.unique(members[:, i])
        domains.append(d.astype(np.int32))
        index.append({int(v): j for j, v in enumerate(d)})
    conn = []
    for i in range(k - 1):
        c = np.zeros((len(domains[i]), len(domains[i + 1])), dtype=bool)
        a = np.searchsorted(domains[i], members[:, i])
        b = np.searchsorted(domains[i + 1], members[:, i + 1])
        c[a, b] = True
        conn.append(c)
    return ODAG(k=k, domains=domains, conn=conn)


def partition_by_cost(odag: ODAG, n_workers: int) -> List[np.ndarray]:
    """Paper §5.3: cost-annotated load balancing.

    Each first-level element is annotated with the number of (possibly
    spurious) paths below it; workers take contiguous runs of first-level
    elements with approximately equal total cost. Returns per-worker boolean
    masks over the first-level domain (a worker extracts only paths starting
    at its masked elements). When one element's cost exceeds the target the
    paper splits recursively on the second level; we assign such an element
    to one worker and rebalance the remainder (bounded imbalance, no
    recursion) — the difference only matters for single-hub graphs.
    """
    if not odag.domains:
        return [np.zeros(0, dtype=bool) for _ in range(n_workers)]
    cost = np.ones(len(odag.domains[-1]), dtype=np.int64)
    for c in reversed(odag.conn):
        cost = c @ cost
    total = int(cost.sum())
    target = max(total / max(n_workers, 1), 1.0)
    masks = [np.zeros(len(cost), dtype=bool) for _ in range(n_workers)]
    w, acc = 0, 0.0
    for i, ci in enumerate(np.asarray(cost)):
        if acc >= target and w < n_workers - 1:
            w += 1
            acc = 0.0
        masks[w][i] = True
        acc += float(ci)
    return masks


def extract_partition(g, odag: ODAG, mask: np.ndarray, **kw) -> np.ndarray:
    """Extract only the paths rooted at the masked first-level elements."""
    sub = ODAG(
        k=odag.k,
        domains=[odag.domains[0][mask]] + odag.domains[1:],
        conn=([odag.conn[0][mask]] + odag.conn[1:]) if odag.conn else [],
    )
    return extract(g, sub, **kw)


def merge(odags: List[ODAG]) -> ODAG:
    """Merge worker-local ODAGs of the same pattern (paper's map-reduce edge
    merging, done as set-union + bitmap OR)."""
    k = odags[0].k
    domains = []
    for i in range(k):
        domains.append(
            np.unique(np.concatenate([o.domains[i] for o in odags])).astype(np.int32)
        )
    conn = []
    for i in range(k - 1):
        c = np.zeros((len(domains[i]), len(domains[i + 1])), dtype=bool)
        for o in odags:
            a = np.searchsorted(domains[i], o.domains[i])
            b = np.searchsorted(domains[i + 1], o.domains[i + 1])
            rows, cols = np.nonzero(o.conn[i])
            c[a[rows], b[cols]] = True
        conn.append(c)
    return ODAG(k=k, domains=domains, conn=conn)


def extract(
    g: DeviceGraph,
    odag: ODAG,
    app_filter: Optional[Callable] = None,
    chunk: int = 65536,
    mode: str = "vertex",
    use_pallas: bool = False,
    interpret=None,
) -> np.ndarray:
    """Enumerate the stored embeddings: follow connectivity edges, dropping
    spurious paths with exactly the Algorithm-1 filters (validity +
    incremental canonicality + app filter).

    Returns (B, k) int32. Host-driven loop over levels; each level is a
    vectorised device mask evaluation (same kernels as exploration:
    ``use_pallas`` routes the canonicality re-check through the Pallas
    kernel dispatch, falling back to jnp exactly as the engines do).
    """
    k = odag.k
    paths = odag.domains[0][:, None].astype(np.int32)     # (P, 1)
    for lvl in range(k - 1):
        nxt_dom = odag.domains[lvl + 1]                    # (D,)
        d = len(nxt_dom)
        out = []
        for lo in range(0, len(paths), chunk):
            pc = paths[lo : lo + chunk]                    # (P, lvl+1)
            p = len(pc)
            a = np.searchsorted(odag.domains[lvl], pc[:, lvl])
            mask = odag.conn[lvl][a]                       # (P, D) conn bit
            cand = np.broadcast_to(nxt_dom[None, :], (p, d))

            mem = jnp.asarray(np.repeat(pc, d, axis=0))    # (P*D, lvl+1)
            cnd = jnp.asarray(cand.reshape(-1))
            nv = jnp.full((p * d,), lvl + 1, dtype=jnp.int32)
            distinct = ~(mem == cnd[:, None]).any(axis=1)
            if mode == "vertex":
                # validity: adjacency to some member + distinctness
                attach = g.is_edge(mem, cnd[:, None]).any(axis=1)
                if use_pallas:
                    canon = cc_ops.canonical_check(
                        g, mem, nv, cnd, mode="vertex", interpret=interpret
                    )
                else:
                    canon = canonical.vertex_check(g, mem, nv, cnd)
            else:
                mu = g.edge_uv[jnp.maximum(mem, 0)]        # (B, k, 2)
                cu = g.edge_uv[jnp.maximum(cnd, 0)]        # (B, 2)
                attach = (
                    (mu[..., 0] == cu[:, None, 0])
                    | (mu[..., 0] == cu[:, None, 1])
                    | (mu[..., 1] == cu[:, None, 0])
                    | (mu[..., 1] == cu[:, None, 1])
                ).any(axis=1)
                if use_pallas:
                    canon = cc_ops.canonical_check(
                        g, mem, nv, cnd, mode="edge", interpret=interpret
                    )
                else:
                    canon = canonical.edge_check(g, mem, nv, cnd)
            keep = np.asarray(attach & distinct & canon) & mask.reshape(-1)
            if app_filter is not None:
                keep = keep & np.asarray(app_filter(mem, nv, cnd))
            sel = np.nonzero(keep)[0]
            children = np.concatenate(
                [np.asarray(mem)[sel], np.asarray(cnd)[sel][:, None]], axis=1
            )
            out.append(children)
        paths = np.concatenate(out, axis=0) if out else np.zeros((0, lvl + 2), np.int32)
    return paths.astype(np.int32)


# ---------------------------------------------------------------------------
# Fixed-shape dense ODAG: the distributed exchange format
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DenseODAG:
    """ODAG with domains/connectivity over the full vertex space: fixed
    shapes make it a pytree leaf set that ``psum``/OR-allreduce merges in one
    collective (DESIGN.md §4)."""

    k: int
    domain_bits: jnp.ndarray   # (k, W) uint32 — vertex-in-domain bitmaps
    conn_bits: jnp.ndarray     # (k-1, N, W) uint32 — consecutive-level pairs

    @property
    def n_bytes(self) -> int:
        return int(self.domain_bits.size + self.conn_bits.size) * 4


def build_dense(members: np.ndarray, n_vertices: int, k: int) -> DenseODAG:
    """Scatter rows straight into the packed bitmaps (LSB-first, matching
    :func:`repro.core.bitset.pack_bool_matrix`): no unpacked (N, N) bool
    intermediate, so the host cost is the *packed* O(k·N²/8) bytes the
    exchange format itself costs — not 8x that."""
    members = np.asarray(members)[:, :k]
    w = (n_vertices + 31) // 32
    dom = np.zeros((k, w), dtype=np.uint32)
    conn = np.zeros((max(k - 1, 0), n_vertices, w), dtype=np.uint32)
    for i in range(k):
        v = members[:, i]
        np.bitwise_or.at(dom[i], v // 32, np.uint32(1) << (v % 32).astype(np.uint32))
        if i < k - 1:
            nxt = members[:, i + 1]
            np.bitwise_or.at(
                conn[i],
                (v, nxt // 32),
                np.uint32(1) << (nxt % 32).astype(np.uint32),
            )
    return DenseODAG(
        k=k,
        domain_bits=jnp.asarray(dom),
        conn_bits=jnp.asarray(conn),
    )


def dense_to_ragged(d: DenseODAG) -> ODAG:
    """Unpack a (merged) DenseODAG for extraction."""
    dom_bits = np.asarray(d.domain_bits)
    k, w = dom_bits.shape
    n = np.asarray(d.conn_bits).shape[1] if d.k > 1 else w * 32
    bits = np.unpackbits(
        dom_bits.view(np.uint8).reshape(k, -1), axis=1, bitorder="little"
    )[:, :n]
    domains = [np.nonzero(bits[i])[0].astype(np.int32) for i in range(k)]
    conn = []
    for i in range(k - 1):
        cb = np.asarray(d.conn_bits[i])
        cbits = np.unpackbits(
            cb.view(np.uint8).reshape(n, -1), axis=1, bitorder="little"
        )[:, :n]
        conn.append(cbits[np.ix_(domains[i], domains[i + 1])].astype(bool))
    return ODAG(k=k, domains=domains, conn=conn)
