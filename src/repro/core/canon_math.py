"""Pure canonical-pattern math (level 2, paper §5.4) — no memo, no device.

Split out of ``core/pattern.py`` so the batched device kernel
(``kernels/canonical_refine.py``), the host memo layer (``pattern.py``)
and the cost-model pilot probe all share one definition of the canonical
contract:

  * canonical code = lexicographic minimum of ``(w0, w1, w2)`` over all
    vertex-position permutations, enumerated in ``itertools.permutations``
    order; the FIRST minimal permutation wins ties;
  * ``sigma[local_pos] = canonical_pos`` for the winning permutation,
    identity for positions ≥ nv;
  * orbit representative ``rep[i]`` = the minimum position automorphic to
    ``i`` (union-find over all automorphisms ≡ min over the permutation
    group, which is fully enumerated here).

Encoding (3 × int64 per pattern, every word < 2^32):
  w0 = n_vertices | adj_bits << 4     (pair (a<b) -> bit b*(b-1)/2 + a)
  w1 = labels[0..3], 8 bits each      (labels must be < 256)
  w2 = labels[4..7], 8 bits each
"""
from __future__ import annotations

import itertools

import numpy as np

MAX_PATTERN_VERTICES = 8


def _pair_bit(a, b):
    """Bit index for unordered position pair (a < b)."""
    return (b * (b - 1)) // 2 + a


def n_pair_bits(nv: int) -> int:
    """Number of adjacency bits for an nv-vertex pattern."""
    return (nv * (nv - 1)) // 2


def decode(code) -> tuple[int, np.ndarray, np.ndarray]:
    """(n_vertices, dense adjacency (nv, nv) bool, labels (nv,))."""
    w0, w1, w2 = (int(x) for x in code)
    nv = w0 & 0xF
    bits = w0 >> 4
    adj = np.zeros((nv, nv), dtype=bool)
    for bb in range(1, nv):
        for aa in range(bb):
            if (bits >> _pair_bit(aa, bb)) & 1:
                adj[aa, bb] = adj[bb, aa] = True
    labels = np.array([(w1 >> (8 * i)) & 0xFF for i in range(4)]
                      + [(w2 >> (8 * i)) & 0xFF for i in range(4)])[:nv]
    return nv, adj, labels.astype(np.int32)


def encode(nv: int, adj: np.ndarray, labels: np.ndarray) -> tuple[int, int, int]:
    bits = 0
    for bb in range(1, nv):
        for aa in range(bb):
            if adj[aa, bb]:
                bits |= 1 << _pair_bit(aa, bb)
    w0 = nv | (bits << 4)
    w1 = w2 = 0
    for i in range(min(nv, 4)):
        w1 |= int(labels[i]) << (8 * i)
    for i in range(4, min(nv, 8)):
        w2 |= int(labels[i]) << (8 * (i - 4))
    return w0, w1, w2


_PERMS_CACHE: dict[int, np.ndarray] = {}


def _perms(nv: int) -> np.ndarray:
    if nv not in _PERMS_CACHE:
        _PERMS_CACHE[nv] = np.array(list(itertools.permutations(range(nv))), np.int32)
    return _PERMS_CACHE[nv]


_PERM_TABLES_CACHE: dict[int, tuple[np.ndarray, np.ndarray]] = {}


def perm_tables(nv: int) -> tuple[np.ndarray, np.ndarray]:
    """Permutations + adjacency-bit source map for the device refine kernel.

    Returns ``(perms, bit_src)`` with ``perms`` (P, nv) int32 in
    ``itertools.permutations`` order (row 0 = identity) and ``bit_src``
    (P, nbits) int32 where target bit ``t = _pair_bit(a, b)`` of the
    permuted adjacency word is source bit
    ``_pair_bit(sorted(perm[a], perm[b]))`` of the unpermuted word —
    i.e. ``padj[a, b] = adj[perm[a], perm[b]]``, matching
    :func:`_canonicalize_batch` exactly.
    """
    got = _PERM_TABLES_CACHE.get(nv)
    if got is None:
        perms = _perms(nv)
        nbits = n_pair_bits(nv)
        src = np.zeros((len(perms), nbits), dtype=np.int32)
        for b in range(1, nv):
            for a in range(b):
                pa = perms[:, a]
                pb = perms[:, b]
                lo = np.minimum(pa, pb)
                hi = np.maximum(pa, pb)
                src[:, _pair_bit(a, b)] = (hi * (hi - 1)) // 2 + lo
        got = _PERM_TABLES_CACHE[nv] = (perms, src)
    return got


def _decode_batch(codes: np.ndarray, nv: int):
    """Vectorised :func:`decode` over (Q, 3) codes sharing ``n_verts``."""
    w0, w1, w2 = codes[:, 0], codes[:, 1], codes[:, 2]
    bits = w0 >> 4
    adj = np.zeros((len(codes), nv, nv), dtype=bool)
    for bb in range(1, nv):
        for aa in range(bb):
            on = ((bits >> _pair_bit(aa, bb)) & 1).astype(bool)
            adj[:, aa, bb] = adj[:, bb, aa] = on
    labels = np.zeros((len(codes), nv), dtype=np.int64)
    for i in range(min(nv, 4)):
        labels[:, i] = (w1 >> (8 * i)) & 0xFF
    for i in range(4, min(nv, 8)):
        labels[:, i] = (w2 >> (8 * (i - 4))) & 0xFF
    return adj, labels


def _encode_batch(adj: np.ndarray, labels: np.ndarray) -> np.ndarray:
    """Vectorised :func:`encode`: (Q, nv, nv) + (Q, nv) -> (Q, 3) int64."""
    q, nv = labels.shape
    bits = np.zeros(q, dtype=np.int64)
    for bb in range(1, nv):
        for aa in range(bb):
            bits |= adj[:, aa, bb].astype(np.int64) << _pair_bit(aa, bb)
    w0 = nv | (bits << 4)
    w1 = np.zeros(q, dtype=np.int64)
    w2 = np.zeros(q, dtype=np.int64)
    for i in range(min(nv, 4)):
        w1 |= labels[:, i] << (8 * i)
    for i in range(4, min(nv, 8)):
        w2 |= labels[:, i] << (8 * (i - 4))
    return np.stack([w0, w1, w2], axis=1)


def _lex_less(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Row-wise lexicographic a < b over (Q, 3) code triples."""
    return (
        (a[:, 0] < b[:, 0])
        | ((a[:, 0] == b[:, 0]) & (a[:, 1] < b[:, 1]))
        | ((a[:, 0] == b[:, 0]) & (a[:, 1] == b[:, 1]) & (a[:, 2] < b[:, 2]))
    )


def _canonicalize_batch(codes: np.ndarray):
    """Batched :func:`canonicalize_one` over (Q, 3) codes sharing
    ``n_verts``: one vectorised pass per permutation instead of a Python
    loop per pattern. Identical tie-breaking (first minimal permutation
    wins), hence bit-identical canon codes and sigmas."""
    q = len(codes)
    nv = int(codes[0, 0]) & 0xF
    sigma = np.tile(np.arange(MAX_PATTERN_VERTICES, dtype=np.int32), (q, 1))
    if nv <= 1:
        return codes.astype(np.int64, copy=True), sigma
    adj, labels = _decode_batch(codes, nv)
    perms = _perms(nv)
    best = None
    best_pi = np.zeros(q, dtype=np.int64)
    for pi, perm in enumerate(perms):
        key = _encode_batch(adj[:, perm][:, :, perm], labels[:, perm])
        if best is None:
            best = key
        else:
            better = _lex_less(key, best)
            best = np.where(better[:, None], key, best)
            best_pi = np.where(better, pi, best_pi)
    chosen = perms[best_pi]                       # (Q, nv): canon pos -> local
    rows = np.arange(q)[:, None]
    sigma[rows, chosen] = np.arange(nv, dtype=np.int32)[None, :]
    return best, sigma


def canonicalize_one(code) -> tuple[tuple[int, int, int], np.ndarray]:
    """Canonical code of one quick pattern + the permutation sigma with
    sigma[local_pos] = canonical_pos achieving it (graph-isomorphism
    canonical form; exact, replaces bliss)."""
    nv, adj, labels = decode(code)
    if nv <= 1:
        return encode(nv, adj, labels), np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    perms = _perms(nv)                        # (p!, nv): perm[i] = new position? see below
    best_key, best_sigma = None, None
    for perm in perms:
        # perm maps canonical position -> local position (a relabeling order)
        padj = adj[np.ix_(perm, perm)]
        plab = labels[perm]
        key = encode(nv, padj, plab)
        if best_key is None or key < best_key:
            best_key = key
            sigma = np.empty(nv, dtype=np.int32)
            sigma[perm] = np.arange(nv, dtype=np.int32)  # local -> canonical
            best_sigma = sigma
    full = np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    full[:nv] = best_sigma
    return best_key, full


def automorphism_orbits(code) -> np.ndarray:
    """Orbit representative per vertex position of a (canonical) pattern.

    Min-image domains are defined over mappings from *any* automorphism of
    an embedding (paper §4.2); with a single fixed isomorphism per embedding
    (our sigma), the full domain of position p is the union of the
    single-isomorphism domains over p's orbit under Aut(pattern). Positions
    sharing a representative must have their domains OR-ed.
    """
    nv, adj, labels = decode(np.asarray(code))
    rep = np.arange(MAX_PATTERN_VERTICES, dtype=np.int32)
    if nv <= 1:
        return rep
    base = encode(nv, adj, labels)
    for perm in _perms(nv):
        padj = adj[np.ix_(perm, perm)]
        plab = labels[perm]
        if encode(nv, padj, plab) == base:
            # perm maps new position i -> old position perm[i]; i and
            # perm[i] are in the same orbit.
            for i in range(nv):
                a, b = rep[i], rep[perm[i]]
                if a != b:
                    lo, hi = (a, b) if a < b else (b, a)
                    rep[rep == hi] = lo
    return rep
