"""Arabesque's contribution in JAX: the filter-process TLE mining engine."""
import jax

# Quick-pattern codes are genuine 64-bit keys (labels + structure bits); the
# model zoo always passes explicit dtypes, so enabling x64 is safe globally.
jax.config.update("jax_enable_x64", True)

from repro.core.api import MiningApp
from repro.core.engine import EngineConfig, MiningResult, run
from repro.core.graph import (
    DeviceGraph, Graph, PartitionedGraph, to_device, to_partitioned,
)
from repro.core.runtime import (
    FaultPlan, FaultSpec, RunConfig, SuperstepRuntime, resume, run_supervised,
)

__all__ = [
    "MiningApp",
    "EngineConfig",
    "FaultPlan",
    "FaultSpec",
    "MiningResult",
    "RunConfig",
    "SuperstepRuntime",
    "resume",
    "run",
    "run_supervised",
    "DeviceGraph",
    "Graph",
    "PartitionedGraph",
    "to_device",
    "to_partitioned",
]
