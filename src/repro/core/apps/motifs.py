"""Counting motifs (paper Fig. 4b): exhaustive vertex-induced exploration up
to ``max_size``, counting embeddings per pattern.

Paper implementation is 18 lines; ours is the class below. ``filter`` is the
default accept-all (the size bound is the termination filter), ``process`` is
``mapOutput(pattern(e), 1)`` which is exactly the engine's pattern
aggregation with counts.
"""
from __future__ import annotations

import dataclasses

from repro.core.api import MiningApp


@dataclasses.dataclass
class MotifsApp(MiningApp):
    mode: str = "vertex"
    max_size: int = 3
    wants_patterns: bool = True
    wants_domains: bool = False
