"""Frequent subgraph mining (paper Fig. 4a): edge-induced exploration with
min-image support [Bringmann & Nijssen] computed via domain aggregation.

phi: size bound (anti-monotonic). map/reduce: domains merged per pattern —
in this engine that is the (Pc, k, N) domain bitmap OR-reduce. alpha: prune
embeddings whose pattern's support < theta. beta: output the frequent
patterns with their supports.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph


@dataclasses.dataclass
class FSMApp(MiningApp):
    mode: str = "edge"
    support: int = 2                 # theta
    max_size: int = 4                # max edges (None = unbounded, paper default)
    wants_patterns: bool = True
    wants_domains: bool = True
    max_vertices: int | None = None  # optional numVertices(e) <= MAX filter

    def filter(self, g: DeviceGraph, members, n_valid, rows, cand):
        if self.max_vertices is None:
            return jnp.ones(rows.shape, dtype=bool)
        # numVertices(e + cand) <= max_vertices: count distinct endpoints.
        # Upper bound: a new edge adds at most one vertex to a connected
        # subgraph, so #vertices <= #edges + 1; exact check is done at
        # aggregation time, this is the cheap anti-monotonic bound.
        n_edges = n_valid[rows] + 1
        return n_edges + 1 <= self.max_vertices + 1

    def pattern_filter(self, agg) -> np.ndarray:
        """alpha at pattern granularity: a pattern survives iff its
        min-image support reaches theta (the per-row mask — identical to
        the old per-row ``aggregation_filter`` — is derived by the engine,
        on device under ``device_aggregate``)."""
        return np.asarray(agg.supports) >= self.support
