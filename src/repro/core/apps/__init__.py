from repro.core.apps.cliques import CliquesApp
from repro.core.apps.fsm import FSMApp
from repro.core.apps.motifs import MotifsApp

__all__ = ["CliquesApp", "FSMApp", "MotifsApp"]
