"""Finding cliques (paper Fig. 4c): vertex-induced exploration where the
filter keeps a candidate only if it is connected to *all* current members —
anti-monotonic local pruning (a non-clique can never extend to a clique).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph


@dataclasses.dataclass
class CliquesApp(MiningApp):
    mode: str = "vertex"
    max_size: int = 4
    wants_patterns: bool = False     # paper §6.3: Cliques skips pattern agg
    collect_embeddings: bool = True

    def filter(self, g: DeviceGraph, members, n_valid, rows, cand):
        """isClique: the new vertex must neighbour every existing member."""
        k = members.shape[1]
        pos = jnp.arange(k)[None, :]
        m = members[rows]                       # (Ncand, k)
        valid = pos < n_valid[rows][:, None]
        adj = g.is_edge(m, cand[:, None])       # (Ncand, k)
        return (adj | ~valid).all(axis=1)


def maximal_cliques(result, g: DeviceGraph):
    """Post-process a CliquesApp result into MAXIMAL cliques (the paper's
    §2 generalisation): a size-k clique is maximal iff no vertex is adjacent
    to all its members. Vectorised over the collected embeddings."""
    import numpy as np

    from repro.core.bitset import popcount_u32

    out = {}
    adj = jnp.asarray(g.adj_bits)
    for size, emb in sorted(result.embeddings.items()):
        m = jnp.asarray(emb)                    # (B, size)
        # AND of the members' adjacency bitmaps = common-neighbour set
        rows = adj[m]                           # (B, size, W)
        common = rows[:, 0]
        for i in range(1, size):
            common = common & rows[:, i]
        n_common = popcount_u32(common).sum(axis=1)
        maximal = np.asarray(n_common == 0)
        out[size] = np.asarray(emb)[maximal]
    return out
