"""Labeled immutable input graphs for mining.

Two views:
  * :class:`Graph` — host-side (numpy) construction / generators / IO.
  * :class:`DeviceGraph` — a pytree of device arrays in the layout the
    vectorised exploration kernels want: padded neighbour table, packed
    adjacency bitset, edge endpoint table, per-vertex incident-edge table.

The paper's datasets (CiteSeer, MiCo, Patents, ...) are not redistributable in
this offline container, so ``generators`` provides statistically similar
synthetic stand-ins (same |V|, |E|, label counts scaled to the container).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import bitset


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected labeled graph (host side).

    Attributes:
      n: number of vertices (ids ``0..n-1``).
      labels: ``(n,)`` int32 vertex labels (``0`` allowed; arbitrary ints).
      edges: ``(m, 2)`` int32, each row ``(u, v)`` with ``u < v``, unique,
        no self loops. Edge ids are row indices.
      edge_labels: optional ``(m,)`` int32.
    """

    n: int
    labels: np.ndarray
    edges: np.ndarray
    edge_labels: Optional[np.ndarray] = None

    def __post_init__(self):
        edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)
        edges = np.sort(edges, axis=1)
        if len(edges):
            if (edges[:, 0] == edges[:, 1]).any():
                raise ValueError("self loops are not supported")
            edges = np.unique(edges, axis=0)
        object.__setattr__(self, "edges", edges)
        object.__setattr__(
            self, "labels", np.asarray(self.labels, dtype=np.int32).reshape(self.n)
        )
        if self.edge_labels is not None:
            object.__setattr__(
                self,
                "edge_labels",
                np.asarray(self.edge_labels, dtype=np.int32).reshape(len(edges)),
            )

    # -- derived host-side structures ------------------------------------
    @property
    def m(self) -> int:
        return len(self.edges)

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int32)
        np.add.at(deg, self.edges[:, 0], 1)
        np.add.at(deg, self.edges[:, 1], 1)
        return deg

    def csr(self):
        """Sorted CSR adjacency: (indptr (n+1,), indices (2m,), eids (2m,))."""
        u = np.concatenate([self.edges[:, 0], self.edges[:, 1]])
        v = np.concatenate([self.edges[:, 1], self.edges[:, 0]])
        e = np.concatenate([np.arange(self.m), np.arange(self.m)]).astype(np.int32)
        order = np.lexsort((v, u))
        u, v, e = u[order], v[order], e[order]
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(indptr, u + 1, 1)
        indptr = np.cumsum(indptr)
        return indptr, v.astype(np.int32), e

    def neighbor_table(self):
        """Padded (n, D) neighbour table + matching edge-id table, pad = -1.

        Vectorised scatter: CSR entry j of vertex v lands at column
        ``j - indptr[v]`` — no per-vertex Python loop, which dominated
        device-graph build time at mico/patents scales."""
        indptr, indices, eids = self.csr()
        deg = (indptr[1:] - indptr[:-1]).astype(np.int32)
        d = max(1, int(deg.max()) if self.n else 1)
        nbr = np.full((self.n, d), -1, dtype=np.int32)
        ned = np.full((self.n, d), -1, dtype=np.int32)
        if len(indices):
            rows = np.repeat(np.arange(self.n), deg)
            cols = np.arange(len(indices)) - np.repeat(indptr[:-1], deg)
            nbr[rows, cols] = indices
            ned[rows, cols] = eids
        return nbr, ned, deg

    def adjacency_tile(self, lo: int, hi: int) -> np.ndarray:
        """Packed adjacency rows for the vertex range ``[lo, hi)``:
        ``(hi - lo, ceil(n/32))`` uint32, built by an O(m) bit scatter —
        never the dense ``(n, n)`` bool intermediate. This is the unit the
        partitioned layout (:func:`to_partitioned`) stacks per shard."""
        lo, hi = int(lo), int(hi)
        w = bitset.n_words(self.n)
        words = np.zeros((max(hi - lo, 0), w), dtype=np.uint32)
        if self.m and hi > lo:
            u = np.concatenate([self.edges[:, 0], self.edges[:, 1]])
            v = np.concatenate([self.edges[:, 1], self.edges[:, 0]])
            sel = (u >= lo) & (u < hi)
            u, v = u[sel] - lo, v[sel]
            np.bitwise_or.at(
                words,
                (u, v // bitset.WORD_BITS),
                np.uint32(1) << (v % bitset.WORD_BITS).astype(np.uint32),
            )
        return words

    def adjacency_bits(self) -> np.ndarray:
        """Whole packed adjacency bitmap — one full-range tile. O(m) bit
        scatter (the old implementation materialised a dense O(n^2) bool
        matrix eagerly, capping host-side setup long before device memory
        did)."""
        return self.adjacency_tile(0, self.n)

    def to_networkx(self):
        import networkx as nx

        g = nx.Graph()
        for i in range(self.n):
            g.add_node(i, label=int(self.labels[i]))
        for eid, (u, v) in enumerate(self.edges):
            lbl = int(self.edge_labels[eid]) if self.edge_labels is not None else 0
            g.add_edge(int(u), int(v), label=lbl)
        return g


class DeviceGraph(NamedTuple):
    """Device-side graph pytree used by the exploration kernels."""

    labels: jnp.ndarray       # (n,) int32
    nbr: jnp.ndarray          # (n, D) int32 neighbour ids, pad -1
    nbr_eid: jnp.ndarray      # (n, D) int32 incident edge ids, pad -1
    deg: jnp.ndarray          # (n,) int32
    adj_bits: jnp.ndarray     # (n, W) uint32 packed adjacency
    edge_uv: jnp.ndarray      # (m, 2) int32 endpoints, u < v
    edge_labels: jnp.ndarray  # (m,) int32 (zeros when unlabeled)

    @property
    def n(self) -> int:
        return self.labels.shape[0]

    @property
    def m(self) -> int:
        return self.edge_uv.shape[0]

    @property
    def max_degree(self) -> int:
        return self.nbr.shape[1]

    def is_edge(self, u, v):
        """Vectorised O(1) edge query; False for negative ids."""
        return bitset.test_bit(self.adj_bits, u, v)


def to_device(g: Graph) -> DeviceGraph:
    nbr, ned, deg = g.neighbor_table()
    edge_labels = (
        g.edge_labels
        if g.edge_labels is not None
        else np.zeros(g.m, dtype=np.int32)
    )
    return DeviceGraph(
        labels=jnp.asarray(g.labels),
        nbr=jnp.asarray(nbr),
        nbr_eid=jnp.asarray(ned),
        deg=jnp.asarray(deg),
        adj_bits=jnp.asarray(g.adjacency_bits()),
        edge_uv=jnp.asarray(g.edges.astype(np.int32)),
        edge_labels=jnp.asarray(edge_labels),
    )


# ---------------------------------------------------------------------------
# Partitioned layout: per-device CSR shards + packed adjacency tiles
# ---------------------------------------------------------------------------

def partition_bounds(g: Graph, n_parts: int, balance: str = "degree") -> np.ndarray:
    """Contiguous vertex-range partition boundaries: ``(n_parts + 1,)`` int32
    offsets with ``offsets[0] == 0`` and ``offsets[-1] == n``.

    ``balance="vertex"`` splits the id space evenly; ``balance="degree"``
    places the boundaries so each shard owns ~1/W of the total adjacency
    *payload* (degree + 1 per vertex, the +1 keeping empty-degree runs from
    collapsing a shard to zero rows on skewed graphs)."""
    n_parts = int(n_parts)
    if n_parts < 1:
        raise ValueError(f"n_parts must be >= 1, got {n_parts}")
    if balance == "vertex":
        bounds = np.linspace(0, g.n, n_parts + 1)
    elif balance == "degree":
        load = np.cumsum(g.degrees().astype(np.int64) + 1)
        total = load[-1] if g.n else 0
        targets = total * np.arange(1, n_parts) / n_parts
        inner = np.searchsorted(load, targets, side="left") + 1
        bounds = np.concatenate([[0], inner, [g.n]])
    else:
        raise ValueError(f"unknown partition balance {balance!r}")
    bounds = np.rint(bounds).astype(np.int64)
    # monotone repair: a degenerate split (tiny n) may duplicate boundaries
    bounds = np.maximum.accumulate(np.clip(bounds, 0, g.n))
    return bounds.astype(np.int32)


class PartitionedGraph(NamedTuple):
    """The partitioned device layout (DESIGN.md §11): contiguous vertex
    ranges, one CSR shard + packed-bitmap adjacency tile per part, padded to
    a common row count so the shards stack into single arrays whose leading
    axis is the shard axis — exactly what ``shard_map`` splits over the mesh
    (``P(axes)``), while ``labels`` / ``edge_uv`` / ``edge_labels`` stay
    replicated (O(n + m) id/label payload, not adjacency).

    On a single process the stacked tables double as a *total* graph view:
    :meth:`is_edge` translates global vertex ids through ``part_offsets``,
    so every layer that only asks id/adjacency questions (canonicality
    checks, quick patterns, ODAG extraction) runs unchanged on either
    layout. The per-shard tables are what a device actually holds; the
    exploration hot path reaches them through gathered halo tiles
    (``explore.build_tile_view`` / ``kernels/gather.py``)."""

    part_offsets: jnp.ndarray  # (W + 1,) int32 vertex-range boundaries
    labels: jnp.ndarray        # (n,) int32 — replicated
    edge_uv: jnp.ndarray       # (m, 2) int32 — replicated
    edge_labels: jnp.ndarray   # (m,) int32 — replicated
    nbr_sh: jnp.ndarray        # (W, P, D) int32 neighbour shards, pad -1
    nbr_eid_sh: jnp.ndarray    # (W, P, D) int32 incident-edge shards, pad -1
    deg_sh: jnp.ndarray        # (W, P) int32 degrees, pad 0
    adj_sh: jnp.ndarray        # (W, P, Wd) uint32 packed adjacency tiles

    @property
    def n(self) -> int:
        return self.labels.shape[0]

    @property
    def m(self) -> int:
        return self.edge_uv.shape[0]

    @property
    def n_parts(self) -> int:
        return self.nbr_sh.shape[0]

    @property
    def tile_rows(self) -> int:
        """Padded rows per shard (P): the common slot count the stacks use."""
        return self.nbr_sh.shape[1]

    @property
    def max_degree(self) -> int:
        return self.nbr_sh.shape[2]

    def owner(self, v):
        """Shard owning each (clipped-safe) global vertex id."""
        safe = jnp.clip(v, 0, self.n - 1)
        return jnp.clip(
            jnp.searchsorted(self.part_offsets, safe, side="right") - 1,
            0, self.n_parts - 1,
        ).astype(jnp.int32)

    def flat_index(self, v):
        """(flat row into the shard-stacked tables, in-range mask) for
        global vertex ids ``v`` — rows of pad slots are never produced."""
        v = jnp.asarray(v)
        own = self.owner(v)
        loc = jnp.clip(v, 0, self.n - 1) - self.part_offsets[own]
        ok = (v >= 0) & (v < self.n)
        return own * self.tile_rows + loc, ok

    def nbr_rows(self, v):
        """Gathered neighbour rows ``(..., D)`` for global ids (pad -1)."""
        fi, ok = self.flat_index(v)
        rows = self.nbr_sh.reshape(-1, self.max_degree)[fi]
        return jnp.where(ok[..., None], rows, -1)

    def is_edge(self, u, v):
        """Total O(1) edge query across the shard stack (False for
        out-of-range ids) — same contract as ``DeviceGraph.is_edge``."""
        fi, ok = self.flat_index(u)
        adj_flat = self.adj_sh.reshape(-1, self.adj_sh.shape[2])
        return bitset.test_bit(adj_flat, jnp.where(ok, fi, -1), v)

    # -- byte accounting for the bench_graphshard gate ---------------------
    @property
    def per_device_adjacency_bytes(self) -> int:
        """Adjacency payload ONE device holds: its CSR shard (neighbour +
        incident-edge + degree rows) plus its packed adjacency tile."""
        w = self.n_parts
        return (
            self.nbr_sh.size + self.nbr_eid_sh.size + self.deg_sh.size
        ) * 4 // w + self.adj_sh.size * 4 // w

    @property
    def replicated_bytes(self) -> int:
        """Payload every device still replicates (labels + edge table)."""
        return (self.labels.size + self.edge_uv.size + self.edge_labels.size) * 4


def replicated_adjacency_bytes(g: DeviceGraph) -> int:
    """Adjacency payload of the replicated layout (every device holds all
    of it): the bench_graphshard baseline."""
    return (g.nbr.size + g.nbr_eid.size + g.deg.size + g.adj_bits.size) * 4


def to_partitioned(
    g: "Graph | DeviceGraph", n_parts: int, balance: str = "degree"
) -> PartitionedGraph:
    """Build the partitioned device layout from a host graph: vertex-range
    CSR shards (optionally degree-balanced boundaries) + per-range packed
    adjacency tiles, padded to a common row count and stacked on a leading
    shard axis. Adjacency tiles are built range-wise (O(m) per shard) — the
    dense O(n^2) intermediate never exists on the host either. A
    ``DeviceGraph`` is accepted too (re-partitioning an already-uploaded
    graph, e.g. on elastic restore): its content arrays round-trip through
    the host ``Graph`` unchanged."""
    if isinstance(g, DeviceGraph):
        g = Graph(
            n=g.n,
            labels=np.asarray(g.labels),
            edges=np.asarray(g.edge_uv),
            edge_labels=np.asarray(g.edge_labels),
        )
    bounds = partition_bounds(g, n_parts, balance)
    nbr, ned, deg = g.neighbor_table()
    d = nbr.shape[1]
    w = bitset.n_words(g.n)
    rows = max(int((bounds[1:] - bounds[:-1]).max()) if n_parts else 1, 1)
    nbr_sh = np.full((n_parts, rows, d), -1, dtype=np.int32)
    ned_sh = np.full((n_parts, rows, d), -1, dtype=np.int32)
    deg_sh = np.zeros((n_parts, rows), dtype=np.int32)
    adj_sh = np.zeros((n_parts, rows, w), dtype=np.uint32)
    for s in range(n_parts):
        lo, hi = int(bounds[s]), int(bounds[s + 1])
        nbr_sh[s, : hi - lo] = nbr[lo:hi]
        ned_sh[s, : hi - lo] = ned[lo:hi]
        deg_sh[s, : hi - lo] = deg[lo:hi]
        adj_sh[s, : hi - lo] = g.adjacency_tile(lo, hi)
    edge_labels = (
        g.edge_labels
        if g.edge_labels is not None
        else np.zeros(g.m, dtype=np.int32)
    )
    return PartitionedGraph(
        part_offsets=jnp.asarray(bounds),
        labels=jnp.asarray(g.labels),
        edge_uv=jnp.asarray(g.edges.astype(np.int32)),
        edge_labels=jnp.asarray(edge_labels),
        nbr_sh=jnp.asarray(nbr_sh),
        nbr_eid_sh=jnp.asarray(ned_sh),
        deg_sh=jnp.asarray(deg_sh),
        adj_sh=jnp.asarray(adj_sh),
    )


# ---------------------------------------------------------------------------
# Generators (synthetic stand-ins for the paper's datasets)
# ---------------------------------------------------------------------------

def random_labeled(
    n: int,
    m: int,
    n_labels: int,
    seed: int = 0,
    power_law: bool = True,
) -> Graph:
    """Random labeled graph with roughly scale-free degrees (paper's graphs
    are scale-free social/citation networks)."""
    rng = np.random.default_rng(seed)
    if power_law:
        w = 1.0 / np.arange(1, n + 1) ** 0.75
        w /= w.sum()
    else:
        w = np.full(n, 1.0 / n)
    us = rng.choice(n, size=int(m * 1.6), p=w)
    vs = rng.choice(n, size=int(m * 1.6), p=w)
    keep = us != vs
    e = np.stack([us[keep], vs[keep]], axis=1)
    e = np.sort(e, axis=1)
    e = np.unique(e, axis=0)
    if len(e) > m:
        idx = rng.choice(len(e), size=m, replace=False)
        e = e[np.sort(idx)]
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    return Graph(n=n, labels=labels, edges=e.astype(np.int32))


def citeseer_like(scale: float = 1.0, seed: int = 7) -> Graph:
    """CiteSeer-shaped: 3,312 vertices / 4,732 edges / 6 labels (Table 1)."""
    n = max(8, int(3312 * scale))
    m = max(8, int(4732 * scale))
    return random_labeled(n, m, n_labels=6, seed=seed)


def mico_like(scale: float = 0.02, seed: int = 11) -> Graph:
    """MiCo-shaped: 100k vertices / 1.08M edges / 29 labels (Table 1),
    scaled down by default for the container."""
    n = max(16, int(100_000 * scale))
    m = max(16, int(1_080_298 * scale))
    return random_labeled(n, m, n_labels=29, seed=seed)


def patents_like(scale: float = 0.001, seed: int = 13) -> Graph:
    """Patents-shaped: 2.74M vertices / 13.97M edges / 37 labels (Table 1)."""
    n = max(16, int(2_745_761 * scale))
    m = max(16, int(13_965_409 * scale))
    return random_labeled(n, m, n_labels=37, seed=seed)


def unlabeled_sn_like(scale: float = 0.0005, seed: int = 17) -> Graph:
    """SN-shaped: dense unlabeled social graph (avg degree 79, Table 1)."""
    n = max(16, int(5_022_893 * scale))
    m = max(32, int(n * 39.5))
    g = random_labeled(n, m, n_labels=1, seed=seed, power_law=True)
    return Graph(n=g.n, labels=np.zeros(g.n, dtype=np.int32), edges=g.edges)


# -- tiny deterministic graphs used throughout the tests --------------------

def paper_figure2() -> Graph:
    """The 4-vertex graph of Figure 2: labels blue/yellow alternating on a
    path 1-2-3-4 (we use ids 0..3; blue=0, yellow=1)."""
    return Graph(
        n=4,
        labels=np.array([0, 1, 0, 1], dtype=np.int32),
        edges=np.array([[0, 1], [1, 2], [2, 3]], dtype=np.int32),
    )


def triangle_plus_tail() -> Graph:
    """Triangle 0-1-2 plus tail 2-3 (Figure 5's example shape)."""
    return Graph(
        n=5,
        labels=np.zeros(5, dtype=np.int32),
        edges=np.array([[0, 1], [0, 2], [1, 2], [2, 3], [3, 4]], dtype=np.int32),
    )


def complete(k: int, n_labels: int = 1, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    e = np.array([(i, j) for i in range(k) for j in range(i + 1, k)], np.int32)
    return Graph(
        n=k,
        labels=rng.integers(0, n_labels, size=k).astype(np.int32),
        edges=e,
    )
