"""Frontier-store interface + the dense-array store (DESIGN.md §7).

A :class:`FrontierStore` owns how the embeddings of one BSP superstep live
*between* supersteps — the data-flow pivot that decouples frontier size from
device memory. The engines never hold "the frontier" as one resident array
any more; they

  * ``append`` child blocks while expanding (write side, staging area),
  * ``seal`` at the superstep boundary (the store may compress / merge
    worker-local state here — this is the paper's §5.2 storage step),
  * iterate ``chunks`` of re-materialised rows at the next superstep
    (read side; bounded waves when a device budget is set), and
  * read byte stats (``raw_bytes`` vs ``stored_bytes``) that feed the
    Fig. 9/10 compression accounting in :class:`repro.core.stats.StepStats`.

Concrete stores: :class:`RawStore` (this module) keeps the rows verbatim —
exactly the pre-subsystem behaviour, extracted behind the interface;
:class:`repro.core.store.odag_store.ODAGStore` keeps them as per-size ODAGs;
:class:`repro.core.store.spill.SpillStore` wraps either to bound the rows
materialised per wave.
"""
from __future__ import annotations

import abc
from typing import Iterator, List, Optional

import numpy as np

from repro.core import obs


def _delete_buffer(buf) -> None:
    try:
        buf.delete()
    except Exception:  # pragma: no cover - deletion is best-effort
        pass


def resolve_rows(rows, count=None) -> np.ndarray:
    """Resolve one staged block to host int32 rows at the superstep seal.

    ``rows`` may be a host array or a *device* array still padded to its
    chunk program's capacity — the fused engine (DESIGN.md §8) appends
    device buffers as-is so no transfer happens mid-superstep; the single
    ``np.asarray`` here (after the step's one count drain) is where the
    device→host copy lands. ``count`` slices the valid prefix of
    capacity-padded blocks — sliced *on device first* so only the valid
    rows cross to the host, never the padding. Device buffers are deleted
    after the copy so peak HBM drops as chunks are folded into the store.
    """
    padded = None
    if count is not None and hasattr(rows, "delete"):
        padded, rows = rows, rows[: int(count)]    # device-side prefix slice
        count = None
    arr = np.asarray(rows, dtype=np.int32)
    if arr is not rows and hasattr(rows, "delete"):
        _delete_buffer(rows)
    if padded is not None:
        _delete_buffer(padded)
    if count is not None:
        arr = arr[: int(count)]
    return arr


class FrontierStore(abc.ABC):
    """Owns one frontier (all embeddings of the current size) between steps."""

    #: "raw" or "odag" — engines use this for the Fig. 9 byte accounting.
    kind: str = "raw"

    # -- write side (during a superstep's expansion) ----------------------
    @abc.abstractmethod
    def append(self, rows, worker: int = 0, count=None) -> None:
        """Stage a block of same-size child embeddings (int32 (B, k)).

        ``rows`` may be a host array or a capacity-padded device array with
        ``count`` valid leading rows; stores MUST NOT force a host transfer
        here — staging is lazy and blocks resolve at ``seal`` (DESIGN.md
        §8, via :func:`resolve_rows`). ``worker`` tags the producing worker
        so distributed seals can merge worker-local state (RawStore
        ignores it)."""

    @abc.abstractmethod
    def seal(self, size: int) -> None:
        """Superstep boundary: promote the staged blocks of ``size``-column
        rows to the current frontier, dropping the previous one. Compressing
        stores build their between-step representation here."""

    # -- read side (the next superstep) -----------------------------------
    @property
    @abc.abstractmethod
    def n_rows(self) -> int:
        """Rows appended into the sealed frontier (the Fig. 9 baseline)."""

    @property
    @abc.abstractmethod
    def size(self) -> int:
        """Embedding size (columns) of the sealed frontier."""

    @property
    def raw_bytes(self) -> int:
        """What shipping the frontier as a dense embedding list costs."""
        return self.n_rows * self.size * 4

    @property
    @abc.abstractmethod
    def stored_bytes(self) -> int:
        """What the store actually holds between supersteps."""

    @property
    def exchange_bytes(self) -> int:
        """Bytes a worker ships per frontier exchange of the sealed
        frontier: the dense row block here (broadcast-then-partition); the
        merged (Dense)ODAG for the ODAG store. Feeds
        ``StepStats.collective_bytes`` in the distributed runtime."""
        return self.raw_bytes

    @abc.abstractmethod
    def chunks(self, max_rows: Optional[int] = None) -> Iterator[np.ndarray]:
        """Yield the frontier re-materialised as int32 (b, size) waves of at
        most ``max_rows`` rows each (one wave when unbounded)."""

    def materialize(self) -> np.ndarray:
        """The whole frontier as one host array (convenience over chunks)."""
        waves = list(self.chunks())
        if not waves:
            return np.zeros((0, max(self.size, 1)), np.int32)
        return waves[0] if len(waves) == 1 else np.concatenate(waves, axis=0)

    def worker_parts(self, n_workers: int) -> List[np.ndarray]:
        """Re-materialise the frontier as one slice per worker (paper §5.3).

        Default: even block split (what ``partition_frontier`` did);
        cost-balancing stores override this with §5.3 cost-annotated
        partitions."""
        rows = self.materialize()
        b = len(rows)
        per = -(-b // n_workers) if b else 0
        return [rows[w * per : (w + 1) * per] for w in range(n_workers)]

    # -- checkpointing (DESIGN.md §9) --------------------------------------
    @abc.abstractmethod
    def state_dict(self) -> dict:
        """The sealed frontier as a serialisable payload:
        ``{"kind": str, "meta": {json-able scalars}, "arrays": {name:
        ndarray}}``. Sealed stores are the ONLY inter-superstep state, so
        this (plus the superstep cursor) IS the mining checkpoint
        (``repro.core.runtime.checkpoint``)."""

    @abc.abstractmethod
    def from_state_dict(self, sd: dict) -> None:
        """Restore a sealed frontier from :meth:`state_dict` output onto a
        freshly constructed store (construction args — graph, filters,
        budgets — come from the resuming run's config, which is what makes
        restore elastic). Raises ``ValueError`` on a payload of a
        different store kind."""

    def _check_kind(self, sd: dict) -> None:
        if sd.get("kind") != self.kind:
            raise ValueError(
                f"checkpoint store payload is {sd.get('kind')!r}, this run "
                f"is configured for a {self.kind!r} store"
            )


class RawStore(FrontierStore):
    """Dense embedding-list store: the pre-subsystem engine behaviour.

    ``stored_bytes == raw_bytes`` — nothing is compressed; ``chunks`` yields
    zero-copy views. This is the Fig. 9/10 baseline the ODAG store is
    measured against."""

    kind = "raw"

    def __init__(self) -> None:
        self._staged: List[tuple] = []        # (rows, count) — lazy blocks
        self._frontier = np.zeros((0, 1), np.int32)

    def append(self, rows, worker: int = 0, count=None) -> None:
        if len(rows) and (count is None or count):
            self._staged.append((rows, count))

    def seal(self, size: int) -> None:
        with obs.span("store.seal", kind="raw", size=size,
                      blocks=len(self._staged)):
            blocks = [resolve_rows(r, c) for r, c in self._staged]
            blocks = [b for b in blocks if len(b)]
            self._frontier = (
                np.concatenate(blocks, axis=0)
                if blocks
                else np.zeros((0, size), np.int32)
            )
            self._staged = []

    @property
    def n_rows(self) -> int:
        return len(self._frontier)

    @property
    def size(self) -> int:
        return self._frontier.shape[1]

    @property
    def stored_bytes(self) -> int:
        return self.raw_bytes

    def chunks(self, max_rows: Optional[int] = None) -> Iterator[np.ndarray]:
        if not len(self._frontier):
            return
        step = max_rows or len(self._frontier)
        for lo in range(0, len(self._frontier), step):
            yield self._frontier[lo : lo + step]

    def materialize(self) -> np.ndarray:
        return self._frontier

    def state_dict(self) -> dict:
        return {
            "kind": "raw",
            "meta": {"size": int(self.size)},
            "arrays": {"frontier": self._frontier},
        }

    def from_state_dict(self, sd: dict) -> None:
        self._check_kind(sd)
        rows = np.asarray(sd["arrays"]["frontier"], dtype=np.int32)
        self._frontier = rows.reshape(len(rows), int(sd["meta"]["size"]))
        self._staged = []


def make_store(
    kind: str,
    g=None,
    *,
    mode: str = "vertex",
    app_filter=None,
    use_pallas: bool = False,
    interpret=None,
    dense_exchange: bool = False,
    device_budget_bytes: Optional[int] = None,
) -> FrontierStore:
    """Build the store an engine config asks for.

    ``kind``: "raw" or "odag". An ``device_budget_bytes`` wraps the store in
    a :class:`SpillStore` so re-materialisation happens in device-budget
    sized waves (larger-than-device-memory mining)."""
    from repro.core.store.odag_store import ODAGStore
    from repro.core.store.spill import SpillStore

    if kind == "raw":
        store: FrontierStore = RawStore()
    elif kind == "odag":
        if g is None:
            raise ValueError("store='odag' needs the device graph")
        store = ODAGStore(
            g,
            mode=mode,
            app_filter=app_filter,
            use_pallas=use_pallas,
            interpret=interpret,
            dense_exchange=dense_exchange,
        )
    else:
        raise ValueError(f"unknown frontier store kind: {kind!r}")
    if device_budget_bytes is not None:
        store = SpillStore(store, device_budget_bytes)
    return store
