"""Pluggable frontier stores: how embeddings live between BSP supersteps.

See DESIGN.md §7. ``RawStore`` keeps the dense embedding list (baseline),
``ODAGStore`` keeps per-size ODAGs with cost-balanced extraction (§5.2/§5.3),
``SpillStore`` bounds per-wave materialisation to a device byte budget.
"""
from repro.core.store.base import FrontierStore, RawStore, make_store
from repro.core.store.odag_store import ODAGStore
from repro.core.store.spill import SpillStore

__all__ = [
    "FrontierStore",
    "RawStore",
    "ODAGStore",
    "SpillStore",
    "make_store",
]
