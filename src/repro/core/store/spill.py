"""Device-budgeted frontier waves: larger-than-memory mining (DESIGN.md §7).

``SpillStore`` wraps any :class:`FrontierStore` and bounds how many rows a
single ``chunks`` wave may materialise, derived from a byte budget for the
device-resident slice. The engine then mines one wave at a time, so the
peak device footprint of a superstep is ``O(budget)`` instead of ``O(B·k)``
— frontiers larger than device memory are mined in waves while the
between-step representation stays whatever the inner store uses (dense rows
on host, or an ODAG).

The inner store's cost-balanced chunking is reused when available (the
ODAG store's §5.3 partitions); waves it over-shoots (a single hub element
whose subtree exceeds the budget) are sliced down to the hard row bound
here.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from repro.core import obs
from repro.core.store.base import FrontierStore


class SpillStore(FrontierStore):
    def __init__(self, inner: FrontierStore, device_budget_bytes: int) -> None:
        if device_budget_bytes <= 0:
            raise ValueError("device_budget_bytes must be positive")
        self._inner = inner
        self._budget_bytes = int(device_budget_bytes)

    @property
    def kind(self) -> str:  # type: ignore[override]
        return self._inner.kind

    @property
    def inner(self) -> FrontierStore:
        return self._inner

    def budget_rows(self) -> int:
        """Rows of the current width that fit the device byte budget."""
        return max(1, self._budget_bytes // (max(self._inner.size, 1) * 4))

    # -- delegation --------------------------------------------------------
    def append(self, rows, worker: int = 0, count=None) -> None:
        self._inner.append(rows, worker=worker, count=count)

    def seal(self, size: int) -> None:
        with obs.span("store.seal", kind=f"spill[{self._inner.kind}]",
                      size=size, budget_rows=self.budget_rows()):
            self._inner.seal(size)

    @property
    def n_rows(self) -> int:
        return self._inner.n_rows

    @property
    def size(self) -> int:
        return self._inner.size

    @property
    def stored_bytes(self) -> int:
        return self._inner.stored_bytes

    @property
    def exchange_bytes(self) -> int:
        return self._inner.exchange_bytes

    def materialize(self) -> np.ndarray:
        return self._inner.materialize()

    def worker_parts(self, n_workers: int) -> List[np.ndarray]:
        return self._inner.worker_parts(n_workers)

    def state_dict(self) -> dict:
        # the budget is config, not state: a spill-wrapped checkpoint is
        # byte-identical to the inner store's, so a run may resume with a
        # different (or no) device budget — elastic in the memory dimension
        return self._inner.state_dict()

    def from_state_dict(self, sd: dict) -> None:
        self._inner.from_state_dict(sd)

    # -- the point of the wrapper -----------------------------------------
    def chunks(self, max_rows: Optional[int] = None) -> Iterator[np.ndarray]:
        budget = self.budget_rows()
        if max_rows is not None:
            budget = min(budget, max_rows)
        for wave in self._inner.chunks(budget):
            if len(wave) <= budget:
                yield wave
                continue
            for lo in range(0, len(wave), budget):
                yield wave[lo : lo + budget]
