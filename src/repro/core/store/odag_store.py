"""ODAG-backed frontier store (paper §5.2/§5.3, DESIGN.md §7).

Between supersteps the frontier lives as one per-size ODAG instead of a
dense embedding list: O(k·N²) bits instead of O(B·k) rows — the compression
that lets Arabesque's supersteps exceed memory (Fig. 9). Re-materialisation
walks the ODAG back into rows, re-applying exactly the Algorithm-1 filters
(validity + incremental canonicality + the app's phi), which by the
completeness argument removes every spurious path.

Two merge paths on ``seal``:

  * single worker: one ragged :func:`repro.core.odag.build`;
  * ``dense_exchange`` with several workers (the distributed engine): each
    worker's staged rows become a fixed-shape :class:`DenseODAG` and the
    bitmaps are merged with a bitwise OR — computed host-side in this
    single-process runtime, but bit-for-bit what the §5.2 "merge and
    broadcast" OR-allreduce collective produces on a real multi-host mesh
    (the fixed shapes exist exactly so the merge can be one collective).
    The merged dense form is unpacked once for extraction, and its byte
    size is recorded as ``exchange_bytes`` (what that collective would
    ship per worker).

Reads are cost-balanced (§5.3): ``worker_parts`` annotates first-level
elements with their path counts via :func:`repro.core.odag.partition_by_cost`
and extracts one approximately equal-cost partition per worker
(:func:`repro.core.odag.extract_partition`); ``chunks`` uses the same
machinery to bound the rows materialised per wave.

Frontier-set semantics: extraction returns a superset of the appended rows
only when earlier supersteps pruned embeddings by *pattern* (FSM's alpha);
such resurrected rows belong to unsupported patterns by anti-monotonicity,
so the next superstep's alpha re-prunes them and pattern outputs are
unchanged (test_store.py asserts this end-to-end).
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core import obs
from repro.core import odag as odag_lib
from repro.core.store.base import FrontierStore, resolve_rows


class ODAGStore(FrontierStore):
    kind = "odag"

    def __init__(
        self,
        g,
        *,
        mode: str = "vertex",
        app_filter=None,
        use_pallas: bool = False,
        interpret=None,
        dense_exchange: bool = False,
    ) -> None:
        self._g = g
        self._mode = mode
        self._app_filter = app_filter
        self._use_pallas = use_pallas
        self._interpret = interpret
        self._dense_exchange = dense_exchange
        self._staged: Dict[int, List[tuple]] = {}   # (rows, count) lazy blocks
        self._odag: Optional[odag_lib.ODAG] = None
        self._n_rows = 0
        self._size = 1
        self._exchange_bytes = 0

    # -- write side --------------------------------------------------------
    def append(self, rows, worker: int = 0, count=None) -> None:
        if len(rows) and (count is None or count):
            self._staged.setdefault(worker, []).append((rows, count))

    def seal(self, size: int) -> None:
        with obs.span("store.seal", kind="odag", size=size):
            self._seal(size)

    def _seal(self, size: int) -> None:
        blocks = {}
        for w, parts in self._staged.items():
            resolved = [resolve_rows(r, c) for r, c in parts]
            resolved = [b for b in resolved if len(b)]
            if resolved:
                blocks[w] = np.concatenate(resolved, axis=0)
        self._staged = {}
        self._size = size
        self._n_rows = sum(len(b) for b in blocks.values())
        if not self._n_rows:
            self._odag = None
            self._exchange_bytes = 0
            return
        # the id space the dense bitmaps span: vertices (vertex mode) or
        # edge ids (edge mode)
        n_ids = self._g.n if self._mode == "vertex" else self._g.m
        if self._dense_exchange and len(blocks) > 1:
            dense = None
            for rows in blocks.values():
                d = odag_lib.build_dense(rows, n_ids, size)
                dense = d if dense is None else odag_lib.DenseODAG(
                    k=size,
                    domain_bits=dense.domain_bits | d.domain_bits,
                    conn_bits=dense.conn_bits | d.conn_bits,
                )
            self._odag = odag_lib.dense_to_ragged(dense)
            self._exchange_bytes = dense.n_bytes
        else:
            all_rows = np.concatenate(list(blocks.values()), axis=0)
            self._odag = odag_lib.build(all_rows, k=size)
            self._exchange_bytes = self._odag.n_bytes

    # -- read side ---------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self._n_rows

    @property
    def size(self) -> int:
        return self._size

    @property
    def stored_bytes(self) -> int:
        return self._odag.n_bytes if self._odag is not None else 0

    @property
    def exchange_bytes(self) -> int:
        return self._exchange_bytes

    @property
    def odag(self) -> Optional[odag_lib.ODAG]:
        """The sealed per-size ODAG (None when the frontier is empty)."""
        return self._odag

    def _extract(self, o: odag_lib.ODAG) -> np.ndarray:
        with obs.span("odag.extract", rows=int(self._n_rows)):
            return odag_lib.extract(
                self._g,
                o,
                app_filter=self._app_filter,
                mode=self._mode,
                use_pallas=self._use_pallas,
                interpret=self._interpret,
            )

    def _extract_mask(self, mask: np.ndarray) -> np.ndarray:
        with obs.span("odag.extract", partition=True):
            return odag_lib.extract_partition(
                self._g,
                self._odag,
                mask,
                app_filter=self._app_filter,
                mode=self._mode,
                use_pallas=self._use_pallas,
                interpret=self._interpret,
            )

    def chunks(self, max_rows: Optional[int] = None) -> Iterator[np.ndarray]:
        if self._odag is None:
            return
        if max_rows is None:
            rows = self._extract(self._odag)
            if len(rows):
                yield rows
            return
        # §5.3 cost-annotated waves: split the first-level domain into
        # approximately equal-cost runs, one extraction per run. The wave
        # count comes from the appended row count (the path upper bound
        # overestimates by the spurious factor); the per-run *balancing*
        # still uses the cost annotation. A single over-budget first-level
        # element (hub) extracts as one partition whose rows are then
        # sliced, so the yielded waves honour the hard max_rows bound.
        n_parts = max(1, -(-self._n_rows // max(max_rows, 1)))
        n_parts = min(n_parts, max(len(self._odag.domains[0]), 1))
        for mask in odag_lib.partition_by_cost(self._odag, n_parts):
            if not mask.any():
                continue
            rows = self._extract_mask(mask)
            for lo in range(0, len(rows), max_rows):
                yield rows[lo : lo + max_rows]

    def state_dict(self) -> dict:
        """Checkpoint payload (DESIGN.md §9): the per-level domains and
        connectivity bitmaps of the sealed ragged ODAG — the compressed
        form IS what gets persisted, so a checkpoint costs ``stored_bytes``
        (not ``raw_bytes``) on disk too."""
        arrays = {}
        levels = 0
        if self._odag is not None:
            levels = self._odag.k
            for i, d in enumerate(self._odag.domains):
                arrays[f"domain{i}"] = d
            for i, c in enumerate(self._odag.conn):
                arrays[f"conn{i}"] = np.packbits(c, axis=1)
        return {
            "kind": "odag",
            "meta": {
                "size": int(self._size),
                "n_rows": int(self._n_rows),
                "exchange_bytes": int(self._exchange_bytes),
                "levels": levels,
                "conn_widths": (
                    [int(c.shape[1]) for c in self._odag.conn]
                    if self._odag is not None
                    else []
                ),
            },
            "arrays": arrays,
        }

    def from_state_dict(self, sd: dict) -> None:
        self._check_kind(sd)
        meta = sd["meta"]
        self._size = int(meta["size"])
        self._n_rows = int(meta["n_rows"])
        self._exchange_bytes = int(meta["exchange_bytes"])
        self._staged = {}
        levels = int(meta["levels"])
        if not levels:
            self._odag = None
            return
        domains = [
            np.asarray(sd["arrays"][f"domain{i}"], dtype=np.int32)
            for i in range(levels)
        ]
        conn = [
            np.unpackbits(
                np.asarray(sd["arrays"][f"conn{i}"], dtype=np.uint8), axis=1
            )[:, : int(meta["conn_widths"][i])].astype(bool)
            for i in range(levels - 1)
        ]
        self._odag = odag_lib.ODAG(k=levels, domains=domains, conn=conn)

    def worker_parts(self, n_workers: int) -> List[np.ndarray]:
        """Cost-balanced per-worker slices (§5.3 as a real execution path)."""
        if self._odag is None:
            return [np.zeros((0, self._size), np.int32)] * n_workers
        masks = odag_lib.partition_by_cost(self._odag, n_workers)
        return [
            self._extract_mask(m)
            if m.any()
            else np.zeros((0, self._size), np.int32)
            for m in masks
        ]
