"""Vectorised incremental embedding-canonicality checks (paper Alg. 2).

Uniqueness + extendibility (paper Appendix, Thm 2/3) guarantee that pruning
non-canonical candidates removes every automorphic duplicate while keeping
exactly one representative, with no cross-worker coordination. Our tests
verify both properties against brute-force oracles (hypothesis property
tests in ``tests/test_property_canonical.py``).

The checks here are branch-free mask expressions evaluated for a whole batch
of candidates at once (one lane per candidate): the TPU-native form of the
paper's per-embedding linear scan.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.graph import DeviceGraph


def vertex_check(
    g: DeviceGraph,
    members: jnp.ndarray,   # (B, k) int32 parent vertices in visit order, pad -1
    n_valid: jnp.ndarray,   # (B,) int32 number of valid members
    cand: jnp.ndarray,      # (B,) int32 candidate extension vertex
) -> jnp.ndarray:
    """True iff ``members[:n_valid] + [cand]`` is canonical (Alg. 2).

    Assumes the parent itself is canonical (inductive invariant maintained by
    the engine) and that ``cand`` is adjacent to at least one member (true by
    construction of the candidate set). Rows with ``n_valid == 0`` are the
    bootstrap case: every single vertex is canonical.
    """
    b, k = members.shape
    pos = jnp.arange(k)[None, :]
    valid = pos < n_valid[:, None]

    # Alg.2 line 1: if v1 > v -> false.
    first_ok = jnp.where(n_valid > 0, members[:, 0] < cand, True)

    # neighbour mask of cand among the (valid) members.
    neigh = g.is_edge(members, cand[:, None]) & valid

    # foundNeighbour becomes true strictly *after* the first neighbour index:
    # elements before/at the first neighbour are exempt from the id test.
    found_after = jnp.cumsum(neigh.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((b, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    return first_ok & ~violation.any(axis=1)


def edge_check(
    g: DeviceGraph,
    members: jnp.ndarray,   # (B, k) int32 parent edge ids in visit order, pad -1
    n_valid: jnp.ndarray,   # (B,) int32
    cand: jnp.ndarray,      # (B,) int32 candidate extension edge id
) -> jnp.ndarray:
    """Edge-based analogue of Alg. 2 (paper §5.1 "the edge-based case is
    analogous").

    Canonical order: start from the smallest incident-edge id and recursively
    append the smallest-id edge sharing an endpoint with the current
    subgraph. Incrementally: scan members for the first edge sharing an
    endpoint with ``cand``; afterwards no member id may exceed ``cand``.
    """
    b, k = members.shape
    pos = jnp.arange(k)[None, :]
    valid = pos < n_valid[:, None]

    first_ok = jnp.where(n_valid > 0, members[:, 0] < cand, True)

    safe = jnp.maximum(members, 0)
    mu = g.edge_uv[safe]                       # (B, k, 2)
    cu = g.edge_uv[jnp.maximum(cand, 0)]       # (B, 2)
    shares = (
        (mu[..., 0] == cu[:, None, 0])
        | (mu[..., 0] == cu[:, None, 1])
        | (mu[..., 1] == cu[:, None, 0])
        | (mu[..., 1] == cu[:, None, 1])
    ) & valid

    found_after = jnp.cumsum(shares.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((b, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    return first_ok & ~violation.any(axis=1)


# ---------------------------------------------------------------------------
# Reference (non-incremental) canonical forms — used by oracles/tests and by
# the ODAG spurious-path filter when it needs a from-scratch recheck.
# ---------------------------------------------------------------------------

def canonical_order_vertices(adj_query, vertices):
    """Host-side reference: canonical visit order of a vertex set (Appendix
    Thm 3 construction): start at min id; repeatedly append the min-id vertex
    adjacent to the prefix."""
    vs = sorted(int(v) for v in vertices)
    order = [vs[0]]
    rest = set(vs[1:])
    while rest:
        nxt = min(
            (v for v in rest if any(adj_query(u, v) for u in order)),
            default=None,
        )
        if nxt is None:  # disconnected: not a valid embedding
            return None
        order.append(nxt)
        rest.remove(nxt)
    return order


def canonical_order_edges(edge_uv, edge_ids):
    """Host-side reference canonical order for an edge set."""
    es = sorted(int(e) for e in edge_ids)
    order = [es[0]]
    verts = set(edge_uv[es[0]])
    rest = set(es[1:])
    while rest:
        nxt = min(
            (
                e
                for e in rest
                if edge_uv[e][0] in verts or edge_uv[e][1] in verts
            ),
            default=None,
        )
        if nxt is None:
            return None
        order.append(nxt)
        verts.update(edge_uv[nxt])
        rest.remove(nxt)
    return order
