"""Dense bitset utilities (uint32-packed) used for O(1) adjacency queries.

The Giraph implementation of Arabesque chases adjacency-list pointers per
candidate; on TPU we replace that with a packed bitset adjacency matrix so the
canonicality check (Algorithm 2) becomes a fused, branch-free mask expression.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    return (int(n_bits) + WORD_BITS - 1) // WORD_BITS


def pack_bool_matrix(dense: np.ndarray) -> np.ndarray:
    """Pack a (R, N) bool matrix into (R, ceil(N/32)) uint32, LSB-first."""
    dense = np.asarray(dense, dtype=bool)
    r, n = dense.shape
    w = n_words(n)
    padded = np.zeros((r, w * WORD_BITS), dtype=bool)
    padded[:, :n] = dense
    bits = padded.reshape(r, w, WORD_BITS)
    weights = (1 << np.arange(WORD_BITS, dtype=np.uint64)).astype(np.uint64)
    return (bits.astype(np.uint64) * weights).sum(axis=2).astype(np.uint32)


def test_bit(words: jnp.ndarray, row: jnp.ndarray, col: jnp.ndarray) -> jnp.ndarray:
    """Query bit (row, col) of a packed (R, W) uint32 matrix.

    ``row``/``col`` may be any (broadcastable) integer arrays. Out-of-range
    indices (negative) return False.
    """
    row = jnp.asarray(row)
    col = jnp.asarray(col)
    ok = (row >= 0) & (col >= 0)
    r = jnp.maximum(row, 0)
    c = jnp.maximum(col, 0)
    word = words[r, c // WORD_BITS]
    bit = (word >> (c % WORD_BITS).astype(jnp.uint32)) & jnp.uint32(1)
    return ok & (bit == 1)


def popcount_u32(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element population count of a uint32 array (SWAR)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def count_bits(words: jnp.ndarray, axis=-1) -> jnp.ndarray:
    """Total set bits along ``axis`` of a packed uint32 array."""
    return popcount_u32(words).sum(axis=axis)
