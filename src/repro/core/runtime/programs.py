"""Shared device-program machinery of the superstep runtime (DESIGN.md §8/§9).

Everything both execution backends need around ``explore.fused_chunk_step``:
the process-wide jitted chunk-program cache, device-side chunk slicing,
quick-pattern dispatch, eager buffer retirement, and the store-facing app
filter adapter. Extracted from the old ``core/engine.py`` so the serial and
shard-map backends build on one copy.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import explore, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph
from repro.core.runtime.config import next_pow2

#: process-wide jitted chunk programs, keyed by (app identity, flags).
#: Re-running an engine with an equivalent app config reuses the compiled
#: programs instead of re-tracing per run — the jit cache is what the pow2
#: bucketing bounds (DESIGN.md §8), so it should be shared, not rebuilt.
_CHUNK_PROGRAM_CACHE: Dict[tuple, object] = {}


def app_cache_key(app: MiningApp):
    """Hashable identity of an app's *traced* behaviour (class + dataclass
    fields), or None when the app carries unhashable state."""
    try:
        fields = tuple(
            (f.name, getattr(app, f.name)) for f in dataclasses.fields(app)
        )
        key = (type(app).__module__, type(app).__qualname__, fields)
        hash(key)
        return key
    except (TypeError, ValueError):
        return None


def make_expand_fn(app: MiningApp, mode: str, use_pallas: bool = False,
                   fused: bool = False, interpret=None,
                   compact_kernel: bool = False, with_patterns: bool = False,
                   with_aggregates: bool = False, agg_qcap: int = 4096,
                   aggregate_kernel: bool = False, aggregate_bin: str = "sort",
                   with_local_verts: bool = True):
    """Jitted chunk program of the superstep pipeline: expand + canonicality
    + app filter + compaction (+ child quick patterns when the pipeline is
    fused, or the binned per-chunk level-1 partial with ``with_aggregates``
    — DESIGN.md §10). Recompiled per (width, capacity) pow2 bucket; cached
    across runs for hashable app configs."""
    app_key = app_cache_key(app)
    key = None
    if app_key is not None:
        key = (app_key, mode, use_pallas, fused, interpret,
               compact_kernel, with_patterns, with_aggregates, agg_qcap,
               aggregate_kernel, aggregate_bin, with_local_verts)
        cached = _CHUNK_PROGRAM_CACHE.get(key)
        if cached is not None:
            return cached

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def fn(g: DeviceGraph, members, n_valid, out_cap: int):
        return explore.fused_chunk_step(
            g, members, n_valid, out_cap,
            mode=mode,
            app=app,
            with_patterns=with_patterns,
            with_aggregates=with_aggregates,
            agg_qcap=agg_qcap,
            with_local_verts=with_local_verts,
            use_pallas=use_pallas,
            fused=fused,
            compact_kernel=compact_kernel,
            aggregate_kernel=aggregate_kernel,
            aggregate_bin=aggregate_bin,
            interpret=interpret,
        )

    if key is not None:
        _CHUNK_PROGRAM_CACHE[key] = fn
    return fn


def jit_cache_size(fn) -> Optional[int]:
    try:
        return int(fn._cache_size())
    except Exception:  # pragma: no cover - older/newer jax internals
        return None


def initial_frontier(g: DeviceGraph, mode: str) -> np.ndarray:
    """Superstep-1 frontier: every vertex (vertex mode) or edge (edge mode)."""
    n0 = g.n if mode == "vertex" else g.m
    return np.arange(n0, dtype=np.int32)[:, None]


def quick_patterns(g: DeviceGraph, mode: str, members, n_valid):
    if mode == "vertex":
        return pattern_lib.quick_pattern_vertex(g, members, n_valid)
    return pattern_lib.quick_pattern_edge(g, members, n_valid)


def device_chunk(wave_dev, lo: int, cb: int, bucket: int, k: int):
    """Slice chunk ``[lo, lo+cb)`` out of a device-resident wave and pad it
    to its pow2 ``bucket`` on device — no host round-trip per chunk (the
    PR-2 loop re-built every chunk from the host wave)."""
    chunk = jax.lax.slice_in_dim(wave_dev, lo, lo + cb)
    n_valid = jnp.full((cb,), k, jnp.int32)
    if bucket > cb:
        chunk = jnp.concatenate(
            [chunk, jnp.full((bucket - cb, k), -1, jnp.int32)]
        )
        n_valid = jnp.concatenate(
            [n_valid, jnp.zeros((bucket - cb,), jnp.int32)]
        )
    return chunk, n_valid


def retire(*buffers) -> None:
    """Best-effort immediate deletion of drained device buffers (instead of
    waiting for GC) — the fused pipeline's peak-HBM control."""
    for b in buffers:
        if hasattr(b, "delete"):
            try:
                b.delete()
            except Exception:
                pass


def iter_chunks(waves, wave_dev, chunk_size: int, size: int):
    """Yield device-sliced, pow2-padded chunks over all waves, uploading
    each wave at most once (reusing the aggregation pass's upload)."""
    for wi, w in enumerate(waves):
        if not len(w):
            continue
        if wave_dev[wi] is None:
            wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
        wd = wave_dev[wi]
        for lo in range(0, len(w), chunk_size):
            cb = min(chunk_size, len(w) - lo)
            bucket = min(chunk_size, next_pow2(max(cb, 1)))
            chunk, n_valid = device_chunk(wd, lo, cb, bucket, size)
            yield wi, lo, cb, bucket, chunk, n_valid


def store_app_filter(app: MiningApp, g: DeviceGraph):
    """Adapt ``app.filter`` to the per-candidate signature ODAG extraction
    re-applies (DESIGN.md §7): extraction rows are already one member-set per
    candidate, so the parent-row indirection is the identity. Returns None
    for the base accept-all filter (nothing to re-apply)."""
    if type(app).filter is MiningApp.filter:
        return None

    def phi(mem, nv, cnd):
        rows = jnp.arange(int(mem.shape[0]), dtype=jnp.int32)
        return app.filter(g, mem, nv, rows, cnd)

    return phi
