"""Serial execution backend: the single-device fused superstep pipeline.

Wraps the chunk-program dataflow of DESIGN.md §8 behind the
:class:`~repro.core.runtime.backend.ExecutionBackend` protocol: the sealed
frontier re-materialises in device-budget waves, each wave is uploaded
once and sliced into pow2-padded chunks on device, a *pilot* chunk
calibrates the step's output-capacity bucket, the remaining chunks dispatch
back-to-back with counts left on device, and the host drains all control
values in stacked window reads — at most TWO host syncs per superstep
(``async_chunks=True``). The PR-2 chunk loop (one blocking ``int(count)``
per chunk) is preserved bit-for-bit as ``async_chunks=False``, the
benchmark baseline of ``benchmarks/bench_superstep.py``.

Pattern aggregation is device-resident by default (DESIGN.md §10,
``device_aggregate=True``): chunk programs emit pre-binned level-1
*partials* that fold across the stacked-drain window
(:class:`repro.core.aggregation.DeviceLevel1`), or — when the store cannot
carry (ODAG resurrection) or FSM needs the local-vertex table — the waves
are re-binned on device at aggregation time. Either way only O(Q) bytes
(distinct codes, counts, canonical domain bitmaps, and an alpha row mask
iff pruning fires) ever cross to the host; ``device_aggregate=False`` keeps
the host reference path (``aggregation.aggregate_rows``).
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, explore, obs, pattern as pattern_lib
from repro.kernels import canonical_refine
from repro.core.api import MiningApp
from repro.core.graph import PartitionedGraph
from repro.core.runtime import faults as faults_lib
from repro.core.runtime import programs
from repro.core.runtime.backend import ExecutionBackend
from repro.core.runtime.config import next_pow2
from repro.core.store import FrontierStore, make_store

#: chunk programs in flight between drains: bounds how many capacity-
#: padded output buffers are device-resident at once (peak HBM is
#: O(window * step_cap), not O(step output)) while keeping host syncs at
#: O(chunks / window) per superstep — 1 + pilot for any step under ~32
#: chunks.
_DRAIN_WINDOW = 32


class SerialBackend(ExecutionBackend):
    name = "serial"

    def _make_store(self) -> FrontierStore:
        config, app = self.config, self.app
        self._use_pallas = config.resolve_use_pallas()
        self._agg_kernel = config.resolve_aggregate_kernel()
        self._agg_bin = config.resolve_aggregate_bin()
        store = make_store(
            config.store, self.g,
            mode=app.mode,
            app_filter=programs.store_app_filter(app, self.g),
            use_pallas=self._use_pallas,
            interpret=config.pallas_interpret,
            device_budget_bytes=config.device_budget_bytes,
        )
        # device-resident aggregation needs alpha at pattern granularity:
        # apps overriding the per-row aggregation_filter keep the host path
        self._device_agg = (
            config.device_aggregate
            and app.wants_patterns
            and type(app).aggregation_filter is MiningApp.aggregation_filter
        )
        # level-2 placement (DESIGN.md §15): host_async needs a deferrable
        # table (loop joins at seal) — pruning/domain apps degrade to the
        # synchronous host reference, bit-identical either way.
        self._canon_placement = config.resolve_canonical_placement()
        if self._canon_placement == "host_async" and not (
            self._device_agg and aggregation.async_level2_ok(app)
        ):
            self._canon_placement = "host"
        if config.canonical_memo_cap is not None:
            pattern_lib.set_memo_cap(config.canonical_memo_cap)
        #: cross-batch level-1 merge capacity, grown pow2 on observed
        #: overflow (the unclamped distinct count rides the one drain)
        self._agg_qcap = max(config.agg_qcap, 1)
        self._run_qcap = next_pow2(self._agg_qcap)
        # child codes / level-1 partials computed in the chunk program are
        # only reusable when the next superstep re-materialises exactly the
        # appended rows in order — true for the raw store (also under a
        # spill budget), not for ODAG extraction (which may resurrect
        # pattern-pruned rows).
        order_preserving = (
            config.async_chunks and app.wants_patterns and store.kind == "raw"
        )
        self.with_patterns = order_preserving and not self._device_agg
        # FSM (wants_domains) re-bins at wave time instead: the domain
        # scatter needs the per-row local-vertex table, which partials
        # deliberately drop
        self.with_aggregates = (
            order_preserving and self._device_agg and not app.wants_domains
        )
        self._expand_fn = programs.make_expand_fn(
            app, app.mode,
            use_pallas=self._use_pallas,
            fused=config.fused_expand,
            interpret=config.pallas_interpret,
            compact_kernel=config.resolve_compact_kernel(),
            with_patterns=self.with_patterns,
            with_aggregates=self.with_aggregates,
            agg_qcap=self._agg_qcap,
            aggregate_kernel=self._agg_kernel,
            aggregate_bin=self._agg_bin,
            with_local_verts=app.wants_domains,
        )
        self._cache_before = programs.jit_cache_size(self._expand_fn)
        self._signatures = set()
        self._lvl1 = None
        self._table = None
        self._gather_probe = (
            self._make_gather_probe()
            if isinstance(self.g, PartitionedGraph)
            else None
        )
        return store

    def _make_gather_probe(self):
        """Jitted tile-gather probe for ``StepStats.t_gather`` (DESIGN.md
        §12): ``build_tile_view`` runs INSIDE the fused chunk program, so
        its share of ``t_expand`` is only separable by re-running the
        gather stage standalone — a probe dispatch paid exclusively under
        ``trace_sync=True`` (the diagnostic mode)."""
        config, mode = self.config, self.app.mode
        use_pallas = self._use_pallas
        compact = config.resolve_compact_kernel()
        interpret = config.pallas_interpret

        @jax.jit
        def probe(g, members, n_valid):
            view = explore.build_tile_view(
                g, members, n_valid, mode,
                use_pallas=use_pallas,
                compact_kernel=compact,
                interpret=interpret,
            )
            return view.nbr_t

        return probe

    # -- superstep hooks ----------------------------------------------------
    def begin_step(self, store, st) -> List[np.ndarray]:
        self._waves = list(store.chunks())
        self._wave_dev: List[Optional[jnp.ndarray]] = [None] * len(self._waves)
        return self._waves

    def quick_codes(self, blocks, size):
        codes_parts, lv_parts = [], []
        for wi, w in enumerate(blocks):
            self._wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
            qp = programs.quick_patterns(
                self.g, self.app.mode, self._wave_dev[wi],
                jnp.full((len(w),), size, dtype=jnp.int32),
            )
            codes_parts.append(np.asarray(qp.codes))
            lv_parts.append(np.asarray(qp.local_verts))
            if self.config.device_budget_bytes is not None:
                # SpillStore contract: one budget wave resident at a time —
                # expansion re-uploads its own wave
                programs.retire(self._wave_dev[wi])
                self._wave_dev[wi] = None
        codes = (
            np.concatenate(codes_parts)
            if codes_parts else np.zeros((0, 3), np.int64)
        )
        lv = (
            np.concatenate(lv_parts)
            if lv_parts
            else np.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), np.int32)
        )
        return codes, lv

    def aggregate(self, codes, lv, st):
        # host-resident level 1 (reference path): placement "device" still
        # routes the miss batch through the refine kernel; "host_async" has
        # no deferrable table here and runs synchronously (bit-identical).
        canon_fn = (
            canonical_refine.make_canon_fn(
                use_kernel=self._agg_kernel,
                interpret=self.config.pallas_interpret,
            )
            if self._canon_placement == "device"
            else None
        )
        agg, canon_slot = aggregation.aggregate_rows(
            self.g.n, codes, lv, self.app.wants_domains, canon_fn=canon_fn
        )
        obs.set_stat(st, "n_quick_patterns", agg.n_quick)
        obs.set_stat(st, "n_canonical_patterns", agg.n_canonical)
        obs.set_stat(st, "n_iso_checks", agg.n_iso_checks)
        return agg, canon_slot

    # -- device-resident aggregation (DESIGN.md §10) ------------------------
    def aggregate_step(self, blocks, size, carried, st):
        if not self._device_agg:
            return super().aggregate_step(blocks, size, carried, st)
        app = self.app
        n_frontier = sum(len(blk) for blk in blocks)
        lvl1 = (
            carried
            if isinstance(carried, aggregation.DeviceLevel1)
            and carried.rows == n_frontier
            else None
        )
        if lvl1 is None:
            lvl1 = self._fold_waves(blocks, size)
        res = lvl1.finish()
        if res is not None and faults_lib.take(
            self.config.faults, "aggregate", st.step, "saturate"
        ):
            # injected count saturation (DESIGN.md §13): discard the packed
            # result exactly as a tripped saturation flag would, forcing
            # the wide re-fold below — same recovery path, deterministic
            res = None
        if res is None:
            # a chunk partial or eager compaction overflowed: the carried
            # state is unrecoverable on device, so re-fold from the waves
            # (whose pristine partials re-merge at the exact capacity).
            # The unclamped distinct count rode the same corruption-flag
            # drain, so grow ``agg_qcap`` pow2-style and rebuild the chunk
            # program at the larger partial capacity — later supersteps
            # keep carrying partials instead of silently falling back to
            # wave re-bins for the rest of the run (labeled graphs like
            # mico cross the default cap with ~37k size-3 quick codes;
            # BENCH_5's carried-partial regression).
            self._run_qcap = max(
                self._run_qcap, next_pow2(max(lvl1.observed_n, 1))
            )
            self._grow_carried_partials(self._run_qcap)
            lvl1 = self._fold_waves(blocks, size)
            res = lvl1.finish()
        uniq, counts_q, nbytes = res
        self._run_qcap = max(self._run_qcap, next_pow2(max(lvl1.observed_n, 1)))
        obs.count(st, "bytes_to_host", nbytes)
        placement = self._canon_placement
        if placement == "host_async":
            # overlap: the loop joins the pending future at the seal
            # boundary, after the next expansion has been enqueued.
            # Eligibility (async_level2_ok) guarantees no pruning reads
            # the table this step, so carrying no _table is safe.
            obs.annotate("canonicalize_submit")
            pending = aggregation.submit_level2(uniq, counts_q)
            self._lvl1, self._table = lvl1, None
            self._agg_blocks, self._agg_size = blocks, size
            return pending, None
        t0 = time.perf_counter()
        with obs.span("canonicalize", placement=placement, n_quick=len(uniq)):
            if placement == "device" and lvl1._final is not None and len(uniq):
                u, c, uv, fcap, _n = lvl1._final
                table, counts, nbytes2 = aggregation.device_level2(
                    u, c, uv, fcap, len(uniq), uniq, counts_q,
                    nvs=aggregation.level2_nvs(app, size),
                    with_domains=app.wants_domains,
                    use_kernel=self._agg_kernel,
                    interpret=self.config.pallas_interpret,
                    method=self._agg_bin,
                )
                obs.count(st, "bytes_to_host", nbytes2)
            else:
                table, counts = aggregation.finish_quick_level2(
                    uniq, counts_q, app.wants_domains
                )
        obs.count(st, "t_canon", time.perf_counter() - t0)
        pc = len(table.canon_codes)
        if app.wants_domains and pc:
            bm = self._scatter_domains(lvl1, table, st)
            supports = aggregation.min_image_support(
                bm, table.canon_n_verts, table.canon_orbits
            )
        else:
            supports = counts.copy()
        agg = aggregation.build_step_aggregates(
            table, counts, supports, len(uniq), st
        )
        self._lvl1, self._table = lvl1, table
        self._agg_blocks, self._agg_size = blocks, size
        return agg, None

    def _grow_carried_partials(self, qcap: int) -> None:
        """Swap the chunk program for one whose per-chunk level-1 partial
        is bound at the grown pow2 ``qcap`` (process-wide cache makes this
        cheap when seen before), keeping the compile accounting consistent
        across the swap. Carried partials stay ON — the old behaviour
        (dropping to wave re-bins for the rest of the run) silently
        forfeited the O(Q) aggregation path on every labeled graph whose
        distinct quick-code count crossed the default cap once."""
        if not self.with_aggregates or qcap <= self._agg_qcap:
            return
        old = programs.jit_cache_size(self._expand_fn)
        done = (
            old - self._cache_before
            if old is not None and self._cache_before is not None
            else None
        )
        self._agg_qcap = qcap
        self._expand_fn = programs.make_expand_fn(
            self.app, self.app.mode,
            use_pallas=self._use_pallas,
            fused=self.config.fused_expand,
            interpret=self.config.pallas_interpret,
            compact_kernel=self.config.resolve_compact_kernel(),
            with_patterns=self.with_patterns,
            with_aggregates=True,
            agg_qcap=self._agg_qcap,
            aggregate_kernel=self._agg_kernel,
            aggregate_bin=self._agg_bin,
            with_local_verts=self.app.wants_domains,
        )
        new = programs.jit_cache_size(self._expand_fn)
        self._cache_before = (
            new - done if new is not None and done is not None else None
        )

    def _fold_waves(self, blocks, size) -> aggregation.DeviceLevel1:
        """Device re-bin of the materialised frontier: quick patterns per
        wave (on the upload the expansion reuses) folded into one
        :class:`DeviceLevel1`; per-wave slot ids and local-vertex tables
        stay device-resident for the FSM domain scatter / alpha masks."""
        config = self.config
        lvl1 = aggregation.DeviceLevel1(
            merge_cap=self._run_qcap,
            use_kernel=self._agg_kernel,
            bin_method=self._agg_bin,
            interpret=config.pallas_interpret,
        )
        wave_dev = (
            self._wave_dev
            if blocks is self._waves
            else [None] * len(blocks)
        )
        for wi, w in enumerate(blocks):
            if not len(w):
                continue
            if wave_dev[wi] is None:
                wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
            qp = programs.quick_patterns(
                self.g, self.app.mode, wave_dev[wi],
                jnp.full((len(w),), size, dtype=jnp.int32),
            )
            lvl1.fold_rows(
                qp.codes,
                qp.local_verts if self.app.wants_domains else None,
            )
            if config.device_budget_bytes is not None:
                programs.retire(wave_dev[wi])
                wave_dev[wi] = None
        return lvl1

    def _scatter_domains(self, lvl1, table, st) -> np.ndarray:
        """FSM phase 2: scatter every batch's vertices into the canonical
        domain bitmap on device; only the (Pc, 8, N) result crosses."""
        pc = len(table.canon_codes)
        pc_cap = next_pow2(max(pc, 1))
        n = self.g.n
        q2c, si = aggregation.level2_device_tables(table, lvl1.final_cap)
        kmax = pattern_lib.MAX_PATTERN_VERTICES
        flat = jnp.zeros((pc_cap * kmax * n + 1,), dtype=bool)
        for i in range(len(lvl1.batches)):
            flat = aggregation.scatter_canon_bitmaps(
                flat, lvl1.batch_slots(i), lvl1.batches[i][1],
                q2c, si, pc_cap, n,
            )
        bm = np.asarray(flat[:-1].reshape(pc_cap, kmax, n)[:pc])
        obs.count(st, "bytes_to_host", bm.nbytes)
        return bm

    def alpha_rows(self, pk, st):
        """Per-row alpha from the per-pattern verdict: gather the (padded)
        per-quick-slot keep table through the device-resident slot ids —
        the O(B) bool mask is the only per-row state that crosses, and only
        because pruning actually fired."""
        lvl1, table = self._lvl1, self._table
        if not lvl1.batches:
            # carried partials hold no per-row slots: re-bin the waves (the
            # distinct table is sorted, so slot order matches `table`)
            lvl1 = self._fold_waves(self._agg_blocks, self._agg_size)
            res = lvl1.finish()
            obs.count(st, "bytes_to_host", res[2])
            self._lvl1 = lvl1
        q = len(table.quick_codes)
        pk_q = np.zeros(lvl1.final_cap, dtype=bool)
        pk_q[:q] = np.asarray(pk, dtype=bool)[table.quick_to_canon]
        pk_dev = jnp.asarray(pk_q)
        parts = [
            pk_dev[lvl1.batch_slots(i)] for i in range(len(lvl1.batches))
        ]
        if not parts:
            return np.zeros((0,), dtype=bool)
        # gather per wave, concatenate on device, drain ONCE — per-wave
        # host round trips would creep back in exactly the spill case
        # (many waves) this pipeline keeps at O(1) drains
        mask = np.asarray(
            parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        )
        obs.count(st, "bytes_to_host", mask.nbytes)
        return mask

    def prune(self, blocks, alpha):
        # pruned rows invalidate the device-resident waves
        programs.retire(*[wd for wd in self._wave_dev if wd is not None])
        blocks = super().prune(blocks, alpha)
        self._waves = blocks
        self._wave_dev = [None] * len(blocks)
        return blocks

    def expand(self, store, blocks, size, st):
        config = self.config
        waves = blocks
        # the device-upload cache is valid only for the exact block list
        # this backend handed out (begin_step) or pruned — anything else
        # re-uploads rather than risking stale rows
        wave_dev = (
            self._wave_dev
            if blocks is self._waves
            else [None] * len(blocks)
        )
        carried = None
        if config.async_chunks:
            #: the NEXT superstep's level-1 state, folded from the chunk
            #: partials as the drain windows complete (DESIGN.md §10)
            lvl1 = (
                aggregation.DeviceLevel1(
                    merge_cap=self._run_qcap,
                    use_kernel=self._agg_kernel,
                    bin_method=self._agg_bin,
                    interpret=config.pallas_interpret,
                )
                if self.with_aggregates
                else None
            )
            if config.device_budget_bytes is not None and len(waves) > 1:
                # SpillStore contract (DESIGN.md §7): at most one budget
                # wave device-resident at a time — pipeline and drain one
                # wave per pass (syncs O(waves), i.e. O(frontier/budget),
                # still independent of the chunk count) and retire each
                # wave's buffers before the next is uploaded.
                parts = []
                for wi in range(len(waves)):
                    sub_dev = [wave_dev[wi]]
                    c, self.capacity = self._expand_fused(
                        store, [waves[wi]], sub_dev, size, self.capacity,
                        st, lvl1,
                    )
                    programs.retire(sub_dev[0])
                    wave_dev[wi] = None
                    if c is not None:
                        parts.append(c)
                if self.with_patterns:
                    carried = (
                        (
                            np.concatenate([p[0] for p in parts]),
                            np.concatenate([p[1] for p in parts]),
                        )
                        if parts
                        else None
                    )
                else:
                    carried = lvl1
            else:
                c, self.capacity = self._expand_fused(
                    store, waves, wave_dev, size, self.capacity, st, lvl1
                )
                carried = lvl1 if self.with_aggregates else c
        else:
            self._expand_legacy(store, waves, size, st)
        # every chunk has been drained — the step's device waves are dead
        programs.retire(*[wd for wd in wave_dev if wd is not None])
        return carried

    def end_step(self, store, st) -> None:
        # release last step's retained level-1 batch state (slot ids,
        # FSM local-vertex tables) and the materialised block list kept
        # for the alpha re-fold, before the checkpoint hook
        self._lvl1 = None
        self._table = None
        self._agg_blocks = None

    def finalize(self, stats) -> None:
        stats.chunk_signatures = sorted(self._signatures)
        cache_after = programs.jit_cache_size(self._expand_fn)
        stats.n_compiles = (
            cache_after - self._cache_before
            if self._cache_before is not None and cache_after is not None
            else len(self._signatures)
        )

    # -- the fused pipeline (DESIGN.md §8) ----------------------------------
    def _rec(self, out, used_cap):
        """Name one chunk program's outputs (layout differs between the
        carried-codes and carried-partials modes)."""
        if self.with_aggregates:
            children, count, u, c, n, ngen, ncanon = out
            return {"children": children, "count": count,
                    "agg": (u, c, n), "ngen": ngen, "ncanon": ncanon,
                    "used_cap": used_cap}
        children, count, codes, lv, ngen, ncanon = out
        return {"children": children, "count": count, "codes": codes,
                "lv": lv, "ngen": ngen, "ncanon": ncanon,
                "used_cap": used_cap}

    def _retire_outputs(self, p) -> None:
        programs.retire(p["children"])
        if "codes" in p:
            programs.retire(p["codes"], p["lv"])
        if "agg" in p:
            programs.retire(*p["agg"][:2])

    def _expand_fused(self, store, waves, wave_dev, size, cap, st, lvl1):
        """One *pilot* chunk calibrates the step's output-capacity bucket
        (sync 1 — the PR-2 loop instead discovers capacity growth once per
        chunk); the remaining chunks dispatch back-to-back with counts left
        on device and drain in stacked reads of ``_DRAIN_WINDOW`` chunks
        (one more sync per window, a single one for typical steps).
        Compaction counts are exact (never clamped to the capacity), so
        overshot chunks are re-dispatched at their exact pow2 bucket
        without any further sync. As a window drains, its children fold
        into the store via device-side prefix slices (only valid rows cross
        to the host), and the next step's pattern state folds device-side:
        carried child quick codes (``with_patterns``) or pre-binned level-1
        partials into ``lvl1`` (``with_aggregates``, DESIGN.md §10); every
        buffer of the window is retired."""
        g, expand_fn = self.g, self._expand_fn
        config, signatures = self.config, self._signatures
        with_patterns, with_aggregates = self.with_patterns, self.with_aggregates
        chunks = list(
            programs.iter_chunks(waves, wave_dev, config.chunk_size, size)
        )
        obs.count(st, "n_chunks", len(chunks))
        if not chunks:
            return None, cap
        if self._gather_probe is not None and obs.sync_active():
            # trace_sync probe (DESIGN.md §12): the tile gather runs INSIDE
            # the fused chunk program; its share of t_expand is only
            # separable by re-running the gather standalone per chunk —
            # paid exclusively in the diagnostic sync mode
            for ch in chunks:
                obs.count(
                    st, "t_gather",
                    obs.probe_time(self._gather_probe, g, ch[4], ch[5]),
                )

        # ---- pilot: sync 1 calibrates the capacity bucket for the step --
        _, _, cb0, bucket0, chunk0, n_valid0 = chunks[0]
        signatures.add((size, bucket0, cap))
        with obs.annotate("fused_chunk.pilot"):
            out = self._rec(expand_fn(g, chunk0, n_valid0, out_cap=cap), cap)
        c0 = int(out["count"])
        obs.count(st, "n_host_syncs", 1)
        if c0 > cap:
            self._retire_outputs(out)
            cap = next_pow2(c0)
            signatures.add((size, bucket0, cap))
            out = self._rec(                       # count known exact
                expand_fn(g, chunk0, n_valid0, out_cap=cap), cap
            )
        # scale the pilot count to a full bucket for the remaining chunks; a
        # chunk that still overshoots is re-dispatched individually below
        est = -((-c0 * bucket0) // max(cb0, 1))        # ceil(c0 * bucket0 / cb0)
        step_cap = max(next_pow2(max(est, 1)), 64)

        codes_parts, lv_parts = [], []

        def drain(pending):
            """One stacked control sync for a window of dispatched chunks,
            exact-cap overflow retries, then fold + retire."""
            meta = np.asarray(
                jnp.stack([
                    s for p, _ in pending
                    for s in (p["count"], p["ngen"], p["ncanon"])
                ])
            ).reshape(-1, 3)
            obs.count(st, "n_host_syncs", 1)
            counts = meta[:, 0]
            obs.count(st, "n_generated", int(meta[:, 1].sum()))
            obs.count(st, "n_canonical", int(meta[:, 2].sum()))
            for i, (p, ch) in enumerate(pending):
                if counts[i] <= p["used_cap"]:
                    continue
                self._retire_outputs(p)             # oversubscribed outputs
                retry_cap = next_pow2(int(counts[i]))
                signatures.add((size, ch[3], retry_cap))
                p2 = self._rec(
                    expand_fn(g, ch[4], ch[5], out_cap=retry_cap), retry_cap
                )
                pending[i] = (p2, ch)
            for i, (p, ch) in enumerate(pending):
                cnt = int(counts[i])
                programs.retire(ch[4], ch[5])       # chunk inputs are dead now
                if cnt:
                    # device-side prefix slices: the padding never crosses
                    # to the host (same contract as store.resolve_rows)
                    store.append(np.asarray(p["children"][:cnt], dtype=np.int32))
                    if with_patterns:
                        codes_parts.append(np.asarray(p["codes"][:cnt]))
                        lv_parts.append(np.asarray(p["lv"][:cnt]))
                    if with_aggregates and lvl1 is not None:
                        # fold the chunk's pre-binned partial; the buffers
                        # are consumed by the merge (refs dropped there)
                        u, c, n = p["agg"]
                        acap = min(p["used_cap"], self._agg_qcap)
                        lvl1.fold_partial(
                            u, c, n, acap, cnt,
                            may_overflow=p["used_cap"] > acap,
                        )
                        p.pop("agg")
                self._retire_outputs(p)

        pending = [(out, chunks[0])]
        for ch in chunks[1:]:
            _, _, _, bucket_i, chunk_i, n_valid_i = ch
            signatures.add((size, bucket_i, step_cap))
            with obs.annotate("fused_chunk"):
                p = self._rec(
                    expand_fn(g, chunk_i, n_valid_i, out_cap=step_cap),
                    step_cap,
                )
            pending.append((p, ch))
            if len(pending) >= _DRAIN_WINDOW:
                drain(pending)
                pending = []
        if pending:
            drain(pending)
        cap = max(cap, step_cap)

        carried = None
        if with_patterns and codes_parts:
            carried = (np.concatenate(codes_parts), np.concatenate(lv_parts))
        return carried, cap

    # -- the PR-2 chunk loop, preserved as the measured baseline -----------
    def _expand_legacy(self, store, waves, size, st):
        """The PR-2 chunk loop, preserved bit-for-bit
        (``benchmarks/bench_superstep.py``): every chunk is sliced and
        padded on the host and re-uploaded (even when aggregation already
        uploaded the wave — the double upload the fused pipeline removes),
        one blocking ``int(count)`` host sync per chunk plus one per
        capacity retry, the capacity bucket reset every superstep, children
        forced through ``np.asarray`` per chunk."""
        g, expand_fn, config = self.g, self._expand_fn, self.config
        cap = max(config.initial_capacity, 1)
        for w in waves:
            for lo in range(0, len(w), config.chunk_size):
                chunk = np.asarray(w[lo : lo + config.chunk_size])
                cb = int(chunk.shape[0])
                bucket = min(config.chunk_size, next_pow2(max(cb, 1)))
                pad = bucket - cb
                if pad:
                    chunk = np.concatenate(
                        [chunk, np.full((pad, size), -1, np.int32)], axis=0
                    )
                n_valid = jnp.concatenate(
                    [jnp.full((cb,), size, jnp.int32),
                     jnp.zeros((pad,), jnp.int32)]
                )
                chunk = jnp.asarray(chunk)
                obs.count(st, "n_chunks", 1)
                while True:
                    self._signatures.add((size, bucket, cap))
                    out = expand_fn(g, chunk, n_valid, out_cap=cap)
                    children, count = out[0], out[1]
                    ngen, ncanon = out[-2], out[-1]
                    count = int(count)
                    obs.count(st, "n_host_syncs", 1)
                    if count <= cap:
                        break
                    programs.retire(children)
                    cap = next_pow2(count)
                obs.count(st, "n_generated", int(ngen))
                obs.count(st, "n_canonical", int(ncanon))
                if count:
                    store.append(np.asarray(children[:count]))
