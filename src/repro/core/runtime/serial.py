"""Serial execution backend: the single-device fused superstep pipeline.

Wraps the chunk-program dataflow of DESIGN.md §8 behind the
:class:`~repro.core.runtime.backend.ExecutionBackend` protocol: the sealed
frontier re-materialises in device-budget waves, each wave is uploaded
once and sliced into pow2-padded chunks on device, a *pilot* chunk
calibrates the step's output-capacity bucket, the remaining chunks dispatch
back-to-back with counts left on device, and the host drains all control
values in stacked window reads — at most TWO host syncs per superstep
(``async_chunks=True``). The PR-2 chunk loop (one blocking ``int(count)``
per chunk) is preserved bit-for-bit as ``async_chunks=False``, the
benchmark baseline of ``benchmarks/bench_superstep.py``.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, pattern as pattern_lib
from repro.core.runtime import programs
from repro.core.runtime.backend import ExecutionBackend
from repro.core.runtime.config import next_pow2
from repro.core.store import FrontierStore, make_store

#: chunk programs in flight between drains: bounds how many capacity-
#: padded output buffers are device-resident at once (peak HBM is
#: O(window * step_cap), not O(step output)) while keeping host syncs at
#: O(chunks / window) per superstep — 1 + pilot for any step under ~32
#: chunks.
_DRAIN_WINDOW = 32


class SerialBackend(ExecutionBackend):
    name = "serial"

    def _make_store(self) -> FrontierStore:
        config, app = self.config, self.app
        self._use_pallas = config.resolve_use_pallas()
        store = make_store(
            config.store, self.g,
            mode=app.mode,
            app_filter=programs.store_app_filter(app, self.g),
            use_pallas=self._use_pallas,
            interpret=config.pallas_interpret,
            device_budget_bytes=config.device_budget_bytes,
        )
        # child codes computed in the chunk program are only reusable when
        # the next superstep re-materialises exactly the appended rows in
        # order — true for the raw store (also under a spill budget), not
        # for ODAG extraction (which may resurrect pattern-pruned rows).
        self.with_patterns = (
            config.async_chunks and app.wants_patterns and store.kind == "raw"
        )
        self._expand_fn = programs.make_expand_fn(
            app, app.mode,
            use_pallas=self._use_pallas,
            fused=config.fused_expand,
            interpret=config.pallas_interpret,
            compact_kernel=config.resolve_compact_kernel(),
            with_patterns=self.with_patterns,
            with_local_verts=app.wants_domains,
        )
        self._cache_before = programs.jit_cache_size(self._expand_fn)
        self._signatures = set()
        return store

    # -- superstep hooks ----------------------------------------------------
    def begin_step(self, store, st) -> List[np.ndarray]:
        self._waves = list(store.chunks())
        self._wave_dev: List[Optional[jnp.ndarray]] = [None] * len(self._waves)
        return self._waves

    def quick_codes(self, blocks, size):
        codes_parts, lv_parts = [], []
        for wi, w in enumerate(blocks):
            self._wave_dev[wi] = jnp.asarray(np.ascontiguousarray(w))
            qp = programs.quick_patterns(
                self.g, self.app.mode, self._wave_dev[wi],
                jnp.full((len(w),), size, dtype=jnp.int32),
            )
            codes_parts.append(np.asarray(qp.codes))
            lv_parts.append(np.asarray(qp.local_verts))
            if self.config.device_budget_bytes is not None:
                # SpillStore contract: one budget wave resident at a time —
                # expansion re-uploads its own wave
                programs.retire(self._wave_dev[wi])
                self._wave_dev[wi] = None
        codes = (
            np.concatenate(codes_parts)
            if codes_parts else np.zeros((0, 3), np.int64)
        )
        lv = (
            np.concatenate(lv_parts)
            if lv_parts
            else np.zeros((0, pattern_lib.MAX_PATTERN_VERTICES), np.int32)
        )
        return codes, lv

    def aggregate(self, codes, lv, st):
        agg, canon_slot = aggregation.aggregate_rows(
            self.g.n, codes, lv, self.app.wants_domains
        )
        st.n_quick_patterns = agg.n_quick
        st.n_canonical_patterns = agg.n_canonical
        st.n_iso_checks = agg.n_iso_checks
        return agg, canon_slot

    def prune(self, blocks, alpha):
        # pruned rows invalidate the device-resident waves
        programs.retire(*[wd for wd in self._wave_dev if wd is not None])
        blocks = super().prune(blocks, alpha)
        self._waves = blocks
        self._wave_dev = [None] * len(blocks)
        return blocks

    def expand(self, store, blocks, size, st):
        config = self.config
        waves = blocks
        # the device-upload cache is valid only for the exact block list
        # this backend handed out (begin_step) or pruned — anything else
        # re-uploads rather than risking stale rows
        wave_dev = (
            self._wave_dev
            if blocks is self._waves
            else [None] * len(blocks)
        )
        carried = None
        if config.async_chunks:
            if config.device_budget_bytes is not None and len(waves) > 1:
                # SpillStore contract (DESIGN.md §7): at most one budget
                # wave device-resident at a time — pipeline and drain one
                # wave per pass (syncs O(waves), i.e. O(frontier/budget),
                # still independent of the chunk count) and retire each
                # wave's buffers before the next is uploaded.
                parts = []
                for wi in range(len(waves)):
                    sub_dev = [wave_dev[wi]]
                    c, self.capacity = self._expand_fused(
                        store, [waves[wi]], sub_dev, size, self.capacity, st
                    )
                    programs.retire(sub_dev[0])
                    wave_dev[wi] = None
                    if c is not None:
                        parts.append(c)
                carried = (
                    (
                        np.concatenate([p[0] for p in parts]),
                        np.concatenate([p[1] for p in parts]),
                    )
                    if parts
                    else None
                )
            else:
                carried, self.capacity = self._expand_fused(
                    store, waves, wave_dev, size, self.capacity, st
                )
        else:
            self._expand_legacy(store, waves, size, st)
        # every chunk has been drained — the step's device waves are dead
        programs.retire(*[wd for wd in wave_dev if wd is not None])
        return carried

    def finalize(self, stats) -> None:
        stats.chunk_signatures = sorted(self._signatures)
        cache_after = programs.jit_cache_size(self._expand_fn)
        stats.n_compiles = (
            cache_after - self._cache_before
            if self._cache_before is not None and cache_after is not None
            else len(self._signatures)
        )

    # -- the fused pipeline (DESIGN.md §8) ----------------------------------
    def _expand_fused(self, store, waves, wave_dev, size, cap, st):
        """One *pilot* chunk calibrates the step's output-capacity bucket
        (sync 1 — the PR-2 loop instead discovers capacity growth once per
        chunk); the remaining chunks dispatch back-to-back with counts left
        on device and drain in stacked reads of ``_DRAIN_WINDOW`` chunks
        (one more sync per window, a single one for typical steps).
        Compaction counts are exact (never clamped to the capacity), so
        overshot chunks are re-dispatched at their exact pow2 bucket
        without any further sync. As a window drains, its children fold
        into the store via device-side prefix slices (only valid rows cross
        to the host), its pattern codes are collected for the next step's
        aggregation, and every buffer of the window is retired."""
        g, expand_fn = self.g, self._expand_fn
        config, signatures = self.config, self._signatures
        with_patterns = self.with_patterns
        chunks = list(
            programs.iter_chunks(waves, wave_dev, config.chunk_size, size)
        )
        st.n_chunks += len(chunks)
        if not chunks:
            return None, cap

        # ---- pilot: sync 1 calibrates the capacity bucket for the step --
        _, _, cb0, bucket0, chunk0, n_valid0 = chunks[0]
        signatures.add((size, bucket0, cap))
        out = expand_fn(g, chunk0, n_valid0, out_cap=cap)
        c0 = int(out[1])
        st.n_host_syncs += 1
        if c0 > cap:
            programs.retire(out[0], out[2], out[3])
            cap = next_pow2(c0)
            signatures.add((size, bucket0, cap))
            out = expand_fn(g, chunk0, n_valid0, out_cap=cap)  # count known exact
        # scale the pilot count to a full bucket for the remaining chunks; a
        # chunk that still overshoots is re-dispatched individually below
        est = -((-c0 * bucket0) // max(cb0, 1))        # ceil(c0 * bucket0 / cb0)
        step_cap = max(next_pow2(max(est, 1)), 64)

        codes_parts, lv_parts = [], []

        def drain(pending):
            """One stacked control sync for a window of dispatched chunks,
            exact-cap overflow retries, then fold + retire."""
            meta = np.asarray(
                jnp.stack([s for p in pending for s in (p[9], p[10], p[11])])
            ).reshape(-1, 3)
            st.n_host_syncs += 1
            counts = meta[:, 0]
            st.n_generated += int(meta[:, 1].sum())
            st.n_canonical += int(meta[:, 2].sum())
            for i, p in enumerate(pending):
                if counts[i] <= p[12]:
                    continue
                programs.retire(p[6], p[7], p[8])   # oversubscribed outputs
                retry_cap = next_pow2(int(counts[i]))
                signatures.add((size, p[3], retry_cap))
                children, _, codes, lv, _, _ = expand_fn(
                    g, p[4], p[5], out_cap=retry_cap
                )
                p[6], p[7], p[8] = children, codes, lv
            for i, p in enumerate(pending):
                cnt = int(counts[i])
                programs.retire(p[4], p[5])         # chunk inputs are dead now
                if cnt:
                    # device-side prefix slices: the padding never crosses
                    # to the host (same contract as store.resolve_rows)
                    store.append(np.asarray(p[6][:cnt], dtype=np.int32))
                    if with_patterns:
                        codes_parts.append(np.asarray(p[7][:cnt]))
                        lv_parts.append(np.asarray(p[8][:cnt]))
                programs.retire(p[6], p[7], p[8])

        # [wi, lo, cb, bucket, chunk, n_valid, children, codes, lv,
        #  count, ngen, ncanon, used_cap]
        pending = [list(chunks[0]) + [out[0], out[2], out[3],
                                      out[1], out[4], out[5], cap]]
        for ch in chunks[1:]:
            _, _, _, bucket_i, chunk_i, n_valid_i = ch
            signatures.add((size, bucket_i, step_cap))
            children, count, codes, lv, ngen, ncanon = expand_fn(
                g, chunk_i, n_valid_i, out_cap=step_cap
            )
            pending.append(
                list(ch) + [children, codes, lv, count, ngen, ncanon, step_cap]
            )
            if len(pending) >= _DRAIN_WINDOW:
                drain(pending)
                pending = []
        if pending:
            drain(pending)
        cap = max(cap, step_cap)

        carried = None
        if with_patterns and codes_parts:
            carried = (np.concatenate(codes_parts), np.concatenate(lv_parts))
        return carried, cap

    # -- the PR-2 chunk loop, preserved as the measured baseline -----------
    def _expand_legacy(self, store, waves, size, st):
        """The PR-2 chunk loop, preserved bit-for-bit
        (``benchmarks/bench_superstep.py``): every chunk is sliced and
        padded on the host and re-uploaded (even when aggregation already
        uploaded the wave — the double upload the fused pipeline removes),
        one blocking ``int(count)`` host sync per chunk plus one per
        capacity retry, the capacity bucket reset every superstep, children
        forced through ``np.asarray`` per chunk."""
        g, expand_fn, config = self.g, self._expand_fn, self.config
        cap = max(config.initial_capacity, 1)
        for w in waves:
            for lo in range(0, len(w), config.chunk_size):
                chunk = np.asarray(w[lo : lo + config.chunk_size])
                cb = int(chunk.shape[0])
                bucket = min(config.chunk_size, next_pow2(max(cb, 1)))
                pad = bucket - cb
                if pad:
                    chunk = np.concatenate(
                        [chunk, np.full((pad, size), -1, np.int32)], axis=0
                    )
                n_valid = jnp.concatenate(
                    [jnp.full((cb,), size, jnp.int32),
                     jnp.zeros((pad,), jnp.int32)]
                )
                chunk = jnp.asarray(chunk)
                st.n_chunks += 1
                while True:
                    self._signatures.add((size, bucket, cap))
                    children, count, _, _, ngen, ncanon = expand_fn(
                        g, chunk, n_valid, out_cap=cap
                    )
                    count = int(count)
                    st.n_host_syncs += 1
                    if count <= cap:
                        break
                    programs.retire(children)
                    cap = next_pow2(count)
                st.n_generated += int(ngen)
                st.n_canonical += int(ncanon)
                if count:
                    store.append(np.asarray(children[:count]))
