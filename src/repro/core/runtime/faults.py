"""Deterministic fault injection + the graceful-degradation ladder (§13).

Arabesque's fault-tolerance story (paper §5.5, and Aridhi et al.,
arXiv:1212.0017) is superstep-granular: fail anywhere, restart from the
last sealed cut. To *test* that story deterministically this module gives
the runtime a :class:`FaultPlan` — an explicit list of (phase, superstep,
kind) triples — tripped at every phase boundary of the BSP loop and at
the shard halo-exchange path. A plan is exact and replayable: the same
plan against the same run fails at the same instruction every time, which
is what lets ``tests/test_faults.py`` assert bit-identical recovery.

Three layers live here:

* **Injection** — :class:`FaultSpec`/:class:`FaultPlan` and the injected
  exception taxonomy (:class:`InjectedCrash`, :class:`InjectedOOM`,
  :class:`InjectedHaloFailure`). Lethal kinds raise (or ``os._exit`` for
  real-kill subprocess tests); benign kinds (``corrupt``, ``saturate``)
  are consumed by the call site that simulates them via :meth:`FaultPlan.take`.
  A plan is *stateful across retries*: a spec fires ``times`` times total,
  shared through every supervisor attempt — so "crash at step 3 once"
  means the retry sails past step 3.
* **Classification** — :func:`classify_failure` maps an arbitrary caught
  exception onto the failure taxonomy the supervisor retries over
  (``oom`` / ``halo`` / ``crash``), matching real XLA OOM messages
  (``RESOURCE_EXHAUSTED``) as well as the injected types.
* **Degradation** — :func:`apply_degradation`, the ladder consulted when
  the *same* phase fails twice: each rung returns a strictly safer
  ``RunConfig`` (fused pipeline -> legacy chunk loop, device aggregation
  -> host ``aggregate_rows``, Pallas -> jnp reference kernels,
  ``all_to_all`` halo -> all-gather, ``device_budget_bytes`` halving on
  OOM). Every rung is bit-identical by the guarantees of the PRs that
  introduced the fast path, so a degraded retry still reproduces the
  clean run's patterns exactly.

``corrupt_checkpoint`` is the test half of the checkpoint-integrity
format: it tampers a written cut while *keeping the stale embedded
checksum*, producing exactly the artifact ``checkpoint.verify`` must
reject and ``load_latest_valid`` must roll back past.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

#: process exit code of a ``kind="exit"`` fault — subprocess kill tests
#: assert on it (mirrors examples/resume_after_crash.py).
EXIT_CODE = 17

#: where a plan can trip: the six loop phases (obs.PHASES) + the halo
#: exchange inside expand (shard backend / partitioned serial).
FAULT_PHASES = (
    "materialize", "aggregate", "alpha", "expand", "seal", "checkpoint",
    "halo",
)

#: lethal kinds abort the attempt at the trip site; benign kinds are
#: consumed by the code path that simulates them (``FaultPlan.take``).
LETHAL_KINDS = ("crash", "exit", "oom", "halo")
BENIGN_KINDS = ("corrupt", "saturate")
FAULT_KINDS = LETHAL_KINDS + BENIGN_KINDS


class InjectedFault(RuntimeError):
    """Root of every deterministically injected failure."""


class InjectedCrash(InjectedFault):
    """A generic process crash at a phase boundary (retryable)."""


class InjectedOOM(InjectedFault):
    """A simulated device allocation failure. The message carries the
    real XLA marker so :func:`classify_failure` treats injected and real
    OOMs identically."""


class InjectedHaloFailure(InjectedFault):
    """A failed halo exchange (lost worker / collective timeout)."""


@dataclasses.dataclass
class FaultSpec:
    """One planned fault: trip ``kind`` when ``phase`` runs at superstep
    ``step``, up to ``times`` times across ALL supervisor attempts."""

    phase: str
    step: int
    kind: str = "crash"
    times: int = 1

    def __post_init__(self) -> None:
        if self.phase not in FAULT_PHASES:
            raise ValueError(
                f"unknown fault phase {self.phase!r} (one of {FAULT_PHASES})"
            )
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (one of {FAULT_KINDS})"
            )


class FaultPlan:
    """A deterministic schedule of faults, shared across retry attempts.

    The plan is the *only* mutable state of the injection layer: each spec
    carries a remaining-fire budget, decremented when it trips, so a
    once-only crash does not re-fire on the supervised retry. ``fired``
    records every (phase, step, kind) that actually tripped — tests assert
    the schedule executed."""

    def __init__(self, specs: Iterable[FaultSpec | Sequence]) -> None:
        self.specs: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(*s) for s in specs
        ]
        self._remaining = [max(int(s.times), 0) for s in self.specs]
        self.fired: List[Tuple[str, int, str]] = []

    def _match(self, phase: str, step: int, kinds) -> Optional[str]:
        for i, s in enumerate(self.specs):
            if (
                self._remaining[i] > 0
                and s.phase == phase
                and s.step == int(step)
                and s.kind in kinds
            ):
                self._remaining[i] -= 1
                self.fired.append((phase, int(step), s.kind))
                return s.kind
        return None

    # -- injection sites -----------------------------------------------------
    def trip(self, phase: str, step: int) -> None:
        """Called at a phase boundary: fire any matching LETHAL spec.
        Benign kinds never raise here — the simulating call site pulls
        them via :meth:`take`."""
        kind = self._match(phase, step, LETHAL_KINDS)
        if kind is None:
            return
        if kind == "exit":
            # a real kill: no unwinding, no atexit — the subprocess kill
            # matrix asserts the parent sees EXIT_CODE
            os._exit(EXIT_CODE)
        if kind == "oom":
            raise InjectedOOM(
                f"RESOURCE_EXHAUSTED: injected device OOM at "
                f"{phase}/step {step}"
            )
        if kind == "halo":
            raise InjectedHaloFailure(
                f"injected halo-exchange failure at step {step}"
            )
        raise InjectedCrash(f"injected crash at {phase}/step {step}")

    def take(self, phase: str, step: int, kind: str) -> bool:
        """Consume a matching BENIGN spec (``corrupt``/``saturate``);
        returns whether one fired. The caller simulates the effect."""
        if kind not in BENIGN_KINDS:
            raise ValueError(f"take() is for benign kinds, not {kind!r}")
        return self._match(phase, step, (kind,)) is not None

    @property
    def exhausted(self) -> bool:
        return not any(self._remaining)


def trip(plan: Optional[FaultPlan], phase: str, step: int) -> None:
    """The one-liner the loop calls at each phase boundary: no-op on the
    (default) ``faults=None`` path — a single attribute read."""
    if plan is not None:
        plan.trip(phase, step)


def take(plan: Optional[FaultPlan], phase: str, step: int, kind: str) -> bool:
    if plan is None:
        return False
    return plan.take(phase, step, kind)


# ---------------------------------------------------------------------------
# checkpoint tampering: the adversarial half of the integrity format
# ---------------------------------------------------------------------------

def corrupt_checkpoint(path: str, mode: str = "payload") -> str:
    """Tamper a written checkpoint in place.

    ``mode="payload"`` flips one element of a payload array and re-saves
    the archive **with the old embedded checksum** — a structurally valid
    .npz whose SHA-256 no longer matches, exactly the artifact
    ``checkpoint.verify`` must reject. ``mode="truncate"`` chops the file
    in half (a torn write that never reached ``os.replace``) — unreadable
    as a zip, also classified corrupt. Returns ``path``."""
    if mode == "truncate":
        with open(path, "r+b") as f:
            f.truncate(max(os.path.getsize(path) // 2, 1))
        return path
    if mode != "payload":
        raise ValueError(f"unknown corruption mode {mode!r}")
    with np.load(path, allow_pickle=False) as z:
        arrays = {key: np.asarray(z[key]) for key in z.files}
    for name in sorted(arrays):
        if name in ("meta", "checksum"):
            continue
        a = arrays[name]
        if a.size and np.issubdtype(a.dtype, np.number):
            a = np.array(a, copy=True)
            flat = a.reshape(-1)
            if np.issubdtype(a.dtype, np.integer):
                flat[0] = int(flat[0]) ^ 1
            else:
                flat[0] = float(flat[0]) + 1.0
            arrays[name] = a
            break
    else:  # no numeric payload to flip (empty run): tear the file instead
        return corrupt_checkpoint(path, mode="truncate")
    np.savez(path, **arrays)
    return path


# ---------------------------------------------------------------------------
# failure classification: what the supervisor retries over
# ---------------------------------------------------------------------------

def classify_failure(exc: BaseException) -> str:
    """Map a caught exception onto the retry taxonomy: ``"oom"`` (device
    allocation — real RESOURCE_EXHAUSTED or injected), ``"halo"``
    (exchange/collective failure), else ``"crash"``. Fatal config errors
    (fingerprint mismatches) are the supervisor's business — it only calls
    this for failures raised *inside* a mining attempt."""
    if isinstance(exc, InjectedOOM):
        return "oom"
    if isinstance(exc, InjectedHaloFailure):
        return "halo"
    msg = str(exc)
    if "RESOURCE_EXHAUSTED" in msg or "out of memory" in msg.lower():
        return "oom"
    return "crash"


# ---------------------------------------------------------------------------
# the graceful-degradation ladder (DESIGN.md §13)
# ---------------------------------------------------------------------------

#: floor of ``device_budget_bytes`` halving — below this a wave holds a
#: handful of rows and further halving cannot help.
_BUDGET_FLOOR = 1 << 16
#: seed budget when OOM strikes a run that never set one (2x halvable).
_BUDGET_SEED = 1 << 26


def apply_degradation(config, phase: str, kind: str):
    """One rung down the ladder for a repeated (phase, kind) failure.

    Returns ``(new_config, event)`` where ``event`` names the downshift
    (recorded as an obs counter + span attribute in the trace), or
    ``(config, None)`` when no safer configuration remains. Every rung is
    behaviour-preserving: the slow path it falls back to is the measured
    reference the fast path was verified against."""
    if kind == "oom":
        # rung 1: halve the spill-wave budget — the direct remedy for a
        # frontier wave outgrowing device memory
        budget = config.device_budget_bytes
        if budget is None:
            new = _BUDGET_SEED
            return (
                dataclasses.replace(config, device_budget_bytes=new),
                f"budget_capped:{new}",
            )
        if budget > _BUDGET_FLOOR:
            new = max(budget // 2, _BUDGET_FLOOR)
            return (
                dataclasses.replace(config, device_budget_bytes=new),
                f"budget_halved:{new}",
            )
        # rung 2: drop the fused pipeline (smaller per-chunk footprint).
        # ``is not False`` because the knob is tri-state (None = cost-model
        # auto, effectively on): an unresolved config still downshifts.
        if config.async_chunks is not False:
            return (
                dataclasses.replace(config, async_chunks=False),
                "fused_off",
            )
        return config, None

    if kind == "halo" or phase == "halo":
        # all_to_all exchange -> ragged all-gather fallback (PR 6)
        if config.resolve_halo() != "gather":
            return dataclasses.replace(config, halo="gather"), "halo_gather"
        return config, None

    if phase in ("aggregate", "alpha"):
        # rung 0: device / overlapped level-2 canonicalisation -> the
        # synchronous memoised host batch (DESIGN.md §15). No-op for an
        # unresolved knob (None resolves to "host" pre-calibration), so
        # existing ladder sequences are unchanged unless the placement was
        # actually lifted off the host.
        if config.resolve_canonical_placement() != "host":
            return (
                dataclasses.replace(config, canonical_placement="host"),
                "canon_host",
            )
        # rung 1: radix bucket bin -> the lax.sort reference bin
        if config.resolve_aggregate_bin() == "radix":
            return (
                dataclasses.replace(config, aggregate_bin="sort"),
                "radix_bin_off",
            )
        # rung 2: device level-1 aggregation -> host aggregate_rows
        # reference (tri-state knob: None = cost-model auto = maybe on)
        if config.device_aggregate is not False:
            return (
                dataclasses.replace(config, device_aggregate=False),
                "host_aggregate",
            )
        if config.resolve_aggregate_kernel():
            return (
                dataclasses.replace(config, aggregate_kernel=False),
                "aggregate_kernel_off",
            )
        return config, None

    if phase in ("materialize", "expand", "seal"):
        # rung 1: fused pipeline -> legacy chunk loop (tri-state knob)
        if config.async_chunks is not False:
            return (
                dataclasses.replace(config, async_chunks=False),
                "fused_off",
            )
        # rung 2: Pallas kernels -> jnp reference lowerings
        if (
            config.resolve_use_pallas()
            or config.resolve_compact_kernel()
            or config.fused_expand
        ):
            return (
                dataclasses.replace(
                    config,
                    use_pallas=False,
                    fused_expand=False,
                    compact_kernel=False,
                ),
                "pallas_off",
            )
        return config, None

    # checkpoint-phase failures have no safer configuration — retry from
    # the previous cut IS the remedy
    return config, None
