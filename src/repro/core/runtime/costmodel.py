"""Pilot-calibrated cost-model dispatch (DESIGN.md §14).

Every ``auto`` knob in :class:`repro.core.runtime.config.RunConfig` used to
resolve through a *static* heuristic (``use_pallas`` on TPU only, fused
pipeline + device aggregation everywhere).  BENCH_8 showed that static
placement is the wrong trade on at least one backend: on CPU the fused
pipeline with device aggregation ran at 0.51x of the legacy chunk loop,
while on TPU the same defaults are the right call.  This module turns the
PR-3 pilot chunk into a **calibration probe**: before the first superstep,
``resolve`` times the candidate implementation of each phase on a small,
real workload slice and fills every unset knob with the measured-fastest
choice.

The subsystem has four layers:

``DecisionTable``
    One record per (backend, platform): the concrete value of every
    decided knob plus the probe timings (µs) that justified it, and a
    ``source`` tag (``static`` / ``calibrated`` / ``cached`` /
    ``forced:<mode>``).  Recorded into ``RunStats.cost_model`` and the
    PR-7 trace so placement is observable after the fact.

``calibrate``
    The probe set.  (1) *expand ladder*: time
    ``explore.expand_and_compact`` on a pilot-sized size-1 chunk across
    {jnp, Pallas} x {jnp-compact, Pallas-compact} -> ``use_pallas``,
    ``compact_kernel``.  (2) *bin ladder*: quick codes of the pilot's
    children, tiled to ~64k rows, through ``kernels.aggregate.bin_rows``
    across {sort, radix} x {jnp, Pallas} -> ``aggregate_bin``,
    ``aggregate_kernel``.  (3) *placement*: per-row device fold+merge cost
    vs per-row host cost (transfer + numpy unique) -> ``device_aggregate``.
    (4) *async*: the legacy loop's per-chunk tax (host sync + chunk upload
    + separate quick-pattern pass) vs the fused pipeline's per-chunk tax
    (carried-partial fold when aggregating on device, ~nothing otherwise)
    -> ``async_chunks``.

caching
    Calibration runs once per (backend, platform, app fingerprint, graph
    fingerprint, config signature) — process-wide in ``_PROCESS_CACHE``
    and, when ``cost_model_dir`` is set, persisted as JSON so repeat runs
    (and repeat *processes*) skip the pilot entirely.  The fingerprints
    are the PR-4 checkpoint fingerprints, so "same graph, same app" means
    exactly what resume already means.

forcing
    ``cost_model="off"`` resolves like the pre-calibration static
    heuristic; ``"force_device"`` / ``"force_host"`` pin the placement
    knobs to the two extremes so every dispatch path stays reachable from
    tests regardless of what the probes would measure.  Explicitly set
    config knobs always win over the table — the model only fills knobs
    the user left at ``None``/auto.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import aggregate as agg_kernel
from repro.kernels.dispatch import COMPILED_BACKENDS

#: table schema version — bump to invalidate every persisted table.
#: v2: + canonical_placement (level-2 placement, DESIGN.md §15).
SCHEMA_VERSION = 2

#: the config knobs a table decides, in resolution order.
DECIDED_KNOBS = (
    "async_chunks",
    "device_aggregate",
    "use_pallas",
    "compact_kernel",
    "aggregate_kernel",
    "aggregate_bin",
    "canonical_placement",
)

COST_MODEL_MODES = ("auto", "off", "force_device", "force_host")

#: pilot rows the expand ladder times (a real size-1 chunk slice).
PROBE_CHUNK_ROWS = 256
#: rows the bin ladder times (pilot children tiled up — large enough that
#: the sort-vs-radix ordering matches full-superstep batches).
PROBE_BIN_ROWS = 65536
#: expand-probe output capacity cap (keeps one probe under ~10 ms).
PROBE_OUT_CAP = 1 << 15
#: a non-jnp expand combo must be >=10% faster than plain jnp at probe
#: time to be chosen — near-ties are measurement noise, not wins.
EXPAND_HYSTERESIS = 0.9

_PROCESS_CACHE: Dict[tuple, "DecisionTable"] = {}


def _platform() -> str:
    return jax.default_backend()


@dataclasses.dataclass
class DecisionTable:
    """Concrete value of every decided knob + the timings that chose it."""

    backend: str                     # execution backend ("serial"/"shard_map")
    platform: str                    # jax.default_backend() at decision time
    source: str                      # static | calibrated | cached | forced:<m>
    async_chunks: bool = True
    device_aggregate: bool = True
    use_pallas: bool = False
    compact_kernel: bool = False
    aggregate_kernel: bool = False
    aggregate_bin: str = "sort"      # "sort" | "radix"
    canonical_placement: str = "host"  # "device" | "host" | "host_async"
    timings: Dict[str, float] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["schema"] = SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "DecisionTable":
        if d.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"decision-table schema {d.get('schema')!r} != {SCHEMA_VERSION}"
            )
        kw = {f.name: d[f.name] for f in dataclasses.fields(cls) if f.name in d}
        return cls(**kw)

    def copy(self) -> "DecisionTable":
        return dataclasses.replace(self, timings=dict(self.timings))

    def decisions(self) -> Dict:
        """The knob -> choice mapping alone (what ``RunStats`` records)."""
        return {k: getattr(self, k) for k in DECIDED_KNOBS}


# ---------------------------------------------------------------------------
# static + forced tables
# ---------------------------------------------------------------------------

def static_table(backend_name: str, platform: Optional[str] = None,
                 source: str = "static") -> DecisionTable:
    """The pre-calibration defaults, exactly as the old static heuristic
    resolved them: fused pipeline + device aggregation everywhere, Pallas
    kernels only where they compile to native code (TPU — the Triton path
    is unvalidated for the 2-D gathers these kernels lean on), sort-based
    bin.  Small graphs (below ``cost_model_min_edges``) resolve here so a
    unit-test-sized run never pays a calibration pilot."""
    p = platform or _platform()
    native = p == "tpu"
    return DecisionTable(
        backend=backend_name, platform=p, source=source,
        async_chunks=True, device_aggregate=True,
        use_pallas=native, compact_kernel=native, aggregate_kernel=native,
        aggregate_bin="sort",
    )


def forced_table(mode: str, backend_name: str,
                 platform: Optional[str] = None) -> DecisionTable:
    """The ``force_device``/``force_host`` placement extremes: both keep
    the kernel knobs at their static defaults (forcing Pallas through the
    CPU interpreter would punish tests, not exercise new paths) and pin
    the placement knobs so each dispatch route is reachable by fiat."""
    t = static_table(backend_name, platform, source=f"forced:{mode}")
    if mode == "force_device":
        t.async_chunks = True
        t.device_aggregate = True
        t.aggregate_bin = "radix"
        t.canonical_placement = "device"
    elif mode == "force_host":
        t.async_chunks = False
        t.device_aggregate = False
        t.aggregate_bin = "sort"
        t.canonical_placement = "host"
    else:
        raise ValueError(f"unknown forced cost_model mode {mode!r}")
    return t


# ---------------------------------------------------------------------------
# cache keys: the PR-4 fingerprints + a config signature
# ---------------------------------------------------------------------------

def config_signature(config) -> str:
    """Hash of the config fields that change what calibration would
    measure (batch geometry + store discipline), NOT of the knobs the
    table decides — a user flipping ``aggregate_kernel`` must not fork the
    cache, it just overrides the table."""
    payload = repr((
        config.chunk_size, config.initial_capacity, config.agg_qcap,
        config.store, config.device_budget_bytes, config.graph_partition,
        config.fused_expand, config.pallas_interpret,
    ))
    return hashlib.sha1(payload.encode()).hexdigest()


def cache_key(backend_name: str, platform: str, app_fp: str, graph_fp: str,
              cfg_sig: str) -> tuple:
    return (SCHEMA_VERSION, backend_name, platform, app_fp, graph_fp, cfg_sig)


def _cache_path(cost_model_dir: str, key: tuple) -> str:
    _, backend, platform, app_fp, graph_fp, cfg_sig = key
    name = (
        f"costmodel-v{SCHEMA_VERSION}-{platform}-{backend}"
        f"-{app_fp[:10]}-{graph_fp[:10]}-{cfg_sig[:10]}.json"
    )
    return os.path.join(cost_model_dir, name)


def _load_cached(cost_model_dir: str, key: tuple) -> Optional[DecisionTable]:
    path = _cache_path(cost_model_dir, key)
    try:
        with open(path, "r", encoding="utf-8") as f:
            t = DecisionTable.from_dict(json.load(f))
    except (OSError, ValueError, KeyError, TypeError):
        return None
    t.source = "cached"
    return t


def _save_cached(cost_model_dir: str, key: tuple, table: DecisionTable) -> None:
    path = _cache_path(cost_model_dir, key)
    os.makedirs(cost_model_dir, exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(table.as_dict(), f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def clear_cache() -> None:
    """Drop the process-wide table cache (tests)."""
    _PROCESS_CACHE.clear()


# ---------------------------------------------------------------------------
# the probes
# ---------------------------------------------------------------------------

def _time_us(fn, repeat: int = 3) -> float:
    """Best-of-``repeat`` wall microseconds of ``fn()`` after one warm-up
    call (the warm-up eats compilation; best-of filters scheduler noise)."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def calibrate(g, app, config, backend_name: str) -> DecisionTable:
    """Run the probe set on a pilot-sized slice of the real workload and
    return the measured-fastest table.  Any probe failure (exotic graph
    layout, pathological sizes) falls back to the static table — the cost
    model must never be able to break a run, only re-place it."""
    try:
        return _calibrate(g, app, config, backend_name)
    except Exception:  # pragma: no cover - safety net, exercised by tests
        return static_table(backend_name, source="static:probe-error")


def _calibrate(g, app, config, backend_name: str) -> DecisionTable:
    from repro.core import explore
    from repro.core.runtime import programs

    platform = _platform()
    table = static_table(backend_name, platform, source="calibrated")
    timings = table.timings
    mode = app.mode
    interpret = config.pallas_interpret

    n0 = int(g.n if mode == "vertex" else g.m)
    if n0 <= 0:
        table.source = "static:empty-graph"
        return table

    # ---- pilot: one cheap jnp expand of a size-1 seed chunk ------------
    # Its children give every later probe a REALISTIC frontier: multi-
    # vertex members exercise the kernels' dedup/validity lanes that an
    # all-valid size-1 chunk skips — probing on size-1 rows picks Pallas
    # on workloads where jnp wins the real supersteps.
    rows = min(PROBE_CHUNK_ROWS, n0, max(int(config.chunk_size), 1))
    members = jnp.arange(rows, dtype=jnp.int32)[:, None]
    n_valid = jnp.ones((rows,), jnp.int32)
    out_cap = min(
        PROBE_OUT_CAP,
        1 << max(0, (rows * max(int(g.max_degree), 1) - 1).bit_length()),
    )

    def expand_probe(up, ck, m=members, nv=n_valid, cap=out_cap):
        return explore.expand_and_compact(
            g, m, nv, mode, cap,
            use_pallas=up, fused=False, interpret=interpret,
            compact_kernel=ck,
        )

    children, count = expand_probe(False, False)[:2]
    childk = children.shape[1]
    n_children = int(count)

    # ---- probe 1: expand ladder -> use_pallas, compact_kernel ----------
    if n_children >= 8:
        lrows = min(n_children, out_cap, PROBE_CHUNK_ROWS)
        lm = children[:lrows]
        lnv = jnp.full((lrows,), childk, jnp.int32)
        lcap = min(
            PROBE_OUT_CAP,
            1 << max(0, (lrows * max(int(g.max_degree), 1) - 1).bit_length()),
        )
    else:                       # degenerate graph: fall back to the seed
        lm, lnv, lcap = members, n_valid, out_cap

    ladder = [("jnp", False, False), ("pallas", True, False),
              ("pallas+compact", True, True), ("jnp+compact", False, True)]
    best_name, best_us = None, float("inf")
    for name, up, ck in ladder:
        us = _time_us(
            lambda up=up, ck=ck: expand_probe(up, ck, lm, lnv, lcap)
        )
        timings[f"expand.{name}"] = round(us, 1)
        if us < best_us:
            best_name, best_us = (up, ck), us
    # hysteresis: a kernel combo must beat plain jnp by a clear margin to
    # displace it — probe argmins between near-tied candidates are noise,
    # and a noise-picked combo can measure slower at real frontier sizes.
    if best_us >= EXPAND_HYSTERESIS * timings["expand.jnp"]:
        best_name = (False, False)
    table.use_pallas, table.compact_kernel = best_name

    if not app.wants_patterns:
        # nothing to aggregate: placement knobs are moot, and the fused
        # pipeline's only per-chunk cost is the device-resident count
        # buffer — strictly cheaper than the legacy loop's per-chunk sync.
        table.async_chunks = True
        return table

    # ---- pilot children -> real quick codes for the bin probes ---------
    nv_children = jnp.where(
        jnp.arange(out_cap) < jnp.minimum(count, out_cap), childk, 0
    ).astype(jnp.int32)
    qp = programs.quick_patterns(g, mode, children, nv_children)
    codes, valid = qp.codes, nv_children > 0
    reps = -(-PROBE_BIN_ROWS // out_cap)
    codes_big = jnp.tile(codes, (reps, 1))[:PROBE_BIN_ROWS]
    valid_big = jnp.tile(valid, (reps,))[:PROBE_BIN_ROWS]
    jax.block_until_ready((codes_big, valid_big))
    cap = min(max(int(config.agg_qcap), 1), 4096)

    bin_fn = jax.jit(
        agg_kernel.bin_rows,
        static_argnums=(2,),
        static_argnames=("use_kernel", "block", "interpret", "method"),
    )

    # ---- probe 2: bin ladder -> aggregate_bin, aggregate_kernel --------
    cands = [("sort", False), ("radix", False)]
    if platform in COMPILED_BACKENDS:
        cands += [("sort", True), ("radix", True)]
    best_bin, best_bin_us = None, float("inf")
    for method, uk in cands:
        us = _time_us(lambda m=method, uk=uk: bin_fn(
            codes_big, valid_big, cap,
            use_kernel=uk, interpret=interpret, method=m,
        ))
        timings[f"bin.{method}{'.kernel' if uk else ''}"] = round(us, 1)
        if us < best_bin_us:
            best_bin, best_bin_us = (method, uk), us
    table.aggregate_bin, table.aggregate_kernel = best_bin

    # ---- probe 3: placement -> device_aggregate ------------------------
    # Device level 1 pays a per-chunk fold (bin over one chunk's children)
    # plus a weighted re-merge of the carried table; the host path pays one
    # per-superstep drain (transfer + numpy lexsort-unique over all rows).
    # Compare them per ROW — that is the unit both scale in.
    method, uk = best_bin
    fold_us = _time_us(lambda: bin_fn(
        codes_big[:out_cap], valid_big[:out_cap], cap,
        use_kernel=uk, interpret=interpret, method=method,
    ))
    n_merge = min(2 * cap, codes_big.shape[0])
    w = jnp.ones((n_merge,), jnp.int64)
    merge_us = _time_us(lambda: bin_fn(
        codes_big[:n_merge], valid_big[:n_merge], cap, w,
        use_kernel=uk, interpret=interpret, method=method,
    ))

    def host_probe():
        c = np.asarray(codes_big)
        v = np.asarray(valid_big)
        cc = c[v]
        if cc.size:
            np.unique(cc, axis=0)
        return ()

    host_us = _time_us(host_probe)
    device_per_row = (fold_us + merge_us) / max(out_cap, 1)
    host_per_row = host_us / max(PROBE_BIN_ROWS, 1)
    timings["place.device_fold"] = round(fold_us, 1)
    timings["place.device_merge"] = round(merge_us, 1)
    timings["place.host_drain"] = round(host_us, 1)
    timings["place.device_per_row"] = round(device_per_row, 4)
    timings["place.host_per_row"] = round(host_per_row, 4)
    table.device_aggregate = device_per_row < host_per_row

    # ---- probe 4: pipeline shape -> async_chunks -----------------------
    # Legacy chunk loop: every chunk pays a host sync, a host->device chunk
    # upload, and a separate quick-pattern pass.  Fused pipeline: chunks
    # stay device-resident; the per-chunk cost is the carried-partial fold
    # when aggregating on device, ~zero when the codes drain once.
    sync_us = _time_us(lambda: jax.device_get(count))
    host_members = np.asarray(members)
    upload_us = _time_us(lambda: jnp.asarray(host_members))
    qp_us = _time_us(lambda: programs.quick_patterns(
        g, mode, children, nv_children
    ))
    legacy_tax = sync_us + upload_us + qp_us
    fused_tax = (fold_us + merge_us) if table.device_aggregate else 0.0
    timings["async.sync"] = round(sync_us, 1)
    timings["async.upload"] = round(upload_us, 1)
    timings["async.quick_patterns"] = round(qp_us, 1)
    timings["async.legacy_chunk_tax"] = round(legacy_tax, 1)
    timings["async.fused_chunk_tax"] = round(fused_tax, 1)
    table.async_chunks = fused_tax <= legacy_tax

    # ---- probe 5: level-2 placement -> canonical_placement -------------
    # Device refine batches the whole distinct-code table through the
    # permutation kernel (upload + refine + drain, the real device-route
    # cost); the host batch is canon_math._canonicalize_batch per nv
    # group (memo-cold, exactly what a miss pays).  Device wins on raw
    # speed; otherwise prefer overlapping the host batch with the next
    # superstep (host_async) when the app's filters allow a deferred
    # table, else stay on the synchronous host reference.
    from repro.core import aggregation, canon_math
    from repro.kernels import canonical_refine

    u = np.unique(np.asarray(codes)[np.asarray(valid)], axis=0)
    if len(u):
        device_us = _time_us(lambda: canonical_refine.canonicalize_on_device(
            u, use_kernel=table.aggregate_kernel, interpret=interpret,
        ))

        def host_canon():
            by_nv: Dict[int, list] = {}
            for i in range(len(u)):
                by_nv.setdefault(int(u[i, 0]) & 0xF, []).append(i)
            for js in by_nv.values():
                canon_math._canonicalize_batch(u[js])
            return ()

        host_us = _time_us(host_canon)
        timings["canon.device"] = round(device_us, 1)
        timings["canon.host"] = round(host_us, 1)
        if device_us < host_us:
            table.canonical_placement = "device"
        elif table.device_aggregate and aggregation.async_level2_ok(app):
            # host_async only exists on the device-aggregation path (the
            # host reference has no deferrable table) — a host_async
            # decision with device_aggregate=False would silently run
            # synchronously, so don't record one
            table.canonical_placement = "host_async"
        else:
            table.canonical_placement = "host"
    return table


# ---------------------------------------------------------------------------
# resolution: the one entry point (ExecutionBackend.bind)
# ---------------------------------------------------------------------------

def resolve(config, g, app, backend_name: str):
    """Resolve every unset knob of ``config`` to a concrete choice.

    Returns ``(concrete_config, table)``: a config copy whose
    ``DECIDED_KNOBS`` are all concrete (the store/program builders never
    see a tri-state again), and the effective decision table (user
    overrides folded in) for ``RunStats``/trace recording."""
    mode = getattr(config, "cost_model", "auto")
    if mode not in COST_MODEL_MODES:
        raise ValueError(
            f"unknown cost_model {mode!r} (expected one of {COST_MODEL_MODES})"
        )
    if mode == "off":
        table = static_table(backend_name, source="forced:off")
    elif mode != "auto":
        table = forced_table(mode, backend_name)
    elif int(g.m) < int(config.cost_model_min_edges):
        table = static_table(backend_name)
    else:
        from repro.core.runtime import checkpoint

        key = cache_key(
            backend_name, _platform(),
            checkpoint.app_fingerprint(app), checkpoint.graph_fingerprint(g),
            config_signature(config),
        )
        table = _PROCESS_CACHE.get(key)
        if table is None and config.cost_model_dir:
            table = _load_cached(config.cost_model_dir, key)
        if table is None:
            table = calibrate(g, app, config, backend_name)
            if config.cost_model_dir and table.source == "calibrated":
                _save_cached(config.cost_model_dir, key, table)
        _PROCESS_CACHE[key] = table

    # explicit config knobs always win; the returned table reflects the
    # EFFECTIVE choices (overrides folded in) without poisoning the cache.
    table = table.copy()
    concrete = {}
    for knob in DECIDED_KNOBS:
        user = getattr(config, knob)
        if user is None:
            concrete[knob] = getattr(table, knob)
        else:
            concrete[knob] = user
            setattr(table, knob, user)
            table.timings[f"override.{knob}"] = 1
    return dataclasses.replace(config, **concrete), table
