"""The ONE superstep driver (DESIGN.md §9): Algorithm 1, BFS level-synchronous.

``SuperstepRuntime`` owns the BSP loop every deployment runs — init frontier
→ (fused or legacy) expand → store seal → pattern aggregate → app post-step —
parameterised by an :class:`~repro.core.runtime.backend.ExecutionBackend`
(serial chunk pipeline or shard-map mesh). ``engine.run`` and
``distributed.run_distributed`` are thin wrappers over this class; the loop
logic they used to duplicate (pilot-chunk calibration, capacity buckets,
drain windows, aggregation/alpha/output plumbing) lives here and in the
backends exactly once.

Because PR 2 made sealed frontier stores the *only* inter-superstep state,
the seal boundary is a checkpointable cut: with ``checkpoint_dir`` set the
runtime persists {sealed store payload, stats, patterns, superstep cursor,
app+graph fingerprints} every ``checkpoint_every`` supersteps, and
:func:`resume` (or :meth:`SuperstepRuntime.resume`) continues an
interrupted run — under ANY backend or worker count, since per-worker
slices are re-partitioned from the store at extraction time (elastic
restore, ``runtime/checkpoint.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import aggregation, obs
from repro.core.api import MiningApp
from repro.core.graph import (
    DeviceGraph, Graph, PartitionedGraph, to_device, to_partitioned,
)
from repro.core.runtime import checkpoint as checkpoint_lib
from repro.core.runtime import faults as faults_lib
from repro.core.runtime import programs
from repro.core.runtime.backend import ExecutionBackend
from repro.core.runtime.config import RunConfig
from repro.core.stats import RunStats, StepStats, Timer


@dataclasses.dataclass
class MiningResult:
    patterns: Dict[tuple, int]                    # canon code -> count/support
    aggregates: List[aggregation.StepAggregates]
    stats: RunStats
    embeddings: Dict[int, np.ndarray]             # size -> (B, size) arrays
    #: Chrome trace exported by this run (``trace=True`` + ``trace_dir``;
    #: DESIGN.md §12), None otherwise.
    trace_path: Optional[str] = None
    #: recovery report of a supervised run that retried (DESIGN.md §13):
    #: {n_retries, t_recovery, degradations, rolled_back, resumed_step}.
    #: None for a clean run (or one not under ``run_supervised``).
    recovery: Optional[Dict] = None

    def pattern_count(self, code) -> int:
        return self.patterns.get(tuple(int(x) for x in code), 0)


class SuperstepRuntime:
    """One BSP mining run: a graph, an app, a config, and a backend."""

    def __init__(
        self,
        graph: Graph | DeviceGraph,
        app: MiningApp,
        config: Optional[RunConfig] = None,
        backend: Optional[ExecutionBackend] = None,
    ) -> None:
        from repro.core.runtime.serial import SerialBackend

        self.config = config if config is not None else RunConfig()
        if isinstance(graph, PartitionedGraph):
            self.g = graph
        elif self.config.graph_partition:
            # partitioned layout (DESIGN.md §11): CSR shards + adjacency
            # tiles replace the replicated DeviceGraph; a DeviceGraph input
            # is re-partitioned (elastic restore across layouts)
            self.g = to_partitioned(
                graph,
                self.config.graph_partition,
                self.config.partition_balance,
            )
        else:
            self.g = to_device(graph) if isinstance(graph, Graph) else graph
        self.app = app
        self.backend = backend if backend is not None else SerialBackend()
        self.store = self.backend.bind(self.g, self.app, self.config)
        # bind resolved every tri-state knob through the cost model
        # (DESIGN.md §14) — the runtime sees the same concrete config the
        # backend built its programs from (the supervisor's degradation
        # ladder inspects these knobs and must see the effective values).
        self.config = self.backend.config

    # -- entry points -------------------------------------------------------
    def run(self) -> MiningResult:
        """Mine from scratch (superstep 1 seeds every vertex/edge)."""
        return self._run(None)

    def resume(self, checkpoint: Optional[str] = None) -> MiningResult:
        """Continue an interrupted run from a checkpoint file or directory
        (directory -> the latest checkpoint in it; None -> the configured
        ``checkpoint_dir``). Graph and app must fingerprint-match what the
        checkpoint was written with; backend and worker count may differ
        (elastic restore)."""
        state = checkpoint_lib.load_for(
            checkpoint if checkpoint is not None else self.config.checkpoint_dir,
            g=self.g,
            app=self.app,
        )
        self.store.from_state_dict(state.store_state)
        self.backend.capacity = max(int(state.capacity), 1)
        return self._run(state)

    def _join_level2(self, pending, result: MiningResult, st) -> None:
        """Join an overlapped ``host_async`` level-2 batch (DESIGN.md §15):
        replace the step's placeholder aggregate and record its surviving
        patterns. ``t_canon`` here is the *residual* blocking wait — the
        overlap win is exactly ``host t_canon - this`` — and the drain does
        not count as a host sync (only control-flow reads do)."""
        t0 = time.perf_counter()
        with obs.span(
            "canonicalize", placement="host_async",
            n_quick=pending.n_quick, step=st.step,
        ):
            table, counts = pending.result()
        obs.count(st, "t_canon", time.perf_counter() - t0)
        agg = aggregation.build_step_aggregates(
            table, counts, counts.copy(), pending.n_quick, st
        )
        assert result.aggregates and result.aggregates[-1] is None
        result.aggregates[-1] = agg
        # beta/outputs deferred from alpha: async eligibility means no
        # pattern pruning, so "surviving" is exactly the live patterns
        for pc in np.flatnonzero(agg.counts > 0):
            code = tuple(int(x) for x in agg.canon_codes[pc])
            result.patterns[code] = (
                result.patterns.get(code, 0) + int(agg.counts[pc])
            )

    # -- the unified loop ---------------------------------------------------
    def _run(self, state) -> MiningResult:
        config, app, store, backend = (
            self.config, self.app, self.store, self.backend,
        )
        ckpt = (
            checkpoint_lib.Checkpointer(config, self.g, app)
            if config.checkpoint_dir is not None
            else None
        )
        #: the run's observability bundle (DESIGN.md §12): tracer + metrics
        #: registry + exporters, all no-ops unless ``config.trace`` /
        #: ``log_every`` asked for them. Kept on the runtime so tests and
        #: tools can read the spans of an in-memory traced run.
        observer = obs.RunObserver(config, backend.name)
        self.observer = observer
        observer.start()
        t_start = time.perf_counter()

        #: fault-injection plan (DESIGN.md §13): None (default) makes every
        #: trip a single attribute read. ``self.failed_phase`` names the
        #: phase an exception escaped from — the supervisor's ladder key.
        plan: Optional[faults_lib.FaultPlan] = config.faults
        self.failed_phase: Optional[str] = None
        #: recovery attribution stamped by ``run_supervised`` before a
        #: retry attempt: lands on the first step this attempt executes
        #: (StepStats.n_retries / t_recovery) + an instant trace span.
        recovery = getattr(self, "recovery", None)
        self.recovery = None
        if recovery is not None:
            with obs.span("recovery", **recovery):
                pass

        #: the effective cost-model table (DESIGN.md §14): an instant span
        #: in the trace + a RunStats record, so every placement decision
        #: is observable without re-deriving it from phase timings.
        decisions = getattr(backend, "decisions", None)
        if decisions is not None:
            with obs.span(
                "cost_model",
                source=decisions.source, **decisions.decisions(),
            ):
                pass

        if state is None:
            result = MiningResult(
                patterns={}, aggregates=[], stats=RunStats(), embeddings={}
            )
            prior_wall = 0.0
            store.append(programs.initial_frontier(self.g, app.mode))
            store.seal(1)
            size, first_step = 1, 1
        else:
            result = MiningResult(
                patterns=dict(state.patterns),
                aggregates=list(state.aggregates),
                stats=RunStats(steps=list(state.stats_steps)),
                embeddings=dict(state.embeddings),
            )
            prior_wall = state.wall_time
            size, first_step = state.size, state.step
        if decisions is not None:
            result.stats.cost_model = decisions.as_dict()

        #: fused mode: (codes, local_verts) of the sealed frontier, carried
        #: from the previous superstep's chunk programs — the next
        #: aggregation pass needs no re-upload and no second device pass.
        #: Dropped across a resume (recomputed from the store, same result).
        carried: Optional[tuple] = None

        try:
            for step in range(first_step, config.max_steps + 1):
                b = store.n_rows
                if b == 0:
                    break
                st = StepStats(step=step, size=size, n_frontier=b)
                if recovery is not None:
                    st.n_retries = int(recovery.get("n_retries", 0))
                    st.t_recovery = float(recovery.get("t_recovery", 0.0))
                    recovery = None
                st.frontier_bytes = store.raw_bytes
                if store.kind == "odag":
                    st.odag_bytes = store.stored_bytes
                timer = Timer()
                done = False
                with obs.span("superstep", step=step, size=size, frontier=b):
                    # ---- re-materialise the frontier (waves / slices) ----
                    with obs.span("materialize", step=step):
                        self.failed_phase = "materialize"
                        faults_lib.trip(plan, "materialize", step)
                        blocks = backend.begin_step(store, st)
                        # extraction may resurrect pattern-pruned rows (a
                        # superset of the appended rows; see ODAGStore) —
                        # stats count what is actually mined
                        st.n_frontier = sum(len(blk) for blk in blocks)
                    obs.set_stat(st, "t_storage", timer.lap())

                    # ---- pattern aggregation of this step's embeddings
                    # (end of the step that generated them, per Algorithm
                    # 1): level-1 state either carried from the chunk
                    # programs that produced the rows (fused, raw store) or
                    # recomputed by the backend; a None canon_slot means
                    # level 1 stayed on device (DESIGN.md §10) ------------
                    canon_slot = None
                    agg = None
                    pending = None
                    if app.wants_patterns:
                        with obs.span(
                            "aggregate", step=step, frontier=st.n_frontier
                        ), obs.annotate("aggregate"):
                            self.failed_phase = "aggregate"
                            faults_lib.trip(plan, "aggregate", step)
                            agg, canon_slot = backend.aggregate_step(
                                blocks, size, carried, st
                            )
                            if isinstance(agg, aggregation.PendingLevel2):
                                # host_async placement (DESIGN.md §15): the
                                # level-2 batch runs on a background thread;
                                # eligibility (async_level2_ok) guarantees
                                # no alpha/beta consumer needs the table
                                # before the join at the seal boundary.
                                # Placeholder replaced at the join.
                                pending, agg = agg, None
                                result.aggregates.append(None)
                            else:
                                result.aggregates.append(agg)
                    carried = None
                    obs.set_stat(st, "t_aggregate", timer.lap())

                    # ---- alpha: aggregation filter on the frontier -------
                    with obs.span("alpha", step=step):
                        self.failed_phase = "alpha"
                        faults_lib.trip(plan, "alpha", step)
                        if agg is not None:
                            if canon_slot is not None:
                                # host path: per-row alpha over per-row
                                # canonical slots
                                alpha = app.aggregation_filter(canon_slot, agg)
                                surviving = (
                                    np.unique(canon_slot[alpha])
                                    if alpha.any()
                                    else []
                                )
                            else:
                                # device path: alpha at pattern granularity;
                                # the O(B) row mask only materialises when
                                # pruning fires
                                pk = app.pattern_filter(agg)
                                live = agg.counts > 0
                                if pk is None:
                                    surviving = np.flatnonzero(live)
                                    alpha = None
                                else:
                                    pk = np.asarray(pk, dtype=bool)
                                    surviving = np.flatnonzero(live & pk)
                                    alpha = (
                                        backend.alpha_rows(pk, st)
                                        if not pk.all()
                                        else None
                                    )
                            # beta / outputs: record aggregates of
                            # surviving patterns
                            for pc in surviving:
                                code = tuple(
                                    int(x) for x in agg.canon_codes[pc]
                                )
                                value = int(
                                    agg.supports[pc]
                                    if app.wants_domains
                                    else agg.counts[pc]
                                )
                                result.patterns[code] = (
                                    result.patterns.get(code, 0) + value
                                )
                            if alpha is not None and not alpha.all():
                                blocks = backend.prune(blocks, alpha)
                        b_live = sum(len(blk) for blk in blocks)
                        if app.collect_embeddings and b_live:
                            live = [blk for blk in blocks if len(blk)]
                            result.embeddings[size] = (
                                np.asarray(live[0])
                                if len(live) == 1
                                else np.concatenate(live, axis=0)
                            )

                    # ---- termination -------------------------------------
                    if (
                        app.termination_filter(size)
                        or b_live == 0
                        or step == config.max_steps
                    ):
                        if pending is not None:
                            # no next superstep to overlap with: drain the
                            # in-flight batch now
                            self._join_level2(pending, result, st)
                        result.stats.steps.append(st)
                        done = True
                    else:
                        # ---- expansion: children appended to the store as
                        # produced ---------------------------------------
                        with obs.span(
                            "expand", step=step, frontier=b_live
                        ), obs.annotate("expand"):
                            self.failed_phase = "expand"
                            faults_lib.trip(plan, "expand", step)
                            carried = backend.expand(store, blocks, size, st)
                            obs.fence(carried)
                        obs.set_stat(st, "t_expand", timer.lap())
                        with obs.span("seal", step=step):
                            self.failed_phase = "seal"
                            faults_lib.trip(plan, "seal", step)
                            store.seal(size + 1)
                            st.n_children = store.n_rows
                        obs.count(st, "t_storage", timer.lap())
                        if pending is not None:
                            # join the overlapped level-2 batch at the seal
                            # boundary: the next frontier is sealed (and the
                            # expansion dispatched), so only the residual
                            # wait — not the whole canonicalisation — lands
                            # on the critical path. Must complete before
                            # end_step/checkpoint so the cut never carries
                            # an in-flight future.
                            self._join_level2(pending, result, st)
                        backend.end_step(store, st)
                        result.stats.steps.append(st)

                        # ---- checkpoint at the seal boundary (§9) --------
                        if (
                            ckpt is not None
                            and store.n_rows
                            and step % max(config.checkpoint_every, 1) == 0
                        ):
                            with obs.span(
                                "checkpoint", step=step
                            ), obs.annotate("checkpoint"):
                                self.failed_phase = "checkpoint"
                                faults_lib.trip(plan, "checkpoint", step)
                                obs.set_stat(
                                    st, "t_checkpoint",
                                    ckpt.save(
                                        step=step + 1,
                                        size=size + 1,
                                        capacity=backend.capacity,
                                        store=store,
                                        result=result,
                                        wall_time=prior_wall
                                        + (time.perf_counter() - t_start),
                                    ),
                                )
                                # benign corruption fault: tamper the cut
                                # just written (keeps the stale checksum)
                                # so resume must detect + roll back past it
                                if faults_lib.take(
                                    plan, "checkpoint", step, "corrupt"
                                ):
                                    faults_lib.corrupt_checkpoint(
                                        checkpoint_lib.checkpoint_path(
                                            ckpt.directory, step + 1
                                        )
                                    )
                observer.step_done(st)
                if done or store.n_rows == 0:
                    break
                size += 1

            result.stats.wall_time = prior_wall + (
                time.perf_counter() - t_start
            )
            backend.finalize(result.stats)
            self.failed_phase = None
            result.trace_path = observer.finish(
                wall_time=result.stats.wall_time
            )
            return result
        finally:
            # exception path: uninstall the tracer/registry so a failed
            # traced run can't leak observation into later runs; exports
            # the partial trace (idempotent after a normal finish), marked
            # aborted so render_trace skips the phase-coverage gate
            observer.finish(
                wall_time=prior_wall + (time.perf_counter() - t_start),
                aborted=True,
            )


def resume(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    checkpoint: str,
    config: Optional[RunConfig] = None,
    backend: Optional[ExecutionBackend] = None,
) -> MiningResult:
    """Convenience wrapper: resume a checkpointed run to completion.

    ``checkpoint`` is a checkpoint file or a directory (the latest one in
    it wins). ``config``/``backend`` may differ from the interrupted run —
    notably the worker count (elastic restore) — but the store kind must
    match the payload and graph/app must fingerprint-match."""
    return SuperstepRuntime(graph, app, config, backend).resume(checkpoint)


def run_supervised(
    graph: Graph | DeviceGraph,
    app: MiningApp,
    config: Optional[RunConfig] = None,
    backend: Optional[ExecutionBackend] = None,
) -> MiningResult:
    """The fault-tolerant entry point (DESIGN.md §13): the BSP loop under
    a supervisor with bounded retry from the last *valid* checkpoint.

    On a failed attempt the supervisor classifies the failure
    (``faults.classify_failure``), sleeps the exponential backoff
    (``retry_backoff * 2**(k-1)``), reloads the newest checkpoint whose
    SHA-256 verifies (``checkpoint.load_latest_valid`` — corrupt cuts are
    rolled back past automatically), and re-runs. When the SAME phase
    fails repeatedly — or immediately for deterministic resource failures
    (OOM, halo) — it consults the graceful-degradation ladder
    (``faults.apply_degradation``) and retries under a strictly safer
    config; every downshift is recorded in the recovery span of the trace
    and the retry attempt stamps ``StepStats.n_retries``/``t_recovery``
    on its first step. After ``max_retries`` failed retries the last
    failure re-raises. Fingerprint mismatches (wrong graph/app) raise
    immediately — a config error, not a fault.

    With no ``checkpoint_dir`` configured, a private temporary directory
    with ``checkpoint_every=1`` provides the retry cut (cleaned up on
    return); a configured directory is used as-is, cadence included."""
    import tempfile

    config = config if config is not None else RunConfig()
    owned_dir = None
    if config.checkpoint_dir is None:
        owned_dir = tempfile.TemporaryDirectory(prefix="repro-supervise-")
        config = dataclasses.replace(
            config, checkpoint_dir=owned_dir.name, checkpoint_every=1
        )
    try:
        attempt = 0              # retries consumed so far
        fail_counts: Dict[tuple, int] = {}
        degradations: List[str] = []
        pending_t = 0.0          # recovery seconds accrued in the except arm
        while True:
            t0 = time.perf_counter()
            runtime = SuperstepRuntime(graph, app, config, backend)
            state = None
            if attempt:
                # newest checkpoint that passes its checksum; corrupt cuts
                # (including one the failure itself tore) are skipped
                state, _, skipped = checkpoint_lib.load_latest_valid(
                    config.checkpoint_dir, runtime.g, app
                )
                if state is not None:
                    runtime.store.from_state_dict(state.store_state)
                    runtime.backend.capacity = max(int(state.capacity), 1)
                runtime.recovery = {
                    "n_retries": attempt,
                    "t_recovery": round(
                        pending_t + (time.perf_counter() - t0), 6
                    ),
                    "degradations": list(degradations),
                    "rolled_back": len(skipped),
                    "resumed_step": int(state.step) if state else 0,
                }
            recovery_report = getattr(runtime, "recovery", None)
            try:
                result = runtime._run(state)
                result.recovery = recovery_report
                return result
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as exc:
                attempt += 1
                if attempt > max(int(config.max_retries), 0):
                    raise
                t_fail = time.perf_counter()
                kind = faults_lib.classify_failure(exc)
                phase = getattr(runtime, "failed_phase", None) or "expand"
                key = (phase, kind)
                fail_counts[key] = fail_counts.get(key, 0) + 1
                # the ladder: repeated failure of the same phase — or any
                # deterministic resource failure — downshifts the config
                if fail_counts[key] >= 2 or kind in ("oom", "halo"):
                    config, event = faults_lib.apply_degradation(
                        config, phase, kind
                    )
                    if event is not None:
                        degradations.append(event)
                if config.retry_backoff > 0:
                    time.sleep(config.retry_backoff * 2 ** (attempt - 1))
                pending_t = time.perf_counter() - t_fail
    finally:
        if owned_dir is not None:
            owned_dir.cleanup()
