"""Shard-map execution backend: the mesh superstep (paper §5.1/§5.3 on JAX).

The Giraph BSP superstep becomes one jitted ``shard_map`` program per
exploration step, behind the same
:class:`~repro.core.runtime.backend.ExecutionBackend` protocol as the
serial pipeline:

  * expansion + canonicality is *coordination-free* (paper §5.1): each
    worker expands its frontier slice with zero communication — the worker
    body is the SAME fused chunk program the serial backend jits
    (``explore.fused_chunk_step``, DESIGN.md §8), children land in the
    store as capacity-padded device arrays, and the host takes ONE control
    sync per superstep on the exact (unclamped) child counts;
  * pattern aggregation is ONE collective: per-pattern counts and FSM
    domain bitmaps are ``psum``/OR-allreduced (two-level aggregation:
    bytes scale with #patterns, never #embeddings — Table 4 as
    collective-bytes);
  * the frontier between supersteps is owned by the shared store
    subsystem: ``store="raw"`` re-balances broadcast-then-partition
    (paper §5.3, even block slicing); ``store="odag"`` folds each worker's
    children into a fixed-shape DenseODAG, merges the worker bitmaps with
    a bitwise OR — host-side in this single-process runtime, bit-for-bit
    the §5.2 "merge and broadcast" OR-allreduce of a multi-host mesh —
    and re-materialises every worker's slice via cost-annotated
    partitioning (§5.3). Exchange bytes ride ``StepStats.collective_bytes``.
"""
from __future__ import annotations

import functools
import time
from typing import List, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import aggregation, explore, obs, pattern as pattern_lib
from repro.core.api import MiningApp
from repro.core.graph import PartitionedGraph
from repro.core.runtime import faults as faults_lib
from repro.core.runtime import programs
from repro.core.runtime.backend import ExecutionBackend
from repro.core.runtime.config import next_pow2
from repro.core.store import FrontierStore, make_store
from repro.kernels import aggregate as agg_kernel_lib
from repro.kernels import canonical_refine
from repro.kernels import gather as gather_kernel_lib
from repro.kernels.dispatch import device_scope

try:  # jax >= 0.6 exports shard_map at top level
    shard_map = jax.shard_map
except AttributeError:  # jax 0.4/0.5: experimental namespace
    from jax.experimental.shard_map import shard_map


def shard_map_pallas_ok(f, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled: pallas_call has no
    replication rule, so worker bodies that may contain a kernel need
    check_rep=False (renamed check_vma in newer jax)."""
    try:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )
    except TypeError:
        return shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )


def mesh_axis_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def pad_parts(parts, k: int):
    """Pad variable-length per-worker row blocks to one dense
    ``(W, per, k)`` int32 array (pad value -1) + per-worker counts — THE
    shard-padding convention, shared by the even block split below and the
    store-provided (cost-balanced) parts in the shard-map backend."""
    n = len(parts)
    per = max(max((len(p) for p in parts), default=0), 1)
    padded = np.full((n, per, k), -1, dtype=np.int32)
    counts = np.zeros(n, dtype=np.int32)
    for s, p in enumerate(parts):
        padded[s, : len(p)] = p
        counts[s] = len(p)
    return padded, counts


def partition_frontier(frontier: np.ndarray, n_shards: int):
    """Broadcast-then-partition (paper §5.3): even block split, padded."""
    b, k = frontier.shape
    per = -(-b // n_shards) if b else 1
    return pad_parts(
        [frontier[s * per : (s + 1) * per] for s in range(n_shards)], k
    )


def make_sharded_expand(app: MiningApp, mesh: Mesh, axes=("data",),
                        use_pallas: bool = False, interpret=None,
                        compact_kernel: bool = False,
                        with_patterns: bool = False):
    """One BSP superstep: coordination-free expand over the mesh.

    The worker body is the SAME fused chunk program the serial backend jits
    (``explore.fused_chunk_step``, DESIGN.md §8): expansion + canonicality
    + app filter + stream compaction, and — with ``with_patterns`` — the
    children's quick-pattern codes in the same device pass, so the next
    superstep's aggregation needs no second upload of the frontier.
    """

    mode = app.mode
    spec_in = P(axes)

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def step(g, members, n_valid, out_cap: int):
        def worker(g, members, n_valid):
            m = members[0]          # shard_map adds the leading shard dim
            nv = n_valid[0]
            with device_scope("fused_chunk"):
                children, count, codes, lv, ngen, ncanon = (
                    explore.fused_chunk_step(
                        g, m, nv, out_cap,
                        mode=mode,
                        app=app,
                        with_patterns=with_patterns,
                        use_pallas=use_pallas,
                        compact_kernel=compact_kernel,
                        interpret=interpret,
                    )
                )
            outs = (children[None], count[None], ngen[None], ncanon[None])
            if with_patterns:
                outs += (codes[None], lv[None])
            return outs

        mapper = (
            shard_map_pallas_ok if (use_pallas or compact_kernel) else shard_map
        )
        n_out = 6 if with_patterns else 4
        return mapper(
            functools.partial(worker, g),
            mesh=mesh,
            in_specs=(spec_in, spec_in),
            out_specs=(spec_in,) * n_out,
        )(members, n_valid)

    return step


def halo_fetch_tile(pg_l, m, nv, *, mode: str, halo: str, axes,
                    w: int, rows: int, n: int,
                    compact_kernel: bool = False, interpret=None):
    """The halo-exchange stage of the partitioned worker body (DESIGN.md
    §11), shared by the mining superstep and the ``trace_sync`` exchange
    probe (``StepStats.t_exchange``): derive the worker's halo — the
    unique vertices its frontier slice touches — and fetch their neighbour
    rows from the owning shards via in-program collectives, returning the
    ``explore.TileView`` the fused chunk program consumes.

      * ``halo="alltoall"``: a position-aligned request matrix (W, H) of
        vertex ids goes through ONE ``all_to_all``; owners gather the
        requested rows from their local shard and a second ``all_to_all``
        returns them. Wire bytes scale with the halo, never the graph.
      * ``halo="gather"``: ragged fallback — ``all_gather`` the full shard
        tables and index locally (bytes scale with the graph; always
        lowers).

    ``w``/``rows``/``n`` are the FULL graph's shard count / padded tile
    rows / vertex count — inside ``shard_map`` the worker-local ``pg_l``
    only sees its own shard's leading dim.
    """
    # static halo capacity (a function of the chunk shape alone):
    # overflow is impossible by construction, so the output contract
    # of the fused step — and the drain protocol — are untouched
    cap = explore.halo_cap(m.shape, mode, n)
    verts = explore.halo_vertices(pg_l, m, nv, mode)
    uniq, _ = gather_kernel_lib.halo_unique(
        verts, n, cap,
        use_kernel=compact_kernel, interpret=interpret,
    )
    ok = uniq < n
    safe = jnp.clip(uniq, 0, n - 1)
    own = jnp.clip(
        jnp.searchsorted(pg_l.part_offsets, safe, side="right") - 1,
        0, w - 1,
    ).astype(jnp.int32)

    if halo == "gather":
        # ragged all-gather fallback: full shard tables on the wire
        fi = jnp.clip(
            own * rows + (safe - pg_l.part_offsets[own]),
            0, w * rows - 1,
        ).astype(jnp.int32)

        def fetch(tbl, fill):
            full = jax.lax.all_gather(tbl, axes)      # (W, rows, ·)
            t = full.reshape(w * rows, tbl.shape[-1])[fi]
            return jnp.where(ok[:, None], t, fill)
    else:
        # all-to-all halo: req[s, i] = uniq[i] iff shard s owns it
        rank = _linear_rank(axes)
        my_lo = pg_l.part_offsets[rank]
        req = jnp.where(
            (own[None, :] == jnp.arange(w, dtype=jnp.int32)[:, None])
            & ok[None, :],
            uniq[None, :], -1,
        ).astype(jnp.int32)                           # (W, cap)
        got = jax.lax.all_to_all(req, axes, 0, 0)
        loc = got - my_lo
        inr = (got >= 0) & (loc >= 0) & (loc < rows)
        sl = jnp.clip(loc, 0, rows - 1)

        def fetch(tbl, fill):
            resp = jnp.where(inr[:, :, None], tbl[sl], fill)
            back = jax.lax.all_to_all(resp, axes, 0, 0)
            t = back[own, jnp.arange(cap)]
            return jnp.where(ok[:, None], t, fill)

    nbr_t = fetch(pg_l.nbr_sh[0], jnp.int32(-1))
    if mode == "edge":
        ned_t = fetch(pg_l.nbr_eid_sh[0], jnp.int32(-1))
        adj_t = jnp.zeros((cap, 1), jnp.uint32)
    else:
        adj_t = fetch(pg_l.adj_sh[0], jnp.uint32(0))
        ned_t = jnp.zeros((cap, 0), jnp.int32)
    return explore.TileView(
        uniq=uniq,
        labels=pg_l.labels,
        edge_uv=pg_l.edge_uv,
        edge_labels=pg_l.edge_labels,
        nbr_t=nbr_t,
        nbr_eid_t=ned_t,
        adj_t=adj_t,
    )


def make_sharded_expand_partitioned(app: MiningApp, mesh: Mesh,
                                    axes=("data",), halo: str = "alltoall",
                                    use_pallas: bool = False, interpret=None,
                                    compact_kernel: bool = False,
                                    with_patterns: bool = False):
    """The partitioned superstep (DESIGN.md §11): halo exchange + fused step.

    Each worker holds ONE CSR shard + adjacency tile of the graph
    (``PartitionedGraph``, in_specs split the shard-stacked tables over the
    mesh; vertex content stays replicated). Before expanding, the worker
    fetches its halo tile (:func:`halo_fetch_tile` — request/response
    ``all_to_all`` or the ragged all-gather fallback) and runs the SAME
    fused chunk program as every other backend. Both collectives live
    inside the one program, so the superstep keeps its single unclamped-
    count host sync — no new syncs appear.
    """

    mode = app.mode
    spec_in = P(axes)
    rep = P()

    @functools.partial(jax.jit, static_argnames=("out_cap",))
    def step(pg, members, n_valid, out_cap: int):
        w = pg.n_parts
        n = pg.n
        rows = pg.tile_rows

        def worker(pg_l, members, n_valid):
            m, nv = members[0], n_valid[0]
            with device_scope("halo_exchange"):
                view = halo_fetch_tile(
                    pg_l, m, nv,
                    mode=mode, halo=halo, axes=axes, w=w, rows=rows, n=n,
                    compact_kernel=compact_kernel, interpret=interpret,
                )
            with device_scope("fused_chunk"):
                children, count, codes, lv, ngen, ncanon = (
                    explore.fused_chunk_step(
                        view, m, nv, out_cap,
                        mode=mode,
                        app=app,
                        with_patterns=with_patterns,
                        use_pallas=use_pallas,
                        compact_kernel=compact_kernel,
                        interpret=interpret,
                    )
                )
            outs = (children[None], count[None], ngen[None], ncanon[None])
            if with_patterns:
                outs += (codes[None], lv[None])
            return outs

        pg_specs = PartitionedGraph(
            part_offsets=rep, labels=rep, edge_uv=rep, edge_labels=rep,
            nbr_sh=spec_in, nbr_eid_sh=spec_in, deg_sh=spec_in,
            adj_sh=spec_in,
        )
        mapper = (
            shard_map_pallas_ok if (use_pallas or compact_kernel) else shard_map
        )
        n_out = 6 if with_patterns else 4
        return mapper(
            worker,
            mesh=mesh,
            in_specs=(pg_specs, spec_in, spec_in),
            out_specs=(spec_in,) * n_out,
        )(pg, members, n_valid)

    return step


def make_sharded_halo_probe(mode: str, mesh: Mesh, axes=("data",),
                            halo: str = "alltoall",
                            compact_kernel: bool = False, interpret=None):
    """Standalone halo-fetch program for the ``trace_sync`` exchange probe
    (``StepStats.t_exchange``, DESIGN.md §12): the exact
    :func:`halo_fetch_tile` stage of the partitioned superstep, minus the
    fused chunk program, so its completion time is measurable without
    breaking the mining program's single-sync contract. Only dispatched
    while a ``sync=True`` tracer is installed."""
    spec_in = P(axes)
    rep = P()

    @jax.jit
    def probe(pg, members, n_valid):
        w, n, rows = pg.n_parts, pg.n, pg.tile_rows

        def worker(pg_l, members, n_valid):
            view = halo_fetch_tile(
                pg_l, members[0], n_valid[0],
                mode=mode, halo=halo, axes=axes, w=w, rows=rows, n=n,
                compact_kernel=compact_kernel, interpret=interpret,
            )
            return view.nbr_t[None]

        pg_specs = PartitionedGraph(
            part_offsets=rep, labels=rep, edge_uv=rep, edge_labels=rep,
            nbr_sh=spec_in, nbr_eid_sh=spec_in, deg_sh=spec_in,
            adj_sh=spec_in,
        )
        mapper = shard_map_pallas_ok if compact_kernel else shard_map
        return mapper(
            worker,
            mesh=mesh,
            in_specs=(pg_specs, spec_in, spec_in),
            out_specs=spec_in,
        )(pg, members, n_valid)

    return probe


class ShardCarried(NamedTuple):
    """Device-resident child pattern state carried between supersteps by
    the shard-map backend under ``device_aggregate`` (DESIGN.md §10): the
    per-worker quick codes / local-vertex tables stay in their padded
    (W, cap, ·) shard layout on device — replacing the post-hoc host
    concatenation the host path pays — plus the host-known valid counts."""

    codes: jnp.ndarray     # (W, cap, 3) int64
    lv: jnp.ndarray        # (W, cap, 8) int32
    counts: np.ndarray     # (W,) valid rows per worker


def _linear_rank(axes):
    """Worker rank linearised over the mesh axes (row-major in axis order,
    matching ``all_gather``'s concatenation order)."""
    r = jnp.int32(0)
    for a in axes:
        r = r * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return r


def make_sharded_quick_bin(mesh: Mesh, axes=("data",), use_kernel=False,
                           bin_method: str = "sort", interpret=None):
    """Device-resident level-1 aggregation over the mesh (DESIGN.md §10).

    Each worker bins its shard's quick codes locally
    (``kernels/aggregate.bin_rows``), all-gathers the O(Q)-sized distinct
    tables, deterministically re-bins the union into ONE global table
    (identical on every worker — the input is the gathered tables), and
    then **psums the per-slot counts** — the paper's Table-4 promise as a
    collective whose bytes scale with #patterns, never #embeddings. Also
    returns each row's global slot id (sharded, device-resident) for the
    FSM domain scatter and alpha row masks.

    Each worker bins at ``local_cap`` — a *pattern*-sized capacity, NOT the
    shard's row count — so the gathered tables are O(Q) on the wire. A
    worker whose distinct count overflows ``local_cap`` raises the
    all-reduced ``corrupt`` flag (riding the same drain as the distinct
    total, no extra sync) and the backend falls back to the host path for
    the step, growing the capacity for the next one.
    """
    spec = P(axes)

    @functools.partial(jax.jit, static_argnames=("local_cap", "global_cap"))
    def agg(codes_sh, valid_sh, local_cap: int, global_cap: int):
        def worker(codes, valid):
            codes, valid = codes[0], valid[0]
            # device-side §12 scope: the whole bin+gather+psum stage
            with device_scope("aggregate_bin"):
                u, c, inv, n, uv = agg_kernel_lib.bin_rows(
                    codes, valid, local_cap,
                    use_kernel=use_kernel, interpret=interpret,
                    method=bin_method,
                )
                gath_u = jax.lax.all_gather(u, axes)    # (W, cap, 3)
                gath_c = jax.lax.all_gather(c, axes)
                gath_v = jax.lax.all_gather(uv, axes)
                w = gath_u.shape[0]
                gu, _, ginv, gn, _ = agg_kernel_lib.bin_rows(
                    gath_u.reshape(w * local_cap, 3),
                    gath_v.reshape(w * local_cap),
                    global_cap,
                    use_kernel=use_kernel, interpret=interpret,
                    method=bin_method,
                )
                rank = _linear_rank(axes)
                my_map = jax.lax.dynamic_slice_in_dim(
                    ginv, rank * local_cap, local_cap
                )
                # THE collective: per-slot counts psum'd over the mesh
                # axes — bytes ∝ #patterns, not #embeddings (Table 4)
                seg = jnp.where(uv & (my_map >= 0), my_map, global_cap)
                local_counts = jnp.zeros(
                    (global_cap + 1,), jnp.int64
                ).at[seg].add(c)
                counts = jax.lax.psum(local_counts[:global_cap], axes)
                corrupt = jax.lax.pmax(
                    (n > local_cap).astype(jnp.int32), axes
                )
                row_slot = jnp.where(
                    inv >= 0, my_map[jnp.maximum(inv, 0)], -1
                ).astype(jnp.int32)
            return (gu[None], counts[None], gn[None], corrupt[None],
                    row_slot[None])

        mapper = shard_map_pallas_ok if use_kernel else shard_map
        return mapper(
            worker,
            mesh=mesh,
            in_specs=(spec, spec),
            out_specs=(spec,) * 5,
        )(codes_sh, valid_sh)

    return agg


def make_sharded_domain_scatter(mesh: Mesh, axes=("data",)):
    """FSM phase 2 under ``device_aggregate``: every worker scatters its
    rows' vertices into the canonical domain bitmap at its global slots,
    then ONE OR(max)-allreduce merges the (pc_cap, 8, N) bitmaps — the
    paper's domain merge as a collective, with per-quick-slot level-2
    tables (q2c, sigma_inv) uploaded replicated."""
    spec = P(axes)
    rep = P()

    @functools.partial(jax.jit, static_argnames=("pc_cap", "n_vertices"))
    def scat(row_slot_sh, lv_sh, q2c, si, pc_cap: int, n_vertices: int):
        kmax = pattern_lib.MAX_PATTERN_VERTICES

        def worker(q2c, si, row_slot, lv):
            flat = jnp.zeros((pc_cap * kmax * n_vertices + 1,), dtype=bool)
            flat = aggregation.scatter_canon_bitmaps(
                flat, row_slot[0], lv[0], q2c, si, pc_cap, n_vertices
            )
            bm = flat[:-1].reshape(pc_cap, kmax, n_vertices)
            bm = jax.lax.pmax(bm.astype(jnp.int32), axes) > 0
            return bm[None]

        return shard_map(
            worker,
            mesh=mesh,
            in_specs=(rep, rep, spec, spec),
            out_specs=spec,
        )(q2c, si, row_slot_sh, lv_sh)

    return scat


def make_sharded_aggregate(mesh: Mesh, axes=("data",)):
    """Two-level aggregation's global reduce as ONE collective: counts psum +
    domain-bitmap OR(max)-allreduce over the mesh axes."""

    spec = P(axes)

    @functools.partial(jax.jit, static_argnames=("n_canon", "n_vertices"))
    def agg(canon_slot, verts_canon, valid, n_canon: int, n_vertices: int):
        def worker(canon_slot, verts_canon, valid):
            slot = canon_slot[0]
            counts = jax.ops.segment_sum(
                valid[0].astype(jnp.int64),
                jnp.where(valid[0], slot, n_canon),
                n_canon + 1,
            )[:n_canon]
            bitmaps = aggregation.domain_bitmaps(
                slot, verts_canon[0], valid[0], n_canon, n_vertices
            )
            # THE collective: bytes ∝ #patterns, not #embeddings (Table 4)
            counts = jax.lax.psum(counts, axes)
            bitmaps = jax.lax.pmax(bitmaps.astype(jnp.int32), axes) > 0
            return counts[None], bitmaps[None]

        counts, bitmaps = shard_map(
            worker,
            mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=(spec, spec),
        )(canon_slot, verts_canon, valid)
        return counts[0], bitmaps[0]

    return agg


class ShardMapBackend(ExecutionBackend):
    name = "shard_map"

    def __init__(self, mesh: Mesh, axes=None) -> None:
        self.mesh = mesh
        self._axes_override = axes

    def _make_store(self) -> FrontierStore:
        config, app = self.config, self.app
        self.axes = (
            self._axes_override if self._axes_override is not None
            else config.axes
        )
        self.n_shards = mesh_axis_size(self.mesh, self.axes)
        resolved_pallas = config.resolve_use_pallas()
        store = make_store(
            config.store, self.g,
            mode=app.mode,
            app_filter=programs.store_app_filter(app, self.g),
            use_pallas=resolved_pallas,
            interpret=config.pallas_interpret,
            dense_exchange=True,
        )
        # carried child codes need the next frontier to be exactly the
        # appended rows in order — raw store only (ODAG extraction
        # resurrects rows), and the naive-aggregation baseline deliberately
        # re-derives everything.
        self.with_patterns = (
            config.async_chunks
            and app.wants_patterns
            and store.kind == "raw"
            and not config.naive_aggregation
        )
        # device-resident level 1 (DESIGN.md §10): local bin + all-gathered
        # global table + per-slot psum/pmax; alpha must be pattern-granular
        self._device_agg = (
            config.device_aggregate
            and app.wants_patterns
            and not config.naive_aggregation
            and type(app).aggregation_filter is MiningApp.aggregation_filter
        )
        self._agg_kernel = config.resolve_aggregate_kernel()
        self._agg_bin = config.resolve_aggregate_bin()
        # level-2 placement (DESIGN.md §15): same contract as the serial
        # backend — host_async needs the deferrable device-agg path
        self._canon_placement = config.resolve_canonical_placement()
        if self._canon_placement == "host_async" and not (
            self._device_agg and aggregation.async_level2_ok(app)
        ):
            self._canon_placement = "host"
        if config.canonical_memo_cap is not None:
            pattern_lib.set_memo_cap(config.canonical_memo_cap)
        #: per-worker distinct-table capacity (pattern-sized, so gathered
        #: bytes stay O(Q)); grows pow2 after a host-fallback step
        self._shard_qcap = next_pow2(max(config.agg_qcap, 1))
        self._partitioned = isinstance(self.g, PartitionedGraph)
        if self._partitioned:
            if self.g.n_parts != self.n_shards:
                raise ValueError(
                    f"graph_partition={self.g.n_parts} must equal the "
                    f"shard-map worker count ({self.n_shards}): the "
                    f"in-program halo exchange maps one CSR shard per worker"
                )
            self._halo = config.resolve_halo()
            self._expand = make_sharded_expand_partitioned(
                app, self.mesh, self.axes,
                halo=self._halo,
                use_pallas=resolved_pallas,
                interpret=config.pallas_interpret,
                compact_kernel=config.resolve_compact_kernel(),
                with_patterns=self.with_patterns,
            )
            self._halo_probe = make_sharded_halo_probe(
                app.mode, self.mesh, self.axes,
                halo=self._halo,
                compact_kernel=config.resolve_compact_kernel(),
                interpret=config.pallas_interpret,
            )
        else:
            self._expand = make_sharded_expand(
                app, self.mesh, self.axes,
                use_pallas=resolved_pallas,
                interpret=config.pallas_interpret,
                compact_kernel=config.resolve_compact_kernel(),
                with_patterns=self.with_patterns,
            )
        self._aggregate = make_sharded_aggregate(self.mesh, self.axes)
        self._quick_bin = make_sharded_quick_bin(
            self.mesh, self.axes,
            use_kernel=self._agg_kernel,
            bin_method=config.resolve_aggregate_bin(),
            interpret=config.pallas_interpret,
        )
        self._domain_scatter = make_sharded_domain_scatter(
            self.mesh, self.axes
        )
        return store

    # -- superstep hooks ----------------------------------------------------
    def begin_step(self, store, st) -> List[np.ndarray]:
        self._row_slot = None
        # raw: deterministic block split (broadcast-then-partition); odag:
        # §5.3 cost-annotated partitions, one extraction per worker.
        return store.worker_parts(self.n_shards)

    def quick_codes(self, blocks, size):
        frontier = (
            np.concatenate(blocks, axis=0)
            if any(len(p) for p in blocks)
            else np.zeros((0, size), np.int32)
        )
        b = len(frontier)
        qp = programs.quick_patterns(
            self.g, self.app.mode, jnp.asarray(frontier),
            jnp.full((b,), size, dtype=jnp.int32),
        )
        return np.asarray(qp.codes), np.asarray(qp.local_verts)

    def aggregate(self, codes, lv, st):
        g, app, config = self.g, self.app, self.config
        n_shards = self.n_shards
        b = len(codes)
        if config.naive_aggregation:
            # naive scheme: exchange per-EMBEDDING codes (an all-gather of
            # B x 24 bytes x workers) and run pattern canonicalisation once
            # per embedding instead of once per quick pattern.
            obs.count(st, "collective_bytes", int(codes.size * 8) * n_shards)
            for row in codes:
                pattern_lib.canonicalize_one(row)           # B iso checks
        uniq, inv = aggregation.quick_slot_ids(codes, np.ones(b, bool))
        # placement "device" routes the miss batch through the refine
        # kernel even on this host-reference path (bit-identical);
        # "host_async" has no deferrable table here and runs synchronously
        canon_fn = (
            canonical_refine.make_canon_fn(
                use_kernel=self._agg_kernel,
                interpret=config.pallas_interpret,
            )
            if self._canon_placement == "device"
            else None
        )
        table = pattern_lib.build_pattern_table(
            uniq, with_orbits=app.wants_domains, canon_fn=canon_fn
        )
        pc = len(table.canon_codes)
        canon_slot, verts_canon = aggregation.map_to_canonical_positions(
            table, inv, lv
        )
        # shard the level-1 inputs, reduce with the collective
        slot_sh, slot_counts = partition_frontier(canon_slot[:, None], n_shards)
        vc_sh, _ = partition_frontier(np.asarray(verts_canon), n_shards)
        per = slot_sh.shape[1]
        valid_sh = np.arange(per)[None, :] < slot_counts[:, None]
        counts, bitmaps = self._aggregate(
            jnp.asarray(slot_sh[:, :, 0]),
            jnp.asarray(vc_sh.reshape(n_shards, per, -1)),
            jnp.asarray(valid_sh),
            n_canon=max(pc, 1),
            n_vertices=g.n,
        )
        counts = np.asarray(counts[:pc])
        if app.wants_domains:
            supports = aggregation.min_image_support(
                bitmaps[:pc], table.canon_n_verts, table.canon_orbits
            )
        else:
            supports = counts.copy()
        agg_out = aggregation.StepAggregates(
            canon_codes=table.canon_codes,
            counts=counts.astype(np.int64),
            supports=np.asarray(supports).astype(np.int64),
            n_quick=len(uniq),
            n_canonical=pc,
            n_iso_checks=table.n_iso_checks,
        )
        obs.set_stat(st, "n_quick_patterns", agg_out.n_quick)
        obs.set_stat(st, "n_canonical_patterns", agg_out.n_canonical)
        obs.set_stat(
            st, "n_iso_checks",
            b if config.naive_aggregation else agg_out.n_iso_checks,
        )
        obs.count(
            st, "collective_bytes",
            counts.nbytes + (
                int(np.asarray(bitmaps[:pc]).size) // 8
                if app.wants_domains else 0
            ),
        )
        return agg_out, canon_slot

    # -- device-resident aggregation (DESIGN.md §10) ------------------------
    def aggregate_step(self, blocks, size, carried, st):
        if not self._device_agg:
            return super().aggregate_step(blocks, size, carried, st)
        g, app = self.g, self.app
        n_shards = self.n_shards
        n_frontier = sum(len(blk) for blk in blocks)
        if (
            isinstance(carried, ShardCarried)
            and int(carried.counts.sum()) == n_frontier
        ):
            # the children's codes never left the device (nor their padded
            # shard layout): aggregation is upload-free AND concat-free
            codes_sh, lv_sh, cnts = carried
            per = int(codes_sh.shape[1])
        else:
            padded, cnts = pad_parts(blocks, size)
            per = next_pow2(max(padded.shape[1], 1))
            if per > padded.shape[1]:
                padded = np.concatenate(
                    [padded,
                     np.full((n_shards, per - padded.shape[1], size),
                             -1, np.int32)],
                    axis=1,
                )
            nv = (
                (np.arange(per)[None, :] < cnts[:, None]) * size
            ).reshape(-1).astype(np.int32)
            qp = programs.quick_patterns(
                g, app.mode,
                jnp.asarray(padded.reshape(n_shards * per, size)),
                jnp.asarray(nv),
            )
            codes_sh = qp.codes.reshape(n_shards, per, 3)
            lv_sh = qp.local_verts.reshape(n_shards, per, -1)
        valid_sh = jnp.asarray(np.arange(per)[None, :] < cnts[:, None])
        local_cap = min(next_pow2(max(per, 1)), self._shard_qcap)
        global_cap = next_pow2(max(n_shards * local_cap, 1))
        gu, gcounts, gn, gcorrupt, row_slot = self._quick_bin(
            codes_sh, valid_sh, local_cap=local_cap, global_cap=global_cap
        )
        flags = np.asarray(jnp.stack([gn[0], gcorrupt[0].astype(gn.dtype)]))
        obs.count(st, "bytes_to_host", flags.nbytes)
        if faults_lib.take(
            self.config.faults, "aggregate", st.step, "saturate"
        ):
            # injected saturation: route through the host reference path
            # exactly as a tripped overflow flag would (DESIGN.md §13)
            flags = flags.copy()
            flags[1] = 1
        if int(flags[1]):
            # a worker's distinct table overflowed the pattern-sized cap:
            # host reference path for this step, bigger cap for the next
            codes, lv = self.quick_codes(blocks, size)
            obs.count(st, "bytes_to_host", codes.nbytes + lv.nbytes)
            agg_out, canon_slot = self.aggregate(codes, lv, st)
            self._shard_qcap = max(
                self._shard_qcap, next_pow2(max(agg_out.n_quick, 1))
            )
            return agg_out, canon_slot
        # the collective itself: gathered O(Q) tables + per-slot psum
        obs.count(
            st, "collective_bytes",
            n_shards * local_cap * (24 + 8 + 1) + global_cap * 8,
        )
        n = int(flags[0])
        # second tiny scalar read sizes the packed transfer (same packed
        # O(Q) drain as the serial backend's DeviceLevel1.finish)
        pflags = np.asarray(jnp.stack([
            jnp.any(gu[0][:n, 1] != 0),
            jnp.any(gu[0][:n, 2] != 0),
            jnp.max(gcounts[0][:n], initial=0) < jnp.int64(2) ** 31,
        ]))
        uniq, counts_q, tbytes = aggregation.drain_distinct(
            gu[0], gcounts[0], n,
            w1_used=bool(pflags[0]), w2_used=bool(pflags[1]),
            fit32=bool(pflags[2]),
        )
        obs.count(st, "bytes_to_host", pflags.nbytes + tbytes)
        placement = self._canon_placement
        if placement == "host_async":
            # overlap: joined by the loop at the seal boundary; eligibility
            # guarantees neither alpha_rows nor the domain scatter fires
            obs.annotate("canonicalize_submit")
            pending = aggregation.submit_level2(uniq, counts_q)
            self._row_slot, self._row_cnts = row_slot, cnts
            self._agg_table, self._agg_global_cap = None, global_cap
            return pending, None
        t0 = time.perf_counter()
        with obs.span("canonicalize", placement=placement, n_quick=n):
            if placement == "device" and n:
                # canonical re-bin runs on the REPLICATED global table
                # (identical on every worker post-gather): a second
                # non-collective program, so the superstep keeps its
                # <=2-sync contract — no new control reads appear
                uv_dev = jnp.arange(global_cap) < jnp.int32(n)
                table, counts, nbytes2 = aggregation.device_level2(
                    gu[0], gcounts[0], uv_dev, global_cap, n,
                    uniq, counts_q,
                    nvs=aggregation.level2_nvs(app, size),
                    with_domains=app.wants_domains,
                    use_kernel=self._agg_kernel,
                    interpret=self.config.pallas_interpret,
                    method=self._agg_bin,
                )
                obs.count(st, "bytes_to_host", nbytes2)
            else:
                table, counts = aggregation.finish_quick_level2(
                    uniq, counts_q, app.wants_domains
                )
        obs.count(st, "t_canon", time.perf_counter() - t0)
        pc = len(table.canon_codes)
        if app.wants_domains and pc:
            pc_cap = next_pow2(pc)
            q2c, si = aggregation.level2_device_tables(table, global_cap)
            bm_sh = self._domain_scatter(
                row_slot, lv_sh, q2c, si, pc_cap=pc_cap, n_vertices=g.n
            )
            obs.count(st, "collective_bytes", (pc_cap * 8 * g.n) // 8)
            bm = np.asarray(bm_sh[0][:pc])
            obs.count(st, "bytes_to_host", bm.nbytes)
            supports = aggregation.min_image_support(
                bm, table.canon_n_verts, table.canon_orbits
            )
        else:
            supports = counts.copy()
        agg_out = aggregation.build_step_aggregates(
            table, counts, supports, n, st
        )
        self._row_slot, self._row_cnts = row_slot, cnts
        self._agg_table, self._agg_global_cap = table, global_cap
        return agg_out, None

    def alpha_rows(self, pk, st):
        """Per-row alpha from the per-pattern verdict: one device gather
        through the sharded per-row global slot ids; only the bool mask
        crosses, re-assembled to sealed-frontier order via the per-worker
        valid counts."""
        table = self._agg_table
        q = len(table.quick_codes)
        pk_q = np.zeros(self._agg_global_cap, dtype=bool)
        pk_q[:q] = np.asarray(pk, dtype=bool)[table.quick_to_canon]
        slot = self._row_slot
        mask_sh = np.asarray(
            jnp.asarray(pk_q)[jnp.maximum(slot, 0)] & (slot >= 0)
        )
        obs.count(st, "bytes_to_host", mask_sh.nbytes)
        return np.concatenate(
            [mask_sh[s, : self._row_cnts[s]] for s in range(self.n_shards)]
        )

    def expand(self, store, blocks, size, st):
        # coordination-free sharded expansion over the (§5.3 cost-balanced)
        # per-worker slices
        g, n_shards = self.g, self.n_shards
        shards, counts_sh = pad_parts(blocks, size)
        per = shards.shape[1]
        n_valid = (np.arange(per)[None, :] < counts_sh[:, None]) * size
        members_dev = jnp.asarray(shards)
        n_valid_dev = jnp.asarray(n_valid.astype(np.int32))
        halo_bytes = (
            self._halo_bytes(per, size) if self._partitioned else 0
        )
        if self._partitioned:
            # the halo-exchange injection site (DESIGN.md §13): a planned
            # "halo" fault aborts here exactly where a lost worker would
            # surface; the supervisor's ladder answers with halo="gather"
            faults_lib.trip(self.config.faults, "halo", st.step)
        if self._partitioned and obs.sync_active():
            # trace_sync probe (DESIGN.md §12): the halo exchange runs
            # INSIDE the jitted superstep, so its share of t_expand is only
            # separable by re-running the fetch stage standalone — paid
            # exclusively in the diagnostic sync mode
            obs.count(
                st, "t_exchange",
                obs.probe_time(self._halo_probe, g, members_dev, n_valid_dev),
            )
        while True:
            outs = self._expand(g, members_dev, n_valid_dev,
                                out_cap=self.capacity)
            children, ccount = outs[0], outs[1]
            ccount = np.asarray(ccount)     # THE per-step control sync
            obs.count(st, "n_host_syncs", 1)
            obs.count(st, "n_chunks", 1)
            obs.count(st, "collective_bytes", halo_bytes)
            if int(ccount.max()) <= self.capacity:
                break
            # counts are exact (unclamped compaction), so exactly one
            # re-dispatch at the next pow2 bucket suffices
            programs.retire(*outs)
            self.capacity = next_pow2(int(ccount.max()))
        obs.set_stat(st, "n_generated", int(np.asarray(outs[2]).sum()))
        obs.set_stat(st, "n_canonical", int(np.asarray(outs[3]).sum()))

        # frontier exchange: worker-local children into the store as device
        # arrays (resolved at seal; odag: DenseODAG OR-allreduce, §5.2);
        # with the fused pipeline the children's pattern codes are carried
        # to the next superstep's aggregation
        for s in range(n_shards):
            store.append(children[s], worker=s, count=int(ccount[s]))
        if not self.with_patterns:
            return None
        if self._device_agg:
            # DESIGN.md §10: the child pattern state stays on device in its
            # shard layout — no post-hoc host concat, no host bytes
            return ShardCarried(
                codes=outs[4], lv=outs[5], counts=np.asarray(ccount)
            )
        codes_all = np.asarray(outs[4])
        lv_all = np.asarray(outs[5])
        return (
            np.concatenate(
                [codes_all[s, : ccount[s]] for s in range(n_shards)]
            ),
            np.concatenate(
                [lv_all[s, : ccount[s]] for s in range(n_shards)]
            ),
        )

    def _halo_bytes(self, per: int, size: int) -> int:
        """Per-dispatch halo-exchange wire bytes, computed host-side: the
        halo capacity is a static function of the chunk shape
        (``explore.halo_cap``) and row widths come from the shard tables,
        so the accounting needs no extra device output or sync."""
        g, mode, w = self.g, self.app.mode, self.n_shards
        cap = explore.halo_cap((per, size), mode, g.n)
        if mode == "edge":
            row = 2 * g.max_degree * 4          # nbr + edge-id rows, int32
        else:
            row = (g.max_degree + g.adj_sh.shape[2]) * 4
        if self._halo == "gather":
            # every worker all-gathers the full shard tables
            return w * w * g.tile_rows * row
        # request all-to-all (vertex ids) + response all-to-all (rows)
        return w * w * cap * (4 + row)

    def end_step(self, store, st) -> None:
        # frontier exchange: what a worker ships (raw rows, or the merged
        # ODAG with store="odag") rides the same collective accounting as
        # the aggregation reduce
        obs.count(st, "collective_bytes", store.exchange_bytes)
