"""Unified superstep runtime (DESIGN.md §9).

One :class:`SuperstepRuntime` BSP loop, parameterised by an
:class:`ExecutionBackend` — :class:`SerialBackend` (single-device fused
chunk pipeline) or :class:`ShardMapBackend` (mesh workers + collectives) —
configured by one :class:`RunConfig`, with superstep-granular
checkpoint/resume (``checkpoint_dir=`` / :func:`resume`) and elastic
restore under a different worker count. ``engine.run`` and
``distributed.run_distributed`` are thin wrappers kept for compatibility.
"""
from repro.core.runtime.backend import ExecutionBackend
from repro.core.runtime.checkpoint import (
    CheckpointCorruptError,
    CheckpointState,
    app_fingerprint,
    graph_fingerprint,
    latest_checkpoint,
    load_latest_valid,
    sweep_stale_tmp,
)
from repro.core.runtime.config import RunConfig, next_pow2
from repro.core.runtime.faults import FaultPlan, FaultSpec, InjectedFault
from repro.core.runtime.loop import (
    MiningResult, SuperstepRuntime, resume, run_supervised,
)
from repro.core.runtime.serial import SerialBackend
from repro.core.runtime.shard import ShardMapBackend

__all__ = [
    "CheckpointCorruptError",
    "CheckpointState",
    "ExecutionBackend",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "MiningResult",
    "RunConfig",
    "SerialBackend",
    "ShardMapBackend",
    "SuperstepRuntime",
    "app_fingerprint",
    "graph_fingerprint",
    "latest_checkpoint",
    "load_latest_valid",
    "next_pow2",
    "resume",
    "run_supervised",
    "sweep_stale_tmp",
]
