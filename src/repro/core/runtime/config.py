"""The one run configuration for the unified superstep runtime (DESIGN.md §9).

``RunConfig`` supersedes the two hand-maintained config dataclasses the
engines grew (``EngineConfig`` in ``core/engine.py`` and ``DistConfig`` in
``core/distributed.py``): every knob, every ``resolve_*`` helper, and the
pow2 capacity-bucket arithmetic now live here exactly once. The old names
are kept as empty subclasses (deprecation shims), so every existing call
site keeps working and old kwargs resolve identically (tested in
``tests/test_runtime.py``).

Serial-only knobs (``chunk_size``, ``device_budget_bytes``) are ignored by
the shard-map backend; distributed-only knobs (``axes``,
``naive_aggregation``) are ignored by the serial backend — a config is a
description of the *run*, the backend picks what applies.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax

from repro.kernels.dispatch import resolve_canonical_placement, resolve_halo


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1): THE capacity-bucket rule.

    Chunk widths and output capacities are bucketed to powers of two so XLA
    recompiles only per bucket (DESIGN.md §8) — shared by both backends and
    the benchmarks."""
    return 1 << max(0, (int(x) - 1).bit_length())


def _static_native() -> bool:
    """Pre-resolution fallback for the kernel knobs: Pallas only where it
    compiles to native code (TPU — see ``costmodel.static_table`` for the
    rationale).  Configs that went through ``costmodel.resolve`` never hit
    this — every decided knob is concrete by the time a backend builds its
    programs; this keeps direct ``resolve_*`` callers (faults ladder,
    benches) working on an unresolved config."""
    return jax.default_backend() == "tpu"


@dataclasses.dataclass
class RunConfig:
    """Configuration of one mining run, backend-agnostic (DESIGN.md §9)."""

    chunk_size: int = 4096        # frontier rows per expansion program (serial)
    initial_capacity: int = 4096  # starting output-capacity bucket (per shard
                                  # in the distributed backend)
    max_steps: int = 16           # hard cap on exploration depth
    #: route the Alg.-2 canonicality check through the Pallas kernel
    #: (VMEM-sized graphs, vertex mode). None -> auto: on for backends with
    #: a native Pallas lowering (TPU/GPU), off on CPU.
    use_pallas: Optional[bool] = None
    #: with use_pallas, also fuse candidate validity + dedup + Alg.-2 into
    #: the single-pass expand_canonical kernel (vertex mode).
    fused_expand: bool = False
    #: Pallas interpret override; None -> auto per backend (compiled on
    #: TPU/GPU, interpreter on CPU).
    pallas_interpret: Optional[bool] = None
    #: how the frontier lives between supersteps: "raw" keeps the dense
    #: embedding list, "odag" stores per-size ODAGs (paper §5.2) and
    #: re-materialises via cost-balanced extraction (§5.3).
    store: str = "raw"
    #: device byte budget for one materialised frontier wave; when set, the
    #: frontier store is wrapped in a SpillStore and each superstep is mined
    #: in waves of at most this many bytes of embedding rows (frontiers
    #: larger than device memory). None -> one wave per step. Serial
    #: backend only.
    device_budget_bytes: Optional[int] = None
    #: fused superstep pipeline (DESIGN.md §8): chunk programs return
    #: children + counts + child quick-pattern codes in one device pass,
    #: counts stay device-resident and the host drains them ONCE per
    #: superstep (O(1) host syncs instead of O(chunks)). False = the PR-2
    #: chunk loop (one host sync per chunk, separate quick-pattern pass) —
    #: kept as the measured baseline. None -> cost model (DESIGN.md §14):
    #: the calibration pilot compares the legacy loop's per-chunk tax
    #: (sync + upload + quick-pattern pass) against the fused pipeline's.
    async_chunks: Optional[bool] = None
    #: route chunk compaction through the Pallas stream-compaction kernel
    #: (block prefix-sum + scatter, ``kernels/compact.py``) instead of the
    #: jnp nonzero gather. None -> auto: on where Pallas compiles to
    #: native code (TPU), off on CPU where the interpreter would lose.
    compact_kernel: Optional[bool] = None
    #: device-resident level-1 pattern aggregation (DESIGN.md §10): quick
    #: codes are binned into per-pattern counts (and FSM domain bitmaps) on
    #: device, and only O(#patterns) bytes cross to the host for level-2
    #: canonicalisation. False = the host reference path
    #: (``aggregation.aggregate_rows``), which drains the full frontier's
    #: codes each superstep. Apps overriding the per-row
    #: ``aggregation_filter`` (instead of ``pattern_filter``) fall back to
    #: the host path automatically — alpha then needs per-row slots.
    #: None -> cost model: measured per-row device fold+merge cost vs
    #: per-row host drain cost decides the placement per backend.
    device_aggregate: Optional[bool] = None
    #: route the level-1 segment-unique/reduce through the Pallas kernel
    #: (``kernels/aggregate.py``; the row sort stays on XLA's tuned sort).
    #: None -> auto: on where Pallas compiles natively (TPU), off on CPU.
    aggregate_kernel: Optional[bool] = None
    #: row-binning algorithm of the device level-1 bin: "sort" keeps XLA's
    #: 2-key ``lax.sort`` (``kernels/aggregate.py``), "radix" routes
    #: through the LSB-radix / fused-key bucket bin
    #: (``kernels/radix_bin.py``) — measured faster on CPU where XLA's
    #: variadic sort is slow. None -> cost model picks per backend.
    aggregate_bin: Optional[str] = None
    #: where level-2 canonicalisation of the distinct quick-code table runs
    #: (DESIGN.md §15): "device" refines all O(Q) codes in a batched
    #: permutation kernel inside the aggregation program
    #: (``kernels/canonical_refine.py``); "host" is the memoised numpy
    #: batch on the critical path (the reference); "host_async" runs that
    #: same host batch on a background thread overlapped with the next
    #: superstep's expansion and joined at the seal boundary (apps that
    #: prune on patterns mid-step — FSM's support filter — or consume
    #: domains fall back to "host" silently: alpha needs the table).
    #: None -> cost model: the calibration pilot times device refine vs
    #: host batch on the pilot's distinct codes and picks per backend.
    canonical_placement: Optional[str] = None
    #: LRU cap of the process-wide quick->canonical memo
    #: (``pattern.set_memo_cap``). None keeps ``pattern.DEFAULT_MEMO_CAP``
    #: (2^20 entries); labeled-graph services that mine many graphs can
    #: lower it to bound resident memo bytes.
    canonical_memo_cap: Optional[int] = None
    #: how the ``None``/auto knobs above resolve (DESIGN.md §14): "auto"
    #: runs the pilot-calibrated cost model (probe timings pick the
    #: fastest implementation per phase per backend, cached per
    #: (backend, app, graph) signature); "off" pins the static defaults
    #: (fused + device aggregation, Pallas on TPU only); "force_device" /
    #: "force_host" pin the placement extremes so every dispatch path is
    #: reachable regardless of measurements.
    cost_model: str = "auto"
    #: directory the calibrated decision tables persist in (JSON, one file
    #: per (backend, platform, app, graph, config) signature) so repeat
    #: runs in fresh processes skip the calibration pilot. None -> the
    #: table is cached process-wide only.
    cost_model_dir: Optional[str] = None
    #: graphs with fewer edges than this resolve through the static table
    #: without calibrating — a unit-test-sized run must never pay a pilot.
    cost_model_min_edges: int = 2048
    #: starting capacity of the cross-batch level-1 merge table (distinct
    #: quick patterns per superstep). Like the output-capacity bucket it
    #: grows by pow2 on overflow — the unclamped distinct count rides the
    #: one aggregation drain, so growth costs a re-merge (or a wave
    #: re-fold), never an extra sync. Labeled graphs with tens of
    #: thousands of quick patterns can set it higher up front.
    agg_qcap: int = 4096
    #: number of graph shards of the partitioned layout (DESIGN.md §11):
    #: the device graph becomes per-device CSR shards + packed adjacency
    #: tiles (``core.graph.PartitionedGraph``) and the fused pipeline opens
    #: with a halo-tile gather instead of whole-graph lookups. None keeps
    #: the replicated ``DeviceGraph`` (the reference layout). The serial
    #: backend mines any shard count as virtual shards; the shard-map
    #: backend requires it to equal the mesh worker count (the shard axis
    #: IS the mesh axis) and exchanges halos in-program.
    graph_partition: Optional[int] = None
    #: partition boundary placement: "degree" balances adjacency payload
    #: across shards, "vertex" splits the id space evenly.
    partition_balance: str = "degree"
    #: halo-exchange strategy of the partitioned shard-map superstep:
    #: "alltoall" (position-aligned request/response all-to-all, O(halo)
    #: bytes per worker), "gather" (ragged all-gather of the shard tables,
    #: O(n) fallback), or None/"auto" -> "alltoall"
    #: (``kernels.dispatch.resolve_halo``).
    halo: Optional[str] = None
    #: mesh axes the shard-map backend shards the frontier over.
    axes: tuple = ("data",)
    #: disable two-level aggregation (§Perf baseline, distributed backend):
    #: every worker all-gathers all embeddings' quick codes and
    #: canonicalises each embedding's pattern itself — the paper's Fig.11
    #: naive scheme.
    naive_aggregation: bool = False
    #: directory for superstep-granular checkpoints (DESIGN.md §9): when
    #: set, the runtime writes {sealed store payload, stats, patterns,
    #: superstep cursor, app+graph fingerprints} at the seal boundary and
    #: ``runtime.resume`` continues from the latest one — under any worker
    #: count (elastic restore: re-partition happens at extraction time).
    checkpoint_dir: Optional[str] = None
    #: write a checkpoint every this-many supersteps (1 = every seal).
    checkpoint_every: int = 1
    #: collect host phase spans + metrics for this run (DESIGN.md §12).
    #: The default False path adds ZERO device syncs and no span
    #: allocation — the observability layer's hard contract, guarded by
    #: ``benchmarks/bench_obs.py`` and ``tests/test_obs.py``.
    trace: bool = False
    #: directory the traced run exports to: a Perfetto-loadable Chrome
    #: trace (``run-<pid>-<seq>.trace.json``) plus a live-tailable JSONL
    #: event stream (``.events.jsonl``). ``trace=True`` with no directory
    #: keeps the spans in memory only (``SuperstepRuntime.observer``).
    trace_dir: Optional[str] = None
    #: blocking ``block_until_ready`` phase boundaries: host phase laps
    #: measure device COMPLETION instead of dispatch, and the in-program
    #: tile-gather / halo-exchange stages get probe-measured into
    #: ``StepStats.t_gather``/``t_exchange``. Diagnostic mode — it
    #: serialises the pipeline; never implied by ``trace`` alone.
    trace_sync: bool = False
    #: print one structured progress line every this-many supersteps
    #: (0 = silent). Works with or without ``trace``.
    log_every: int = 0
    #: deterministic fault-injection plan (DESIGN.md §13): a
    #: ``runtime.faults.FaultPlan`` of (phase, superstep, kind) triples
    #: tripped at the loop's phase boundaries and the halo-exchange path.
    #: None (the default) compiles to a single attribute read per phase —
    #: production runs pay nothing. Test/chaos tooling only.
    faults: Optional[object] = None
    #: retry budget of ``run_supervised`` (DESIGN.md §13): how many times a
    #: failed attempt restarts from the last *valid* checkpoint before the
    #: failure is re-raised. 0 = one attempt, no retries.
    max_retries: int = 3
    #: base seconds of the supervisor's exponential backoff: retry k sleeps
    #: ``retry_backoff * 2**(k-1)``. 0 = retry immediately (tests/benches).
    retry_backoff: float = 0.0
    #: keep-last-K checkpoint retention (0 = keep every cut). K >= 2 keeps
    #: a rollback target when the newest checkpoint fails its checksum.
    keep_checkpoints: int = 0

    def resolve_use_pallas(self) -> bool:
        return _static_native() if self.use_pallas is None else self.use_pallas

    def resolve_compact_kernel(self) -> bool:
        return (
            _static_native()
            if self.compact_kernel is None
            else self.compact_kernel
        )

    def resolve_aggregate_kernel(self) -> bool:
        return (
            _static_native()
            if self.aggregate_kernel is None
            else self.aggregate_kernel
        )

    def resolve_aggregate_bin(self) -> str:
        return "sort" if self.aggregate_bin is None else self.aggregate_bin

    def resolve_halo(self) -> str:
        return resolve_halo(self.halo)

    def resolve_canonical_placement(self) -> str:
        return resolve_canonical_placement(self.canonical_placement)
