"""The pluggable execution-backend protocol of the superstep runtime.

One :class:`repro.core.runtime.loop.SuperstepRuntime` loop drives every
deployment (DESIGN.md §9); what varies between "one device" and "a shard_map
mesh" is captured here as an :class:`ExecutionBackend`:

  * how the sealed frontier is re-materialised for one superstep
    (device-budget waves vs per-worker cost-balanced slices),
  * how quick patterns are computed and level-1 aggregation is reduced
    (host fold vs psum/OR-allreduce collective),
  * how the expansion itself is dispatched (pilot + stacked-drain chunk
    pipeline vs one sharded program with exact-capacity retries), and
  * what per-step accounting rides on top (compile signatures, collective
    bytes).

Implementations: :class:`repro.core.runtime.serial.SerialBackend` and
:class:`repro.core.runtime.shard.ShardMapBackend`. Both append children to
the shared :class:`repro.core.store.FrontierStore` — sealed stores are the
*only* inter-superstep state, which is exactly what makes the superstep
boundary a checkpointable cut (``runtime/checkpoint.py``).
"""
from __future__ import annotations

import abc
from typing import List, Optional, Tuple

import numpy as np

from repro.core import obs
from repro.core.aggregation import StepAggregates
from repro.core.api import MiningApp
from repro.core.graph import DeviceGraph
from repro.core.runtime.config import RunConfig
from repro.core.stats import RunStats, StepStats
from repro.core.store import FrontierStore


class ExecutionBackend(abc.ABC):
    """One BSP superstep's execution strategy, behind the unified loop."""

    name: str = "base"

    def bind(self, g: DeviceGraph, app: MiningApp,
             config: RunConfig) -> FrontierStore:
        """Attach to one run: build the frontier store and the jitted
        programs this backend dispatches. Returns the store (the runtime
        owns the loop, the backend owns the programs). ``capacity`` is the
        persistent output-capacity bucket — it survives across supersteps
        (one overflow re-dispatch per run, not per step) and is part of the
        checkpoint cursor.

        Every tri-state knob is resolved here, ONCE, through the cost
        model (DESIGN.md §14): ``self.config`` and everything built from
        it see only concrete choices, and ``self.decisions`` carries the
        effective table for ``RunStats``/trace recording."""
        from repro.core.runtime import costmodel

        config, self.decisions = costmodel.resolve(config, g, app, self.name)
        self.g = g
        self.app = app
        self.config = config
        self.capacity = max(config.initial_capacity, 1)
        return self._make_store()

    @abc.abstractmethod
    def _make_store(self) -> FrontierStore:
        """Build the store this backend mines through."""

    # -- one superstep, in loop order --------------------------------------
    @abc.abstractmethod
    def begin_step(self, store: FrontierStore,
                   st: StepStats) -> List[np.ndarray]:
        """Re-materialise the sealed frontier as row blocks: device-budget
        waves (serial) or per-worker slices (shard map)."""

    @abc.abstractmethod
    def quick_codes(
        self, blocks: List[np.ndarray], size: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Quick-pattern ``(codes (B,3) int64, local_verts (B,8) int32)``
        of the materialised frontier — only called when the previous step's
        chunk programs did not carry them."""

    @abc.abstractmethod
    def aggregate(
        self, codes: np.ndarray, lv: np.ndarray, st: StepStats
    ) -> Tuple[StepAggregates, np.ndarray]:
        """Two-level pattern aggregation over the frontier's quick codes.
        Returns ``(aggregates, per-row canonical slot)`` and fills the
        step's pattern/iso/collective counters."""

    def aggregate_step(
        self, blocks: List[np.ndarray], size: int, carried, st: StepStats
    ) -> Tuple[StepAggregates, Optional[np.ndarray]]:
        """One superstep's pattern aggregation, end to end. ``carried`` is
        whatever this backend's :meth:`expand` returned last step (opaque
        to the loop). Returns ``(aggregates, per-row canonical slot)``;
        a ``None`` slot array means level 1 stayed on device (DESIGN.md
        §10) and alpha must be evaluated via ``app.pattern_filter`` +
        :meth:`alpha_rows`. This base implementation is the host reference
        flow: host codes (carried or recomputed) through
        ``aggregation.aggregate_rows``-style :meth:`aggregate`."""
        n_frontier = sum(len(blk) for blk in blocks)
        if (
            isinstance(carried, tuple)
            and len(carried) == 2
            and len(carried[0]) == n_frontier
        ):
            codes, lv = carried
        else:
            codes, lv = self.quick_codes(blocks, size)
        obs.count(st, "bytes_to_host", codes.nbytes + lv.nbytes)
        return self.aggregate(codes, lv, st)

    def alpha_rows(self, pk: np.ndarray, st: StepStats) -> np.ndarray:
        """Per-row alpha mask over the materialised frontier, derived from
        the per-pattern verdict ``pk`` ((Pc,) bool) of the device
        aggregation path. Only called when ``pk`` actually prunes."""
        raise NotImplementedError(
            "per-row alpha requires the host aggregation path"
        )

    def prune(self, blocks: List[np.ndarray],
              alpha: np.ndarray) -> List[np.ndarray]:
        """Apply the app's aggregation filter to the materialised blocks
        (the mask spans their concatenation, in order)."""
        off, pruned = 0, []
        for blk in blocks:
            pruned.append(blk[alpha[off: off + len(blk)]])
            off += len(blk)
        return pruned

    @abc.abstractmethod
    def expand(self, store: FrontierStore, blocks: List[np.ndarray],
               size: int, st: StepStats) -> Optional[tuple]:
        """Expand the frontier one size, appending children to ``store``.
        Returns carried ``(codes, local_verts)`` of the children when the
        chunk programs computed them in the same pass (DESIGN.md §8), else
        None."""

    def end_step(self, store: FrontierStore, st: StepStats) -> None:
        """Post-seal accounting hook (e.g. frontier-exchange bytes)."""

    def finalize(self, stats: RunStats) -> None:
        """End-of-run accounting hook (compile signatures etc.)."""
