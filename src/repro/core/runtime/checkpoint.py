"""Superstep-granular checkpoint/resume for the mining runtime (DESIGN.md §9).

Because sealed frontier stores are the *only* inter-superstep state
(DESIGN.md §7), a mining checkpoint is tiny and exact: {sealed store
payload (raw rows, or the ODAG's per-level domains + connectivity
bitmaps), the patterns/aggregates/stats accumulated so far, the superstep
cursor (next step, embedding size, capacity bucket), and app + graph
fingerprints}. It is written atomically at the seal boundary — the same
cut the paper's fault-tolerance story checkpoints (Aridhi et al.,
arXiv:1212.0017) — so a resumed run replays nothing and recomputes only
the carried quick-pattern codes (identical by construction).

Elasticity falls out of the store subsystem: the payload is
worker-count-free, and per-worker slices are re-partitioned from the
restored store at extraction time (``worker_parts`` / ``partition_by_cost``),
so a run checkpointed under W workers resumes under any W' — or under the
serial backend — with identical pattern output (tested in
``tests/test_checkpoint.py``).

This file replaces nothing in ``training/checkpoint.py`` (the model-zoo
scaffolding keeps its own shard-metadata format); the *mining* engines
checkpoint here.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import time
import zipfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import obs
from repro.core.aggregation import StepAggregates
from repro.core.graph import DeviceGraph
from repro.core.stats import StepStats

#: v2 embeds a SHA-256 payload checksum (DESIGN.md §13) — v1 checkpoints
#: (no integrity record) are rejected as corrupt rather than trusted.
CHECKPOINT_VERSION = 2
_FILE_RE = re.compile(r"^ckpt-step(\d+)\.npz$")
#: the staging-file shape ``save`` writes before ``os.replace`` — a crash
#: mid-``np.savez`` leaves exactly one of these behind (satellite: swept on
#: resume / Checkpointer init, never loadable as a checkpoint)
_TMP_RE = re.compile(r"^ckpt-step\d+\.npz\.tmp-.*\.npz$")


class CheckpointCorruptError(ValueError):
    """A checkpoint file exists but cannot be trusted: unreadable archive,
    missing integrity record, or SHA-256 payload mismatch. The supervisor
    (``run_supervised``) treats this as "roll back one cut", never as a
    fatal config error."""


# ---------------------------------------------------------------------------
# fingerprints: a checkpoint only resumes against the run that wrote it
# ---------------------------------------------------------------------------

def graph_fingerprint(g) -> str:
    """Content hash of the mined graph (labels + edges + edge labels).

    Deliberately *layout-independent*: ``DeviceGraph`` and any
    ``PartitionedGraph`` of the same graph hash identically (the replicated
    content arrays are the identity; shard tables are derived data), so a
    checkpoint resumes across layouts — elastic restore re-partitions the
    graph alongside the frontier. The layout that *wrote* a checkpoint is
    recorded separately (:func:`graph_layout`, in the meta)."""
    h = hashlib.sha1()
    for arr in (g.labels, g.edge_uv, g.edge_labels):
        a = np.ascontiguousarray(np.asarray(arr))
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def graph_layout(g) -> str:
    """The partition layout a run mines under, recorded in every
    checkpoint's fingerprint block: ``"replicated"`` for a ``DeviceGraph``,
    else ``partitioned:w=<parts>:rows=<padded rows>:off=<boundary hash>``.
    Purely informational for restore (the content fingerprint gates
    validity); a resume under a different layout re-partitions."""
    off = getattr(g, "part_offsets", None)
    if off is None:
        return "replicated"
    off = np.ascontiguousarray(np.asarray(off))
    return (
        f"partitioned:w={len(off) - 1}:rows={int(g.tile_rows)}"
        f":off={hashlib.sha1(off.tobytes()).hexdigest()[:12]}"
    )


def app_fingerprint(app) -> str:
    """Identity of the app's traced behaviour: class + dataclass fields
    (the same identity the chunk-program cache keys on)."""
    if dataclasses.is_dataclass(app):
        fields = {
            f.name: repr(getattr(app, f.name))
            for f in dataclasses.fields(app)
        }
    else:  # non-dataclass apps: best effort over the instance dict
        fields = {k: repr(v) for k, v in sorted(vars(app).items())}
    payload = json.dumps(
        [type(app).__module__, type(app).__qualname__, fields], sort_keys=True
    )
    return hashlib.sha1(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# on-disk format: one .npz per checkpoint, meta as an embedded JSON string
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CheckpointState:
    """Everything a resumed run needs, already deserialised."""

    step: int                      # next superstep index to execute
    size: int                      # embedding size of the sealed frontier
    capacity: int                  # persistent output-capacity bucket
    wall_time: float               # wall clock accumulated before the cut
    patterns: Dict[tuple, int]
    embeddings: Dict[int, np.ndarray]
    aggregates: List[StepAggregates]
    stats_steps: List[StepStats]
    store_state: dict              # FrontierStore.state_dict() payload
    graph_fp: str
    app_fp: str
    #: partition layout of the writing run (informational; resume under a
    #: different layout re-partitions — content fp is what gates validity)
    graph_layout: str = "replicated"


def checkpoint_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt-step{step:04d}.npz")


def list_checkpoints(directory: str) -> List[str]:
    """All checkpoint files in ``directory``, newest (highest step) first."""
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return []
    found = []
    for name in names:
        m = _FILE_RE.match(name)
        if m:
            found.append((int(m.group(1)), os.path.join(directory, name)))
    return [p for _, p in sorted(found, reverse=True)]


def latest_checkpoint(directory: str) -> Optional[str]:
    """The highest-step checkpoint file in ``directory`` (None if empty)."""
    paths = list_checkpoints(directory)
    return paths[0] if paths else None


def sweep_stale_tmp(directory: str) -> List[str]:
    """Remove orphaned ``*.tmp-*.npz`` staging files a crash mid-save left
    behind (``os.replace`` never ran, so they are garbage by construction).
    Returns the removed paths. Called on Checkpointer init and on every
    directory resume."""
    removed: List[str] = []
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return removed
    for name in names:
        if _TMP_RE.match(name):
            path = os.path.join(directory, name)
            try:
                os.unlink(path)
            except OSError:  # pragma: no cover - raced by another sweeper
                continue
            removed.append(path)
    return removed


def _payload_checksum(arrays: Dict[str, np.ndarray]) -> str:
    """SHA-256 over every payload array (sorted by name; name + shape +
    dtype + raw bytes). The ``checksum`` entry itself is excluded — it IS
    the digest, stored inside the same atomic .npz."""
    h = hashlib.sha256()
    for name in sorted(arrays):
        if name == "checksum":
            continue
        a = np.ascontiguousarray(np.asarray(arrays[name]))
        h.update(name.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def save(path: str, state: CheckpointState) -> None:
    """Atomic single-file write: everything lands in one ``np.savez`` (no
    pickle — arrays plus one JSON meta string), staged next to the target
    and ``os.replace``d so a crash mid-write never leaves a torn
    checkpoint behind."""
    arrays: Dict[str, np.ndarray] = {}
    if state.patterns:
        arrays["pat_codes"] = np.asarray(
            [list(code) for code in state.patterns], dtype=np.int64
        )
        arrays["pat_values"] = np.asarray(
            list(state.patterns.values()), dtype=np.int64
        )
    for size, emb in state.embeddings.items():
        arrays[f"emb{int(size)}"] = np.asarray(emb, dtype=np.int32)
    agg_meta = []
    for i, agg in enumerate(state.aggregates):
        arrays[f"agg{i}_canon"] = np.asarray(agg.canon_codes, dtype=np.int64)
        arrays[f"agg{i}_counts"] = np.asarray(agg.counts, dtype=np.int64)
        arrays[f"agg{i}_supports"] = np.asarray(agg.supports, dtype=np.int64)
        agg_meta.append([agg.n_quick, agg.n_canonical, agg.n_iso_checks])
    for name, arr in state.store_state["arrays"].items():
        arrays[f"store_{name}"] = np.asarray(arr)
    meta = {
        "version": CHECKPOINT_VERSION,
        "step": int(state.step),
        "size": int(state.size),
        "capacity": int(state.capacity),
        "wall_time": float(state.wall_time),
        "graph_fp": state.graph_fp,
        "app_fp": state.app_fp,
        "graph_layout": state.graph_layout,
        "emb_sizes": sorted(int(s) for s in state.embeddings),
        "n_aggregates": len(state.aggregates),
        "agg_meta": agg_meta,
        "stats": [dataclasses.asdict(s) for s in state.stats_steps],
        "store": {
            "kind": state.store_state["kind"],
            "meta": state.store_state["meta"],
            "array_keys": sorted(state.store_state["arrays"]),
        },
    }
    arrays["meta"] = np.asarray(json.dumps(meta))
    # integrity record (DESIGN.md §13): rides inside the same atomic file,
    # so a torn/bit-flipped payload can never verify
    arrays["checksum"] = np.asarray(_payload_checksum(arrays))

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp-{os.getpid()}.npz"
    try:
        np.savez(tmp, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):  # pragma: no cover - only on a failed write
            os.unlink(tmp)


def verify(path: str) -> Dict[str, np.ndarray]:
    """Read a checkpoint's raw arrays and verify the embedded SHA-256.
    Raises :class:`CheckpointCorruptError` on an unreadable archive, a
    missing integrity record, or a digest mismatch."""
    try:
        with np.load(path, allow_pickle=False) as z:
            arrays = {key: np.asarray(z[key]) for key in z.files}
    except FileNotFoundError:
        # a missing file is a caller error (bad path), not corruption —
        # rollback must never silently skip past a typo'd checkpoint
        raise
    except (zipfile.BadZipFile, OSError, EOFError, KeyError, ValueError) as e:
        raise CheckpointCorruptError(
            f"unreadable checkpoint {path}: {e}"
        ) from e
    if "checksum" not in arrays:
        raise CheckpointCorruptError(
            f"checkpoint {path} has no integrity record (pre-v2 or torn)"
        )
    want = str(arrays["checksum"][()])
    got = _payload_checksum(arrays)
    if want != got:
        raise CheckpointCorruptError(
            f"checkpoint {path} failed checksum "
            f"(stored {want[:12]} != computed {got[:12]})"
        )
    return arrays


def load(path: str) -> CheckpointState:
    z = verify(path)
    try:
        meta = json.loads(str(z["meta"][()]))
    except (KeyError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"bad meta in {path}: {e}") from e
    if meta["version"] != CHECKPOINT_VERSION:
        raise ValueError(
            f"checkpoint version {meta['version']} != "
            f"{CHECKPOINT_VERSION} ({path})"
        )
    patterns: Dict[tuple, int] = {}
    if "pat_codes" in z:
        codes, values = z["pat_codes"], z["pat_values"]
        patterns = {
            tuple(int(x) for x in codes[i]): int(values[i])
            for i in range(len(codes))
        }
    embeddings = {
        int(s): np.asarray(z[f"emb{int(s)}"]) for s in meta["emb_sizes"]
    }
    aggregates = [
        StepAggregates(
            canon_codes=np.asarray(z[f"agg{i}_canon"]),
            counts=np.asarray(z[f"agg{i}_counts"]),
            supports=np.asarray(z[f"agg{i}_supports"]),
            n_quick=int(meta["agg_meta"][i][0]),
            n_canonical=int(meta["agg_meta"][i][1]),
            n_iso_checks=int(meta["agg_meta"][i][2]),
        )
        for i in range(meta["n_aggregates"])
    ]
    store_state = {
        "kind": meta["store"]["kind"],
        "meta": meta["store"]["meta"],
        "arrays": {
            key: np.asarray(z[f"store_{key}"])
            for key in meta["store"]["array_keys"]
        },
    }
    return CheckpointState(
        step=int(meta["step"]),
        size=int(meta["size"]),
        capacity=int(meta["capacity"]),
        wall_time=float(meta["wall_time"]),
        patterns=patterns,
        embeddings=embeddings,
        aggregates=aggregates,
        stats_steps=[StepStats(**d) for d in meta["stats"]],
        store_state=store_state,
        graph_fp=meta["graph_fp"],
        app_fp=meta["app_fp"],
        graph_layout=meta.get("graph_layout", "replicated"),
    )


def load_for(checkpoint: Optional[str], g: DeviceGraph, app) -> CheckpointState:
    """Resolve + load + fingerprint-verify a checkpoint for (graph, app).

    ``checkpoint`` may be a file, a directory (latest checkpoint in it
    wins), or None (error). Raises ``ValueError`` when the checkpoint was
    written against a different graph or app — resuming would silently mix
    two runs' patterns otherwise."""
    if checkpoint is None:
        raise ValueError("no checkpoint given (and no checkpoint_dir set)")
    path = checkpoint
    if os.path.isdir(path):
        sweep_stale_tmp(path)
        path = latest_checkpoint(path)
        if path is None:
            raise FileNotFoundError(f"no checkpoints in {checkpoint!r}")
    state = load(path)
    gfp = graph_fingerprint(g)
    if state.graph_fp != gfp:
        raise ValueError(
            f"checkpoint {path} was written for a different graph "
            f"({state.graph_fp[:12]} != {gfp[:12]})"
        )
    afp = app_fingerprint(app)
    if state.app_fp != afp:
        raise ValueError(
            f"checkpoint {path} was written for a different app config "
            f"({state.app_fp[:12]} != {afp[:12]})"
        )
    return state


def load_latest_valid(
    directory: str, g: DeviceGraph, app
) -> Tuple[Optional[CheckpointState], Optional[str], List[str]]:
    """Roll back past corrupt cuts (DESIGN.md §13): walk the directory's
    checkpoints newest-first, skip any that fail the SHA-256 verify, and
    return ``(state, path, skipped)`` for the newest *valid* one —
    ``(None, None, skipped)`` when no checkpoint survives. Fingerprint
    mismatches (wrong graph/app) still raise: that is a config error, not
    a fault to retry past. Stale tmp staging files are swept first."""
    sweep_stale_tmp(directory)
    skipped: List[str] = []
    for path in list_checkpoints(directory):
        try:
            state = load(path)
        except CheckpointCorruptError:
            skipped.append(path)
            continue
        gfp = graph_fingerprint(g)
        if state.graph_fp != gfp:
            raise ValueError(
                f"checkpoint {path} was written for a different graph "
                f"({state.graph_fp[:12]} != {gfp[:12]})"
            )
        afp = app_fingerprint(app)
        if state.app_fp != afp:
            raise ValueError(
                f"checkpoint {path} was written for a different app config "
                f"({state.app_fp[:12]} != {afp[:12]})"
            )
        return state, path, skipped
    return None, None, skipped


class Checkpointer:
    """Writes one checkpoint per seal boundary the cadence selects."""

    def __init__(self, config, g, app) -> None:
        self.directory = config.checkpoint_dir
        self.graph_fp = graph_fingerprint(g)
        self.graph_layout = graph_layout(g)
        self.app_fp = app_fingerprint(app)
        #: keep-last-K retention (0 = keep everything); K >= 2 leaves a
        #: rollback target when the newest cut fails its checksum
        self.keep = int(getattr(config, "keep_checkpoints", 0) or 0)
        os.makedirs(self.directory, exist_ok=True)
        sweep_stale_tmp(self.directory)

    def save(self, *, step: int, size: int, capacity: int, store, result,
             wall_time: float) -> float:
        """Persist the cut after a sealed superstep; returns seconds spent
        (charged to ``StepStats.t_checkpoint`` — the bench_checkpoint
        overhead gate reads exactly this)."""
        t0 = time.perf_counter()
        state = CheckpointState(
            step=step,
            size=size,
            capacity=capacity,
            wall_time=wall_time,
            patterns=result.patterns,
            embeddings=result.embeddings,
            aggregates=result.aggregates,
            stats_steps=result.stats.steps,
            store_state=store.state_dict(),
            graph_fp=self.graph_fp,
            app_fp=self.app_fp,
            graph_layout=self.graph_layout,
        )
        path = checkpoint_path(self.directory, step)
        save(path, state)
        if self.keep > 0:
            for old in list_checkpoints(self.directory)[self.keep:]:
                try:
                    os.unlink(old)
                except OSError:  # pragma: no cover - raced removal
                    pass
        # checkpoint size as a metrics gauge (DESIGN.md §12) — the traced
        # run's counter track shows the persisted cut growing per cadence
        obs.gauge("checkpoint_bytes", os.path.getsize(path), step=step)
        return time.perf_counter() - t0
