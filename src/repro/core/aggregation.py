"""Pattern-keyed aggregation (paper §4.1 map/reduce + §5.4 two levels).

Level 1 runs on device over all embeddings of the step (counts, FSM domain
bitmaps keyed by *quick*-pattern slot). Level 2 maps quick slots to canonical
slots (host table from :mod:`repro.core.pattern`) and folds level-1 state —
the only stage that ever touches graph isomorphism.

Since DESIGN.md §10 level 1 is *device-resident* end to end:
:class:`DeviceLevel1` folds per-chunk / per-wave quick codes into a
device-side distinct table (``kernels/aggregate.py`` sort + segment-reduce),
and only O(Q) bytes — the distinct codes (packed uint32), their counts, and
the (Pc, 8, N) canonical domain bitmaps — ever cross to the host for level-2
canonicalisation. :func:`aggregate_rows` below is the host reference path
(``device_aggregate=False``), bit-identical by construction because both
paths emit distinct codes in ascending lexicographic order.

In the distributed runtime the level-1 state is exactly what gets
all-reduced: per-pattern scalars and domain bitmaps, never embeddings
(DESIGN.md §4) — this is how the paper's Table-4 reduction becomes a
collective-bytes reduction.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import obs
from repro.core import pattern as pattern_lib
from repro.kernels import aggregate as agg_kernel
from repro.kernels import canonical_refine


def _next_pow2(x: int) -> int:
    # lazy import: runtime.config (the canonical home of next_pow2) sits in
    # a package whose __init__ imports the loop, which imports this module
    from repro.core.runtime.config import next_pow2

    return next_pow2(x)


class StepAggregates(NamedTuple):
    """Aggregation output of one exploration step (canonical-pattern keyed)."""

    canon_codes: np.ndarray    # (Pc, 3) int64
    counts: np.ndarray         # (Pc,) int64 — #embeddings per pattern
    supports: np.ndarray       # (Pc,) int64 — min-image support (== counts
                               #   when domains were not requested)
    n_quick: int               # distinct quick patterns this step (Table 4)
    n_canonical: int           # distinct canonical patterns
    n_iso_checks: int          # graph-isomorphism invocations


def _unique_rows3(codes: np.ndarray):
    """``np.unique(axis=0, return_inverse=True)`` for (B, 3) int64 rows via
    a 3-key lexsort — ~5x faster than numpy's void-dtype row sort, which is
    the hottest host op of a superstep's aggregation (DESIGN.md §8)."""
    order = np.lexsort((codes[:, 2], codes[:, 1], codes[:, 0]))
    sc = codes[order]
    new = np.empty(len(sc), dtype=bool)
    new[0] = True
    np.any(sc[1:] != sc[:-1], axis=1, out=new[1:])
    uniq = sc[new]
    inv = np.empty(len(sc), dtype=np.int64)
    inv[order] = np.cumsum(new) - 1
    return uniq, inv


def quick_slot_ids(codes: jnp.ndarray, valid: jnp.ndarray):
    """Host-side unique over the (B, 3) quick codes -> (unique (Q,3), inv (B,)).

    The two-level scheme makes Q tiny (Table 4), so one host unique per step
    is cheap; rows with ``valid == False`` are mapped to slot -1.
    """
    codes_np = np.asarray(codes)
    valid_np = np.asarray(valid)
    if not valid_np.any():
        return np.zeros((0, 3), np.int64), np.full(len(codes_np), -1, np.int32)
    uniq, inv = _unique_rows3(codes_np[valid_np])
    full_inv = np.full(len(codes_np), -1, dtype=np.int32)
    full_inv[valid_np] = inv.astype(np.int32)
    return uniq, full_inv


@functools.partial(jax.jit, static_argnames=("n_canon", "n_vertices"))
def domain_bitmaps(
    canon_slot: jnp.ndarray,     # (B,) int32 canonical slot per embedding
    verts_canonical: jnp.ndarray,  # (B, 8) int32 graph vertex at canonical pos
    valid: jnp.ndarray,          # (B,) bool
    n_canon: int,
    n_vertices: int,
) -> jnp.ndarray:
    """FSM min-image domains (level-1): bool (Pc, 8, N) — vertex v appears at
    canonical position p of some embedding of pattern pc.

    One dense scatter; in the distributed engine this tensor is OR-allreduced
    (bool max) across workers — the paper's domain merge as one collective.
    """
    b, kmax = verts_canonical.shape
    flat = jnp.zeros((n_canon * kmax * n_vertices + 1,), dtype=bool)
    slot_ok = valid[:, None] & (verts_canonical >= 0) & (canon_slot[:, None] >= 0)
    idx = (
        canon_slot[:, None].astype(jnp.int64) * (kmax * n_vertices)
        + jnp.arange(kmax)[None, :] * n_vertices
        + jnp.maximum(verts_canonical, 0)
    )
    idx = jnp.where(slot_ok, idx, n_canon * kmax * n_vertices)
    flat = flat.at[idx.reshape(-1)].set(True)
    return flat[:-1].reshape(n_canon, kmax, n_vertices)


def min_image_support(
    bitmaps: jnp.ndarray, canon_n_verts: np.ndarray, canon_orbits: np.ndarray
) -> np.ndarray:
    """Support(p) = min over pattern positions of |domain(position)| [7].

    Domains are defined over *all* isomorphisms pattern->embedding; with one
    fixed isomorphism per embedding the missing mappings are recovered by
    OR-ing domains across each position's automorphism orbit
    (pattern.automorphism_orbits).
    """
    bm = np.asarray(bitmaps)                          # (Pc, 8, N) bool
    pc, kmax, n = bm.shape
    if pc == 0:
        return np.zeros((0,), np.int64)
    # orbit merge as one batched boolean matmul: eq[p, i, j] marks positions
    # in the same orbit, so (eq @ bm)[p, pos] > 0 ORs the orbit-mates'
    # domains — the (Pc x 8) Python double loop this replaces dominated
    # t_aggregate on labeled graphs (Pc large). uint8 is safe: row sums
    # are bounded by kmax = 8.
    orb = np.asarray(canon_orbits)[:, :kmax]
    eq = (orb[:, :, None] == orb[:, None, :]).astype(np.uint8)   # (Pc, 8, 8)
    merged = np.matmul(eq, bm.astype(np.uint8)) > 0              # (Pc, 8, N)
    counts = merged.sum(axis=2)                       # (Pc, 8)
    pos_ok = np.arange(kmax)[None, :] < np.asarray(canon_n_verts)[:, None]
    counts = np.where(pos_ok, counts, np.iinfo(np.int64).max)
    return counts.min(axis=1).astype(np.int64)


def map_to_canonical_positions(
    table: pattern_lib.PatternTable,
    quick_slot: np.ndarray,       # (B,) int32
    local_verts: jnp.ndarray,     # (B, 8) int32
) -> tuple[np.ndarray, jnp.ndarray]:
    """Per-embedding canonical slot + vertices re-ordered to canonical
    positions (position p holds local vertex with sigma[local]=p)."""
    sigma = table.sigma[np.maximum(quick_slot, 0)]    # (B, 8) local -> canon
    sigma_inv = np.argsort(sigma, axis=1)             # canon -> local
    lv = np.asarray(local_verts)
    verts_canon = np.take_along_axis(lv, sigma_inv, axis=1)
    canon_slot = np.where(
        quick_slot >= 0, table.quick_to_canon[np.maximum(quick_slot, 0)], -1
    ).astype(np.int32)
    return canon_slot, jnp.asarray(verts_canon)


def aggregate_rows(
    g_n_vertices: int,
    codes: np.ndarray,        # (B, 3) int64 quick codes (host)
    local_verts,              # (B, 8) int32 (host); None iff not with_domains
    with_domains: bool,
    canon_fn=None,            # level-2 miss hook (device placement)
) -> tuple[StepAggregates, np.ndarray]:
    """Full two-level aggregation for one step's embeddings, over
    pre-computed quick patterns (DESIGN.md §7).

    The engine computes quick patterns one device-budget wave at a time and
    merges the level-1 state here on the host (``bincount`` + boolean
    scatter), so aggregation never allocates a device array of frontier
    length — the frontier-store subsystem's device-budget contract. The
    distributed runtime keeps its own sharded level-1 path
    (:func:`make_sharded_aggregate` in :mod:`repro.core.runtime.shard`)
    whose reduce is the collective.

    Since DESIGN.md §10 this is the ``device_aggregate=False`` *reference*
    path: the default engines fold level 1 on device (:class:`DeviceLevel1`)
    and only O(Q) bytes cross to the host. Both paths emit distinct codes
    in ascending lexicographic order, so their outputs are bit-identical.

    Returns (aggregates, per-embedding canonical slot).
    """
    codes = np.asarray(codes)
    b = len(codes)
    uniq, inv = quick_slot_ids(codes, np.ones(b, dtype=bool))
    table = pattern_lib.build_pattern_table(
        uniq, with_orbits=with_domains, canon_fn=canon_fn
    )
    q = len(uniq)
    pc = len(table.canon_codes)
    if q == 0:
        empty = StepAggregates(
            canon_codes=np.zeros((0, 3), np.int64),
            counts=np.zeros((0,), np.int64),
            supports=np.zeros((0,), np.int64),
            n_quick=0,
            n_canonical=0,
            n_iso_checks=0,
        )
        return empty, np.full(b, -1, np.int32)

    quick_counts = np.bincount(inv, minlength=q).astype(np.int64)
    counts = np.zeros(pc, dtype=np.int64)
    np.add.at(counts, table.quick_to_canon, quick_counts)

    if with_domains:
        # domains need every embedding's vertices re-ordered to canonical
        # positions; without them the slot lookup is the whole mapping
        canon_slot, verts_canon = map_to_canonical_positions(
            table, inv, np.asarray(local_verts)
        )
        verts_canon = np.asarray(verts_canon)
        kmax = verts_canon.shape[1]
        bm = np.zeros((pc, kmax, g_n_vertices), dtype=bool)
        ok = (verts_canon >= 0) & (canon_slot[:, None] >= 0)
        rows, pos = np.nonzero(ok)
        bm[canon_slot[rows], pos, verts_canon[rows, pos]] = True
        supports = min_image_support(bm, table.canon_n_verts, table.canon_orbits)
    else:
        canon_slot = table.quick_to_canon[inv].astype(np.int32)
        supports = counts.copy()

    agg = StepAggregates(
        canon_codes=table.canon_codes,
        counts=counts,
        supports=np.asarray(supports).astype(np.int64),
        n_quick=q,
        n_canonical=pc,
        n_iso_checks=table.n_iso_checks,
    )
    return agg, canon_slot


# ---------------------------------------------------------------------------
# Device-resident level 1 (DESIGN.md §10)
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("cap", "use_kernel", "interpret", "method")
)
def _bin_all_valid(codes, cap: int, use_kernel: bool, interpret,
                   method: str = "sort"):
    """Bin one batch of all-valid quick codes at capacity ``cap``."""
    b = codes.shape[0]
    return agg_kernel.bin_rows(
        codes, jnp.ones((b,), bool), cap,
        use_kernel=use_kernel, interpret=interpret, method=method,
    )


@functools.partial(
    jax.jit, static_argnames=("cap", "use_kernel", "interpret", "method")
)
def _bin_weighted(codes, valid, weights, cap: int, use_kernel: bool,
                  interpret, method: str = "sort"):
    """Fold pre-binned partials: weighted re-bin of stacked unique tables."""
    return agg_kernel.bin_rows(
        codes, valid, cap, weights=weights,
        use_kernel=use_kernel, interpret=interpret, method=method,
    )


@jax.jit
def _finish_flags(uniq, counts, uvalid, n_stack, corrupt, sat):
    """The ONE scalar drain of a step's level-1 state: [final distinct
    count, max distinct count over every fold (merge-overflow detection),
    partial-corruption flag (a chunk's distinct count overflowed its bin
    capacity), w1/w2 column-used flags, counts-fit-int32 flag,
    count-saturation flag (a folded int32 partial hit the I32_SAT
    sentinel — totals would be floors, not counts)] — read together so
    overflow/saturation handling and the packed transfer cost no extra
    round trips."""
    w1_used = jnp.any(jnp.where(uvalid, uniq[:, 1], 0) != 0)
    w2_used = jnp.any(jnp.where(uvalid, uniq[:, 2], 0) != 0)
    fit32 = jnp.max(jnp.where(uvalid, counts, 0)) < jnp.int64(2) ** 31
    return jnp.stack(
        [n_stack[-1], jnp.max(n_stack), corrupt.astype(jnp.int32),
         w1_used.astype(jnp.int32), w2_used.astype(jnp.int32),
         fit32.astype(jnp.int32), sat.astype(jnp.int32)]
    ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("flat_slots", "n_vertices"))
def _scatter_canon_flat(bm_flat, slot, lv, q2c, sigma_inv,
                        flat_slots: int, n_vertices: int):
    """Phase-2 FSM domain scatter (device): one batch of rows into the
    flat (pc_cap * 8 * N + 1) canonical-position bitmap (last slot = dump).

    ``slot`` is the per-row quick slot (final table order), ``q2c`` /
    ``sigma_inv`` the uploaded level-2 tables; vertex ``lv[r, sigma_inv[p]]``
    lands at canonical position ``p`` — the same re-ordering
    :func:`map_to_canonical_positions` applies on the host."""
    b, kmax = lv.shape
    safe = jnp.maximum(slot, 0)
    cs = jnp.where(slot >= 0, q2c[safe], -1)                       # (B,)
    vc = jnp.take_along_axis(lv, sigma_inv[safe], axis=1)          # (B, 8)
    ok = (vc >= 0) & (cs[:, None] >= 0)
    idx = (
        cs[:, None].astype(jnp.int64) * (kmax * n_vertices)
        + jnp.arange(kmax)[None, :] * n_vertices
        + jnp.maximum(vc, 0)
    )
    idx = jnp.where(ok, idx, flat_slots)
    return bm_flat.at[idx.reshape(-1)].set(True)


class DeviceLevel1:
    """Device-resident level-1 state of ONE superstep (DESIGN.md §10).

    Folds batches of quick codes — raw rows from a frontier wave
    (:meth:`fold_rows`) or pre-binned per-chunk partials emitted by the
    fused chunk programs (:meth:`fold_partial`) — into a device-side
    distinct table, without any host transfer. :meth:`finish` drains the
    O(Q) result: one (7,) scalar read, then the distinct codes packed to
    uint32 (label words dropped when unused) and the counts (int32 when
    they fit). Distinct codes come out in ascending lexicographic order,
    matching the host reference path bit for bit.

    Capacity discipline mirrors the chunk pipeline: per-batch bins use the
    batch's own pow2 capacity (can never overflow); cross-batch *merges*
    use ``merge_cap``, and an overflow — the unclamped distinct total rides
    the one scalar read — is re-merged at the exact pow2 capacity from the
    retained partials. Only when eager compaction (the stacked-drain fold,
    which merges pending chunk partials to bound device memory) has already
    dropped partials does :meth:`finish` return ``None``, and the caller
    re-folds from the frontier waves.

    Partial buffers are dropped (not eagerly deleted) once merged — they
    are O(cap) control state, not the O(step-output) children buffers the
    drain window retires.
    """

    def __init__(self, *, merge_cap: int, use_kernel: bool = False,
                 bin_method: str = "sort", interpret=None,
                 pending_limit: int = 32) -> None:
        self.merge_cap = int(merge_cap)
        self.rows = 0                   # host-known rows folded so far
        self.parts: List[tuple] = []    # (uniq, counts i64, uvalid, cap, n)
        self.batches: List[tuple] = []  # (inv, lv, part_idx)  [fold_rows]
        self._merge_ns: List = []       # device n of every cross-batch merge
        self._corrupt = None            # device flag: a partial overflowed
        self._sat = None                # device flag: int32 partial saturated
        self._compacted = False
        self._use_kernel = use_kernel
        self._bin_method = bin_method
        self._interpret = interpret
        self._pending_limit = pending_limit
        self._final = None              # (uniq, counts, uvalid, cap, n)
        self._maps: Optional[List] = None

    # -- folding ------------------------------------------------------------
    def fold_rows(self, codes, lv=None) -> None:
        """Fold one wave's (B, 3) quick codes (all rows valid); ``lv``
        (device) is retained for the FSM phase-2 domain scatter."""
        b = int(codes.shape[0])
        if b == 0:
            return
        cap = _next_pow2(b)
        u, c, inv, n, uv = _bin_all_valid(
            codes, cap, self._use_kernel, self._interpret, self._bin_method
        )
        self.parts.append((u, c, uv, cap, n))
        self.batches.append((inv, lv, len(self.parts) - 1))
        self.rows += b

    def fold_partial(self, uniq, counts, n, cap: int, rows: int,
                     may_overflow: bool = False) -> None:
        """Fold one chunk program's pre-binned partial: ``uniq`` (cap, 3),
        ``counts`` (cap,) and the device distinct count ``n`` (unclamped).
        ``may_overflow`` marks partials binned below the chunk's child
        capacity (``agg_qcap``-bounded): ``n > cap`` then means the dump
        slot swallowed patterns — tracked as a device flag that rides the
        finish drain, after which the caller re-folds from the waves."""
        uv = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n, cap)
        if counts.dtype == jnp.int32:
            # a narrowed partial (the fused chunk programs emit int32):
            # the I32_SAT sentinel means the true count was clipped — a
            # device flag rides the finish drain, after which the caller
            # re-folds the step from the waves in int64 (DESIGN.md §13)
            hit = jnp.any(
                jnp.where(uv, counts, 0) >= jnp.int32(agg_kernel.I32_SAT)
            )
            self._sat = hit if self._sat is None else (self._sat | hit)
        self.parts.append((uniq, counts.astype(jnp.int64), uv, cap, n))
        self.rows += rows
        if may_overflow:
            bad = n > cap
            self._corrupt = bad if self._corrupt is None else (
                self._corrupt | bad
            )
        if len(self.parts) >= self._pending_limit:
            self._compact()

    def _merge(self, parts, cap: int):
        u = jnp.concatenate([p[0] for p in parts])
        c = jnp.concatenate([p[1] for p in parts])
        v = jnp.concatenate([p[2] for p in parts])
        mu, mc, minv, mn, muv = _bin_weighted(
            u, v, c, cap, self._use_kernel, self._interpret, self._bin_method
        )
        self._merge_ns.append(mn)
        return mu, mc, minv, mn, muv

    def _compact(self) -> None:
        mu, mc, _, mn, muv = self._merge(self.parts, self.merge_cap)
        self.parts = [(mu, mc, muv, self.merge_cap, mn)]
        self._compacted = True

    # -- the O(Q) drain -----------------------------------------------------
    def _finalize(self, cap: int):
        if len(self.parts) == 1:
            # a lone batch bin (cap >= rows) or an eager compaction: never
            # re-merged — overflow of the latter is caught via _merge_ns
            u, c, uv, pcap, n = self.parts[0]
            self._maps = [None]
            return u, c, uv, pcap, n
        mu, mc, minv, mn, muv = self._merge(self.parts, cap)
        off, maps = 0, []
        for p in self.parts:
            maps.append(jax.lax.slice_in_dim(minv, off, off + p[3]))
            off += p[3]
        self._maps = maps
        return mu, mc, muv, cap, mn

    def finish(self):
        """Drain the folded state to the host: ``(uniq (Q, 3) int64,
        counts (Q,) int64, bytes_to_host)`` — or ``None`` when an eager
        compaction overflowed ``merge_cap`` (state unrecoverable on device;
        re-fold from the frontier waves). ``observed_n`` afterwards holds
        the true distinct total, so the re-fold can size itself exactly."""
        if not self.parts:
            self.observed_n = 0
            return np.zeros((0, 3), np.int64), np.zeros((0,), np.int64), 0
        u, c, uv, cap, n = self._finalize(self.merge_cap)
        corrupt = (
            self._corrupt if self._corrupt is not None else jnp.zeros((), bool)
        )
        sat = self._sat if self._sat is not None else jnp.zeros((), bool)
        stack = jnp.stack([jnp.asarray(x, jnp.int32) for x in
                           (self._merge_ns + [n])])
        flags = np.asarray(_finish_flags(u, c, uv, stack, corrupt, sat))
        nbytes = flags.nbytes
        self.observed_n = n_final = int(flags[0])
        max_n = int(flags[1])
        if flags[2]:
            return None             # a chunk partial overflowed its bin
        if flags[6]:
            # an int32 partial saturated at I32_SAT: its totals are floors;
            # the wave re-fold re-bins everything in int64 (DESIGN.md §13)
            return None
        if max_n > cap:
            if self._compacted:
                return None
            # exact re-merge from the retained partials: the unclamped
            # distinct total rode the scalar read, no extra sync
            u, c, uv, cap, n = self._finalize(_next_pow2(max_n))
            stack = jnp.stack([jnp.asarray(self._merge_ns[-1], jnp.int32)])
            flags = np.asarray(
                _finish_flags(u, c, uv, stack, jnp.zeros((), bool),
                              jnp.zeros((), bool))
            )
            nbytes += flags.nbytes
            self.observed_n = n_final = int(flags[0])
        # packed transfer: only used code words cross, counts narrowed
        uniq, counts, tbytes = drain_distinct(
            u, c, n_final,
            w1_used=bool(flags[3]), w2_used=bool(flags[4]),
            fit32=bool(flags[5]),
        )
        self._final = (u, c, uv, cap, n)
        return uniq, counts, nbytes + tbytes

    # -- per-row slots (alpha masks, FSM phase 2) ---------------------------
    def batch_slots(self, i: int):
        """Device per-row slot ids of batch ``i`` in FINAL table order."""
        inv, _, pidx = self.batches[i]
        m = self._maps[pidx] if self._maps is not None else None
        return m[inv] if m is not None else inv

    @property
    def final_cap(self) -> int:
        return self._final[3] if self._final is not None else self.merge_cap


def drain_distinct(u_dev, c_dev, n: int, w1_used: bool, w2_used: bool,
                   fit32: bool):
    """The packed O(Q) device→host drain both backends share: distinct
    codes as uint32 with unused label words dropped (lossless by the
    encoding), counts narrowed to int32 when they fit. Returns
    ``(uniq (n, 3) int64, counts (n,) int64, bytes_transferred)``."""
    cols = [0] + ([1] if w1_used else []) + ([2] if w2_used else [])
    packed = np.asarray(
        agg_kernel.pack_codes_u32(u_dev[:n][:, jnp.asarray(cols)])
    )
    uniq = np.zeros((n, 3), np.int64)
    uniq[:, cols] = agg_kernel.unpack_codes_u32(packed)
    cdev = c_dev[:n]
    counts = np.asarray(cdev.astype(jnp.int32) if fit32 else cdev)
    return uniq, counts.astype(np.int64), packed.nbytes + counts.nbytes


def build_step_aggregates(table: pattern_lib.PatternTable,
                          counts: np.ndarray, supports, n_quick: int,
                          st) -> StepAggregates:
    """Assemble a step's :class:`StepAggregates` from level-2 output and
    mirror the pattern counters into the step stats — shared by both
    backends' device-aggregation paths so the two can never drift."""
    agg = StepAggregates(
        canon_codes=table.canon_codes,
        counts=counts,
        supports=np.asarray(supports).astype(np.int64),
        n_quick=n_quick,
        n_canonical=len(table.canon_codes),
        n_iso_checks=table.n_iso_checks,
    )
    obs.set_stat(st, "n_quick_patterns", agg.n_quick)
    obs.set_stat(st, "n_canonical_patterns", agg.n_canonical)
    obs.set_stat(st, "n_iso_checks", agg.n_iso_checks)
    return agg


def finish_quick_level2(uniq: np.ndarray, counts_q: np.ndarray,
                        with_domains: bool, canon_fn=None):
    """Host level 2 over device-drained level-1 state: canonicalise the Q
    distinct quick codes (memoised, :func:`pattern.build_pattern_table`)
    and fold the quick counts to canonical slots. Returns
    ``(table, counts (Pc,) int64)``."""
    table = pattern_lib.build_pattern_table(
        uniq, with_orbits=with_domains, canon_fn=canon_fn
    )
    pc = len(table.canon_codes)
    counts = np.zeros(pc, dtype=np.int64)
    np.add.at(counts, table.quick_to_canon, counts_q.astype(np.int64))
    return table, counts


# ---------------------------------------------------------------------------
# Level-2 placement (DESIGN.md §15): device re-bin + async host overlap
# ---------------------------------------------------------------------------

def async_level2_ok(app) -> bool:
    """True when level 2 may run off the critical path (``host_async``).

    The deferred table must not be consulted mid-step: apps that override
    ``pattern_filter`` (FSM's support prune feeds alpha) or the per-row
    ``aggregation_filter``, or that consume orbit domains, need the table
    before expansion — they silently run the synchronous host placement
    instead (bit-identical output either way)."""
    from repro.core.api import MiningApp

    return (
        app.wants_patterns
        and not app.wants_domains
        and type(app).pattern_filter is MiningApp.pattern_filter
        and type(app).aggregation_filter is MiningApp.aggregation_filter
    )


_ASYNC_EXECUTOR = None


def _async_executor():
    global _ASYNC_EXECUTOR
    if _ASYNC_EXECUTOR is None:
        from concurrent.futures import ThreadPoolExecutor

        # one worker: supersteps submit at most one level-2 batch each and
        # join it at the next seal, so deeper parallelism buys nothing and
        # single-worker FIFO keeps memo writes ordered.
        _ASYNC_EXECUTOR = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-canon"
        )
    return _ASYNC_EXECUTOR


class PendingLevel2:
    """An in-flight ``host_async`` level-2 batch: the backend submits the
    drained O(Q) table to the background thread and the loop joins the
    future at the seal boundary — canonicalisation overlaps the next
    superstep's expansion instead of sitting on the critical path."""

    def __init__(self, future, n_quick: int):
        self._future = future
        self.n_quick = n_quick

    def done(self) -> bool:
        return self._future.done()

    def result(self):
        """Block until the batch lands: ``(table, counts (Pc,) int64)``."""
        return self._future.result()


def submit_level2(uniq: np.ndarray, counts_q: np.ndarray) -> PendingLevel2:
    """Queue one step's host level 2 on the background thread (domains are
    never requested here — ``async_level2_ok`` excludes domain apps)."""
    fut = _async_executor().submit(finish_quick_level2, uniq, counts_q, False)
    return PendingLevel2(fut, len(uniq))


@functools.partial(
    jax.jit,
    static_argnames=("cap", "nvs", "with_orbits", "use_kernel", "interpret",
                     "method"),
)
def _level2_program(u, c, uv, cap: int, nvs: tuple, with_orbits: bool,
                    use_kernel: bool, interpret, method: str):
    """The in-program device level 2: batched canonical refine of the
    O(Q) distinct table + weighted quick→canonical re-bin (+ the orbit
    pass over the canonical table for FSM). ``bin_rows`` emits distinct
    codes in ascending lexicographic order — the same order as the host's
    ``np.unique`` — so every output is bit-identical to the host path."""
    canon, sigma, _ = canonical_refine.refine_codes(
        u, uv, nvs, with_orbits=False, use_kernel=use_kernel,
        interpret=interpret,
    )
    canon = jnp.where(uv[:, None], canon, 0)
    cu, cc, q2c, cn, cuv = agg_kernel.bin_rows(
        canon, uv, cap, weights=c,
        use_kernel=use_kernel, interpret=interpret, method=method,
    )
    if with_orbits:
        _, _, rep = canonical_refine.refine_codes(
            cu, cuv, nvs, with_orbits=True, use_kernel=use_kernel,
            interpret=interpret,
        )
    else:
        rep = jnp.tile(jnp.arange(8, dtype=jnp.int32), (cap, 1))
    return canon, sigma, cu, cc, q2c, cn, cuv, rep


def device_level2(u, c, uv, cap: int, n_final: int, quick_codes: np.ndarray,
                  counts_q: np.ndarray, *, nvs: tuple, with_domains: bool,
                  use_kernel: bool = False, interpret=None,
                  method: str = "sort"):
    """Device-placed level 2 over the finalized device level-1 state.

    ``u``/``c``/``uv`` are the device distinct table (capacity ``cap``),
    ``n_final`` the already-drained distinct count, ``quick_codes`` /
    ``counts_q`` the host copies from the level-1 drain (the quick table
    still crosses — phase 2 and the memo need it; what this path removes
    is the host permutation search). The canonical table can never
    overflow ``cap`` (Pc ≤ Q ≤ cap), so no growth rung is needed.

    Returns ``(table, counts (Pc,) int64, bytes_to_host)``.
    """
    canon_d, sigma_d, cu_d, cc_d, q2c_d, cn_d, cuv_d, rep_d = _level2_program(
        u, c, uv, cap, nvs, with_domains, use_kernel, interpret, method
    )
    q = int(n_final)
    pc = int(cn_d)
    sigma = np.asarray(sigma_d[:q], dtype=np.int32)
    q2c = np.asarray(q2c_d[:q], dtype=np.int32)
    cu = np.asarray(cu_d[:pc], dtype=np.int64)
    cc = np.asarray(cc_d[:pc], dtype=np.int64)
    canon_rows = np.asarray(canon_d[:q], dtype=np.int64)
    if with_domains:
        orbits = np.asarray(rep_d[:pc], dtype=np.int32)
    else:
        orbits = np.tile(
            np.arange(pattern_lib.MAX_PATTERN_VERTICES, dtype=np.int32),
            (pc, 1),
        )
    nbytes = (sigma.nbytes + q2c.nbytes + cu.nbytes + cc.nbytes
              + canon_rows.nbytes + (orbits.nbytes if with_domains else 0) + 4)
    table = pattern_lib.PatternTable(
        quick_codes=quick_codes,
        canon_codes=cu,
        quick_to_canon=q2c,
        sigma=sigma,
        canon_n_verts=(cu[:, 0] & 0xF).astype(np.int32),
        canon_orbits=orbits,
        n_iso_checks=q,
    )
    # warm the host memo with the device results: a later host placement
    # (degradation rung, resumed run) over the same patterns is then pure
    # cache hits.
    pattern_lib.seed_memo(
        quick_codes, canon_rows, sigma,
        canon_codes=cu if with_domains else None,
        orbits=orbits if with_domains else None,
    )
    return table, cc, nbytes


def level2_nvs(app, size: int) -> tuple:
    """STATIC nv set of the patterns a step of ``size`` may emit: the
    per-nv refine passes of the device placement are compiled per this
    tuple. Vertex mode explores fixed-size embeddings (nv == size); edge
    mode's embeddings of k edges span 2..min(k+1, 8) vertices."""
    if getattr(app, "mode", "vertex") == "edge":
        # a connected k-edge embedding spans 2..k+1 vertices (tree upper
        # bound), capped at the 8-vertex encoding limit
        hi = min(int(size) + 1, pattern_lib.MAX_PATTERN_VERTICES)
        return tuple(range(2, hi + 1))
    return (min(int(size), pattern_lib.MAX_PATTERN_VERTICES),)


def level2_device_tables(table: pattern_lib.PatternTable, cap: int):
    """Upload the level-2 mapping for device phase-2 consumers (domain
    scatter, alpha-mask gathers): ``q2c`` (cap,) int32 padded with -1 and
    ``sigma_inv`` (cap, 8) int32 (canonical pos -> local pos)."""
    q = len(table.quick_codes)
    q2c = np.full(cap, -1, np.int32)
    q2c[:q] = table.quick_to_canon
    si = np.zeros((cap, pattern_lib.MAX_PATTERN_VERTICES), np.int32)
    si[:q] = np.argsort(table.sigma, axis=1)
    return jnp.asarray(q2c), jnp.asarray(si)


def scatter_canon_bitmaps(bm_flat, slot, lv, q2c, sigma_inv,
                          pc_cap: int, n_vertices: int):
    """Accumulate one batch into the flat canonical domain bitmap (see
    :func:`_scatter_canon_flat`); ``bm_flat`` is the
    (pc_cap * 8 * N + 1,) bool accumulator threaded across batches."""
    return _scatter_canon_flat(
        bm_flat, slot, lv, q2c, sigma_inv,
        pc_cap * pattern_lib.MAX_PATTERN_VERTICES * n_vertices, n_vertices,
    )


