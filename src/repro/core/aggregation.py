"""Pattern-keyed aggregation (paper §4.1 map/reduce + §5.4 two levels).

Level 1 runs on device over all embeddings of the step (counts, FSM domain
bitmaps keyed by *quick*-pattern slot). Level 2 maps quick slots to canonical
slots (host table from :mod:`repro.core.pattern`) and folds level-1 state —
the only stage that ever touches graph isomorphism.

In the distributed runtime the level-1 state is exactly what gets
all-reduced: per-pattern scalars and domain bitmaps, never embeddings
(DESIGN.md §4) — this is how the paper's Table-4 reduction becomes a
collective-bytes reduction.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pattern as pattern_lib


class StepAggregates(NamedTuple):
    """Aggregation output of one exploration step (canonical-pattern keyed)."""

    canon_codes: np.ndarray    # (Pc, 3) int64
    counts: np.ndarray         # (Pc,) int64 — #embeddings per pattern
    supports: np.ndarray       # (Pc,) int64 — min-image support (== counts
                               #   when domains were not requested)
    n_quick: int               # distinct quick patterns this step (Table 4)
    n_canonical: int           # distinct canonical patterns
    n_iso_checks: int          # graph-isomorphism invocations


def _unique_rows3(codes: np.ndarray):
    """``np.unique(axis=0, return_inverse=True)`` for (B, 3) int64 rows via
    a 3-key lexsort — ~5x faster than numpy's void-dtype row sort, which is
    the hottest host op of a superstep's aggregation (DESIGN.md §8)."""
    order = np.lexsort((codes[:, 2], codes[:, 1], codes[:, 0]))
    sc = codes[order]
    new = np.empty(len(sc), dtype=bool)
    new[0] = True
    np.any(sc[1:] != sc[:-1], axis=1, out=new[1:])
    uniq = sc[new]
    inv = np.empty(len(sc), dtype=np.int64)
    inv[order] = np.cumsum(new) - 1
    return uniq, inv


def quick_slot_ids(codes: jnp.ndarray, valid: jnp.ndarray):
    """Host-side unique over the (B, 3) quick codes -> (unique (Q,3), inv (B,)).

    The two-level scheme makes Q tiny (Table 4), so one host unique per step
    is cheap; rows with ``valid == False`` are mapped to slot -1.
    """
    codes_np = np.asarray(codes)
    valid_np = np.asarray(valid)
    if not valid_np.any():
        return np.zeros((0, 3), np.int64), np.full(len(codes_np), -1, np.int32)
    uniq, inv = _unique_rows3(codes_np[valid_np])
    full_inv = np.full(len(codes_np), -1, dtype=np.int32)
    full_inv[valid_np] = inv.astype(np.int32)
    return uniq, full_inv


@functools.partial(jax.jit, static_argnames=("n_canon", "n_vertices"))
def domain_bitmaps(
    canon_slot: jnp.ndarray,     # (B,) int32 canonical slot per embedding
    verts_canonical: jnp.ndarray,  # (B, 8) int32 graph vertex at canonical pos
    valid: jnp.ndarray,          # (B,) bool
    n_canon: int,
    n_vertices: int,
) -> jnp.ndarray:
    """FSM min-image domains (level-1): bool (Pc, 8, N) — vertex v appears at
    canonical position p of some embedding of pattern pc.

    One dense scatter; in the distributed engine this tensor is OR-allreduced
    (bool max) across workers — the paper's domain merge as one collective.
    """
    b, kmax = verts_canonical.shape
    flat = jnp.zeros((n_canon * kmax * n_vertices + 1,), dtype=bool)
    slot_ok = valid[:, None] & (verts_canonical >= 0) & (canon_slot[:, None] >= 0)
    idx = (
        canon_slot[:, None].astype(jnp.int64) * (kmax * n_vertices)
        + jnp.arange(kmax)[None, :] * n_vertices
        + jnp.maximum(verts_canonical, 0)
    )
    idx = jnp.where(slot_ok, idx, n_canon * kmax * n_vertices)
    flat = flat.at[idx.reshape(-1)].set(True)
    return flat[:-1].reshape(n_canon, kmax, n_vertices)


def min_image_support(
    bitmaps: jnp.ndarray, canon_n_verts: np.ndarray, canon_orbits: np.ndarray
) -> np.ndarray:
    """Support(p) = min over pattern positions of |domain(position)| [7].

    Domains are defined over *all* isomorphisms pattern->embedding; with one
    fixed isomorphism per embedding the missing mappings are recovered by
    OR-ing domains across each position's automorphism orbit
    (pattern.automorphism_orbits).
    """
    bm = np.asarray(bitmaps)                          # (Pc, 8, N) bool
    pc, kmax, n = bm.shape
    merged = np.zeros_like(bm)
    for p in range(pc):
        for pos in range(kmax):
            merged[p, pos] = bm[p, canon_orbits[p] == canon_orbits[p, pos]].any(axis=0)
    counts = merged.sum(axis=2)                       # (Pc, 8)
    pos_ok = np.arange(kmax)[None, :] < np.asarray(canon_n_verts)[:, None]
    counts = np.where(pos_ok, counts, np.iinfo(np.int64).max)
    return counts.min(axis=1).astype(np.int64)


def map_to_canonical_positions(
    table: pattern_lib.PatternTable,
    quick_slot: np.ndarray,       # (B,) int32
    local_verts: jnp.ndarray,     # (B, 8) int32
) -> tuple[np.ndarray, jnp.ndarray]:
    """Per-embedding canonical slot + vertices re-ordered to canonical
    positions (position p holds local vertex with sigma[local]=p)."""
    sigma = table.sigma[np.maximum(quick_slot, 0)]    # (B, 8) local -> canon
    sigma_inv = np.argsort(sigma, axis=1)             # canon -> local
    lv = np.asarray(local_verts)
    verts_canon = np.take_along_axis(lv, sigma_inv, axis=1)
    canon_slot = np.where(
        quick_slot >= 0, table.quick_to_canon[np.maximum(quick_slot, 0)], -1
    ).astype(np.int32)
    return canon_slot, jnp.asarray(verts_canon)


def aggregate_rows(
    g_n_vertices: int,
    codes: np.ndarray,        # (B, 3) int64 quick codes (host)
    local_verts,              # (B, 8) int32 (host); None iff not with_domains
    with_domains: bool,
) -> tuple[StepAggregates, np.ndarray]:
    """Full two-level aggregation for one step's embeddings, over
    pre-computed quick patterns (DESIGN.md §7).

    The engine computes quick patterns one device-budget wave at a time and
    merges the level-1 state here on the host (``bincount`` + boolean
    scatter), so aggregation never allocates a device array of frontier
    length — the frontier-store subsystem's device-budget contract. The
    distributed runtime keeps its own sharded level-1 path
    (:func:`make_sharded_aggregate` in :mod:`repro.core.distributed`) whose
    reduce is the collective.

    Returns (aggregates, per-embedding canonical slot).
    """
    codes = np.asarray(codes)
    b = len(codes)
    uniq, inv = quick_slot_ids(codes, np.ones(b, dtype=bool))
    table = pattern_lib.build_pattern_table(uniq, with_orbits=with_domains)
    q = len(uniq)
    pc = len(table.canon_codes)
    if q == 0:
        empty = StepAggregates(
            canon_codes=np.zeros((0, 3), np.int64),
            counts=np.zeros((0,), np.int64),
            supports=np.zeros((0,), np.int64),
            n_quick=0,
            n_canonical=0,
            n_iso_checks=0,
        )
        return empty, np.full(b, -1, np.int32)

    quick_counts = np.bincount(inv, minlength=q).astype(np.int64)
    counts = np.zeros(pc, dtype=np.int64)
    np.add.at(counts, table.quick_to_canon, quick_counts)

    if with_domains:
        # domains need every embedding's vertices re-ordered to canonical
        # positions; without them the slot lookup is the whole mapping
        canon_slot, verts_canon = map_to_canonical_positions(
            table, inv, np.asarray(local_verts)
        )
        verts_canon = np.asarray(verts_canon)
        kmax = verts_canon.shape[1]
        bm = np.zeros((pc, kmax, g_n_vertices), dtype=bool)
        ok = (verts_canon >= 0) & (canon_slot[:, None] >= 0)
        rows, pos = np.nonzero(ok)
        bm[canon_slot[rows], pos, verts_canon[rows, pos]] = True
        supports = min_image_support(bm, table.canon_n_verts, table.canon_orbits)
    else:
        canon_slot = table.quick_to_canon[inv].astype(np.int32)
        supports = counts.copy()

    agg = StepAggregates(
        canon_codes=table.canon_codes,
        counts=counts,
        supports=np.asarray(supports).astype(np.int64),
        n_quick=q,
        n_canonical=pc,
        n_iso_checks=table.n_iso_checks,
    )
    return agg, canon_slot
