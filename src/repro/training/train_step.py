"""The jitted train step + fault-tolerance scaffolding.

``make_train_step`` builds the pjit'd (loss+grad+AdamW) program with full
in/out shardings; ``TrainLoop`` adds the production posture: checkpoint
cadence with atomic commit + auto-resume, a per-step watchdog that flags
stragglers (steps beyond mean+4*sigma), and NaN-step skipping (grad norm
guard) — each exercised by tests/test_training.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint as ckpt_lib
from repro.training.optimizer import AdamWConfig, OptState, apply_update, init_opt_state


def make_train_step(model, opt_cfg: AdamWConfig):
    def step_fn(params, opt_state: OptState, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        new_params, new_state, metrics = apply_update(opt_cfg, params, grads, opt_state)
        # NaN guard: skip the update when the gradient exploded
        ok = jnp.isfinite(metrics["grad_norm"]) & jnp.isfinite(loss)
        new_params = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_params, params
        )
        new_state = jax.tree.map(
            lambda n, o: jnp.where(ok, n, o), new_state, opt_state
        )
        metrics = dict(metrics, loss=loss, skipped=(~ok).astype(jnp.int32))
        return new_params, new_state, metrics

    return step_fn


@dataclasses.dataclass
class TrainLoop:
    model: Any
    opt_cfg: AdamWConfig
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    straggler_sigma: float = 4.0

    def run(self, params, batches, jit: bool = True):
        """``batches``: iterable of batch dicts. Returns (params, history)."""
        step_fn = make_train_step(self.model, self.opt_cfg)
        if jit:
            step_fn = jax.jit(step_fn)
        opt_state = init_opt_state(params)
        start = 0

        if self.ckpt_dir:
            latest = ckpt_lib.latest_step(self.ckpt_dir)
            if latest is not None:
                params, opt_state = ckpt_lib.restore(
                    self.ckpt_dir, latest, (params, opt_state)
                )
                start = latest

        history = []
        durations = []
        for i, batch in enumerate(batches):
            step = start + i
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            straggler = False
            if len(durations) >= 5:
                mu, sd = np.mean(durations), np.std(durations) + 1e-9
                straggler = dt > mu + self.straggler_sigma * sd
            durations.append(dt)
            history.append(
                {"step": step, "loss": loss, "time_s": dt, "straggler": straggler,
                 "skipped": int(metrics["skipped"])}
            )
            if self.ckpt_dir and (step + 1) % self.ckpt_every == 0:
                ckpt_lib.save(self.ckpt_dir, step + 1, (params, opt_state))
                ckpt_lib.retain(self.ckpt_dir)
        return params, opt_state, history
