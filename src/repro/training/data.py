"""Deterministic synthetic token pipeline.

Host-side, seedable, shardable: each (step, shard) pair derives its chunk of
the global batch independently — so data loading is reproducible across
restarts and elastic resharding (a worker only materialises its slice).
A real deployment would swap `_tokens_for` for a tokenised corpus reader
with the same (step, index-range) contract.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


def _tokens_for(cfg: DataConfig, step: int, row: int) -> np.ndarray:
    """One (seq_len,) row; Zipf-ish marginal + order-2 structure so the LM
    has something learnable (loss must drop during the example run)."""
    rng = np.random.default_rng((cfg.seed, step, row))
    base = rng.zipf(1.4, size=cfg.seq_len) % cfg.vocab
    # inject copy structure: every other position repeats with offset
    base[1::2] = (base[0::2] + 1) % cfg.vocab
    return base.astype(np.int32)


def global_batch(cfg: DataConfig, step: int) -> dict:
    toks = np.stack([_tokens_for(cfg, step, r) for r in range(cfg.global_batch)])
    return {"tokens": toks, "labels": toks}


def shard_batch(cfg: DataConfig, step: int, shard: int, n_shards: int) -> dict:
    per = cfg.global_batch // n_shards
    rows = range(shard * per, (shard + 1) * per)
    toks = np.stack([_tokens_for(cfg, step, r) for r in rows])
    return {"tokens": toks, "labels": toks}
