"""Fault-tolerant sharded checkpointing (no orbax dependency).

Layout per step:
    <dir>/step_<N>.tmp/            (written, fsync'd)
        manifest.json              tree structure + shapes/dtypes
        shard_<i>.npz              flat leaf arrays (host shards)
    <dir>/step_<N>/                atomic rename commit

Restart contract: ``latest_step``/``restore`` never see a torn checkpoint
(atomic rename). ``restore`` reshards to ANY mesh: arrays are saved as full
logical values per leaf (single-host container) or per-shard with index
metadata in the multi-host layout; loading re-slices with the new sharding,
so elastic shrink/grow is a restore away (DESIGN.md §4).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_names(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step:08d}.tmp")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten_with_names(tree)

    def to_np(l):
        arr = np.asarray(l)
        # npz has no bf16: store the raw uint16 view, dtype in the manifest
        if arr.dtype == jnp.bfloat16:
            return arr.view(np.uint16)
        return arr

    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [
            {"shape": list(np.shape(l)), "dtype": str(np.asarray(l).dtype)}
            for l in leaves
        ],
    }
    np.savez(
        os.path.join(tmp, "shard_0.npz"),
        **{f"leaf_{i}": to_np(l) for i, l in enumerate(leaves)},
    )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str):
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Load into the structure of ``like_tree``; optionally device_put with
    new shardings (elastic resharding)."""
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, "shard_0.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(like_tree)
    loaded = []
    for i, ref in enumerate(leaves):
        arr = data[f"leaf_{i}"]
        if manifest["leaves"][i]["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        want = jnp.asarray(ref).dtype if not hasattr(ref, "dtype") else ref.dtype
        loaded.append(jnp.asarray(arr, dtype=want))
    tree = jax.tree.unflatten(treedef, loaded)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def retain(directory: str, keep: int = 3):
    """Garbage-collect old checkpoints, keeping the newest ``keep``."""
    if not os.path.isdir(directory):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)
