"""Sharded AdamW with ZeRO-1-style state sharding (no optax dependency).

Master weights + first/second moments are fp32 and carry their own
PartitionSpecs: optimizer state is sharded over BOTH the FSDP axes and the
tensor axis (one extra dim vs. the bf16 compute params), so per-chip
optimizer memory is params_bytes*12/(fsdp*tp) — the ZeRO trick expressed
through GSPMD shardings rather than hand-written reduce-scatters.

Gradient cross-pod compression: grads are reduced in bf16 (matching param
dtype) and promoted to fp32 only inside the update — the standard
bandwidth-halving trick; toggle with ``fp32_grad_reduce``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    fp32_grad_reduce: bool = False   # False = bf16 cross-pod reduce (compressed)


class OptState(NamedTuple):
    step: jnp.ndarray      # () int32
    master: Any            # fp32 master weights
    m: Any                 # fp32 first moment
    v: Any                 # fp32 second moment


def init_opt_state(params) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def apply_update(cfg: AdamWConfig, params, grads, state: OptState):
    """One AdamW step; returns (new bf16 params, new state, metrics)."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gsq = sum(jnp.sum(g * g) for g in jax.tree.leaves(gf))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step.astype(jnp.float32))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(master, m, v, g):
        g = g * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        decay = cfg.weight_decay if master.ndim >= 2 else 0.0
        master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + decay * master)
        return master, m, v

    flat_master, tdef = jax.tree.flatten(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_g = jax.tree.leaves(gf)
    new = [upd(a, b, c, d) for a, b, c, d in zip(flat_master, flat_m, flat_v, flat_g)]
    master = jax.tree.unflatten(tdef, [x[0] for x in new])
    m = jax.tree.unflatten(tdef, [x[1] for x in new])
    v = jax.tree.unflatten(tdef, [x[2] for x in new])

    params_dtype = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    del params_dtype
    return new_params, OptState(step=step, master=master, m=m, v=v), {
        "grad_norm": gnorm,
        "lr": lr,
    }


def opt_state_specs(param_specs, params_struct=None, mesh=None,
                    fsdp_axes=("data",)) -> OptState:
    """ZeRO-1 optimizer-state PartitionSpecs: start from the param spec and
    additionally shard the first unsharded, divisible dim over the data
    axes. GSPMD then reduce-scatters grads into the shard domain for the
    update and all-gathers the bf16 params ONCE per step — the ZeRO-1
    schedule with no hand-written collectives."""
    from jax.sharding import PartitionSpec

    if params_struct is None or mesh is None:
        states = param_specs
    else:
        import numpy as np

        fs = int(np.prod([mesh.shape[a] for a in fsdp_axes]))
        fsdp = tuple(fsdp_axes)

        def extend(spec, leaf):
            parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
            used = {a for p in parts if p for a in (p if isinstance(p, tuple) else (p,))}
            if used & set(fsdp):
                return PartitionSpec(*parts)   # already data-sharded (experts)
            for i, (s, dim) in enumerate(zip(parts, leaf.shape)):
                if s is None and fs > 1 and dim % fs == 0 and dim >= fs:
                    parts[i] = fsdp
                    break
            return PartitionSpec(*parts)

        states = jax.tree.map(
            extend, param_specs, params_struct,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )
    return OptState(
        step=PartitionSpec(),
        master=states,
        m=states,
        v=states,
    )
