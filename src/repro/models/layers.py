"""Shared model layers: init helpers, sharding rules, norms, RoPE,
attention (GQA + MLA), MLP, MoE. Pure-pytree params (no flax), explicit
dtypes everywhere (bf16 params/activations, fp32 reductions)."""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Activation-sharding context (perf iteration 1, EXPERIMENTS.md §Perf):
# without explicit activation constraints GSPMD reshards the residual
# stream over 'model' and all-reduces attention scores (dh-contraction
# partials) — measured at ~58 GB/layer/device on yi-34b train_4k. The
# Megatron-style layout below pins: residual (dp, None, None), heads on
# 'model' only when divisible, MLP hidden (dp, None, model).
# ---------------------------------------------------------------------------

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar("act_ctx", default=None)
#: "opt" (ZeRO-1 weights + activation constraints + blocked attention) or
#: "baseline" (FSDP-sharded weights, no constraints, naive attention) —
#: the §Perf iteration ladder's endpoints.
LAYOUT: contextvars.ContextVar = contextvars.ContextVar("layout", default="opt")


@dataclasses.dataclass(frozen=True)
class ActSharding:
    dp: tuple          # data-parallel axes for the batch dim
    tp: str            # tensor axis name
    tp_size: int


@contextlib.contextmanager
def activation_sharding(dp_axes, tp_axis, tp_size):
    token = _ACT_CTX.set(ActSharding(tuple(dp_axes), tp_axis, tp_size))
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def constrain(x, *dims):
    """with_sharding_constraint if an activation context is active.

    ``dims`` entries: 'dp' (batch axes), 'tp:<size>' (tensor axis, applied
    only when the dim is divisible), or None.
    """
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    parts = []
    for i, d in enumerate(dims):
        if d == "dp":
            parts.append(ctx.dp if x.shape[i] > 1 else None)
        elif d == "tp":
            parts.append(ctx.tp if x.shape[i] % ctx.tp_size == 0 and x.shape[i] >= ctx.tp_size else None)
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))


# ---------------------------------------------------------------------------
# Param init + sharding rules
# ---------------------------------------------------------------------------

def _normal(key, shape, scale):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(DTYPE)


class Init:
    """Key-splitting param factory."""

    def __init__(self, key):
        self.key = key

    def take(self):
        self.key, k = jax.random.split(self.key)
        return k

    def dense(self, d_in, d_out, scale=None, bias=False):
        scale = scale if scale is not None else d_in ** -0.5
        w = _normal(self.take(), (d_in, d_out), scale)
        if bias:
            return {"w": w, "b": jnp.zeros((d_out,), DTYPE)}
        return {"w": w}

    def stack(self, n, fn):
        """Stacked params for scan-over-layers."""
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn() for _ in range(n)])


def _divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def spec_for(path: str, shape, mesh_axis_sizes: dict, fsdp_axes, tp_axis="model"):
    """Compute-param sharding rule (perf iteration 2, EXPERIMENTS.md §Perf):

    ZeRO-1 layout — weights are TENSOR-PARALLEL ONLY and replicated over the
    data axes; only optimizer state (opt_state_specs) is additionally
    data-sharded. Sharding weight contracting dims over 'data' (FSDP-style,
    the iteration-0/1 baselines) makes GSPMD regather ~1 GB of weights or
    activations per matmul per layer; with ZeRO-1 the params move across
    'data' ONCE per step, inside the optimizer.

    Exception: MoE expert banks are also sharded over the data axes on d_in
    (e.g. DeepSeek-V2's 472 GB of experts would not fit per-chip otherwise);
    their per-layer regather is 1/n_experts-weighted and cheap.
    """
    tp = mesh_axis_sizes.get(tp_axis, 1)
    fs = int(np.prod([mesh_axis_sizes.get(a, 1) for a in fsdp_axes])) if fsdp_axes else 1
    nd = len(shape)
    spec = [None] * nd
    if nd == 0 or max(shape) < 256:
        return P(*spec)

    def put(dim, axis, size):
        if spec[dim] is None and _divisible(shape[dim], size):
            spec[dim] = axis
            return True
        return False

    fsdp = tuple(fsdp_axes) if fs > 1 else None
    p = path.lower()
    row_parallel = any(t in p for t in ("wo", "w_out", "out_proj", "down"))
    expert = "experts" in p
    zero1 = LAYOUT.get() != "baseline"   # baseline = FSDP-everything (iter 0)
    if expert and nd >= 3:
        put(0, tp_axis, tp)
        if fsdp:
            put(1, fsdp, fs) or put(2, fsdp, fs)
    elif "unembed" in p and nd == 2:
        put(1, tp_axis, tp)
        if fsdp and not zero1:
            put(0, fsdp, fs)
    elif "embed" in p and nd == 2:
        # vocab over data: big tables, and the token-lookup gather is tiny
        put(1, tp_axis, tp)
        if fsdp:
            put(0, fsdp, fs)
    elif nd >= 2 and row_parallel:
        put(nd - 2, tp_axis, tp)
        if fsdp and not zero1:
            put(nd - 1, fsdp, fs)
    elif nd >= 2:
        put(nd - 1, tp_axis, tp)
        if fsdp and not zero1:
            put(nd - 2, fsdp, fs)
    return P(*spec)


def build_param_specs(params, mesh, fsdp_axes):
    """Spec tree parallel to a param tree via path-based rules. Stacked
    (scan) leading layer dims are detected by name prefix 'layers' and left
    unsharded on dim 0 (the scan axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def rule(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        shape = leaf.shape
        stacked = pstr.startswith("layers") or "/layers" in pstr or "blocks" in pstr
        if stacked and len(shape) >= 1:
            inner = spec_for(pstr, shape[1:], sizes, fsdp_axes)
            return P(None, *inner)
        return spec_for(pstr, shape, sizes, fsdp_axes)

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# Norms / RoPE / losses
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_freqs(positions, dim, theta):
    """positions (...,) -> cos/sin (..., dim//2) fp32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., H, dh) with cos/sin (..., dh//2); rotates pairs."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, vocab):
    """Mean next-token loss; fp32, gather-based (never materialises a
    one-hot of the vocab — critical at vocab>100k x 1M tokens)."""
    del vocab
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# Attention: GQA (train + decode) and MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def init_gqa(cfg: ArchConfig, ini: Init):
    dh = cfg.head_dim
    return {
        "wq": ini.dense(cfg.d_model, cfg.n_heads * dh, bias=cfg.qkv_bias),
        "wk": ini.dense(cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wv": ini.dense(cfg.d_model, cfg.n_kv_heads * dh, bias=cfg.qkv_bias),
        "wo": ini.dense(cfg.n_heads * dh, cfg.d_model),
    }


def _proj(x, p):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


#: query-block size for blocked (flash-style) attention; 0 disables.
ATTN_BLOCK = 512


def _sdpa(q, k, v, q_pos, k_pos, scale, causal, window):
    """Dense attention on one query block. q (B,QB,KV,G,D); k/v (B,S,KV,D)."""
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    mask = q_pos[:, :, None] >= k_pos[:, None, :] if causal else None
    if window:
        near = k_pos[:, None, :] > q_pos[:, :, None] - window
        mask = near if mask is None else (mask & near)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def gqa_attention(cfg: ArchConfig, p, x, positions, *, causal=True, window=0):
    """Training/prefill attention, blocked over queries (perf iteration 3,
    EXPERIMENTS.md §Perf): the (S,S) score matrix is never materialised —
    per q-block temps are (B,H,QB,S), an S/QB reduction of the dominant
    memory-roofline term at prefill_32k. On real TPU the Pallas
    flash_attention kernel (kernels/flash_attention) replaces this path.
    """
    b, s, _ = x.shape
    dh = cfg.head_dim
    q = _proj(x, p["wq"]).reshape(b, s, cfg.n_heads, dh)
    k = _proj(x, p["wk"]).reshape(b, s, cfg.n_kv_heads, dh)
    v = _proj(x, p["wv"]).reshape(b, s, cfg.n_kv_heads, dh)
    # pin head-sharded layout: dh must stay unsharded or the scores einsum
    # goes partial and GSPMD all-reduces (B,H,S,S) scores
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    cos, sin = rope_freqs(positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    g = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, s, cfg.n_kv_heads, g, dh)
    o = blocked_attention(q, k, v, positions, dh**-0.5, causal, window,
                          unroll=cfg.unroll)
    o = o.reshape(b, s, cfg.n_heads * dh)
    return constrain(_proj(o, p["wo"]), "dp", None, None)


def blocked_attention(q, k, v, positions, scale, causal=True, window=0,
                      unroll=False):
    """q (B,S,KV,G,D); k/v (B,S,KV,Dk/Dv). Chunked over queries when the
    optimised layout is active; dense otherwise (baseline). ``unroll``
    python-unrolls the block loop (dry-run cost accounting: a lax.map body
    would be counted once by cost_analysis)."""
    b, s = q.shape[:2]
    blk = ATTN_BLOCK if LAYOUT.get() != "baseline" else 0
    if blk and s > blk and s % blk == 0:
        nb = s // blk
        qb = q.reshape((b, nb, blk) + q.shape[2:]).transpose(1, 0, 2, 3, 4, 5)
        pb = positions.reshape(b, nb, blk).transpose(1, 0, 2)

        def one_block(args):
            qi, pi = args
            return _sdpa(qi, k, v, pi, positions, scale, causal, window)

        if unroll:
            o = jnp.stack([one_block((qb[i], pb[i])) for i in range(nb)])
        else:
            o = jax.lax.map(one_block, (qb, pb))          # (nb,B,blk,KV,G,Dv)
        return o.transpose(1, 0, 2, 3, 4, 5).reshape((b, s) + o.shape[3:])
    return _sdpa(q, k, v, positions, positions, scale, causal, window)


def gqa_decode(cfg: ArchConfig, p, x, cache_k, cache_v, pos):
    """One-token decode. x (B,1,d); cache_k/v (B,S,kv,dh); pos () current
    index. Returns (out, new_k, new_v)."""
    b = x.shape[0]
    dh = cfg.head_dim
    s = cache_k.shape[1]
    q = _proj(x, p["wq"]).reshape(b, 1, cfg.n_heads, dh)
    k = _proj(x, p["wk"]).reshape(b, 1, cfg.n_kv_heads, dh)
    v = _proj(x, p["wv"]).reshape(b, 1, cfg.n_kv_heads, dh)
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    cos, sin = rope_freqs(posv, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)

    g = cfg.n_heads // cfg.n_kv_heads
    q = q.reshape(b, cfg.n_kv_heads, g, dh)
    scores = jnp.einsum("bhgd,bkhd->bhgk", q, cache_k.astype(x.dtype),
                        preferred_element_type=jnp.float32) * dh**-0.5
    valid = jnp.arange(s)[None, :] <= pos
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, cache_v.astype(x.dtype)).reshape(b, 1, cfg.n_heads * dh)
    return _proj(o, p["wo"]), cache_k, cache_v


# ---- MLA ------------------------------------------------------------------

def init_mla(cfg: ArchConfig, ini: Init):
    """DeepSeek-V2 Multi-head Latent Attention (arXiv:2405.04434 §2.1)."""
    dq = cfg.nope_head_dim + cfg.rope_head_dim
    return {
        "wq_a": ini.dense(cfg.d_model, cfg.q_lora),       # q down
        "q_norm": jnp.ones((cfg.q_lora,), DTYPE),
        "wq_b": ini.dense(cfg.q_lora, cfg.n_heads * dq),  # q up (nope+rope)
        "wkv_a": ini.dense(cfg.d_model, cfg.kv_lora + cfg.rope_head_dim),
        "kv_norm": jnp.ones((cfg.kv_lora,), DTYPE),
        "wk_b": ini.dense(cfg.kv_lora, cfg.n_heads * cfg.nope_head_dim),
        "wv_b": ini.dense(cfg.kv_lora, cfg.n_heads * cfg.v_head_dim),
        "wo": ini.dense(cfg.n_heads * cfg.v_head_dim, cfg.d_model),
    }


def mla_attention(cfg: ArchConfig, p, x, positions):
    """Training/prefill MLA; materialises per-head K/V from the latent."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = _proj(rmsnorm(_proj(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, s, h, dn + dr)
    q = constrain(q, "dp", None, "tp", None)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv = _proj(x, p["wkv_a"])
    c_kv, k_rope = kv[..., : cfg.kv_lora], kv[..., cfg.kv_lora :]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.norm_eps)
    c_kv = constrain(c_kv, "dp", None, None)
    k_nope = constrain(_proj(c_kv, p["wk_b"]).reshape(b, s, h, dn), "dp", None, "tp", None)
    v = constrain(_proj(c_kv, p["wv_b"]).reshape(b, s, h, dv), "dp", None, "tp", None)

    cos, sin = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # shared across heads

    # fold rope+nope into one head dim and reuse the blocked MHA path
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)      # (b,s,h,dn+dr)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1
    )
    q_cat = constrain(q_cat, "dp", None, "tp", None)
    k_cat = constrain(k_cat, "dp", None, "tp", None)
    o = blocked_attention(
        q_cat[:, :, :, None, :], k_cat, v, positions, (dn + dr) ** -0.5,
        unroll=cfg.unroll,
    )
    o = o.reshape(b, s, h * dv)
    return constrain(_proj(o, p["wo"]), "dp", None, None)


def mla_decode(cfg: ArchConfig, p, x, cache_ckv, cache_krope, pos):
    """Absorbed-weight MLA decode: the cache holds only the compressed
    latent (kv_lora) + shared rope key (rope_head_dim) per token — the
    paper's 93%-smaller KV cache. Score via W_k_b absorbed into q."""
    b = x.shape[0]
    h, dn, dr, dv = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    s = cache_ckv.shape[1]

    q = _proj(rmsnorm(_proj(x, p["wq_a"]), p["q_norm"], cfg.norm_eps), p["wq_b"])
    q = q.reshape(b, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posv = jnp.full((b, 1), pos, dtype=jnp.int32)
    cos, sin = rope_freqs(posv, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope[:, None], cos, sin)[:, 0]

    kv = _proj(x[:, 0], p["wkv_a"])
    c_kv = rmsnorm(kv[..., : cfg.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv[:, None, None, cfg.kv_lora :], cos, sin)[:, 0, 0]
    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv[:, None].astype(cache_ckv.dtype), pos, axis=1
    )
    cache_krope = jax.lax.dynamic_update_slice_in_dim(
        cache_krope, k_rope[:, None].astype(cache_krope.dtype), pos, axis=1
    )

    # absorb W_k_b into q: q_lat (b,h,kv_lora)
    wkb = p["wk_b"]["w"].reshape(cfg.kv_lora, h, dn)
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope, wkb)
    scores = (
        jnp.einsum("bhc,bkc->bhk", q_lat, cache_ckv.astype(x.dtype),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhd,bkd->bhk", q_rope, cache_krope.astype(x.dtype),
                     preferred_element_type=jnp.float32)
    ) * (dn + dr) ** -0.5
    valid = jnp.arange(s)[None, :] <= pos
    scores = jnp.where(valid[:, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhk,bkc->bhc", w, cache_ckv.astype(x.dtype))
    wvb = p["wv_b"]["w"].reshape(cfg.kv_lora, h, dv)
    o = jnp.einsum("bhc,chd->bhd", o_lat, wvb).reshape(b, 1, h * dv)
    return _proj(o, p["wo"]), cache_ckv, cache_krope


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def init_mlp(d_model, d_ff, ini: Init):
    return {
        "w_gate": ini.dense(d_model, d_ff),
        "w_in": ini.dense(d_model, d_ff),
        "w_out": ini.dense(d_ff, d_model),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]["w"]) * (x @ p["w_in"]["w"])
    if h.ndim == 3:
        h = constrain(h, "dp", None, "tp")      # Megatron column-parallel hidden
    out = h @ p["w_out"]["w"]
    return constrain(out, *(["dp"] + [None] * (out.ndim - 1)))


def init_moe(cfg: ArchConfig, ini: Init):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    params = {
        "router": ini.dense(d, e, scale=0.02),
        "experts": {
            "w_gate": _normal(ini.take(), (e, d, f), d**-0.5),
            "w_in": _normal(ini.take(), (e, d, f), d**-0.5),
            "w_out": _normal(ini.take(), (e, f, d), f**-0.5),
        },
    }
    if cfg.n_shared_experts:
        params["shared"] = init_mlp(d, f * cfg.n_shared_experts, ini)
    return params


def _moe_groups(t: int) -> int:
    """Token-group count for grouped dispatch (perf iteration 5): groups
    align with the data axes so routing (sort/scatter) is group-LOCAL and
    the only cross-device movement is the (G,E,C,d) dispatch all-to-all —
    the GShard schedule. A global argsort dispatch makes GSPMD all-gather
    the full token matrix per MoE layer (measured regression, §Perf)."""
    ctx = _ACT_CTX.get()
    target = 256 if ctx is not None else 8
    g = min(target, t)
    while t % g:
        g -= 1
    return max(g, 1)


def _moe_cap(cfg: ArchConfig, tg: int) -> int:
    e, k = cfg.n_experts, cfg.top_k
    return max(4, min(int(cfg.capacity_factor * tg * k / e), tg * k))


def _moe_one_group(cfg: ArchConfig, p, xt, cap: int):
    """Sorted capacity-bounded dispatch for ONE token group. xt (Tg, d)."""
    tg, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (xt @ p["router"]["w"]).astype(jnp.float32)          # (Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                          # (Tg, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                                     # (Tg*k,)
    flat_t = jnp.repeat(jnp.arange(tg), k)
    flat_w = topv.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sw = flat_e[order], flat_t[order], flat_w[order]
    first = jnp.searchsorted(se, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(tg * k) - first[se]
    keep = pos_in_e < cap

    slot = jnp.where(keep, se * cap + pos_in_e, e * cap)          # dropped -> dump
    disp = jnp.zeros((e * cap + 1, d), DTYPE).at[slot].set(xt[st_])[:-1]
    return disp.reshape(e, cap, d), (se, st_, sw, keep, pos_in_e)


def _moe_combine_one_group(meta, out, tg, d, cap: int):
    se, st_, sw, keep, pos_in_e = meta
    contrib = out.reshape(-1, d)[jnp.where(keep, se * cap + pos_in_e, 0)]
    contrib = contrib * jnp.where(keep, sw, 0.0).astype(DTYPE)[:, None]
    return jnp.zeros((tg, d), DTYPE).at[st_].add(contrib)


def moe(cfg: ArchConfig, p, x):
    """Top-k token-choice MoE, grouped sorted dispatch (GShard schedule).

    Tokens are split into groups (vmapped routing, no cross-group
    coordination — the groups ARE the data shards at scale), dispatched into
    a (G, E, C, d) tensor whose layout change (G on the data axes -> E on
    'model') is the expert-parallel all-to-all.
    """
    b, s, d = x.shape
    t = b * s
    g = _moe_groups(t)
    tg = t // g
    cap = _moe_cap(cfg, tg)
    xt = x.reshape(g, tg, d)

    disp, meta = jax.vmap(lambda xg: _moe_one_group(cfg, p, xg, cap))(xt)
    disp = constrain(disp, "dp", "tp", None, None)   # (G, E, C, d) all-to-all

    h = jnp.einsum("gecd,edf->gecf", disp, p["experts"]["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", disp, p["experts"]["w_in"])
    out = jnp.einsum("gecf,efd->gecd", h, p["experts"]["w_out"])
    out = constrain(out, "dp", "tp", None, None)

    y = jax.vmap(lambda m, o: _moe_combine_one_group(m, o, tg, d, cap))(meta, out)

    if cfg.n_shared_experts:
        y = y + jax.vmap(lambda xg: mlp(p["shared"], xg))(xt)
    return constrain(y.reshape(b, s, d), "dp", None, None)
