"""State-space blocks: Mamba2 (SSD, chunkwise-parallel) and xLSTM
(mLSTM chunkwise matrix-memory + sLSTM recurrent scan).

TPU adaptation: both use the chunkwise matmul formulation (intra-chunk dense
attention-like matmuls + inter-chunk state recurrence over #chunks) so the
MXU does the work instead of a length-S sequential scan. The sLSTM block is
inherently sequential (recurrent weights) and stays a lax.scan — it appears
only every ``slstm_every`` blocks.

Deviation noted in DESIGN.md: xLSTM's exponential input gate + stabilizer is
replaced with bounded sigmoid gates (same state structure); qk head dim is
d_head/2 to keep the matrix memory within HBM at decode_32k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import DTYPE, Init, _normal, rmsnorm


# ---------------------------------------------------------------------------
# Mamba2 / SSD
# ---------------------------------------------------------------------------

MAMBA_HEADDIM = 64
MAMBA_CONV = 4


def mamba_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // MAMBA_HEADDIM
    return d_inner, n_heads, cfg.ssm_state


def init_mamba2(cfg: ArchConfig, ini: Init):
    d, (d_inner, h, n) = cfg.d_model, mamba_dims(cfg)
    return {
        "in_proj": ini.dense(d, 2 * d_inner + 2 * n + h),
        "conv_w": _normal(ini.take(), (MAMBA_CONV, d_inner + 2 * n), 0.5),
        "A_log": jnp.zeros((h,), DTYPE),
        "dt_bias": jnp.zeros((h,), DTYPE),
        "D": jnp.ones((h,), DTYPE),
        "gate_norm": jnp.ones((d_inner,), DTYPE),
        "out_proj": ini.dense(d_inner, d),
    }


def _causal_conv(x, w):
    """Depthwise causal conv, x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i]
    return out


def chunked_linear_attention(q, k, v, decay, chunk):
    """Chunkwise gated linear attention / SSD (Mamba-2 arXiv:2405.21060 §6;
    same dataflow as GLA/mLSTM):

        S_t = a_t * S_{t-1} + k_t v_t^T ;   y_t = q_t . S_t

    q/k: (B,S,N) shared across heads (SSD's B/C) or (B,S,H,N) per head
    (mLSTM); v: (B,S,H,P); decay: (B,S,H) in (0,1]. Returns (B,S,H,P).

    Intra-chunk work is dense masked matmuls (MXU), inter-chunk is a scan
    over S/chunk steps — the TPU-native formulation of the recurrence.
    """
    b, s, h, p = v.shape
    per_head = q.ndim == 4
    n = q.shape[-1]
    nc = s // chunk
    vc = v.reshape(b, nc, chunk, h, p)
    a = decay.reshape(b, nc, chunk, h).astype(jnp.float32)
    qc = q.reshape((b, nc, chunk, h, n) if per_head else (b, nc, chunk, n))
    kc = k.reshape((b, nc, chunk, h, n) if per_head else (b, nc, chunk, n))

    log_a = jnp.log(jnp.maximum(a, 1e-20))
    cum = jnp.cumsum(log_a, axis=2)                       # (b,nc,L,h)

    # intra-chunk: M[i,j,h] = q_i.k_j * exp(cum_i - cum_j), j <= i
    if per_head:
        scores = jnp.einsum("bcihn,bcjhn->bcijh", qc, kc)
    else:
        scores = jnp.einsum("bcin,bcjn->bcij", qc, kc)[..., None]
    pair = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    )                                                     # (b,nc,i,j,h)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])[
        None, None, :, :, None
    ]
    cdtype = vc.dtype
    w = (scores * pair * mask).astype(cdtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, vc)

    # per-chunk outgoing state: S_c = sum_j exp(cum_L - cum_j) k_j v_j^T
    tail = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0)).astype(cdtype)
    if per_head:
        states = jnp.einsum("bcjhn,bcjh,bcjhp->bchnp", kc, tail, vc)
    else:
        states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", kc, tail, vc)

    # inter-chunk recurrence (sequential over nc)
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0)).astype(cdtype)

    def scan_fn(carry, inp):
        st, dec = inp                                     # (b,h,n,p), (b,h)
        new = (carry * dec[:, :, None, None] + st).astype(cdtype)
        return new, carry                                 # emit state BEFORE chunk

    init = jnp.zeros((b, h, n, p), cdtype)
    _, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (b,nc,h,n,p)

    into = jnp.exp(jnp.clip(cum, -60.0, 0.0)).astype(cdtype)
    if per_head:
        y_inter = jnp.einsum("bcihn,bcih,bchnp->bcihp", qc, into, prev_states)
    else:
        y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", qc, into, prev_states)
    return (y_intra + y_inter).reshape(b, s, h, p)


def mamba2_forward(cfg: ArchConfig, p, x):
    """x (B,S,d) -> (B,S,d)."""
    b, s, _ = x.shape
    d_inner, h, n = mamba_dims(cfg)
    zxbcdt = x @ p["in_proj"]["w"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"]))
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(A[None, None, :] * dt)                    # (B,S,H) decay
    xh = (xin * dt.repeat(MAMBA_HEADDIM, axis=-1).astype(DTYPE)).reshape(
        b, s, h, MAMBA_HEADDIM
    )
    y = chunked_linear_attention(Cc, Bc, xh, a, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"]["w"]


def mamba2_decode(cfg: ArchConfig, p, x, state, conv_state):
    """One-token decode. state (B,H,N,P) f32; conv_state (B,K-1,C)."""
    b = x.shape[0]
    d_inner, h, n = mamba_dims(cfg)
    zxbcdt = x[:, 0] @ p["in_proj"]["w"]
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)     # (B,C)
    window = jnp.concatenate([conv_state, conv_in[:, None]], axis=1)  # (B,K,C)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]))
    new_conv_state = window[:, 1:]
    xin, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(A[None, :] * dt)                          # (B,H)
    xh = (xin * dt.repeat(MAMBA_HEADDIM, axis=-1).astype(DTYPE)).reshape(
        b, h, MAMBA_HEADDIM
    )
    new_state = state * a[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bc.astype(jnp.float32), xh.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhnp->bhp", Cc.astype(jnp.float32), new_state).astype(DTYPE)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(b, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return (y @ p["out_proj"]["w"])[:, None], new_state, new_conv_state


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) + sLSTM (scalar recurrent)
# ---------------------------------------------------------------------------

def xlstm_dims(cfg: ArchConfig):
    d_inner = 2 * cfg.d_model
    h = cfg.n_heads
    dv = d_inner // h
    dqk = dv // 2
    return d_inner, h, dqk, dv


def init_mlstm(cfg: ArchConfig, ini: Init):
    d, (d_inner, h, dqk, dv) = cfg.d_model, xlstm_dims(cfg)
    return {
        "up_proj": ini.dense(d, 2 * d_inner),
        "wq": ini.dense(d_inner, h * dqk),
        "wk": ini.dense(d_inner, h * dqk),
        "wv": ini.dense(d_inner, h * dv),
        "w_gates": ini.dense(d_inner, 2 * h, scale=0.02),
        "out_norm": jnp.ones((d_inner,), DTYPE),
        "down_proj": ini.dense(d_inner, d),
    }


def mlstm_forward(cfg: ArchConfig, p, x):
    """Chunkwise mLSTM: C_t = f_t C_{t-1} + i_t v_t k_t^T; y_t = C_t q_t."""
    b, s, _ = x.shape
    d_inner, h, dqk, dv = xlstm_dims(cfg)
    up = x @ p["up_proj"]["w"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"]["w"]).reshape(b, s, h, dqk) * dqk**-0.5
    k = (u @ p["wk"]["w"]).reshape(b, s, h, dqk)
    v = (u @ p["wv"]["w"]).reshape(b, s, h, dv)
    gates = u @ p["w_gates"]["w"]
    f = jax.nn.sigmoid(gates[..., :h].astype(jnp.float32) + 4.0)   # forget
    i = jax.nn.sigmoid(gates[..., h:].astype(jnp.float32))         # input

    # normalizer trick: append a ones-column to v so one pass yields both
    # numerator (dv cols) and q·n_t (last col)
    iv = i[..., None].astype(DTYPE)
    v_aug = jnp.concatenate([v * iv, jnp.broadcast_to(iv, (b, s, h, 1))], axis=-1)
    out = chunked_linear_attention(q, k, v_aug, f, cfg.ssm_chunk)
    num, qn = out[..., :dv], out[..., dv]
    den = jnp.maximum(jnp.abs(qn.astype(jnp.float32)), 1.0)
    y = (num.astype(jnp.float32) / den[..., None]).astype(DTYPE)
    y = y.reshape(b, s, d_inner)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return y @ p["down_proj"]["w"]


def init_slstm(cfg: ArchConfig, ini: Init):
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    return {
        "w_in": ini.dense(d, 4 * d),       # i,f,z,o pre-activations
        "r": _normal(ini.take(), (h, dh, 4 * dh), dh**-0.5),  # recurrent (block-diag)
        "out_norm": jnp.ones((d,), DTYPE),
        "proj": ini.dense(d, d),
    }


def slstm_forward(cfg: ArchConfig, p, x):
    """sLSTM: scalar-memory LSTM with head-blocked recurrent weights —
    genuinely sequential (lax.scan over time)."""
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    pre_all = (x @ p["w_in"]["w"]).reshape(b, s, h, 4 * dh)

    def step(carry, pre_t):
        c, hidden = carry                              # (B,h,dh) each
        rec = jnp.einsum("bhd,hdk->bhk", hidden, p["r"])
        z4 = (pre_t + rec).astype(jnp.float32)
        ig, fg, zg, og = jnp.split(z4, 4, axis=-1)
        c = jax.nn.sigmoid(fg + 4.0) * c + jax.nn.sigmoid(ig) * jnp.tanh(zg)
        hidden = (jax.nn.sigmoid(og) * jnp.tanh(c)).astype(DTYPE)
        return (c, hidden), hidden

    init = (
        jnp.zeros((b, h, dh), jnp.float32),
        jnp.zeros((b, h, dh), DTYPE),
    )
    _, ys = jax.lax.scan(step, init, pre_all.transpose(1, 0, 2, 3))
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    return rmsnorm(y, p["out_norm"], cfg.norm_eps) @ p["proj"]["w"]


def slstm_decode(cfg: ArchConfig, p, x, c, hidden):
    b = x.shape[0]
    h = cfg.n_heads
    dh = cfg.d_model // h
    pre = (x[:, 0] @ p["w_in"]["w"]).reshape(b, h, 4 * dh)
    rec = jnp.einsum("bhd,hdk->bhk", hidden, p["r"])
    z4 = (pre + rec).astype(jnp.float32)
    ig, fg, zg, og = jnp.split(z4, 4, axis=-1)
    c = jax.nn.sigmoid(fg + 4.0) * c + jax.nn.sigmoid(ig) * jnp.tanh(zg)
    hidden = (jax.nn.sigmoid(og) * jnp.tanh(c)).astype(DTYPE)
    y = rmsnorm(hidden.reshape(b, cfg.d_model), p["out_norm"], cfg.norm_eps)
    return (y @ p["proj"]["w"])[:, None], c, hidden


def mlstm_decode(cfg: ArchConfig, p, x, C, norm_n):
    """One-token mLSTM decode; C (B,H,dqk,dv) f32, norm_n (B,H,dqk) f32."""
    b = x.shape[0]
    d_inner, h, dqk, dv = xlstm_dims(cfg)
    up = x[:, 0] @ p["up_proj"]["w"]
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"]["w"]).reshape(b, h, dqk) * dqk**-0.5
    k = (u @ p["wk"]["w"]).reshape(b, h, dqk)
    v = (u @ p["wv"]["w"]).reshape(b, h, dv)
    gates = u @ p["w_gates"]["w"]
    f = jax.nn.sigmoid(gates[..., :h].astype(jnp.float32) + 4.0)
    i = jax.nn.sigmoid(gates[..., h:].astype(jnp.float32))
    C = C * f[..., None, None] + i[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    norm_n = norm_n * f[..., None] + i[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), norm_n)), 1.0
    )
    y = (num / den[..., None]).astype(DTYPE).reshape(b, d_inner)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    return (y @ p["down_proj"]["w"])[:, None], C, norm_n
