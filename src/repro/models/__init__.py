from repro.models.lm import build_model

__all__ = ["build_model"]
