"""Model assembly for the 10 assigned architectures.

Families:
  dense / moe  — decoder-only transformer (GQA or MLA attention, SwiGLU or
                 expert MLP), scan-over-layers.
  hybrid       — Zamba2: Mamba2 backbone + ONE shared attention+MLP block
                 applied every ``attn_every`` blocks (own KV per application).
  ssm          — xLSTM: mLSTM blocks with an sLSTM block every
                 ``slstm_every``.
  encdec       — Whisper: bidirectional encoder over stub frame embeddings +
                 causal decoder with cross-attention.
  vlm          — InternVL2: LM backbone consuming stub patch embeddings
                 prepended to the token sequence.

All params are pure pytrees; layers are stacked on a leading axis and run
under ``lax.scan`` (keeps HLO size O(1) in depth — essential for the 40-cell
dry-run). ``build_model`` returns a :class:`Model` facade exposing init /
loss / decode / cache / input_specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.layers import DTYPE, Init


def _scan(body, x, xs, unroll: bool = False):
    """lax.scan, or a python-unrolled equivalent when ``unroll`` is set.

    The dry-run compiles small unrolled depths to recover true per-layer
    FLOPs/bytes (XLA cost_analysis counts a while-loop body once)."""
    if not unroll:
        return jax.lax.scan(body, x, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x, y = body(x, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return x, ys


# ---------------------------------------------------------------------------
# Transformer blocks (dense / moe, GQA / MLA)
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, ini: Init, kind: str):
    p = {"ln1": jnp.ones((cfg.d_model,), DTYPE), "ln2": jnp.ones((cfg.d_model,), DTYPE)}
    p["attn"] = L.init_mla(cfg, ini) if cfg.use_mla else L.init_gqa(cfg, ini)
    if kind == "moe":
        p["moe"] = L.init_moe(cfg, ini)
    else:
        d_ff = cfg.dense_d_ff if kind == "dense_first" and cfg.dense_d_ff else cfg.d_ff
        p["mlp"] = L.init_mlp(cfg.d_model, d_ff, ini)
    return p


def _block_fwd(cfg: ArchConfig, p, x, positions, kind: str, window=0):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        x = x + L.mla_attention(cfg, p["attn"], h, positions)
    else:
        x = x + L.gqa_attention(cfg, p["attn"], h, positions, window=window)
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        x = x + L.moe(cfg, p["moe"], h)
    else:
        x = x + L.mlp(p["mlp"], h)
    return x


def _block_decode(cfg: ArchConfig, p, x, cache, pos, kind: str):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    if cfg.use_mla:
        a, ckv, kr = L.mla_decode(cfg, p["attn"], h, cache["ckv"], cache["krope"], pos)
        new_cache = {"ckv": ckv, "krope": kr}
    else:
        a, k, v = L.gqa_decode(cfg, p["attn"], h, cache["k"], cache["v"], pos)
        new_cache = {"k": k, "v": v}
    x = x + a
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + (L.moe(cfg, p["moe"], h) if kind == "moe" else L.mlp(p["mlp"], h))
    return x, new_cache


def _attn_cache_struct(cfg: ArchConfig, b, s):
    if cfg.use_mla:
        return {
            "ckv": jnp.zeros((b, s, cfg.kv_lora), DTYPE),
            "krope": jnp.zeros((b, s, cfg.rope_head_dim), DTYPE),
        }
    dh = cfg.head_dim
    return {
        "k": jnp.zeros((b, s, cfg.n_kv_heads, dh), DTYPE),
        "v": jnp.zeros((b, s, cfg.n_kv_heads, dh), DTYPE),
    }


# ---------------------------------------------------------------------------
# Decoder-only LM (dense, moe, vlm backbones share this)
# ---------------------------------------------------------------------------

class DecoderLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.kind = "moe" if cfg.family == "moe" else "dense"
        self.n_scan = cfg.n_layers - cfg.first_dense_layers

    def init(self, rng):
        cfg = self.cfg
        ini = Init(rng)
        params = {
            "embed": L._normal(ini.take(), (cfg.vocab, cfg.d_model), 0.02),
            "layers": ini.stack(self.n_scan, lambda: _init_block(cfg, ini, self.kind)),
            "ln_f": jnp.ones((cfg.d_model,), DTYPE),
            "unembed": L._normal(ini.take(), (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5),
        }
        if cfg.first_dense_layers:
            params["first"] = ini.stack(
                cfg.first_dense_layers, lambda: _init_block(cfg, ini, "dense_first")
            )
        if cfg.family == "vlm":
            params["patch_proj"] = ini.dense(cfg.d_model, cfg.d_model)
        return params

    def _backbone(self, params, x, positions):
        cfg = self.cfg

        if cfg.first_dense_layers:
            def fbody(h, lp):
                return _block_fwd(cfg, lp, h, positions, "dense_first"), None
            if cfg.remat:
                fbody = jax.checkpoint(fbody)
            x, _ = _scan(fbody, x, params["first"], cfg.unroll)

        def body(h, lp):
            return _block_fwd(cfg, lp, h, positions, self.kind), None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = _scan(body, x, params["layers"], cfg.unroll)
        return L.rmsnorm(x, params["ln_f"], cfg.norm_eps)

    def forward(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        if patch_embeds is not None:
            pe = patch_embeds.astype(DTYPE) @ params["patch_proj"]["w"]
            x = jnp.concatenate([pe, x], axis=1)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        x = self._backbone(params, x, positions)
        return x @ params["unembed"]

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"], batch.get("patch_embeds"))
        if "patch_embeds" in batch:
            logits = logits[:, batch["patch_embeds"].shape[1] :]
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], self.cfg.vocab)

    # -- decode -----------------------------------------------------------
    def init_cache(self, b, s):
        cfg = self.cfg
        cache = {
            "layers": jax.tree.map(
                lambda x: jnp.zeros((self.n_scan,) + x.shape, x.dtype),
                _attn_cache_struct(cfg, b, s),
            )
        }
        if cfg.first_dense_layers:
            cache["first"] = jax.tree.map(
                lambda x: jnp.zeros((cfg.first_dense_layers,) + x.shape, x.dtype),
                _attn_cache_struct(cfg, b, s),
            )
        return cache

    def decode_step(self, params, cache, token, pos):
        """token (B,1) int32; pos () int32. Returns (logits (B,1,V), cache)."""
        cfg = self.cfg
        x = params["embed"][token]

        new_cache = {}
        if cfg.first_dense_layers:
            def fbody(h, xs):
                lp, c = xs
                h, nc = _block_decode(cfg, lp, h, c, pos, "dense_first")
                return h, nc
            x, new_cache["first"] = _scan(
                fbody, x, (params["first"], cache["first"])
            , cfg.unroll)

        def body(h, xs):
            lp, c = xs
            h, nc = _block_decode(cfg, lp, h, c, pos, self.kind)
            return h, nc

        x, new_cache["layers"] = _scan(
            body, x, (params["layers"], cache["layers"])
        , cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"], new_cache


# ---------------------------------------------------------------------------
# Zamba2-style hybrid
# ---------------------------------------------------------------------------

class HybridLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.n_layers % cfg.attn_every == 0
        self.n_super = cfg.n_layers // cfg.attn_every

    def init(self, rng):
        cfg = self.cfg
        ini = Init(rng)

        def super_block():
            return {
                "mamba": ini.stack(
                    cfg.attn_every, lambda: {"ln": jnp.ones((cfg.d_model,), DTYPE),
                                             "m": S.init_mamba2(cfg, ini)}
                )
            }

        return {
            "embed": L._normal(ini.take(), (cfg.vocab, cfg.d_model), 0.02),
            "blocks": ini.stack(self.n_super, super_block),
            "shared": _init_block(cfg, ini, "dense"),   # ONE shared attn+MLP
            "ln_f": jnp.ones((cfg.d_model,), DTYPE),
            "unembed": L._normal(ini.take(), (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5),
        }

    def forward(self, params, tokens, window=0):
        cfg = self.cfg
        x = params["embed"][tokens]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def super_body(h, sp):
            def mbody(hh, mp):
                return hh + S.mamba2_forward(cfg, mp["m"], L.rmsnorm(hh, mp["ln"], cfg.norm_eps)), None
            h, _ = _scan(mbody, h, sp["mamba"], cfg.unroll)
            h = _block_fwd(cfg, params["shared"], h, positions, "dense", window=window)
            return h, None

        body = jax.checkpoint(super_body) if cfg.remat else super_body
        x, _ = _scan(body, x, params["blocks"], cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"]

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], self.cfg.vocab)

    def init_cache(self, b, s):
        cfg = self.cfg
        d_inner, h, n = S.mamba_dims(cfg)
        s_attn = min(s, cfg.sliding_window_long) if s > 65536 else s
        return {
            "ssm": jnp.zeros(
                (self.n_super, cfg.attn_every, b, h, n, S.MAMBA_HEADDIM), jnp.float32
            ),
            "conv": jnp.zeros(
                (self.n_super, cfg.attn_every, b, S.MAMBA_CONV - 1, d_inner + 2 * n),
                DTYPE,
            ),
            "attn": jax.tree.map(
                lambda x: jnp.zeros((self.n_super,) + x.shape, x.dtype),
                _attn_cache_struct(cfg, b, s_attn),
            ),
        }

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = params["embed"][token]
        s_attn = cache["attn"]["k"].shape[2]
        attn_pos = jnp.minimum(pos, s_attn - 1)  # ring-buffer clamp for window

        def super_body(h, xs):
            sp, ssm_c, conv_c, attn_c = xs

            def mbody(hh, ms):
                mp, st, cv = ms
                y, st2, cv2 = S.mamba2_decode(
                    cfg, mp["m"], L.rmsnorm(hh, mp["ln"], cfg.norm_eps), st, cv
                )
                return hh + y, (st2, cv2)

            h, (ssm2, conv2) = _scan(mbody, h, (sp["mamba"], ssm_c, conv_c), cfg.unroll)
            h, attn2 = _block_decode(cfg, params["shared"], h, attn_c, attn_pos, "dense")
            return h, (ssm2, conv2, attn2)

        x, (ssm2, conv2, attn2) = _scan(
            super_body, x, (params["blocks"], cache["ssm"], cache["conv"], cache["attn"])
        , cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"], {"ssm": ssm2, "conv": conv2, "attn": attn2}


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------

class XLSTMLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        assert cfg.n_layers % cfg.slstm_every == 0
        self.n_super = cfg.n_layers // cfg.slstm_every
        self.m_per = cfg.slstm_every - 1

    def init(self, rng):
        cfg = self.cfg
        ini = Init(rng)

        def super_block():
            return {
                "mlstm": ini.stack(
                    self.m_per,
                    lambda: {"ln": jnp.ones((cfg.d_model,), DTYPE),
                             "m": S.init_mlstm(cfg, ini)},
                ),
                "sln": jnp.ones((cfg.d_model,), DTYPE),
                "slstm": S.init_slstm(cfg, ini),
            }

        return {
            "embed": L._normal(ini.take(), (cfg.vocab, cfg.d_model), 0.02),
            "blocks": ini.stack(self.n_super, super_block),
            "ln_f": jnp.ones((cfg.d_model,), DTYPE),
            "unembed": L._normal(ini.take(), (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5),
        }

    def forward(self, params, tokens):
        cfg = self.cfg
        x = params["embed"][tokens]

        def super_body(h, sp):
            def mbody(hh, mp):
                return hh + S.mlstm_forward(cfg, mp["m"], L.rmsnorm(hh, mp["ln"], cfg.norm_eps)), None
            h, _ = _scan(mbody, h, sp["mlstm"], cfg.unroll)
            h = h + S.slstm_forward(cfg, sp["slstm"], L.rmsnorm(h, sp["sln"], cfg.norm_eps))
            return h, None

        body = jax.checkpoint(super_body) if cfg.remat else super_body
        x, _ = _scan(body, x, params["blocks"], cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"]

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], self.cfg.vocab)

    def init_cache(self, b, s):
        cfg = self.cfg
        del s  # state is O(1) in sequence length
        d_inner, h, dqk, dv = S.xlstm_dims(cfg)
        dh = cfg.d_model // cfg.n_heads
        return {
            "mC": jnp.zeros((self.n_super, self.m_per, b, h, dqk, dv), jnp.float32),
            "mN": jnp.zeros((self.n_super, self.m_per, b, h, dqk), jnp.float32),
            "sc": jnp.zeros((self.n_super, b, cfg.n_heads, dh), jnp.float32),
            "sh": jnp.zeros((self.n_super, b, cfg.n_heads, dh), DTYPE),
        }

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        del pos
        x = params["embed"][token]

        def super_body(h, xs):
            sp, mC, mN, sc, sh = xs

            def mbody(hh, ms):
                mp, C, N = ms
                y, C2, N2 = S.mlstm_decode(
                    cfg, mp["m"], L.rmsnorm(hh, mp["ln"], cfg.norm_eps), C, N
                )
                return hh + y, (C2, N2)

            h, (mC2, mN2) = _scan(mbody, h, (sp["mlstm"], mC, mN), cfg.unroll)
            y, sc2, sh2 = S.slstm_decode(
                cfg, sp["slstm"], L.rmsnorm(h, sp["sln"], cfg.norm_eps), sc, sh
            )
            return h + y, (mC2, mN2, sc2, sh2)

        x, (mC, mN, sc, sh) = _scan(
            super_body, x, (params["blocks"], cache["mC"], cache["mN"], cache["sc"], cache["sh"])
        , cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"], {"mC": mC, "mN": mN, "sc": sc, "sh": sh}


# ---------------------------------------------------------------------------
# Whisper enc-dec
# ---------------------------------------------------------------------------

class EncDecLM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def init(self, rng):
        cfg = self.cfg
        ini = Init(rng)

        def enc_block():
            return {
                "ln1": jnp.ones((cfg.d_model,), DTYPE),
                "attn": L.init_gqa(cfg, ini),
                "ln2": jnp.ones((cfg.d_model,), DTYPE),
                "mlp": L.init_mlp(cfg.d_model, cfg.d_ff, ini),
            }

        def dec_block():
            return {
                "ln1": jnp.ones((cfg.d_model,), DTYPE),
                "self_attn": L.init_gqa(cfg, ini),
                "lnx": jnp.ones((cfg.d_model,), DTYPE),
                "cross_q": ini.dense(cfg.d_model, cfg.n_heads * cfg.head_dim),
                "cross_k": ini.dense(cfg.d_model, cfg.n_kv_heads * cfg.head_dim),
                "cross_v": ini.dense(cfg.d_model, cfg.n_kv_heads * cfg.head_dim),
                "cross_o": ini.dense(cfg.n_heads * cfg.head_dim, cfg.d_model),
                "ln2": jnp.ones((cfg.d_model,), DTYPE),
                "mlp": L.init_mlp(cfg.d_model, cfg.d_ff, ini),
            }

        return {
            "enc_pos": L._normal(ini.take(), (cfg.encoder_seq, cfg.d_model), 0.02),
            "enc_layers": ini.stack(cfg.encoder_layers, enc_block),
            "enc_ln": jnp.ones((cfg.d_model,), DTYPE),
            "embed": L._normal(ini.take(), (cfg.vocab, cfg.d_model), 0.02),
            "dec_layers": ini.stack(cfg.n_layers, dec_block),
            "ln_f": jnp.ones((cfg.d_model,), DTYPE),
            "unembed": L._normal(ini.take(), (cfg.d_model, cfg.vocab), cfg.d_model ** -0.5),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(DTYPE) + params["enc_pos"][None]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(h, lp):
            hh = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            h = h + L.gqa_attention(cfg, lp["attn"], hh, positions, causal=False)
            hh = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            return h + L.mlp(lp["mlp"], hh), None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = _scan(body, x, params["enc_layers"], cfg.unroll)
        return L.rmsnorm(x, params["enc_ln"], cfg.norm_eps)

    def _cross_attn(self, lp, x, enc):
        cfg = self.cfg
        b, s, _ = x.shape
        se = enc.shape[1]
        dh = cfg.head_dim
        q = (x @ lp["cross_q"]["w"]).reshape(b, s, cfg.n_heads, dh)
        k = (enc @ lp["cross_k"]["w"]).reshape(b, se, cfg.n_kv_heads, dh)
        v = (enc @ lp["cross_v"]["w"]).reshape(b, se, cfg.n_kv_heads, dh)
        g = cfg.n_heads // cfg.n_kv_heads
        q = q.reshape(b, s, cfg.n_kv_heads, g, dh)
        sc = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * dh**-0.5
        w = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v).reshape(b, s, cfg.n_heads * dh)
        return o @ lp["cross_o"]["w"]

    def forward(self, params, tokens, frames):
        cfg = self.cfg
        enc = self.encode(params, frames)
        x = params["embed"][tokens]
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(h, lp):
            hh = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            h = h + L.gqa_attention(cfg, lp["self_attn"], hh, positions)
            hh = L.rmsnorm(h, lp["lnx"], cfg.norm_eps)
            h = h + self._cross_attn(lp, hh, enc)
            hh = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            return h + L.mlp(lp["mlp"], hh), None

        body = jax.checkpoint(body) if cfg.remat else body
        x, _ = _scan(body, x, params["dec_layers"], cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        return x @ params["unembed"]

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"], batch["frames"])
        return L.cross_entropy(logits[:, :-1], batch["labels"][:, 1:], self.cfg.vocab)

    def init_cache(self, b, s):
        cfg = self.cfg
        dh = cfg.head_dim
        return {
            "self": jax.tree.map(
                lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype),
                _attn_cache_struct(cfg, b, s),
            ),
            # cross K/V precomputed at prefill from the encoder output
            "cross_k": jnp.zeros((cfg.n_layers, b, cfg.encoder_seq, cfg.n_kv_heads, dh), DTYPE),
            "cross_v": jnp.zeros((cfg.n_layers, b, cfg.encoder_seq, cfg.n_kv_heads, dh), DTYPE),
        }

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = params["embed"][token]
        dh = cfg.head_dim
        b = token.shape[0]

        def body(h, xs):
            lp, c, ck, cv = xs
            hh = L.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            a, k2, v2 = L.gqa_decode(cfg, lp["self_attn"], hh, c["k"], c["v"], pos)
            h = h + a
            hh = L.rmsnorm(h, lp["lnx"], cfg.norm_eps)
            q = (hh @ lp["cross_q"]["w"]).reshape(b, cfg.n_kv_heads,
                                                  cfg.n_heads // cfg.n_kv_heads, dh)
            sc = jnp.einsum("bhgd,bkhd->bhgk", q, ck).astype(jnp.float32) * dh**-0.5
            w = jax.nn.softmax(sc, axis=-1).astype(h.dtype)
            o = jnp.einsum("bhgk,bkhd->bhgd", w, cv).reshape(b, 1, cfg.n_heads * dh)
            h = h + o @ lp["cross_o"]["w"]
            hh = L.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            return h + L.mlp(lp["mlp"], hh), {"k": k2, "v": v2}

        x, new_self = _scan(
            body, x, (params["dec_layers"], cache["self"], cache["cross_k"], cache["cross_v"])
        , cfg.unroll)
        x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
        cache = dict(cache)
        cache["self"] = new_self
        return x @ params["unembed"], cache


# ---------------------------------------------------------------------------
# Facade
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    impl: Any

    def init(self, rng):
        return self.impl.init(rng)

    def init_shapes(self, rng):
        """Param ShapeDtypeStructs without allocation (for the dry-run)."""
        return jax.eval_shape(self.impl.init, rng)

    def loss(self, params, batch):
        return self.impl.loss(params, batch)

    def decode_step(self, params, cache, token, pos):
        return self.impl.decode_step(params, cache, token, pos)

    def init_cache(self, b, s):
        return self.impl.init_cache(b, s)

    def cache_shapes(self, b, s):
        return jax.eval_shape(lambda: self.impl.init_cache(b, s))

    # -- input specs per assigned shape ------------------------------------
    def train_inputs(self, shape: ShapeConfig):
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        batch = {
            "tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32),
        }
        if cfg.family == "vlm":
            batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), DTYPE)
        if cfg.family == "encdec":
            batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), DTYPE)
        return batch

    def decode_inputs(self, shape: ShapeConfig):
        b, s = shape.global_batch, shape.seq_len
        sds = jax.ShapeDtypeStruct
        return {
            "token": sds((b, 1), jnp.int32),
            "pos": sds((), jnp.int32),
            "cache": self.cache_shapes(b, s),
        }

    def make_batch(self, shape: ShapeConfig, rng):
        """Real (small) batch for smoke tests."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        k1, k2 = jax.random.split(rng)
        batch = {
            "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab, dtype=jnp.int32),
        }
        batch["labels"] = batch["tokens"]
        if cfg.family == "vlm":
            batch["patch_embeds"] = jax.random.normal(
                k2, (b, cfg.n_patches, cfg.d_model), DTYPE
            )
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                k2, (b, cfg.encoder_seq, cfg.d_model), DTYPE
            )
        return batch


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        impl = DecoderLM(cfg)
    elif cfg.family == "hybrid":
        impl = HybridLM(cfg)
    elif cfg.family == "ssm":
        impl = XLSTMLM(cfg)
    elif cfg.family == "encdec":
        impl = EncDecLM(cfg)
    else:
        raise ValueError(cfg.family)
    return Model(cfg=cfg, impl=impl)
