"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 [--reduced] [--ckpt-dir ckpt/]

On the container this runs reduced configs on CPU; on a real cluster the
same entrypoint runs the full config on the production mesh (mesh axes and
shardings come from launch.mesh + models.layers.spec rules; multi-host
initialisation would go through jax.distributed.initialize, keyed off the
TPU_WORKER_* env, before building the mesh).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.models import build_model
from repro.training.data import DataConfig, global_batch
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    dc = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch, kind="train")

    def batches():
        for s in range(args.steps):
            b = global_batch(dc, s)
            if cfg.family == "vlm" or cfg.family == "encdec":
                extra = model.make_batch(shape, jax.random.PRNGKey(s))
                for k in ("patch_embeds", "frames"):
                    if k in extra:
                        b[k] = extra[k]
            yield b

    loop = TrainLoop(
        model,
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
    )
    _, _, hist = loop.run(params, batches())
    for h in hist:
        if h["step"] % args.log_every == 0 or h["step"] == hist[-1]["step"]:
            flag = " STRAGGLER" if h["straggler"] else ""
            print(f"step {h['step']:5d} loss {h['loss']:.4f} "
                  f"({h['time_s']*1e3:.0f} ms){flag}", flush=True)
    print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} steps")


if __name__ == "__main__":
    main()
