"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A function, never a module-level constant: importing this module must not
touch jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel (batch / FSDP) axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"
