import os
os.environ["XLA_FLAGS"] = os.environ.get("REPRO_DRYRUN_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x shape) cell and mesh, AOT-lower and compile the
real step program (train_step for train shapes, forward for prefill,
decode_step for decode shapes) against ShapeDtypeStruct inputs on the
production mesh, then record memory_analysis / cost_analysis / collective
bytes for §Dry-run and §Roofline. No arrays are ever allocated.

Run:  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--multi-pod] [--all]
Results accumulate in benchmarks/dryrun_results.json (incremental cache).
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, SHAPE_BY_NAME, cell_is_runnable
from repro.configs.registry import ARCHS, get_arch
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import build_model
from repro.models.layers import build_param_specs
from repro.roofline import analysis
from repro.training.optimizer import AdamWConfig, init_opt_state, opt_state_specs
from repro.training.train_step import make_train_step

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "../../../benchmarks/dryrun_results.json")
RESULTS_PATH = os.path.normpath(RESULTS_PATH)


# ---------------------------------------------------------------------------
# Sharding assignment
# ---------------------------------------------------------------------------

def _axis_size(mesh, axes):
    s = 1
    for a in axes if isinstance(axes, tuple) else (axes,):
        s *= mesh.shape[a]
    return s


def batch_specs(batch_struct, mesh):
    dp = dp_axes(mesh)
    dps = _axis_size(mesh, dp)

    def rule(leaf):
        spec = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and leaf.shape[0] % dps == 0 and leaf.shape[0] > 1:
            spec[0] = dp
        return P(*spec)

    return jax.tree.map(rule, batch_struct)


def cache_specs_tree(cache_struct, mesh, batch: int, seq: int):
    """Cache sharding by size matching: batch dim -> dp axes; the cache
    sequence dim -> 'model' (flash-decoding style KV split); fall back to
    sharding the largest divisible trailing dim over 'model'."""
    dp = dp_axes(mesh)
    dps = _axis_size(mesh, dp)
    tps = mesh.shape["model"]

    def rule(leaf):
        shape = leaf.shape
        spec = [None] * len(shape)
        used_tp = False
        bi = next((i for i in range(1, len(shape)) if shape[i] == batch), None)
        if bi is not None and batch % dps == 0 and batch > 1:
            spec[bi] = dp
        si = next(
            (i for i in range(1, len(shape)) if shape[i] == seq and i != bi), None
        )
        if si is not None and seq % tps == 0:
            spec[si] = "model"
            used_tp = True
        if not used_tp:
            # largest trailing dim divisible by tp (e.g. SSM state heads)
            cands = [
                i
                for i in range(1, len(shape))
                if i != bi and spec[i] is None and shape[i] % tps == 0 and shape[i] >= tps
            ]
            if cands:
                best = max(cands, key=lambda i: shape[i])
                spec[best] = "model"
        return P(*spec)

    return jax.tree.map(rule, cache_struct)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------

def _depth_ladder(cfg):
    """(cfg_small1, cfg_small2, units1, units2, units_real) for the affine
    cost extrapolation: XLA cost_analysis counts a lax.scan body ONCE, so
    the full scanned program under-reports per-layer work. We compile two
    small *unrolled* depths and extrapolate cost(L) = a + b*L (verified in
    tests/test_roofline.py)."""
    import dataclasses as dc

    if cfg.family == "hybrid":
        g = cfg.attn_every
    elif cfg.family == "ssm":
        g = cfg.slstm_every
    else:
        g = 1
    base = cfg.first_dense_layers
    l1, l2 = base + g, base + 2 * g
    kw = dict(unroll=True)
    if cfg.family == "encdec":
        c1 = dc.replace(cfg, n_layers=1, encoder_layers=1, **kw)
        c2 = dc.replace(cfg, n_layers=2, encoder_layers=2, **kw)
        return c1, c2, 1, 2, cfg.n_layers
    c1 = dc.replace(cfg, n_layers=l1, **kw)
    c2 = dc.replace(cfg, n_layers=l2, **kw)
    return c1, c2, l1, l2, cfg.n_layers


def _lower_program(cfg, shape, mesh, model=None, act_constraints=True):
    """Build + lower the right step program for (cfg, shape) on mesh.

    ``act_constraints`` toggles the Megatron-style activation sharding
    layout (perf iteration 1); False reproduces the paper-faithful
    weights-only-sharded baseline recorded in dryrun_results_baseline.json.
    """
    import contextlib

    from repro.models.layers import LAYOUT, activation_sharding

    model = model or build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params_struct = model.init_shapes(rng)
    fsdp = dp_axes(mesh)
    layout_token = LAYOUT.set("opt" if act_constraints else "baseline")
    param_specs = build_param_specs(params_struct, mesh, fsdp)
    act_ctx = (
        activation_sharding(fsdp, "model", mesh.shape["model"])
        if act_constraints
        else contextlib.nullcontext()
    )

    with mesh, act_ctx:
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            step_fn = make_train_step(model, opt_cfg)
            opt_struct = jax.eval_shape(init_opt_state, params_struct)
            opt_specs = opt_state_specs(param_specs, params_struct, mesh, fsdp)
            batch_struct = model.train_inputs(shape)
            b_specs = batch_specs(batch_struct, mesh)
            lowered = jax.jit(
                step_fn,
                in_shardings=(named(mesh, param_specs), named(mesh, opt_specs), named(mesh, b_specs)),
                out_shardings=(named(mesh, param_specs), named(mesh, opt_specs), None),
            ).lower(params_struct, opt_struct, batch_struct)
        elif shape.kind == "prefill":
            batch_struct = model.train_inputs(shape)
            b_specs = batch_specs(batch_struct, mesh)

            if cfg.family == "encdec":
                fwd = lambda p, b: model.impl.forward(p, b["tokens"], b["frames"])
            elif cfg.family == "vlm":
                fwd = lambda p, b: model.impl.forward(p, b["tokens"], b["patch_embeds"])
            else:
                fwd = lambda p, b: model.impl.forward(p, b["tokens"])
            lowered = jax.jit(
                fwd,
                in_shardings=(named(mesh, param_specs), named(mesh, b_specs)),
            ).lower(params_struct, batch_struct)
        else:  # decode / long-decode
            dec = model.decode_inputs(shape)
            c_specs = cache_specs_tree(dec["cache"], mesh, shape.global_batch, shape.seq_len)
            tok_spec = batch_specs({"t": dec["token"]}, mesh)["t"]
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(
                    named(mesh, param_specs),
                    named(mesh, c_specs),
                    NamedSharding(mesh, tok_spec),
                    NamedSharding(mesh, P()),
                ),
                out_shardings=(None, named(mesh, c_specs)),
            ).lower(params_struct, dec["cache"], dec["token"], dec["pos"])

    LAYOUT.reset(layout_token)
    return lowered, params_struct


def _raw_costs(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = analysis.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "hbm_bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(coll.values())),
        "coll_by_kind": coll,
    }


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               extrapolate: bool = True, act_constraints: bool = True):
    cfg = get_arch(arch_name)
    shape = SHAPE_BY_NAME[shape_name]
    runnable, why = cell_is_runnable(cfg, shape)
    if not runnable:
        return {"status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))

    # 1) full-depth scanned program: THE compile proof + memory analysis
    t0 = time.time()
    lowered, params_struct = _lower_program(cfg, shape, mesh,
                                            act_constraints=act_constraints)
    compiled = lowered.compile()
    t_compile = time.time() - t0
    full_costs = _raw_costs(compiled)

    # 2) per-layer cost extrapolation from two small unrolled depths
    costs = dict(full_costs)
    extrap = None
    if extrapolate:
        try:
            c1, c2, l1, l2, lreal = _depth_ladder(cfg)
            k1 = _raw_costs(
                _lower_program(c1, shape, mesh, act_constraints=act_constraints)[0].compile()
            )
            k2 = _raw_costs(
                _lower_program(c2, shape, mesh, act_constraints=act_constraints)[0].compile()
            )
            costs = {}
            for key in ("flops", "hbm_bytes", "coll_bytes"):
                slope = (k2[key] - k1[key]) / (l2 - l1)
                costs[key] = k1[key] + slope * (lreal - l1)
            extrap = {"l1": l1, "l2": l2, "lreal": lreal,
                      "c1": {k: k1[k] for k in ("flops", "hbm_bytes", "coll_bytes")},
                      "c2": {k: k2[k] for k in ("flops", "hbm_bytes", "coll_bytes")}}
        except Exception as e:  # fall back to scanned-program numbers
            extrap = {"error": f"{type(e).__name__}: {e}"}
            costs = dict(full_costs)

    mf = analysis.model_flops_for(cfg, shape, params_struct)
    roof = analysis.Roofline(
        flops=costs["flops"],
        hbm_bytes=costs["hbm_bytes"],
        coll_bytes=costs["coll_bytes"],
        chips=chips,
        model_flops=mf,
    )
    counts = analysis.count_params(params_struct)

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
    except Exception:
        pass

    return {
        "status": "ok",
        "arch": arch_name,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "chips": chips,
        "kind": shape.kind,
        "compile_s": round(t_compile, 1),
        "params": counts,
        "memory_analysis": mem,
        "collectives": full_costs["coll_by_kind"],
        "scanned_program_costs": {k: full_costs[k] for k in ("flops", "hbm_bytes", "coll_bytes")},
        "extrapolation": extrap,
        "roofline": roof.to_dict(),
    }


# ---------------------------------------------------------------------------
# Mining-engine dry-run cell (the paper's own workload on the mesh)
# ---------------------------------------------------------------------------

def lower_mining(multi_pod: bool, n_vertices=65536, max_deg=64, frontier=1 << 20, k=5):
    from repro.core.distributed import mining_step_for_dryrun
    from repro.core.graph import DeviceGraph

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    axes = dp_axes(mesh)
    n_shards = _axis_size(mesh, axes)
    sds = jax.ShapeDtypeStruct
    w = (n_vertices + 31) // 32
    g = DeviceGraph(
        labels=sds((n_vertices,), jnp.int32),
        nbr=sds((n_vertices, max_deg), jnp.int32),
        nbr_eid=sds((n_vertices, max_deg), jnp.int32),
        deg=sds((n_vertices,), jnp.int32),
        adj_bits=sds((n_vertices, w), jnp.uint32),
        edge_uv=sds((n_vertices * max_deg // 2, 2), jnp.int32),
        edge_labels=sds((n_vertices * max_deg // 2,), jnp.int32),
    )
    per = frontier // n_shards
    members = sds((n_shards, per, k), jnp.int32)
    n_valid = sds((n_shards, per), jnp.int32)
    quick_dict = sds((512, 3), jnp.int64)

    step = mining_step_for_dryrun(mesh, axes)
    gspec = DeviceGraph(
        labels=P(), nbr=P(), nbr_eid=P(), deg=P(),
        adj_bits=P("model"), edge_uv=P(), edge_labels=P(),
    )
    spec = P(axes)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            step,
            in_shardings=(
                named(mesh, gspec),
                NamedSharding(mesh, spec),
                NamedSharding(mesh, spec),
                NamedSharding(mesh, P()),
            ),
        ).lower(g, members, n_valid, quick_dict)
        compiled = lowered.compile()
    roof = analysis.from_compiled(compiled, chips)
    return {
        "status": "ok",
        "arch": "arabesque-mining-step",
        "shape": f"frontier{frontier}_n{n_vertices}",
        "mesh": list(mesh.devices.shape),
        "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "collectives": analysis.collective_bytes(compiled.as_text()),
        "roofline": roof.to_dict(),
    }


# ---------------------------------------------------------------------------
# Driver with incremental cache
# ---------------------------------------------------------------------------

def load_results():
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_results(res):
    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mining", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-act-constraints", action="store_true",
                    help="paper-faithful weights-only-sharded baseline layout")
    ap.add_argument("--results", default=None,
                    help="alternate results JSON path")
    args = ap.parse_args()
    global RESULTS_PATH
    if args.results:
        RESULTS_PATH = args.results

    results = load_results()
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    if args.mining:
        for mp in meshes:
            key = f"mining|{'multi' if mp else 'single'}"
            if key in results and not args.force:
                continue
            print(f"[dryrun] {key} ...", flush=True)
            try:
                results[key] = lower_mining(mp)
            except Exception as e:
                results[key] = {"status": "error", "error": f"{type(e).__name__}: {e}"}
                traceback.print_exc()
            save_results(results)
        return

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and results[key].get("status") in ("ok", "skipped") and not args.force:
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    results[key] = lower_cell(
                        arch, shape, mp,
                        act_constraints=not args.no_act_constraints,
                    )
                    st = results[key]["status"]
                    if st == "ok":
                        r = results[key]["roofline"]
                        print(
                            f"  ok compile={results[key]['compile_s']}s "
                            f"bottleneck={r['bottleneck']} "
                            f"frac={r['roofline_fraction']:.3f}",
                            flush=True,
                        )
                    else:
                        print(f"  {st}: {results[key].get('reason','')}", flush=True)
                except Exception as e:
                    results[key] = {
                        "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                    }
                    print(f"  ERROR {type(e).__name__}: {e}", flush=True)
                save_results(results)

    n_ok = sum(1 for v in results.values() if v.get("status") == "ok")
    n_err = sum(1 for v in results.values() if v.get("status") == "error")
    print(f"[dryrun] done: {n_ok} ok, {n_err} errors, {len(results)} total cells")


if __name__ == "__main__":
    main()
