"""Serving launcher: batched greedy decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b \
        --batch 4 --prompt-len 16 --gen 32 [--reduced]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_arch
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    total = args.prompt_len + args.gen
    cache = model.init_cache(args.batch, total)
    rng = jax.random.PRNGKey(1)
    prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab)

    step = jax.jit(model.decode_step)
    tok = prompt[:, :1]
    t0 = time.perf_counter()
    out_tokens = []
    for t in range(total - 1):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        if t + 1 < args.prompt_len:
            tok = prompt[:, t + 1 : t + 2]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            if tok.ndim == 3:
                tok = tok[..., 0]
            out_tokens.append(tok)
    dt = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({args.batch * len(out_tokens) / dt:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
