"""The 10 assigned architectures, exact configs from the assignment table."""
from __future__ import annotations

from repro.configs.base import ArchConfig

_A = ArchConfig

ARCHS = {
    "stablelm-1.6b": _A(
        name="stablelm-1.6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=5632, vocab=100352, d_head=64,
        source="hf:stabilityai/stablelm-2-1_6b; unverified",
    ),
    "smollm-135m": _A(
        name="smollm-135m", family="dense",
        n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        d_ff=1536, vocab=49152, d_head=64,
        source="hf:HuggingFaceTB/SmolLM-135M; hf",
    ),
    "qwen2.5-14b": _A(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, d_head=128, qkv_bias=True,
        source="hf:Qwen/Qwen2.5-0.5B; hf",
    ),
    "yi-34b": _A(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000, d_head=128,
        source="arXiv:2403.04652; hf",
    ),
    "deepseek-v2-236b": _A(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab=102400,
        n_experts=160, top_k=6, n_shared_experts=2,
        first_dense_layers=1, dense_d_ff=12288,
        use_mla=True, kv_lora=512, q_lora=1536,
        rope_head_dim=64, nope_head_dim=128, v_head_dim=128, d_head=192,
        source="arXiv:2405.04434; hf",
    ),
    "llama4-maverick-400b-a17b": _A(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, d_head=128,
        n_experts=128, top_k=1, n_shared_experts=1,
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    ),
    "zamba2-2.7b": _A(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, d_head=80,
        ssm_state=64, attn_every=6,
        source="arXiv:2411.15242; hf",
    ),
    "whisper-base": _A(
        name="whisper-base", family="encdec",
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
        d_ff=2048, vocab=51865, d_head=64,
        encoder_layers=6, encoder_seq=1500,
        source="arXiv:2212.04356; unverified",
    ),
    "xlstm-1.3b": _A(
        name="xlstm-1.3b", family="ssm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, d_head=512,
        slstm_every=8,
        source="arXiv:2405.04517; unverified",
    ),
    "internvl2-26b": _A(
        name="internvl2-26b", family="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=16384, vocab=92553, d_head=128,
        n_patches=256,
        source="arXiv:2404.16821; hf",
    ),
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
