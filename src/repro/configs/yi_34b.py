"""Assigned architecture config: yi-34b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("yi-34b")
