"""Assigned architecture config: stablelm-1.6b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("stablelm-1.6b")
