"""Assigned architecture config: llama4-maverick-400b-a17b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("llama4-maverick-400b-a17b")
