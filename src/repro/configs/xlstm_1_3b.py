"""Assigned architecture config: xlstm-1.3b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("xlstm-1.3b")
