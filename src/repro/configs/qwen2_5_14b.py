"""Assigned architecture config: qwen2.5-14b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("qwen2.5-14b")
