"""Assigned architecture config: internvl2-26b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("internvl2-26b")
