"""Assigned architecture config: whisper-base (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("whisper-base")
