"""Assigned architecture config: smollm-135m (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("smollm-135m")
