"""Assigned architecture config: deepseek-v2-236b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("deepseek-v2-236b")
