"""Architecture + shape configuration schema for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0     # leading dense layers (DeepSeek-V2: 1)
    dense_d_ff: int = 0             # d_ff of those dense layers
    capacity_factor: float = 1.25
    # --- MLA (DeepSeek-V2) ---
    use_mla: bool = False
    kv_lora: int = 0
    q_lora: int = 0
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0             # zamba2: shared attn block period
    slstm_every: int = 0            # xlstm: sLSTM block period
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0            # stub frame count after conv frontend
    # --- vlm ---
    n_patches: int = 0              # stub patch-embedding count
    # --- misc ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window_long: int = 4096  # hybrid attn window in long-context mode
    remat: bool = True
    #: unroll layer loops instead of lax.scan — used by the dry-run cost
    #: extrapolation (XLA cost_analysis counts a while body ONCE, so scanned
    #: programs under-report FLOPs by the trip count; see roofline/analysis)
    unroll: bool = False
    source: str = ""                 # provenance per the assignment table

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid-with-window families)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink(x, lo, cap):
            return 0 if x == 0 else max(lo, min(x, cap))

        ratio = max(1, self.n_heads // max(self.n_kv_heads, 1))
        new_kv = max(1, 4 // ratio)
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=new_kv * ratio,
            n_kv_heads=new_kv if self.n_kv_heads else 0,
            d_head=16,
            d_ff=shrink(self.d_ff, 1, 128),
            vocab=256,
            n_experts=shrink(self.n_experts, 4, 8),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            first_dense_layers=min(self.first_dense_layers, 1),
            dense_d_ff=shrink(self.dense_d_ff, 1, 128),
            kv_lora=32 if self.use_mla else 0,
            q_lora=32 if self.q_lora else 0,
            rope_head_dim=8 if self.use_mla else self.rope_head_dim,
            nope_head_dim=16 if self.use_mla else self.nope_head_dim,
            v_head_dim=16 if self.use_mla else self.v_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=16,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            slstm_every=min(self.slstm_every, 2) if self.slstm_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            n_patches=min(self.n_patches, 8) if self.n_patches else 0,
            sliding_window_long=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode | long-decode

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long-decode")


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="long-decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_is_runnable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Which (arch x shape) dry-run cells run vs. skip (DESIGN.md §5)."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, "pure full-attention family: 512k dense decode skipped per assignment"
    if shape.name == "long_500k" and arch.family == "encdec":
        return False, "enc-dec audio family has no 512k decode context"
    return True, ""
