"""Assigned architecture config: zamba2-2.7b (see registry.py for provenance)."""
from repro.configs.registry import get_arch

CONFIG = get_arch("zamba2-2.7b")
