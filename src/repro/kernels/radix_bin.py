"""Radix/bucket-partition level-1 binning (`bin_rows` without `lax.sort`).

BENCH_8 was blunt about the device aggregation bin on CPU: `lax.sort`
over the packed 2x`uint64` quick-pattern keys costs ~140 ms of the
~200 ms `bin_rows` spends on a 350k-row batch, and the whole device
path loses wall time to the host `aggregate_rows` there. The culprit is
specific: XLA's *variadic* sort (keys + payload operands) runs a
comparator network ~5x slower than its single-operand sort on CPU
(measured 141 ms vs 26 ms at 350k rows). This module removes the
payload-carrying sort from the bin in both directions:

* ``radix_sort_codes`` — a multi-pass LSB radix sort in Pallas: one
  8-bit digit per pass over the quick-code words (w2, w1, w0, then the
  invalid flag, least-significant first), each pass a block-histogram
  kernel + host-free exclusive scan + a stable scatter kernel whose
  per-digit write cursor is carried across the sequential grid in a
  revisited output window — the same grid-carried-total dataflow as
  ``kernels/compact.py``. Passes whose digit is constant over the batch
  (unlabeled graphs zero both label words) are skipped with `lax.cond`,
  so the common workloads pay for the bits they actually use.

* ``bin_rows_radix`` (jnp route) — a *bucket-partition* fallback built
  on the fast single-operand sort: the three code words are fused into
  ONE `uint64` key at their measured bit-widths (a runtime reduction;
  quick codes use 4 + 28 structure bits plus 8 bits per label, so
  labeled size-3/4 patterns fit comfortably), sorted payload-free, and
  the permutation is never materialised — per-row slots come back from
  a binary-search gather against the sorted keys and counts from
  segment-boundary differences. When the words genuinely need more
  than 63 bits, a `lax.cond` falls back to the 2-key sort path inside
  the same jitted program, so the contract is exact for every input.

Both routes honour ``aggregate.bin_rows``'s exact contract — distinct
codes ascending-lex, unclamped ``n``, per-row ``inv`` unclamped past
``cap`` (-1 invalid), dump-slot overflow sliced off — which is what lets
the cost model (`runtime/costmodel.py`) flip `aggregate_bin` between
"sort" and "radix" without changing a single emitted count.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

#: digit width of one radix pass; 256-entry histograms stay VMEM-trivial.
RADIX_BITS = 8
#: real digit channels per pass ...
NDIGITS = 1 << RADIX_BITS
#: ... plus one reserved channel that only block-padding rows occupy, so
#: pads sort stably after every real row in every pass and never
#: interleave with genuine high-digit codes.
PAD_DIGIT = NDIGITS

#: VMEM budget for the scatter kernel's revisited full-length output
#: window plus the (block, NDIGITS+1) one-hot rank matrix.
VMEM_SORT_LIMIT = 8 * 2**20

#: (word index, shift) per pass, least-significant digit first; word
#: index 3 is the synthesized invalid flag that pushes invalid rows last.
_PASSES = (
    (2, 0), (2, 8), (2, 16), (2, 24),
    (1, 0), (1, 8), (1, 16), (1, 24),
    (0, 0), (0, 8), (0, 16), (0, 24),
    (3, 0),
)

#: kept a Python int (not a jnp constant): module import may happen inside
#: an active jit trace (lazy ``method="radix"`` dispatch), where a
#: module-level jnp op would capture a tracer and leak it across traces.
_SENTINEL = 0xFFFFFFFFFFFFFFFF


def radix_fits_vmem(b: int, block: int) -> bool:
    """True when the scatter kernel's windows fit the VMEM budget: the
    revisited (b,) int32 payload output plus the block's one-hot ranks."""
    return b * 4 + block * (NDIGITS + 1) * 4 <= VMEM_SORT_LIMIT


def _hist_kernel(digits_ref, hist_ref):
    """Per-block digit histogram: one (NDIGITS + 1,) row per grid step."""
    block = digits_ref.shape[0]
    d = digits_ref[...]
    chan = jax.lax.broadcasted_iota(jnp.int32, (block, NDIGITS + 1), 1)
    eq = (d[:, None] == chan).astype(jnp.int32)
    hist_ref[...] = eq.sum(axis=0, dtype=jnp.int32).reshape(1, NDIGITS + 1)


def _scatter_kernel(digits_ref, payload_ref, bases_ref, out_ref, cursor_ref):
    """One stable counting-scatter block: rank every row within its digit
    bucket (exclusive one-hot prefix sum), place it at the carried
    per-digit cursor, then advance the cursor by the block histogram —
    ``cursor_ref`` is the revisited grid-carried total, seeded from the
    global exclusive scan on the first step (compact.py idiom)."""
    i = pl.program_id(0)
    block = digits_ref.shape[0]

    @pl.when(i == 0)
    def _init():
        cursor_ref[...] = bases_ref[...]

    d = digits_ref[...]
    chan = jax.lax.broadcasted_iota(jnp.int32, (block, NDIGITS + 1), 1)
    onehot = (d[:, None] == chan).astype(jnp.int32)
    incl = jnp.cumsum(onehot, axis=0, dtype=jnp.int32)
    # exclusive rank of each row among same-digit rows of this block
    rank = jnp.take_along_axis(incl, d[:, None], axis=1)[:, 0] - 1
    cursor = cursor_ref[...]
    pos = cursor[d] + rank
    out_ref[...] = out_ref[...].at[pos].set(payload_ref[...])
    cursor_ref[...] = cursor + incl[-1, :]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def radix_sort_codes(codes, valid, block: int = 2048, interpret=None):
    """Stable LSB-radix sort of (B, 3) quick-code rows, invalid rows last.

    Same contract as ``aggregate.sort_codes``: returns (sorted codes,
    sorted valid, order). Each 8-bit pass runs only if its digit varies
    over the batch (`lax.cond`), so e.g. unlabeled motifs pay for the
    structure word alone; the payload permuted through the passes is the
    row index only — code words are re-gathered per pass on the host-free
    side of the program.
    """
    b = codes.shape[0]
    block = max(1, min(block, b))
    pad = (-b) % block
    nblocks = (b + pad) // block
    itp = resolve_interpret(interpret)

    # word bit-patterns as int32 (quick-code words are < 2^32 by
    # construction); byte-wise digits of the two's-complement pattern
    # order exactly as the unsigned words do
    words = jax.lax.bitcast_convert_type(
        codes.astype(jnp.uint32), jnp.int32
    )
    invalid = jnp.where(valid, 0, 1).astype(jnp.int32)
    order = jnp.arange(b, dtype=jnp.int32)
    pad_digits = jnp.full((pad,), PAD_DIGIT, jnp.int32)
    pad_payload = jnp.zeros((pad,), jnp.int32)

    def one_pass(order, digits):
        dp = jnp.concatenate([digits, pad_digits])
        op = jnp.concatenate([order, pad_payload])
        hist = pl.pallas_call(
            _hist_kernel,
            grid=(nblocks,),
            in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
            out_specs=pl.BlockSpec((1, NDIGITS + 1), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((nblocks, NDIGITS + 1), jnp.int32),
            interpret=itp,
        )(dp)
        totals = hist.sum(axis=0, dtype=jnp.int32)
        bases = jnp.cumsum(totals, dtype=jnp.int32) - totals
        out, _ = pl.pallas_call(
            _scatter_kernel,
            grid=(nblocks,),
            in_specs=[
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((block,), lambda i: (i,)),
                pl.BlockSpec((NDIGITS + 1,), lambda i: (0,)),
            ],
            out_specs=[
                pl.BlockSpec((b + pad,), lambda i: (0,)),   # revisited
                pl.BlockSpec((NDIGITS + 1,), lambda i: (0,)),  # carry
            ],
            out_shape=[
                jax.ShapeDtypeStruct((b + pad,), jnp.int32),
                jax.ShapeDtypeStruct((NDIGITS + 1,), jnp.int32),
            ],
            interpret=itp,
        )(dp, op, bases)
        return out[:b]

    for word, shift in _PASSES:
        if word == 3:
            src = invalid
        else:
            src = words[:, word]
        digits = jax.lax.shift_right_logical(
            src[order], jnp.int32(shift)
        ) & jnp.int32(0xFF)
        # a constant digit permutes nothing — skip the pass at runtime
        varies = jnp.min(digits) != jnp.max(digits)
        order = jax.lax.cond(
            varies, lambda o, d: one_pass(o, d), lambda o, d: o,
            order, digits,
        )
    return codes[order], valid[order], order


def _fused_keys(codes, valid):
    """Reduce (B, 3) code words to ONE uint64 sort key at their measured
    bit-widths. Returns (key, widths (b1, b2), fits) — ``fits`` is the
    runtime flag that all three words share 63 bits (the top bit is kept
    clear so valid keys stay below the invalid sentinel)."""
    z = jnp.uint64(0)

    def width(w):
        m = jnp.max(jnp.where(valid, w, z))
        return jnp.where(
            m > 0, jnp.uint64(64) - jax.lax.clz(m).astype(jnp.uint64),
            jnp.uint64(0),
        )

    c0 = codes[:, 0].astype(jnp.uint64)
    c1 = codes[:, 1].astype(jnp.uint64)
    c2 = codes[:, 2].astype(jnp.uint64)
    b0, b1, b2 = width(c0), width(c1), width(c2)
    fits = (b0 + b1 + b2) <= jnp.uint64(63)
    key = jnp.where(
        valid, (((c0 << b1) | c1) << b2) | c2, jnp.uint64(_SENTINEL)
    )
    return key, (b1, b2), fits


def _bin_fused(codes, valid, cap, weights, key, widths):
    """Bucket-partition bin over the fused single-word key: one
    payload-free sort, then slots/counts recovered by gathers alone."""
    b = codes.shape[0]
    b1, b2 = widths
    skey = jax.lax.sort((key,), num_keys=1)[0]
    boundary = jnp.concatenate(
        [jnp.ones((1,), bool), skey[1:] != skey[:-1]]
    )
    svalid = skey != jnp.uint64(_SENTINEL)
    newv = boundary & svalid
    n = newv.sum(dtype=jnp.int32)
    # dense rank of every sorted position's distinct key (unclamped)
    rank = jnp.cumsum(newv.astype(jnp.int32), dtype=jnp.int32) - 1
    # first-occurrence positions of the first `cap` distinct keys
    (bpos,) = jnp.nonzero(newv, size=cap + 1, fill_value=b)
    total_valid = svalid.sum(dtype=jnp.int64)
    nxt = jnp.concatenate([bpos[1:], jnp.full((1,), b)])
    seg_end = jnp.minimum(nxt, total_valid)
    seg_start = jnp.minimum(bpos, total_valid)
    uvalid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n, cap)
    # per-row slot: binary search for the row's key among the sorted
    # keys, then the dense rank at that (first-occurrence) position —
    # exact and unclamped even past cap, with zero scatters
    first = jnp.searchsorted(skey, key).astype(jnp.int32)
    inv = jnp.where(valid, rank[jnp.minimum(first, b - 1)], -1)
    # distinct keys unpacked back to the three words
    dkey = jnp.where(uvalid, skey[jnp.minimum(bpos[:cap], b - 1)], 0)
    one = jnp.uint64(1)
    u2 = dkey & ((one << b2) - one)
    u1 = (dkey >> b2) & ((one << b1) - one)
    u0 = dkey >> (b1 + b2)
    uniq = jnp.stack(
        [u0.astype(jnp.int64), u1.astype(jnp.int64), u2.astype(jnp.int64)],
        axis=1,
    )
    uniq = jnp.where(uvalid[:, None], uniq, 0)
    if weights is None:
        counts = jnp.maximum(seg_end - seg_start, 0)[:cap] * uvalid
    else:
        seg = jnp.where(valid & (inv >= 0) & (inv < cap), inv, cap)
        counts = jax.ops.segment_sum(
            jnp.where(valid, weights, 0).astype(jnp.int64), seg,
            num_segments=cap + 1,
        )[:cap]
    return uniq, counts.astype(jnp.int64), inv, n, uvalid


def bin_rows_radix(codes, valid, cap: int, weights=None, *,
                   use_kernel: bool = False, block: int = 8192,
                   interpret=None):
    """Level-1 binning with the radix/bucket partition in place of the
    payload-carrying ``lax.sort`` — the exact `aggregate.bin_rows`
    contract (see that docstring for the output shapes and the unclamped
    overflow semantics).

    ``use_kernel=True`` routes the sort through the Pallas LSB-radix
    passes (where the batch fits the VMEM budget); otherwise the fused
    single-key jnp route runs, with a traced `lax.cond` fallback to the
    2-key sort bin for batches whose words exceed 63 used bits.
    """
    from repro.kernels import aggregate as _agg

    b = codes.shape[0]
    if b == 0:
        return (jnp.zeros((cap, 3), jnp.int64), jnp.zeros((cap,), jnp.int64),
                jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((cap,), bool))
    if weights is None and b >= _agg.I32_SAT:
        weights = jnp.ones((b,), jnp.int64)

    if use_kernel:
        sort_block = max(1, min(2048, b))
        if not radix_fits_vmem(b + (-b) % sort_block, sort_block):
            return _agg.bin_rows(
                codes, valid, cap, weights,
                use_kernel=use_kernel, block=block, interpret=interpret,
            )
        sc, sv, order = radix_sort_codes(
            codes, valid, block=sort_block, interpret=interpret
        )
        prev_diff = jnp.concatenate(
            [jnp.ones((1,), bool), (sc[1:] != sc[:-1]).any(axis=1)]
        )
        new = sv & prev_diff
        if _agg.fits_vmem(cap):
            src, counts32, slot, n = _agg.seg_unique_pallas(
                new, sv, cap, block=block, interpret=interpret
            )
        else:
            src, counts32, slot, n = _agg.seg_unique_ref(new, sv, cap)
        uvalid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n, cap)
        uniq = jnp.where(uvalid[:, None], sc[jnp.minimum(src, b - 1)], 0)
        if weights is None:
            counts = counts32.astype(jnp.int64)
        else:
            w_sorted = jnp.where(sv, weights[order], 0).astype(jnp.int64)
            seg = jnp.where(sv & (slot >= 0) & (slot < cap), slot, cap)
            counts = jax.ops.segment_sum(
                w_sorted, seg, num_segments=cap + 1
            )[:cap]
        inv = jnp.zeros((b,), jnp.int32).at[order].set(slot)
        return uniq, counts, inv, n, uvalid

    key, widths, fits = _fused_keys(codes, valid)
    w_arg = (jnp.zeros((b,), jnp.int64) if weights is None
             else weights.astype(jnp.int64))

    def fast(codes, valid, w):
        return _bin_fused(
            codes, valid, cap, None if weights is None else w, key, widths
        )

    def slow(codes, valid, w):
        return _agg.bin_rows(
            codes, valid, cap, None if weights is None else w,
            use_kernel=False, block=block, interpret=interpret,
        )

    return jax.lax.cond(fits, fast, slow, codes, valid, w_arg)
