"""Device-resident level-1 pattern binning (sort + segment-unique/reduce).

The two-level aggregation of paper §5.4 promises that only per-*pattern*
state ever leaves the exploration engine, yet until DESIGN.md §10 the
level-1 fold ran on the host: every superstep drained the full frontier's
(B, 3) quick codes (and the (B, 8) local-vertex table for FSM) to the host
and lexsort-uniqued them there — an O(B) host transfer in the hottest
phase. This module is the device replacement: given a batch of quick codes
it produces, **on device**,

  * ``uniq``   — the distinct codes, lexicographically sorted, padded to a
    static capacity ``cap``;
  * ``counts`` — embeddings per distinct code (optionally weighted, for
    folding pre-binned partial aggregates);
  * ``inv``    — the per-row slot id into ``uniq`` (-1 for invalid rows);
  * ``n``      — the UNCLAMPED distinct total. Like the stream-compaction
    kernel's count contract (``kernels/compact.py``), overflow past ``cap``
    is a pure host decision on an already-drained value: slots ≥ ``cap``
    land in a dump slot that is sliced off, and the caller re-bins at the
    exact pow2 capacity.

The row sort itself stays on ``jax.lax.sort`` — XLA's tuned variadic sort
network, which a hand-rolled Pallas sort would not beat. What the Pallas
kernel (``seg_unique_pallas``) fuses is everything *after* the sort, the
four passes XLA otherwise materialises separately in HBM: segment-boundary
detection carry, exclusive prefix-sum of the boundary flags, the
first-occurrence scatter into the unique window, and the per-slot count
accumulation — one VMEM pass with the running unique total carried across
the sequential grid (the same revisited-window dataflow as
``kernels/compact.py``).

Dispatch follows :mod:`repro.kernels.dispatch`: ``interpret=None``
compiles on TPU/GPU and interprets on CPU; the engine's
``aggregate_kernel=None`` auto-knob only routes here where Pallas lowers
natively (TPU). The jnp route (``seg_unique_ref``) honours the identical
contract, so the two are interchangeable inside one jitted program.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

#: bytes of VMEM-resident unique windows (src + counts, int32 each) we
#: allow; larger capacities route to the jnp segment path.
VMEM_SLOT_LIMIT = 4 * 2**20

#: the int32 count ceiling (DESIGN.md §13): when a pipeline stage must
#: narrow per-pattern counts to int32 (the fused chunk programs' partial
#: emission), it SATURATES at this sentinel instead of wrapping negative.
#: ``DeviceLevel1.fold_partial`` detects the sentinel on device and its
#: finish drain reports it (7th scalar of the one flags read) — the caller
#: then re-folds the step from the frontier waves in int64, so totals past
#: 2^31 stay exact instead of silently corrupting.
I32_SAT = 2**31 - 1


def fits_vmem(cap: int) -> bool:
    """True when the two (cap + 1) int32 slot windows are VMEM-sized."""
    return (int(cap) + 1) * 4 * 2 <= VMEM_SLOT_LIMIT


def _seg_unique_kernel(new_ref, valid_ref, src_ref, counts_ref, slot_ref,
                       n_ref):
    """One grid step over a block of sorted rows: boundary prefix-sum +
    first-occurrence scatter + count accumulate, with ``n_ref`` doubling as
    the cross-block carry of the running distinct total (the compact.py
    revisited-window idiom)."""
    i = pl.program_id(0)
    block = new_ref.shape[0]
    slots = src_ref.shape[0]              # cap + 1 (last slot = dump)

    @pl.when(i == 0)
    def _init():
        src_ref[...] = jnp.zeros((slots,), jnp.int32)
        counts_ref[...] = jnp.zeros((slots,), jnp.int32)
        n_ref[...] = jnp.zeros((1,), jnp.int32)

    new = new_ref[...]
    valid = valid_ref[...]
    newv = new & valid
    base = n_ref[0]
    # inclusive prefix sum of boundary flags, offset by the carried base:
    # slot of a row = (#boundaries at or before it) - 1 (dtypes pinned —
    # the repo enables x64, which would promote the sums)
    incl = jnp.cumsum(newv.astype(jnp.int32), dtype=jnp.int32)
    slot = jnp.where(valid, base + incl - 1, -1)
    # global source index of every row in this block (2-D iota: TPU has no
    # 1-D iota — see the canonical-check kernels)
    src = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)[:, 0]
    # first occurrences scatter their source index; overflowed and
    # non-boundary rows land in the dump slot (sliced off by the wrapper)
    pos_src = jnp.where(newv & (slot < slots - 1), slot, slots - 1)
    src_ref[...] = src_ref[...].at[pos_src].set(jnp.where(newv, src, 0))
    # per-slot count accumulate (duplicates within the block fold via .add)
    pos_cnt = jnp.where(valid & (slot >= 0) & (slot < slots - 1),
                        slot, slots - 1)
    counts_ref[...] = counts_ref[...].at[pos_cnt].add(valid.astype(jnp.int32))
    slot_ref[...] = slot
    n_ref[...] = (base + newv.sum(dtype=jnp.int32)).reshape(1)


@functools.partial(
    jax.jit, static_argnames=("cap", "block", "interpret")
)
def seg_unique_pallas(new, valid, cap: int, block: int = 8192,
                      interpret=None):
    """(new (B,) bool boundary flags, valid (B,) bool) over SORTED rows ->
    (src (cap,) int32, counts (cap,) int32, slot (B,) int32, n () int32).

    ``src[:min(n, cap)]`` are the first-occurrence indices of each distinct
    segment in ascending order (pad slots 0); ``counts`` the per-segment
    row totals; ``slot`` the per-row segment id (-1 invalid, unclamped past
    ``cap``); ``n`` the unclamped distinct total. Valid rows must form a
    prefix of the sort order (the code sort pushes invalid rows last).
    """
    b = new.shape[0]
    if b == 0:
        return (jnp.zeros((cap,), jnp.int32), jnp.zeros((cap,), jnp.int32),
                jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32))
    block = max(1, min(block, b))
    pad = (-b) % block
    if pad:
        new = jnp.concatenate([new, jnp.zeros((pad,), new.dtype)])
        valid = jnp.concatenate([valid, jnp.zeros((pad,), valid.dtype)])

    src, counts, slot, n = pl.pallas_call(
        _seg_unique_kernel,
        grid=((b + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((cap + 1,), lambda i: (0,)),   # revisited window
            pl.BlockSpec((cap + 1,), lambda i: (0,)),   # revisited window
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),         # carry + result
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cap + 1,), jnp.int32),
            jax.ShapeDtypeStruct((cap + 1,), jnp.int32),
            jax.ShapeDtypeStruct((b + pad,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(new, valid)
    return src[:cap], counts[:cap], slot[:b], n[0]


def seg_unique_ref(new, valid, cap: int):
    """The jnp route (cumsum + scatter + segment_sum) with the kernel's
    exact contract — what ``bin_rows`` uses when the kernel is off."""
    b = new.shape[0]
    if b == 0:
        return (jnp.zeros((cap,), jnp.int32), jnp.zeros((cap,), jnp.int32),
                jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32))
    newv = new & valid
    incl = jnp.cumsum(newv.astype(jnp.int32), dtype=jnp.int32)
    slot = jnp.where(valid, incl - 1, -1)
    n = incl[-1]
    iota = jnp.arange(b, dtype=jnp.int32)
    pos_src = jnp.where(newv & (slot < cap), slot, cap)
    src = jnp.zeros((cap + 1,), jnp.int32).at[pos_src].set(
        jnp.where(newv, iota, 0)
    )[:cap]
    pos_cnt = jnp.where(valid & (slot >= 0) & (slot < cap), slot, cap)
    counts = jax.ops.segment_sum(
        valid.astype(jnp.int32), pos_cnt, num_segments=cap + 1
    )[:cap].astype(jnp.int32)
    return src, counts, slot, n


def sort_codes(codes, valid):
    """Sort (B, 3) code rows lexicographically with invalid rows pushed
    last. Returns (sorted codes, sorted valid, order).

    Exploits the quick-code encoding (every word < 2^32 by construction:
    4 + 28 structure bits, four 8-bit labels per label word) to pack the
    four sort keys (invalid, w0, w1, w2) into TWO uint64 keys — XLA's
    variadic sort scales with operand count, and the 2-key unstable sort
    is ~2x the 5-operand stable one. Tie order among equal codes is
    irrelevant: every :func:`bin_rows` output is value-determined.
    """
    b = codes.shape[0]
    k1 = (
        jnp.where(valid, 0, 1).astype(jnp.uint64) << 32
    ) | codes[:, 0].astype(jnp.uint64)
    k2 = (
        codes[:, 1].astype(jnp.uint64) << 32
    ) | codes[:, 2].astype(jnp.uint64)
    iota = jnp.arange(b, dtype=jnp.int32)
    _, _, order = jax.lax.sort((k1, k2, iota), num_keys=2, is_stable=False)
    return codes[order], valid[order], order


def bin_rows(codes, valid, cap: int, weights=None, *, use_kernel: bool = False,
             block: int = 8192, interpret=None, method: str = "sort"):
    """Level-1 device binning of one batch of quick codes.

    ``codes`` (B, 3) int64, ``valid`` (B,) bool ->
    ``(uniq (cap, 3) int64, counts (cap,) int64, inv (B,) int32,
    n () int32, uvalid (cap,) bool)``.

    ``uniq`` holds the distinct valid codes in ascending lexicographic
    order (deterministic across every caller — the host path's lexsort
    unique produces the same order, which is what makes the two paths
    bit-identical); ``counts[q]`` sums ``weights`` (default 1) over the
    rows of slot ``q``; ``inv`` maps each input row to its slot (-1
    invalid, *unclamped* on overflow); ``n`` is the unclamped distinct
    total — ``n > cap`` means the dump slot swallowed patterns and the
    caller must re-bin at ``next_pow2(n)``. Plain traced function: call it
    inside a jitted program (the chunk programs, the fold programs) or
    wrap it yourself.

    Precondition (from the quick-code encoding, see :func:`sort_codes`):
    every code word is non-negative and < 2^32.

    ``method`` selects the partition strategy: ``"sort"`` is this
    module's `lax.sort` + segment-unique route; ``"radix"`` routes to
    :mod:`repro.kernels.radix_bin` (Pallas LSB radix / fused-key bucket
    partition) — same contract, bit-identical outputs, chosen per
    backend by the cost model (`runtime/costmodel.py`).
    """
    if method == "radix":
        # late import: radix_bin's slow-path fallback calls back into this
        # module (one-way lazy edge breaks the cycle). The module holds no
        # jnp-valued globals, so importing mid-trace is safe.
        from repro.kernels import radix_bin

        return radix_bin.bin_rows_radix(
            codes, valid, cap, weights,
            use_kernel=use_kernel, block=block, interpret=interpret,
        )
    b = codes.shape[0]
    if b == 0:
        return (jnp.zeros((cap, 3), jnp.int64), jnp.zeros((cap,), jnp.int64),
                jnp.zeros((0,), jnp.int32), jnp.zeros((), jnp.int32),
                jnp.zeros((cap,), bool))
    if weights is None and b >= I32_SAT:
        # static wide guard: the unweighted path accumulates per-slot
        # counts in int32 inside the seg-unique kernels, exact only while
        # a slot's count (<= B, a static shape) fits — past that, route
        # through the int64 weighted segment-sum instead of wrapping
        weights = jnp.ones((b,), jnp.int64)
    sc, sv, order = sort_codes(codes, valid)
    prev_diff = jnp.concatenate(
        [jnp.ones((1,), bool), (sc[1:] != sc[:-1]).any(axis=1)]
    )
    new = sv & prev_diff
    if use_kernel and fits_vmem(cap):
        src, counts32, slot, n = seg_unique_pallas(
            new, sv, cap, block=block, interpret=interpret
        )
    else:
        src, counts32, slot, n = seg_unique_ref(new, sv, cap)
    uvalid = jnp.arange(cap, dtype=jnp.int32) < jnp.minimum(n, cap)
    uniq = jnp.where(uvalid[:, None], sc[jnp.minimum(src, b - 1)], 0)
    if weights is None:
        counts = counts32.astype(jnp.int64)
    else:
        w_sorted = jnp.where(sv, weights[order], 0).astype(jnp.int64)
        seg = jnp.where(sv & (slot >= 0) & (slot < cap), slot, cap)
        counts = jax.ops.segment_sum(
            w_sorted, seg, num_segments=cap + 1
        )[:cap]
    inv = jnp.zeros((b,), jnp.int32).at[order].set(slot)
    return uniq, counts, inv, n, uvalid


def pack_codes_u32(uniq):
    """Lossless device-side packing of (Q, 3) int64 quick codes to uint32.

    By construction (``repro.core.pattern``): ``w0 = nv | bits << 4`` with
    ``nv <= 8`` and at most C(8,2) = 28 adjacency bits (32 bits total);
    ``w1``/``w2`` hold four 8-bit labels each. All three words fit uint32
    exactly, halving the aggregation bytes that cross to the host."""
    return uniq.astype(jnp.uint32)


def unpack_codes_u32(packed) -> "np.ndarray":  # noqa: F821 - host side
    """Host-side inverse of :func:`pack_codes_u32` (numpy)."""
    import numpy as np

    return np.asarray(packed, dtype=np.uint32).astype(np.int64)
