"""Stream compaction (block prefix-sum + scatter) as a Pallas kernel.

The chunk program of the fused superstep pipeline (DESIGN.md §8) must turn
a flat keep mask over candidate slots into the dense child frontier. The
jnp route is ``jnp.nonzero(keep, size=out_cap, fill_value=0)`` — a full
sort-based gather that XLA materialises in HBM per chunk. This kernel
replaces it with the classic stream-compaction dataflow: the grid walks
``keep`` in blocks, each block computes its exclusive prefix sum, adds the
running total carried across the (sequential) grid, and scatters its kept
global indices straight into the VMEM-resident output window.

Contract (identical to the jnp route, so the two are interchangeable
inside one jitted chunk program):

  * ``idx[:count]`` are the kept positions in ascending order; slots past
    ``count`` hold 0 (the callers mask them out via ``count``).
  * ``count`` is the TOTAL number of kept slots, *not* clamped to
    ``out_cap`` — overflow detection stays a pure host decision on the
    already-drained count, which is what keeps the fused engine's retry
    path sync-free (``repro.core.runtime.serial``).

Dispatch follows the shared rules in :mod:`repro.kernels.dispatch`:
``interpret=None`` compiles on TPU/GPU and interprets on CPU; the engine's
``compact_kernel=None`` auto-knob only routes here where Pallas lowers
natively (TPU), everything else keeps the jnp route. Like the
canonical-check kernels, the compiled (Mosaic) path is the TPU target; the
Triton lowering of the in-kernel scatter has not been validated, so GPU
remains opt-in.

The output window is revisited (read-modified-written) by every grid
step, so total traffic is O(n_blocks * out_cap) — the same
VMEM-resident-window tradeoff as the canonical-check bitmap. Callers
guard with :func:`fits_vmem` (``explore.compact`` falls back to the jnp
gather past :data:`VMEM_IDX_LIMIT`) and the default block is sized large
to keep the number of window passes small.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

#: bytes of packed-index output window we allow resident in VMEM; larger
#: capacities route to the jnp nonzero gather (streamed from HBM by XLA).
VMEM_IDX_LIMIT = 4 * 2**20


def fits_vmem(out_cap: int) -> bool:
    """True when the (out_cap + 1) int32 index window is VMEM-sized."""
    return (int(out_cap) + 1) * 4 <= VMEM_IDX_LIMIT


def _compact_kernel(keep_ref, idx_ref, count_ref):
    """One grid step: block prefix-sum + scatter with a running carry.

    ``idx_ref``/``count_ref`` use constant index maps, so the same output
    window is revisited by every (sequential) grid step — ``count_ref``
    doubles as the cross-block carry of the running kept total.
    """
    i = pl.program_id(0)
    block = keep_ref.shape[0]
    out_slots = idx_ref.shape[0]          # out_cap + 1 (last slot = dump)

    @pl.when(i == 0)
    def _init():
        idx_ref[...] = jnp.zeros((out_slots,), jnp.int32)
        count_ref[...] = jnp.zeros((1,), jnp.int32)

    keep = keep_ref[...]
    kept = keep.astype(jnp.int32)
    base = count_ref[0]
    # exclusive prefix sum inside the block, offset by the carried base
    # (dtypes pinned: the repo enables x64, which would promote the sums)
    local = jnp.cumsum(kept, dtype=jnp.int32) - kept
    gpos = base + local
    # global source index of every slot in this block (2-D iota: TPU has no
    # 1-D iota — see the canonical-check kernels)
    src = i * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)[:, 0]
    # scatter kept sources to their output position; dropped and overflowed
    # slots land in the dump slot (sliced off by the wrapper)
    pos = jnp.where(keep & (gpos < out_slots - 1), gpos, out_slots - 1)
    idx_ref[...] = idx_ref[...].at[pos].set(jnp.where(keep, src, 0))
    count_ref[...] = (base + kept.sum(dtype=jnp.int32)).reshape(1)


@functools.partial(jax.jit, static_argnames=("out_cap", "block", "interpret"))
def stream_compact_pallas(keep, out_cap: int, block: int = 8192,
                          interpret=None):
    """keep (B,) bool -> (idx (out_cap,) int32, count () int32).

    ``idx[:min(count, out_cap)]`` are the kept positions of ``keep`` in
    ascending order (pad slots 0); ``count`` is the unclamped kept total.
    Accepts any ``B`` including 0 and non-multiples of ``block``.
    """
    n = keep.shape[0]
    if n == 0:
        return jnp.zeros((out_cap,), jnp.int32), jnp.zeros((), jnp.int32)
    block = max(1, min(block, n))
    pad = (-n) % block
    if pad:
        keep = jnp.concatenate([keep, jnp.zeros((pad,), keep.dtype)])

    idx, count = pl.pallas_call(
        _compact_kernel,
        grid=((n + pad) // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((out_cap + 1,), lambda i: (0,)),   # revisited window
            pl.BlockSpec((1,), lambda i: (0,)),             # carry + result
        ],
        out_shape=[
            jax.ShapeDtypeStruct((out_cap + 1,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(keep)
    return idx[:out_cap], count[0]


def stream_compact_ref(keep, out_cap: int):
    """The jnp route (nonzero gather) with the kernel's exact contract —
    the fallback `explore.compact` uses when the kernel is off."""
    count = keep.sum().astype(jnp.int32)
    (idx,) = jnp.nonzero(keep, size=out_cap, fill_value=0)
    return idx.astype(jnp.int32), count
