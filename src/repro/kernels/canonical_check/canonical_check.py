"""Embedding-canonicality check (paper Alg. 2) as a Pallas TPU kernel.

The hot inner loop of exploration: millions of (parent, candidate) pairs per
step, each needing k adjacency-bit lookups plus the prefix-order test. The
kernel tiles candidates into VMEM blocks and keeps the *whole packed
adjacency bitmap resident in VMEM* (graphs up to ~8k vertices: N*N/8 bytes
<= 8 MB), so every adjacency query is a VMEM gather instead of an HBM
round-trip — the TPU-native replacement for the CPU pointer chase.

Two kernels:

  * :func:`canonical_check_pallas` — the standalone Alg.-2 check over a flat
    batch of (members, cand) pairs. Batches of any size are accepted: the
    wrapper pads to a block multiple internally (pad rows have
    ``n_valid=0`` / ``cand=-1`` and are sliced off the output).
  * :func:`expand_canonical_pallas` — the *fused* expansion kernel: for a
    block of parent embeddings it enumerates every neighbour-table
    candidate, evaluates slot validity / is-member / first-occurrence dedup
    *and* the Alg.-2 check in one VMEM pass over the packed bitmap. The
    member↔candidate adjacency gather is computed once and reused by both
    the dedup rule and the canonicality test, eliminating the ``(C, k, k,
    D)`` boolean intermediate that the unfused path materialises in HBM
    through ``g.is_edge``.

``interpret=None`` auto-selects compiled vs interpreter per backend (see
``repro.kernels.dispatch``). Graph-size dispatch (VMEM limits, jnp
fallback) lives in ``ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

WORD_BITS = 32


def _pad_batch(block, members, n_valid, *rest):
    """Pad the leading batch dim to a multiple of ``block`` with inert rows
    (``members=-1``, ``n_valid=0``, trailing 1-D operands ``-1``). Returns
    ``(padded_batch, block, members, n_valid, *rest)`` — the shared padding
    protocol of every canonical-check kernel entry point."""
    b = members.shape[0]
    block = max(1, min(block, b))
    pad = (-b) % block
    if pad:
        members = jnp.concatenate(
            [members, jnp.full((pad,) + members.shape[1:], -1, members.dtype)]
        )
        n_valid = jnp.concatenate([n_valid, jnp.zeros((pad,), n_valid.dtype)])
        rest = tuple(
            jnp.concatenate([r, jnp.full((pad,), -1, r.dtype)]) for r in rest
        )
    return (b + pad, block, members, n_valid) + rest


def _kernel(members_ref, nvalid_ref, cand_ref, adj_ref, out_ref):
    members = members_ref[...]              # (TB, k) int32
    nvalid = nvalid_ref[...]                # (TB,)
    cand = cand_ref[...]                    # (TB,)
    adj = adj_ref[...]                      # (N, W) uint32 — VMEM resident

    tb, k = members.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (tb, k), 1)
    valid = pos < nvalid[:, None]

    safe_m = jnp.maximum(members, 0)
    safe_c = jnp.maximum(cand, 0)
    word = adj[safe_m, safe_c[:, None] // WORD_BITS]
    bit = (word >> (safe_c[:, None] % WORD_BITS).astype(jnp.uint32)) & jnp.uint32(1)
    neigh = (bit == 1) & valid & (members >= 0) & (cand[:, None] >= 0)

    first_ok = jnp.where(nvalid > 0, members[:, 0] < cand, True)
    found_after = jnp.cumsum(neigh.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((tb, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    out_ref[...] = first_ok & ~violation.any(axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def canonical_check_pallas(members, n_valid, cand, adj_bits, block_b=1024,
                           interpret=None):
    """members (B,k) int32; n_valid (B,); cand (B,); adj_bits (N,W) uint32.
    Returns (B,) bool — True iff members[:n_valid]+[cand] is canonical.

    Handles any batch size ``B`` (including 0 and non-multiples of
    ``block_b``) by padding internally and slicing the pad back off.
    """
    b, k = members.shape
    n, w = adj_bits.shape
    if b == 0:
        return jnp.zeros((0,), jnp.bool_)
    bp, block_b, members, n_valid, cand = _pad_batch(
        block_b, members, n_valid, cand
    )

    out = pl.pallas_call(
        _kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((n, w), lambda i: (0, 0)),   # bitmap VMEM-resident
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.bool_),
        interpret=resolve_interpret(interpret),
    )(members, n_valid, cand, adj_bits)
    return out[:b]


def _tiles_kernel(members_ref, ranks_ref, nvalid_ref, cand_ref, adj_ref,
                  out_ref):
    """Alg. 2 over a gathered halo tile (DESIGN.md §11): ``adj`` holds only
    the chunk's halo rows, so adjacency is indexed by the members' tile
    *ranks* while the order tests still compare global member ids — the
    replicated kernel above uses ``members`` for both, which is exactly
    what a partitioned bitmap cannot do."""
    members = members_ref[...]              # (TB, k) int32 global ids
    ranks = ranks_ref[...]                  # (TB, k) int32 rows into adj
    nvalid = nvalid_ref[...]                # (TB,)
    cand = cand_ref[...]                    # (TB,) global ids
    adj = adj_ref[...]                      # (U, W) uint32 — VMEM resident

    tb, k = members.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (tb, k), 1)
    valid = pos < nvalid[:, None]

    safe_r = jnp.clip(ranks, 0, adj.shape[0] - 1)
    safe_c = jnp.maximum(cand, 0)
    word = adj[safe_r, safe_c[:, None] // WORD_BITS]
    bit = (word >> (safe_c[:, None] % WORD_BITS).astype(jnp.uint32)) & jnp.uint32(1)
    neigh = (
        (bit == 1) & valid & (members >= 0) & (ranks >= 0)
        & (cand[:, None] >= 0)
    )

    first_ok = jnp.where(nvalid > 0, members[:, 0] < cand, True)
    found_after = jnp.cumsum(neigh.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((tb, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    out_ref[...] = first_ok & ~violation.any(axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def canonical_check_tiles_pallas(members, ranks, n_valid, cand, adj_tile,
                                 block_b=1024, interpret=None):
    """Tile-indexed Alg.-2 check: members/ranks (B, k) int32, n_valid (B,),
    cand (B,) global ids, adj_tile (U, W) uint32 gathered halo rows
    (``ranks`` index ``adj_tile``; out-of-tile ranks < 0 read as
    non-adjacent). Returns (B,) bool; any ``B`` accepted."""
    b, k = members.shape
    u, w = adj_tile.shape
    if b == 0:
        return jnp.zeros((0,), jnp.bool_)
    bp, block_b, members, n_valid, cand = _pad_batch(
        block_b, members, n_valid, cand
    )
    if bp > b:
        ranks = jnp.concatenate(
            [ranks, jnp.full((bp - b, k), -1, ranks.dtype)]
        )

    out = pl.pallas_call(
        _tiles_kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((u, w), lambda i: (0, 0)),   # halo tile VMEM-resident
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((bp,), jnp.bool_),
        interpret=resolve_interpret(interpret),
    )(members, ranks, n_valid, cand, adj_tile)
    return out[:b]


# ---------------------------------------------------------------------------
# Fused expansion + canonicality kernel
# ---------------------------------------------------------------------------

def _expand_kernel(members_ref, nvalid_ref, nbr_ref, adj_ref,
                   cand_ref, valid_ref, keep_ref):
    members = members_ref[...]              # (TC, k) int32
    nvalid = nvalid_ref[...]                # (TC,)
    nbr = nbr_ref[...]                      # (N, D) int32 — VMEM resident
    adj = adj_ref[...]                      # (N, W) uint32 — VMEM resident

    tc, k = members.shape
    d = nbr.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, (tc, k), 1)
    member_ok = pos < nvalid[:, None]                        # (TC, k)

    safe_m = jnp.maximum(members, 0)
    cand = jnp.where(member_ok[:, :, None], nbr[safe_m], -1)  # (TC, k, D)
    slot_ok = cand >= 0
    safe_c = jnp.maximum(cand, 0)

    # not already a member of the embedding
    is_member = (cand[:, :, :, None] == members[:, None, None, :]).any(-1)

    # member↔candidate adjacency, gathered ONCE from the VMEM bitmap and
    # shared by the dedup rule and the Alg.-2 scan below.
    word = adj[safe_m[:, :, None, None], safe_c[:, None, :, :] // WORD_BITS]
    bit = (
        word >> (safe_c[:, None, :, :] % WORD_BITS).astype(jnp.uint32)
    ) & jnp.uint32(1)
    adj_mc = (bit == 1) & member_ok[:, :, None, None] & slot_ok[:, None, :, :]
    # adj_mc: (TC, k_m, k_i, D) — member m adjacent to candidate slot (i, j)

    # first-occurrence dedup: drop if an *earlier* member is adjacent.
    m_idx = jax.lax.broadcasted_iota(jnp.int32, (1, k, k, 1), 1)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (1, k, k, 1), 2)
    earlier = m_idx < i_idx
    seen_earlier = (adj_mc & earlier).any(axis=1)            # (TC, k, D)

    valid = slot_ok & ~is_member & ~seen_earlier

    # Alg. 2 on every candidate slot, reusing adj_mc as the neighbour mask.
    first_ok = jnp.where(
        (nvalid > 0)[:, None, None], members[:, 0][:, None, None] < cand, True
    )
    found_after = jnp.cumsum(adj_mc.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((tc, 1, k, d), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = (
        member_ok[:, :, None, None]
        & found_before
        & (members[:, :, None, None] > cand[:, None, :, :])
    )
    canon = first_ok & ~violation.any(axis=1)                # (TC, k, D)

    cand_ref[...] = cand
    valid_ref[...] = valid
    keep_ref[...] = valid & canon


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def expand_canonical_pallas(members, n_valid, nbr, adj_bits, block_c=64,
                            interpret=None):
    """Fused vertex expansion: members (C,k) int32, n_valid (C,),
    nbr (N,D) int32 padded neighbour table, adj_bits (N,W) uint32.

    Returns ``(cand, valid, keep)``, each ``(C, k, D)``: the candidate
    vertex per slot, the pre-canonicality validity mask (slot-ok &
    not-member & first-occurrence) and the final keep mask (valid &
    Alg.-2 canonical). Any ``C`` is accepted (padded internally).
    """
    c, k = members.shape
    n, d = nbr.shape
    w = adj_bits.shape[1]
    if c == 0:
        z = jnp.zeros((0, k, d), jnp.int32)
        return z, z.astype(bool), z.astype(bool)
    cp, block_c, members, n_valid = _pad_batch(block_c, members, n_valid)

    cand, valid, keep = pl.pallas_call(
        _expand_kernel,
        grid=(cp // block_c,),
        in_specs=[
            pl.BlockSpec((block_c, k), lambda i: (i, 0)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((n, d), lambda i: (0, 0)),   # neighbour table resident
            pl.BlockSpec((n, w), lambda i: (0, 0)),   # bitmap resident
        ],
        out_specs=[
            pl.BlockSpec((block_c, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, k, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_c, k, d), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cp, k, d), jnp.int32),
            jax.ShapeDtypeStruct((cp, k, d), jnp.bool_),
            jax.ShapeDtypeStruct((cp, k, d), jnp.bool_),
        ],
        interpret=resolve_interpret(interpret),
    )(members, n_valid, nbr, adj_bits)
    return cand[:c], valid[:c], keep[:c]
