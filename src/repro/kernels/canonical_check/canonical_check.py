"""Embedding-canonicality check (paper Alg. 2) as a Pallas TPU kernel.

The hot inner loop of exploration: millions of (parent, candidate) pairs per
step, each needing k adjacency-bit lookups plus the prefix-order test. The
kernel tiles candidates into VMEM blocks and keeps the *whole packed
adjacency bitmap resident in VMEM* (graphs up to ~8k vertices: N*N/8 bytes
<= 8 MB), so every adjacency query is a VMEM gather instead of an HBM
round-trip — the TPU-native replacement for the CPU pointer chase.

For larger graphs the engine falls back to the pure-jnp path where XLA
streams the bitmap from HBM (canonical.vertex_check).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WORD_BITS = 32


def _kernel(members_ref, nvalid_ref, cand_ref, adj_ref, out_ref):
    members = members_ref[...]              # (TB, k) int32
    nvalid = nvalid_ref[...]                # (TB,)
    cand = cand_ref[...]                    # (TB,)
    adj = adj_ref[...]                      # (N, W) uint32 — VMEM resident

    tb, k = members.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (tb, k), 1)
    valid = pos < nvalid[:, None]

    safe_m = jnp.maximum(members, 0)
    safe_c = jnp.maximum(cand, 0)
    word = adj[safe_m, safe_c[:, None] // WORD_BITS]
    bit = (word >> (safe_c[:, None] % WORD_BITS).astype(jnp.uint32)) & jnp.uint32(1)
    neigh = (bit == 1) & valid & (members >= 0) & (cand[:, None] >= 0)

    first_ok = jnp.where(nvalid > 0, members[:, 0] < cand, True)
    found_after = jnp.cumsum(neigh.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((tb, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    out_ref[...] = first_ok & ~violation.any(axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def canonical_check_pallas(members, n_valid, cand, adj_bits, block_b=1024,
                           interpret=True):
    """members (B,k) int32; n_valid (B,); cand (B,); adj_bits (N,W) uint32.
    Returns (B,) bool — True iff members[:n_valid]+[cand] is canonical."""
    b, k = members.shape
    n, w = adj_bits.shape
    block_b = min(block_b, b)
    assert b % block_b == 0, "pad candidate batch to a block multiple"

    return pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((n, w), lambda i: (0, 0)),   # bitmap VMEM-resident
        ],
        out_specs=pl.BlockSpec((block_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.bool_),
        interpret=interpret,
    )(members, n_valid, cand, adj_bits)
