"""Oracle: the engine's pure-jnp Algorithm-2 check."""
from repro.core import canonical
from repro.core.graph import DeviceGraph


def canonical_check_ref(g: DeviceGraph, members, n_valid, cand):
    return canonical.vertex_check(g, members, n_valid, cand)
