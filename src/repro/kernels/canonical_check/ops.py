"""Public wrappers: graph-size + backend dispatch for the Alg.-2 kernels.

Dispatch rules (the contract the engine relies on):

  * **VMEM bitmap limit** — the kernel keeps the whole packed adjacency
    bitmap resident in VMEM, so it is only used when ``N * ceil(N/32) * 4``
    bytes fit under :data:`VMEM_BITMAP_LIMIT` (~8k vertices). Larger graphs
    take the pure-jnp path where XLA streams the bitmap from HBM
    (``canonical.vertex_check`` / ``edge_check``).
  * **Fused-expansion limit** — :func:`expand_canonical` additionally keeps
    the padded neighbour table in VMEM; both structures together must fit
    under :data:`VMEM_FUSED_LIMIT`.
  * **interpret auto-detection** — ``interpret=None`` compiles on TPU/GPU
    and interprets on CPU (``repro.kernels.dispatch.resolve_interpret``).
  * **edge mode** — there is no edge-mode kernel yet; ``mode="edge"``
    always routes to the jnp ``canonical.edge_check``. Callers go through
    this wrapper anyway so the kernel lands on the edge hot path the day
    it exists.

Batch-shape handling (empty batches, non-multiples of the block size) lives
inside the kernel wrappers themselves — callers never pad.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitset, canonical
from repro.core.graph import DeviceGraph
from repro.kernels.canonical_check.canonical_check import (
    canonical_check_pallas,
    canonical_check_tiles_pallas,
    expand_canonical_pallas,
)

VMEM_BITMAP_LIMIT = 8 * 2**20   # bytes of adjacency bitmap we allow in VMEM
#: resident tables (bitmap + neighbour) budget for the fused kernel; the
#: per-block temporaries get their own budget via _fused_block_c so the two
#: together stay under the ~16 MB of VMEM.
VMEM_FUSED_LIMIT = 8 * 2**20
FUSED_TEMP_BUDGET = 4 * 2**20   # per-block (block_c, k, k, D) temporaries
FUSED_TEMP_ARRAYS = 6           # ~concurrent 4-byte k*k*D-shaped temps


def fits_vmem(g) -> bool:
    """True when the packed adjacency bitmap is VMEM-resident-sized.
    Graphs without a replicated bitmap (``PartitionedGraph``) never fit —
    their kernel path is the tile-indexed check below."""
    adj = getattr(g, "adj_bits", None)
    return adj is not None and adj.size * 4 <= VMEM_BITMAP_LIMIT


def fits_vmem_fused(g) -> bool:
    """True when bitmap + neighbour table both fit for the fused kernel
    (per-block temporaries are bounded separately by _fused_block_c)."""
    adj = getattr(g, "adj_bits", None)
    return (
        adj is not None
        and (adj.size + g.nbr.size) * 4 <= VMEM_FUSED_LIMIT
    )


def _fused_block_c(k: int, d: int) -> int:
    """Block size keeping the fused kernel's (block_c, k, k, D)-shaped
    temporaries (word gather, adj_mc, cumsum, violation, ...) under
    FUSED_TEMP_BUDGET — high-degree graphs get small blocks instead of
    blowing VMEM after passing the resident-table guard."""
    per_row = FUSED_TEMP_ARRAYS * k * k * d * 4
    return max(1, min(64, FUSED_TEMP_BUDGET // max(per_row, 1)))


def canonical_check(g: DeviceGraph, members, n_valid, cand, *,
                    mode: str = "vertex", block_b=1024, interpret=None):
    """Alg.-2 check: kernel path for VMEM-sized graphs (vertex mode), jnp
    fallback otherwise. Accepts any batch size, including 0."""
    if mode == "edge":
        return canonical.edge_check(g, members, n_valid, cand)
    if not fits_vmem(g):
        return canonical.vertex_check(g, members, n_valid, cand)
    return canonical_check_pallas(
        members, n_valid, cand, g.adj_bits, block_b=block_b, interpret=interpret
    )


def canonical_check_tiles_ref(members, ranks, n_valid, cand, adj_tile):
    """jnp route of the tile-indexed Alg.-2 check, exact kernel contract:
    adjacency read at the members' halo-tile ``ranks`` (< 0 = not in tile =
    not adjacent), order tests on the global ids."""
    b, k = members.shape
    pos = jnp.arange(k)[None, :]
    valid = pos < n_valid[:, None]
    first_ok = jnp.where(n_valid > 0, members[:, 0] < cand, True)
    neigh = (
        bitset.test_bit(adj_tile, ranks, cand[:, None])
        & valid & (members >= 0)
    )
    found_after = jnp.cumsum(neigh.astype(jnp.int32), axis=1) > 0
    found_before = jnp.concatenate(
        [jnp.zeros((b, 1), dtype=bool), found_after[:, :-1]], axis=1
    )
    violation = valid & found_before & (members > cand[:, None])
    return first_ok & ~violation.any(axis=1)


def canonical_check_tiles(members, ranks, n_valid, cand, adj_tile, *,
                          use_pallas: bool = False, block_b=1024,
                          interpret=None):
    """Tile-indexed Alg.-2 dispatch (vertex mode, partitioned layout):
    kernel path when the gathered halo tile is VMEM-resident-sized, jnp
    route otherwise — the halo is frontier-sized, not graph-sized, so the
    kernel stays live on graphs whose full bitmap long overflowed
    :data:`VMEM_BITMAP_LIMIT`."""
    if not use_pallas or adj_tile.size * 4 > VMEM_BITMAP_LIMIT:
        return canonical_check_tiles_ref(members, ranks, n_valid, cand,
                                         adj_tile)
    return canonical_check_tiles_pallas(
        members, ranks, n_valid, cand, adj_tile,
        block_b=block_b, interpret=interpret,
    )


def expand_canonical(g: DeviceGraph, members, n_valid, *, block_c=None,
                     interpret=None):
    """Fused vertex expansion + canonicality (see kernel docstring).

    Returns ``(cand, valid, keep)`` each ``(C, k, D)``. Callers must check
    :func:`fits_vmem_fused` first; oversized graphs raise ValueError.
    ``block_c`` defaults to the VMEM-temporary-bounded size for
    (k, max_degree).
    """
    if not fits_vmem_fused(g):
        raise ValueError(
            "graph too large for the fused VMEM kernel: "
            f"{(g.adj_bits.size + g.nbr.size) * 4} resident bytes > "
            f"{VMEM_FUSED_LIMIT} (use the unfused canonical_check path)"
        )
    if block_c is None:
        block_c = _fused_block_c(members.shape[1], g.max_degree)
    return expand_canonical_pallas(
        members, n_valid, g.nbr, g.adj_bits, block_c=block_c,
        interpret=interpret,
    )
