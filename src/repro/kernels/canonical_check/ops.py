"""Public wrapper with padding + graph-size dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import canonical
from repro.core.graph import DeviceGraph
from repro.kernels.canonical_check.canonical_check import canonical_check_pallas

VMEM_BITMAP_LIMIT = 8 * 2**20  # bytes of adjacency bitmap we allow in VMEM


def canonical_check(g: DeviceGraph, members, n_valid, cand, block_b=1024,
                    interpret=True):
    """Kernel path for VMEM-sized graphs, jnp fallback otherwise."""
    if g.adj_bits.size * 4 > VMEM_BITMAP_LIMIT:
        return canonical.vertex_check(g, members, n_valid, cand)
    b = members.shape[0]
    block = min(block_b, b) if b else 1
    pad = (-b) % block
    if pad:
        members = jnp.concatenate(
            [members, jnp.full((pad, members.shape[1]), -1, members.dtype)]
        )
        n_valid = jnp.concatenate([n_valid, jnp.zeros((pad,), n_valid.dtype)])
        cand = jnp.concatenate([cand, jnp.full((pad,), -1, cand.dtype)])
    out = canonical_check_pallas(
        members, n_valid, cand, g.adj_bits, block_b=block, interpret=interpret
    )
    return out[:b]
