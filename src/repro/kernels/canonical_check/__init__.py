from repro.kernels.canonical_check.ops import canonical_check

__all__ = ["canonical_check"]
