from repro.kernels.canonical_check.ops import (
    canonical_check,
    expand_canonical,
    fits_vmem,
    fits_vmem_fused,
)

__all__ = [
    "canonical_check",
    "expand_canonical",
    "fits_vmem",
    "fits_vmem_fused",
]
