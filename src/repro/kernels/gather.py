"""Halo-tile gather for the partitioned graph layout (DESIGN.md §11).

The partitioned engine never walks the whole graph: each chunk program
first derives its *halo* — the ascending unique set of member vertices
(vertex mode) or member-edge endpoints (edge mode) whose neighbour /
adjacency rows the chunk will touch — and then gathers exactly those rows
out of the shard-stacked tables into a dense tile the rest of the fused
pipeline consumes (``explore.build_tile_view``).

Two pieces, same dispatch idioms as ``compact.py``:

  * :func:`halo_unique` — presence-bitmap scatter + stream compaction.
    The compaction reuses ``kernels/compact.py`` verbatim (kernel or jnp
    ref), so it inherits THE unclamped-count contract: ``count`` is the
    true number of distinct vertices even when it exceeds ``cap``. The
    engine sizes ``cap`` from static chunk shapes (``next_pow2(min(slots,
    n))``), which makes overflow impossible by construction — the
    unclamped count still rides the outputs so callers can assert it.
    Pad slots hold the sentinel ``n`` (one past the last vertex id), which
    keeps the tile *ascending* — rank translation in the tile view is a
    single ``searchsorted``.
  * :func:`gather_rows` — the new Pallas kernel: the shard-stacked table
    is kept **VMEM-resident** (same residency pattern as the
    canonical-check bitmap) and each grid step gathers one block of halo
    rows out of it. ``gather_rows_ref`` is the jnp route with the exact
    same contract; :func:`fits_vmem` guards the residency, larger tables
    fall back to the jnp gather streamed from HBM.

Out-of-range row ids (the sentinel pad, or any negative id) produce
``fill``-valued rows in both routes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compact as compact_lib
from repro.kernels.dispatch import resolve_interpret

#: bytes of gathered-from table we allow resident in VMEM; larger tables
#: route to the jnp gather (streamed from HBM by XLA) — same budget shape
#: as the canonical-check bitmap limit.
VMEM_TABLE_LIMIT = 8 * 2**20


def fits_vmem(table) -> bool:
    """True when the (rows, R) source table is VMEM-resident-sized."""
    return table.size * table.dtype.itemsize <= VMEM_TABLE_LIMIT


def _gather_kernel(rows_ref, table_ref, out_ref):
    """One grid step: gather a block of table rows. The source table uses a
    constant index map, so it stays VMEM-resident across the grid."""
    rows = rows_ref[...]                    # (block,) int32
    table = table_ref[...]                  # (N, R) — resident
    out_ref[...] = table[jnp.clip(rows, 0, table.shape[0] - 1)]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gather_rows_pallas(table, rows, block: int = 1024, interpret=None):
    """table (N, R); rows (U,) int32 -> (U, R) = table[rows], no masking
    (callers apply the fill; see :func:`gather_rows`). Any ``U`` accepted —
    padded internally to a block multiple and sliced back."""
    u = rows.shape[0]
    n, r = table.shape
    if u == 0:
        return jnp.zeros((0, r), table.dtype)
    block = max(1, min(block, u))
    pad = (-u) % block
    if pad:
        rows = jnp.concatenate([rows, jnp.zeros((pad,), rows.dtype)])

    out = pl.pallas_call(
        _gather_kernel,
        grid=((u + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),   # table VMEM-resident
        ],
        out_specs=pl.BlockSpec((block, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((u + pad, r), table.dtype),
        interpret=resolve_interpret(interpret),
    )(rows, table)
    return out[:u]


def gather_rows_ref(table, rows):
    """The jnp route (clipped take) with the kernel's exact contract."""
    return table[jnp.clip(rows, 0, table.shape[0] - 1)]


def gather_rows(table, rows, fill, *, use_kernel: bool = False,
                interpret=None):
    """Gather ``table[rows]`` with out-of-range rows replaced by ``fill``.

    ``use_kernel`` routes through the VMEM-resident Pallas gather when the
    table fits (:func:`fits_vmem`); otherwise — and always on the ref path —
    XLA's HBM-streamed take runs. Both routes return identical values."""
    if use_kernel and fits_vmem(table):
        out = gather_rows_pallas(table, rows, interpret=interpret)
    else:
        out = gather_rows_ref(table, rows)
    ok = (rows >= 0) & (rows < table.shape[0])
    return jnp.where(ok[:, None], out, jnp.asarray(fill, table.dtype))


def halo_unique(verts, n: int, cap: int, *, use_kernel: bool = False,
                interpret=None):
    """Ascending distinct vertex ids of ``verts`` (invalid ids < 0 or >= n
    ignored), padded with the sentinel ``n``.

    Returns ``(uniq (cap,) int32 ascending, count () int32)`` where
    ``count`` is the UNCLAMPED distinct total (same overflow contract as
    ``compact.py`` — detection is a pure host decision; the engine's
    static ``cap = next_pow2(min(slots, n))`` bound makes it impossible on
    the hot path). The presence scatter is one ``.at[].set`` over an
    ``(n + 1,)`` bitmap; the compaction is ``kernels/compact.py``."""
    verts = jnp.asarray(verts).reshape(-1)
    ok = (verts >= 0) & (verts < n)
    slot = jnp.where(ok, verts, n)
    presence = jnp.zeros((n + 1,), bool).at[slot].set(True)[:n]
    if use_kernel and compact_lib.fits_vmem(cap):
        idx, count = compact_lib.stream_compact_pallas(
            presence, cap, interpret=interpret
        )
    else:
        idx, count = compact_lib.stream_compact_ref(presence, cap)
    valid = jnp.arange(cap) < jnp.minimum(count, cap)
    uniq = jnp.where(valid, idx, n).astype(jnp.int32)
    return uniq, count
