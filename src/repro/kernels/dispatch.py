"""Backend-aware dispatch shared by every Pallas kernel wrapper.

One rule, stated once (the per-kernel ``ops`` wrappers all defer here):

  * ``interpret=None`` (the default everywhere) resolves automatically:
    the kernel *compiles* (Mosaic on TPU, Triton on GPU) when the active
    JAX backend can lower Pallas, and runs in the Pallas *interpreter*
    on CPU where no native lowering exists. This is what finally makes
    the kernels real code on accelerators — the seed hardcoded
    ``interpret=True`` so nothing ever compiled.
  * ``interpret=True`` / ``False`` forces the choice (tests pin ``True``
    so CI on CPU exercises the exact kernel dataflow deterministically).
"""
from __future__ import annotations

from typing import Optional

import jax

#: backends with a native Pallas lowering (everything else interprets).
COMPILED_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Map the tri-state ``interpret`` knob to a concrete bool."""
    if interpret is None:
        return jax.default_backend() not in COMPILED_BACKENDS
    return bool(interpret)


#: halo-exchange strategies of the partitioned layout (DESIGN.md §11).
HALO_STRATEGIES = ("alltoall", "gather")


def resolve_halo(halo: Optional[str] = None) -> str:
    """Map the halo knob (``RunConfig.halo``) to a concrete strategy.

    ``None``/``"auto"`` -> ``"alltoall"``: the position-aligned
    request/response all-to-all ships only the rows each worker actually
    asked for (O(halo) per worker). ``"gather"`` is the ragged fallback —
    every worker all-gathers the full shard tables (O(n) per worker), kept
    for meshes whose all-to-all lowering is unavailable and as the
    equivalence oracle."""
    if halo is None or halo == "auto":
        return "alltoall"
    if halo not in HALO_STRATEGIES:
        raise ValueError(
            f"unknown halo strategy {halo!r} (expected one of "
            f"{HALO_STRATEGIES} or 'auto')"
        )
    return halo


#: level-2 canonicalisation placements (DESIGN.md §15).
CANONICAL_PLACEMENTS = ("device", "host", "host_async")


def resolve_canonical_placement(placement: Optional[str] = None) -> str:
    """Map the level-2 placement knob (``RunConfig.canonical_placement``)
    to a concrete choice.

    ``None``/``"auto"`` -> ``"host"``: the memoised host batch is the
    reference placement and the static pre-calibration default — the cost
    model (``costmodel.resolve``) replaces it with the measured-fastest of
    ``"device"`` (batched permutation-refinement kernel,
    ``kernels/canonical_refine.py``) and ``"host_async"`` (background
    thread joined at the seal boundary) when calibration runs."""
    if placement is None or placement == "auto":
        return "host"
    if placement not in CANONICAL_PLACEMENTS:
        raise ValueError(
            f"unknown canonical placement {placement!r} (expected one of "
            f"{CANONICAL_PLACEMENTS} or 'auto')"
        )
    return placement


def device_scope(name: str):
    """Named XLA scope for a device-program stage (``repro/<name>``):
    the device-side half of the §12 span taxonomy. ``jax.named_scope``
    only relabels operations during tracing — zero runtime cost — so the
    fused-chunk / halo-exchange / aggregation-bin stages are ALWAYS
    scoped, and a ``jax.profiler.trace`` capture lines its device slices
    up with the host spans (``obs.annotate``) without recompiling."""
    return jax.named_scope(f"repro/{name}")

# NB: the old ``default_use_pallas`` static heuristic moved into the
# cost-model layer (``runtime/costmodel.py``): ``static_table`` keeps its
# TPU-only rule as the pre-calibration default, and calibration replaces
# it with a measured choice per backend (DESIGN.md §14).
