"""Causal GQA flash attention, Pallas TPU.

Blocked online-softmax attention (FlashAttention dataflow re-tiled for
VMEM/MXU): grid over (batch*kv_head*q_group, q blocks); the kernel streams
KV blocks through VMEM with running (max, sum, acc) state. Block shapes are
multiples of 128 on the contracting dims so the MXU is fully fed.

Validated in interpret mode against ref.py (the pure-jnp oracle); on real
TPU the same pallas_call lowers via Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float,
                 causal: bool):
    """One (q-block x full-KV) program instance.

    q_ref: (BQ, D); k_ref/v_ref: (S, D); o_ref: (BQ, D).
    """
    bq, d = q_ref.shape
    s = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * sm_scale
    q_pos = pl.program_id(1) * bq + jax.lax.iota(jnp.int32, bq)

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(i * block_k, block_k), slice(None)))
        scores = q @ k.astype(jnp.float32).T                   # (BQ, BK)
        if causal:
            k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_pos[:, None] >= k_pos[None, :]
            scores = jnp.where(mask, scores, NEG_INF)
        m_cur = jnp.maximum(m_prev, scores.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(scores - m_cur[:, None])
        l_cur = l_prev * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v.astype(jnp.float32)
        return acc, m_cur, l_cur

    n_k = s // block_k
    if causal:
        # only KV blocks at or before this q block contribute
        n_k = jnp.minimum(n_k, (pl.program_id(1) + 1) * bq // block_k + 1)
    acc = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_k, body, (acc, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"),
)
def flash_attention_bhsd(
    q, k, v, causal=True, block_q=DEFAULT_BLOCK_Q, block_k=DEFAULT_BLOCK_K,
    interpret=None,
):
    """q: (BH, Sq, D); k/v: (BH, Sk, D) (kv heads already broadcast).
    Returns (BH, Sq, D)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    sm_scale = d ** -0.5

    kernel = functools.partial(
        _attn_kernel, block_k=block_k, sm_scale=sm_scale, causal=causal
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q),
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, sk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, sk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=resolve_interpret(interpret),
    )(q, k, v)
