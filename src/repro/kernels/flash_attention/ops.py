"""Jit'd public wrapper: GQA layout handling around the Pallas kernel.

``interpret=None`` auto-selects compiled vs interpreter per backend (see
``repro.kernels.dispatch``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd


def flash_attention(q, k, v, causal=True, interpret=None, **block_kw):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) with H a multiple of KV.
    Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qs = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    ks = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, d)
    vs = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, d)
    o = flash_attention_bhsd(qs, ks, vs, causal=causal, interpret=interpret, **block_kw)
    return o.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
