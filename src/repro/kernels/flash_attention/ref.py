"""Pure-jnp oracle for flash attention."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """q: (BH, Sq, D); k/v: (BH, Sk, D)."""
    d = q.shape[-1]
    scores = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * d**-0.5
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
