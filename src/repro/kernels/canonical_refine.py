"""Batched device canonical refinement — level 2 on device (DESIGN.md §15).

Level 2 of the paper's two-level aggregation canonicalises each *distinct*
quick pattern (§5.4). The host implementation (`core/canon_math.py`) brute
forces the k! vertex-position permutations in numpy; on labeled graphs the
distinct-pattern table alone reaches tens of thousands of rows (mico: 37k
size-3 quick patterns) and that host pass becomes the last O(work) host
phase of the superstep. This module is the device replacement: a batched
permutation-refinement kernel over the O(Q) unique-code table that emits

  * ``canon``  — the lexicographically minimal (w0, w1, w2) encoding over
    all permutations, per row;
  * ``sigma``  — local→canonical position map of the FIRST minimal
    permutation (``itertools.permutations`` order), identity for pos ≥ nv;
  * ``rep``    — automorphism-orbit representative per position
    (min over the automorphism group — run it on *canonical* codes).

all bit-identical to :func:`canon_math.canonicalize_one` /
:func:`canon_math.automorphism_orbits`.

Dataflow: permutations act on the *encoded* words directly — a host-built
per-nv table (``canon_math.perm_tables``) maps each target adjacency bit
to its source bit under every permutation, so a permuted w0 is 28 shift/or
ops per permutation tile and never touches a dense (nv, nv) matrix. All
kernel arithmetic is uint32 (every code word < 2^32 by construction) and
the permutation axis is tiled with a running cross-tile argmin whose
strict-less merge preserves the first-minimal-wins tie-break.

Routes: ``_refine_nv_jnp`` (lax.fori_loop over permutation tiles) and
``_refine_nv_pallas`` (grid = rows × permutation tiles, revisited output
windows carrying the running best — the compact.py idiom). Same contract,
interchangeable inside one jitted program; dispatch follows
:mod:`repro.kernels.dispatch`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core import canon_math
from repro.kernels.dispatch import resolve_interpret

#: permutation-axis tile (the fori/grid step); 128 divides 8! and bounds
#: the (rows × tile) key intermediates to VMEM-friendly sizes.
PERM_TILE = 128
#: row-axis block of the Pallas route.
ROW_BLOCK = 128
#: adjacency bits of an 8-vertex pattern — the padded bit-source width.
MAX_BITS = canon_math.n_pair_bits(canon_math.MAX_PATTERN_VERTICES)

_U32_MAX = np.uint32(0xFFFFFFFF)


def _padded_tables(nv: int, tile: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-nv permutation tables padded for tiled device iteration.

    ``perms`` (P', 8) int32: columns ≥ nv hold the identity position (their
    gathered label is 0, so padded columns contribute nothing to w1/w2 and
    the one-scatter sigma recovery yields identity there). ``src`` (P', 28)
    int32: target bits ≥ n_pair_bits(nv) read source bit 31, which is 0 in
    every code word (bits occupy ≤ 28 + 4 positions... bit 31 is never set
    for nv ≤ 8 since adj_bits < 2^28). Rows are padded to a multiple of
    ``tile`` by REPEATING the last permutation: duplicates can never win
    the strict-less merge and the orbit min is idempotent.
    """
    perms, src = canon_math.perm_tables(nv)
    p = len(perms)
    nbits = canon_math.n_pair_bits(nv)
    perms_pad = np.tile(np.arange(8, dtype=np.int32), (p, 1))
    perms_pad[:, :nv] = perms
    src_pad = np.full((p, MAX_BITS), 31, dtype=np.int32)
    src_pad[:, :nbits] = src
    rows = -(-p // tile) * tile
    if rows > p:
        perms_pad = np.concatenate(
            [perms_pad, np.tile(perms_pad[-1:], (rows - p, 1))]
        )
        src_pad = np.concatenate(
            [src_pad, np.tile(src_pad[-1:], (rows - p, 1))]
        )
    return perms_pad, src_pad


def _split_codes(codes):
    """(Q, 3) int64 codes -> (bits (Q,) uint32, labels (Q, 8) uint32,
    own (Q, 3) uint32). Exact: every code word < 2^32."""
    cu = codes.astype(jnp.uint32)
    bits = cu[:, 0] >> 4
    lab_cols = []
    for i in range(4):
        lab_cols.append((cu[:, 1] >> (8 * i)) & jnp.uint32(0xFF))
    for i in range(4):
        lab_cols.append((cu[:, 2] >> (8 * i)) & jnp.uint32(0xFF))
    labels = jnp.stack(lab_cols, axis=1)
    return bits, labels, cu


def _permuted_keys(bits, labels, pt, st, nv: int):
    """Keys of every (row, permutation-in-tile) pair.

    ``bits`` (R,) uint32, ``labels`` (R, 8) uint32, ``pt`` (T, 8) int32
    padded perms, ``st`` (T, 28) int32 padded bit sources ->
    (w0, w1, w2) each (R, T) uint32. Label gather is 8×8 selects (no
    dynamic gather — lowers on every backend, Pallas included)."""
    nbits = canon_math.n_pair_bits(nv)
    new_bits = jnp.zeros((bits.shape[0], pt.shape[0]), jnp.uint32)
    for tb in range(nbits):
        s = st[:, tb].astype(jnp.uint32)
        new_bits = new_bits | (
            ((bits[:, None] >> s[None, :]) & jnp.uint32(1)) << tb
        )
    w0 = (new_bits << 4) | jnp.uint32(nv)
    w1 = jnp.zeros_like(new_bits)
    w2 = jnp.zeros_like(new_bits)
    for i in range(8):
        pti = pt[:, i][None, :]                              # (1, T)
        li = jnp.zeros_like(new_bits)
        for s in range(8):
            li = li | jnp.where(pti == s, labels[:, s][:, None],
                                jnp.uint32(0))
        if i < 4:
            w1 = w1 | (li << (8 * i))
        else:
            w2 = w2 | (li << (8 * (i - 4)))
    return w0, w1, w2


def _tile_first_min(w0, w1, w2):
    """Per-row lexicographic minimum over the tile axis + the FIRST column
    achieving it (three-stage masked min, then argmax of eligibility —
    jnp.argmax returns the first maximal index)."""
    m0 = w0.min(axis=1, keepdims=True)
    e = w0 == m0
    m1 = jnp.where(e, w1, _U32_MAX).min(axis=1, keepdims=True)
    e = e & (w1 == m1)
    m2 = jnp.where(e, w2, _U32_MAX).min(axis=1, keepdims=True)
    e = e & (w2 == m2)
    loc = jnp.argmax(e, axis=1).astype(jnp.int32)
    return m0[:, 0], m1[:, 0], m2[:, 0], loc


def _lex_less3(a0, a1, a2, b0, b1, b2):
    return (a0 < b0) | (
        (a0 == b0) & ((a1 < b1) | ((a1 == b1) & (a2 < b2)))
    )


def _identity_rows(q):
    return jnp.tile(jnp.arange(8, dtype=jnp.int32), (q, 1))


def _sigma_from_pi(best_pi, perms_dev):
    """sigma[local] = canonical position, via one scatter of the winning
    permutation (padded columns are identity, so pos ≥ nv comes out
    identity exactly as the host contract requires)."""
    chosen = perms_dev[best_pi]                               # (Q, 8) int32
    q = chosen.shape[0]
    rows = jnp.arange(q, dtype=jnp.int32)[:, None]
    return jnp.zeros((q, 8), jnp.int32).at[rows, chosen].set(
        jnp.arange(8, dtype=jnp.int32)[None, :]
    )


# ---------------------------------------------------------------------------
# jnp reference route
# ---------------------------------------------------------------------------

def _refine_nv_jnp(codes, nv: int, with_orbits: bool, tile: int):
    """Single-nv refine, lax.fori_loop over permutation tiles. Returns
    (canon (Q, 3) int64, sigma (Q, 8) int32, rep (Q, 8) int32); rows whose
    actual nv differs produce garbage the caller masks out."""
    q = codes.shape[0]
    perms_np, src_np = _padded_tables(nv, tile)
    perms_dev = jnp.asarray(perms_np)
    src_dev = jnp.asarray(src_np)
    bits, labels, own = _split_codes(codes)
    n_tiles = len(perms_np) // tile

    def body(j, carry):
        b0, b1, b2, bpi, rep = carry
        pt = jax.lax.dynamic_slice(perms_dev, (j * tile, 0), (tile, 8))
        st = jax.lax.dynamic_slice(src_dev, (j * tile, 0), (tile, MAX_BITS))
        w0, w1, w2 = _permuted_keys(bits, labels, pt, st, nv)
        m0, m1, m2, loc = _tile_first_min(w0, w1, w2)
        tpi = j.astype(jnp.int32) * tile + loc
        better = _lex_less3(m0, m1, m2, b0, b1, b2)
        b0 = jnp.where(better, m0, b0)
        b1 = jnp.where(better, m1, b1)
        b2 = jnp.where(better, m2, b2)
        bpi = jnp.where(better, tpi, bpi)
        if with_orbits:
            auto = (
                (w0 == own[:, 0:1]) & (w1 == own[:, 1:2])
                & (w2 == own[:, 2:3])
            )
            cand = jnp.where(auto[:, :, None], pt[None, :, :],
                             jnp.int32(8)).min(axis=1)
            rep = jnp.minimum(rep, cand)
        return b0, b1, b2, bpi, rep

    init = (
        jnp.full((q,), _U32_MAX, jnp.uint32),
        jnp.full((q,), _U32_MAX, jnp.uint32),
        jnp.full((q,), _U32_MAX, jnp.uint32),
        jnp.zeros((q,), jnp.int32),
        _identity_rows(q),
    )
    b0, b1, b2, bpi, rep = jax.lax.fori_loop(0, n_tiles, body, init)
    canon = jnp.stack([b0, b1, b2], axis=1).astype(jnp.int64)
    sigma = _sigma_from_pi(bpi, perms_dev)
    return canon, sigma, rep


# ---------------------------------------------------------------------------
# Pallas route
# ---------------------------------------------------------------------------

def _refine_kernel(codes_ref, labels_ref, perms_ref, src_ref,
                   best_ref, pi_ref, rep_ref, *, nv: int, tile: int,
                   with_orbits: bool):
    """Grid step (i, j) = (row block, permutation tile): permute keys for
    the tile, fold its first-min into the revisited best/pi/rep windows.
    The permutation axis is the FAST grid dimension, so for a fixed row
    block j sweeps all tiles before i advances — the running windows carry
    across j and re-initialise at j == 0."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best_ref[...] = jnp.full(best_ref.shape, _U32_MAX, jnp.uint32)
        pi_ref[...] = jnp.zeros(pi_ref.shape, jnp.int32)
        rep_ref[...] = jax.lax.broadcasted_iota(
            jnp.int32, rep_ref.shape, 1
        )

    codes = codes_ref[...]                                    # (R, 3) uint32
    bits = codes[:, 0] >> 4
    labels = labels_ref[...]                                  # (R, 8) uint32
    pt = perms_ref[...]                                       # (T, 8) int32
    st = src_ref[...]                                         # (T, 28) int32
    w0, w1, w2 = _permuted_keys(bits, labels, pt, st, nv)
    m0, m1, m2, loc = _tile_first_min(w0, w1, w2)
    tpi = j * tile + loc
    cur = best_ref[...]
    better = _lex_less3(m0, m1, m2, cur[:, 0], cur[:, 1], cur[:, 2])
    best_ref[...] = jnp.stack(
        [jnp.where(better, m0, cur[:, 0]),
         jnp.where(better, m1, cur[:, 1]),
         jnp.where(better, m2, cur[:, 2])], axis=1
    )
    pi_ref[...] = jnp.where(better, tpi, pi_ref[...][:, 0])[:, None]
    if with_orbits:
        auto = (
            (w0 == codes[:, 0:1]) & (w1 == codes[:, 1:2])
            & (w2 == codes[:, 2:3])
        )
        cand = jnp.where(auto[:, :, None], pt[None, :, :],
                         jnp.int32(8)).min(axis=1)
        rep_ref[...] = jnp.minimum(rep_ref[...], cand)


def _refine_nv_pallas(codes, nv: int, with_orbits: bool, tile: int,
                      row_block: int, interpret):
    """Single-nv refine through the Pallas kernel (same contract as
    :func:`_refine_nv_jnp`)."""
    q = codes.shape[0]
    perms_np, src_np = _padded_tables(nv, tile)
    perms_dev = jnp.asarray(perms_np)
    src_dev = jnp.asarray(src_np)
    _, labels, cu = _split_codes(codes)
    row_block = max(1, min(row_block, q))
    pad = (-q) % row_block
    if pad:
        cu = jnp.concatenate([cu, jnp.zeros((pad, 3), jnp.uint32)])
        labels = jnp.concatenate([labels, jnp.zeros((pad, 8), jnp.uint32)])
    n_tiles = len(perms_np) // tile
    best, bpi, rep = pl.pallas_call(
        functools.partial(_refine_kernel, nv=nv, tile=tile,
                          with_orbits=with_orbits),
        grid=((q + pad) // row_block, n_tiles),
        in_specs=[
            pl.BlockSpec((row_block, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, 8), lambda i, j: (i, 0)),
            pl.BlockSpec((tile, 8), lambda i, j: (j, 0)),
            pl.BlockSpec((tile, MAX_BITS), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((row_block, 3), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((row_block, 8), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q + pad, 3), jnp.uint32),
            jax.ShapeDtypeStruct((q + pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((q + pad, 8), jnp.int32),
        ],
        interpret=resolve_interpret(interpret),
    )(cu, labels, perms_dev, src_dev)
    canon = best[:q].astype(jnp.int64)
    sigma = _sigma_from_pi(bpi[:q, 0], perms_dev)
    return canon, sigma, rep[:q]


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def refine_codes(codes, valid, nvs: tuple, *, with_orbits: bool = False,
                 use_kernel: bool = False, interpret=None,
                 tile: int = PERM_TILE, row_block: int = ROW_BLOCK):
    """Mixed-nv batched canonical refine (plain traced function — call it
    inside a jitted program, or use :func:`refine_batch`).

    ``codes`` (Q, 3) int64, ``valid`` (Q,) bool, ``nvs`` the STATIC tuple
    of vertex counts that may occur ->
    ``(canon (Q, 3) int64, sigma (Q, 8) int32, rep (Q, 8) int32)``.

    One refine pass per nv in ``nvs``; each row takes the pass matching its
    encoded nv. Rows with nv ≤ 1, rows whose nv is outside ``nvs``, and
    invalid rows pass through unchanged with identity sigma/rep (exactly
    the host contract for nv ≤ 1). ``rep`` is the orbit table of the INPUT
    codes — meaningful on canonical codes (Aut(canon) ≠ Aut(quick))."""
    q = codes.shape[0]
    canon = codes.astype(jnp.int64)
    sigma = _identity_rows(q)
    rep = _identity_rows(q)
    if q == 0:
        return canon, sigma, rep
    row_nv = (codes[:, 0] & 0xF).astype(jnp.int32)
    for nv in sorted(set(int(v) for v in nvs)):
        if nv <= 1 or nv > canon_math.MAX_PATTERN_VERTICES:
            continue
        if use_kernel:
            c, s, r = _refine_nv_pallas(codes, nv, with_orbits, tile,
                                        row_block, interpret)
        else:
            c, s, r = _refine_nv_jnp(codes, nv, with_orbits, tile)
        m = valid & (row_nv == nv)
        canon = jnp.where(m[:, None], c, canon)
        sigma = jnp.where(m[:, None], s, sigma)
        rep = jnp.where(m[:, None], r, rep)
    return canon, sigma, rep


@functools.partial(
    jax.jit,
    static_argnames=("nvs", "with_orbits", "use_kernel", "interpret",
                     "tile", "row_block"),
)
def refine_batch(codes, valid, nvs: tuple, with_orbits: bool = False,
                 use_kernel: bool = False, interpret=None,
                 tile: int = PERM_TILE, row_block: int = ROW_BLOCK):
    """Jitted :func:`refine_codes` (standalone use: tests, host helper,
    cost-model probe)."""
    return refine_codes(codes, valid, nvs, with_orbits=with_orbits,
                        use_kernel=use_kernel, interpret=interpret,
                        tile=tile, row_block=row_block)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def canonicalize_on_device(codes_np, *, with_orbits: bool = False,
                           use_kernel: bool = False, interpret=None):
    """Host convenience: numpy (M, 3) int64 mixed-nv codes -> numpy
    ``(canon (M, 3) int64, sigma (M, 8) int32, rep (M, 8) int32)`` via the
    device kernel. Pads the batch to the next power of two so repeated
    calls reuse a bounded set of compiled shapes. This is the
    ``canon_fn`` hook of :func:`pattern.build_pattern_table` and the
    cost-model probe body."""
    codes_np = np.ascontiguousarray(codes_np, dtype=np.int64)
    m = len(codes_np)
    if m == 0:
        return (codes_np.copy(),
                np.zeros((0, 8), np.int32), np.zeros((0, 8), np.int32))
    nvs = tuple(sorted(set(int(w) & 0xF for w in codes_np[:, 0])))
    cap = _next_pow2(m)
    padded = np.zeros((cap, 3), dtype=np.int64)
    padded[:m] = codes_np
    valid = np.zeros((cap,), dtype=bool)
    valid[:m] = True
    canon, sigma, rep = refine_batch(
        jnp.asarray(padded), jnp.asarray(valid), nvs,
        with_orbits=with_orbits, use_kernel=use_kernel, interpret=interpret,
    )
    return (np.asarray(canon[:m]), np.asarray(sigma[:m]),
            np.asarray(rep[:m]))


def make_canon_fn(*, use_kernel: bool = False, interpret=None):
    """A :func:`pattern.build_pattern_table` ``canon_fn`` bound to the
    device refine (placement "device" over a host-resident level-1)."""
    def canon_fn(miss_codes):
        canon, sigma, _ = canonicalize_on_device(
            miss_codes, use_kernel=use_kernel, interpret=interpret
        )
        return canon, sigma
    return canon_fn
