"""Fused RMSNorm Pallas kernel: one VMEM pass (read x, fp32 reduce, scale,
write) instead of XLA's separate reduce + broadcast-multiply HBM trips."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dispatch import resolve_interpret


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (TB, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = ((x * jax.lax.rsqrt(var + eps)) * scale_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x2d, scale, eps=1e-5, block_rows=256, interpret=None):
    """x2d (R, D), scale (D,) -> (R, D)."""
    r, d = x2d.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x2d.dtype),
        interpret=resolve_interpret(interpret),
    )(x2d, scale)
