"""Oracle: the model stack's rmsnorm."""
from repro.models.layers import rmsnorm as rmsnorm_ref  # noqa: F401
