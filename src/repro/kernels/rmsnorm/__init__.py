from repro.kernels.rmsnorm.ops import rmsnorm

__all__ = ["rmsnorm"]
