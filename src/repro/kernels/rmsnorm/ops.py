"""Public wrapper: arbitrary leading dims + row padding.

``interpret=None`` auto-selects compiled vs interpreter per backend (see
``repro.kernels.dispatch``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas


def rmsnorm(x, scale, eps=1e-5, interpret=None):
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    r = x2.shape[0]
    block = min(256, r)
    pad = (-r) % block
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, d), x2.dtype)])
    out = rmsnorm_pallas(x2, scale, eps=eps, block_rows=block, interpret=interpret)
    return out[:r].reshape(shape)
