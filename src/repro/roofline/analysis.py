"""Roofline terms from a compiled dry-run artifact (assignment §ROOFLINE).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
parsed from the HLO text (cost_analysis does not expose them): we sum the
result-shape bytes of every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one result shape: bf16[128,4096]{1,0:T(8,128)} etc.
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\(?[^=]*?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of result bytes per collective kind (whole program, all devices'
    logical tensors — i.e. per-participant payload of each op)."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue  # avoid double-counting async pairs
        out[kind] += _shape_bytes(shapes)
    return out


@dataclasses.dataclass
class Roofline:
    """All byte/FLOP inputs are PER-DEVICE: jax's compiled.cost_analysis()
    reports the SPMD per-device module (verified empirically: an 8-way
    sharded matmul reports 1/8 of the logical FLOPs), and the HLO text the
    collective bytes are parsed from is likewise the per-device program."""

    flops: float                   # per-device HLO FLOPs
    hbm_bytes: float               # per-device bytes accessed
    coll_bytes: float              # per-device collective payload bytes
    chips: int
    model_flops: float = 0.0       # GLOBAL 6*N_active*D (train) / 2*N_active*D
    per_device_hbm: Optional[float] = None  # peak bytes from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / hw.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS share per device / compiled per-device FLOPs."""
        return (self.model_flops / self.chips) / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (bound = max term)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        if bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / hw.PEAK_FLOPS_BF16) / bound

    def to_dict(self):
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "per_device_hbm": self.per_device_hbm,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def from_compiled(compiled, chips: int, model_flops: float = 0.0) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    try:
        text = compiled.as_text()
    except Exception:
        text = ""
    coll = collective_bytes(text)
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
        )
    except Exception:
        pass
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        chips=chips,
        model_flops=model_flops,
        per_device_hbm=mem,
    )


def count_params(shape_tree, exclude_substrings=("embed",)) -> dict:
    """Param counts from an eval_shape tree: total, embedding, expert."""
    import jax

    total = emb = expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shape_tree)[0]:
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = int(np.prod(leaf.shape))
        total += n
        if any(s in pstr.lower() for s in exclude_substrings):
            emb += n
        if "experts" in pstr.lower():
            expert += n
    return {"total": total, "embedding": emb, "experts": expert}


def model_flops_for(cfg, shape, params_shape_tree) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (serve),
    N_active excluding embeddings and inactive experts."""
    counts = count_params(params_shape_tree)
    n = counts["total"] - counts["embedding"]
    if cfg.n_experts:
        active_frac = (cfg.top_k + cfg.n_shared_experts) / max(
            cfg.n_experts + cfg.n_shared_experts, 1
        )
        n = n - counts["experts"] + counts["experts"] * active_frac
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
