"""Target hardware constants (TPU v5e-class, assignment §ROOFLINE)."""

PEAK_FLOPS_BF16 = 197e12       # per chip, FLOP/s
HBM_BW = 819e9                 # per chip, B/s
ICI_BW = 50e9                  # per link, B/s
HBM_BYTES = 16 * 2**30         # per chip
