"""Level-2 placement equivalence + the bounded canonical memo (§15).

The acceptance regression of the device-resident/overlapped level-2
refactor: a mico-like labeled workload whose depth-3 frontier emits tens
of thousands of DISTINCT size-3 quick patterns (crossing the default
``agg_qcap`` so the pow2 growth rung fires) must produce bit-identical
patterns/counts under every ``canonical_placement`` — the synchronous
host batch, the device refine kernel, and the seal-joined background
thread — plus the memo-cap knob and thread-safety of the quick→canonical
cache that all placements share.
"""
import threading

import numpy as np
import pytest

from repro.core import graph as G, pattern as pattern_lib
from repro.core.apps.motifs import MotifsApp
from repro.core.runtime.config import RunConfig, next_pow2
from repro.core.runtime.loop import SuperstepRuntime

PLACEMENTS = ["host", "device", "host_async", None]


@pytest.fixture(autouse=True)
def _fresh_memo():
    pattern_lib.clear_memo()
    yield
    pattern_lib.set_memo_cap(None)
    pattern_lib.clear_memo()


def _run(placement, **kw):
    # pin the device-aggregation path: the placement dispatch, the qcap
    # growth rung, and the async overlap all live there (the CPU cost
    # model would otherwise choose the host reference and the regression
    # would silently test nothing)
    cfg = RunConfig(canonical_placement=placement, pallas_interpret=True,
                    device_aggregate=True, **kw)
    rt = SuperstepRuntime(G.mico_like(scale=0.005), MotifsApp(max_size=3),
                          cfg)
    return rt, rt.run()


def test_mico_like_depth3_identical_across_placements():
    results = {}
    for placement in PLACEMENTS:
        pattern_lib.clear_memo()       # cold level 2 for every placement
        rt, res = _run(placement)
        results[placement] = res
        n_quick = max(s.n_quick_patterns for s in res.stats.steps)
        # the regression's whole point: a LABELED graph whose distinct
        # size-3 quick-pattern table dwarfs the default agg_qcap
        assert n_quick >= 10_000
        # ... which must have fired the pow2 capacity growth rung
        assert rt.backend._run_qcap >= next_pow2(n_quick)
        assert rt.backend._run_qcap > next_pow2(rt.config.agg_qcap)
    base = results["host"]
    assert len(base.patterns) > 1_000
    for placement, res in results.items():
        assert res.patterns == base.patterns, placement
        for a, b in zip(res.aggregates, base.aggregates):
            np.testing.assert_array_equal(a.canon_codes, b.canon_codes)
            np.testing.assert_array_equal(a.counts, b.counts)
            assert a.n_quick == b.n_quick


def test_host_async_overlaps_and_host_critical_path_shrinks():
    pattern_lib.clear_memo()
    _, sync = _run("host")
    pattern_lib.clear_memo()
    _, overlapped = _run("host_async")
    assert overlapped.patterns == sync.patterns
    # overlap exists only where a NEXT superstep runs underneath the
    # in-flight batch: the terminal step joins on the done path with
    # nothing to hide behind, so compare the non-final steps — there the
    # join waits only for the residual, not the whole host batch
    # (bench_canon gates the full 5x critical-path reduction on the
    # depth-4 workload whose big table is non-terminal)
    t_sync = sum(s.t_canon for s in sync.stats.steps[:-1])
    t_async = sum(s.t_canon for s in overlapped.stats.steps[:-1])
    assert t_sync > 0
    assert t_async < t_sync


# ---------------------------------------------------------------------------
# the shared quick->canonical memo: bounded + thread-safe (satellite a)
# ---------------------------------------------------------------------------

def _codes(n, seed):
    from repro.core import canon_math
    rng = np.random.default_rng(seed)
    out = set()
    while len(out) < n:
        adj = np.zeros((4, 4), dtype=bool)
        for bb in range(1, 4):
            for aa in range(bb):
                if rng.random() < 0.5:
                    adj[aa, bb] = adj[bb, aa] = True
        out.add(canon_math.encode(4, adj, rng.integers(0, 6, size=4)))
    return np.array(sorted(out), dtype=np.int64)


def test_memo_cap_bounds_and_evicts_lru():
    old = pattern_lib.set_memo_cap(8)
    try:
        assert old == pattern_lib.DEFAULT_MEMO_CAP
        codes = _codes(24, seed=0)
        pattern_lib.build_pattern_table(codes)
        canon_size, _ = pattern_lib.memo_sizes()
        assert canon_size <= 8
        # shrinking the cap evicts down immediately
        pattern_lib.set_memo_cap(2)
        canon_size, _ = pattern_lib.memo_sizes()
        assert canon_size <= 2
    finally:
        pattern_lib.set_memo_cap(None)
    assert pattern_lib.set_memo_cap(None) == pattern_lib.DEFAULT_MEMO_CAP


def test_memo_cap_config_knob_applies():
    pattern_lib.set_memo_cap(None)
    _run("host", canonical_memo_cap=16)
    canon_size, _ = pattern_lib.memo_sizes()
    assert canon_size <= 16


def test_memo_concurrent_build_is_consistent():
    codes = _codes(64, seed=3)
    want = pattern_lib.build_pattern_table(codes)
    pattern_lib.clear_memo()
    pattern_lib.set_memo_cap(32)        # force concurrent eviction too
    tables, errors = [None] * 8, []

    def worker(i):
        try:
            rng = np.random.default_rng(i)
            sub = codes[np.sort(rng.choice(len(codes), 48, replace=False))]
            for _ in range(5):
                pattern_lib.build_pattern_table(sub)
            tables[i] = pattern_lib.build_pattern_table(codes)
        except Exception as exc:          # surfaced below
            errors.append(exc)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    for tab in tables:
        np.testing.assert_array_equal(tab.canon_codes, want.canon_codes)
        np.testing.assert_array_equal(tab.sigma, want.sigma)
        np.testing.assert_array_equal(tab.quick_to_canon, want.quick_to_canon)
