"""Observability subsystem tests (DESIGN.md §12).

Covers the tracer contracts (span nesting/ordering, thread ids, the
disabled path staying a shared no-op), the metrics registry as the
single StepStats write path (``obs.count``/``set_stat`` bit-identical to
the raw ``st.x += v`` arithmetic, traced or not), the Chrome trace-event
export schema (every "X" event carries name/ph/ts/dur/pid/tid — the
subset Perfetto needs), phase coverage of real runs on both backends,
the ``trace=False`` zero-extra-syncs guard, the ``trace_sync`` probe
timings (``t_gather``/``t_exchange``) on partitioned runs, and the
summary/phase-wall additions to ``RunStats``.

Graphs stay ~40 vertices: every engine run here is sub-second.
"""
import json
import threading

import numpy as np
import pytest

from benchmarks import render_trace
from repro.core import RunConfig, SuperstepRuntime, graph as G, obs
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.obs import metrics as metrics_lib
from repro.core.obs import tracer as tracer_lib
from repro.core.stats import StepStats


def _graph():
    return G.random_labeled(40, 200, n_labels=3, seed=4)


APPS = {
    "motifs": lambda: MotifsApp(max_size=3),
    "cliques": lambda: CliquesApp(max_size=4),
    "fsm": lambda: FSMApp(support=3, max_size=3),
}

#: per-step counter stats that must be bit-identical traced vs untraced.
COUNTER_STATS = (
    "n_frontier", "n_children", "n_chunks", "n_host_syncs",
    "bytes_to_host", "collective_bytes", "n_generated", "n_canonical",
    "n_quick_patterns", "n_canonical_patterns", "n_iso_checks",
)


# ---------------------------------------------------------------------------
# tracer: spans, nesting, ordering, disabled path
# ---------------------------------------------------------------------------

def test_span_nesting_and_ordering():
    tr = tracer_lib.Tracer()
    with tr.span("outer", step=1):
        with tr.span("inner_a"):
            pass
        with tr.span("inner_b"):
            pass
    # spans close innermost-first; outer closes last
    names = [sp.name for sp in tr.spans]
    assert names == ["inner_a", "inner_b", "outer"]
    outer = tr.spans[-1]
    a, b = tr.spans[0], tr.spans[1]
    assert a.parent == "outer" and b.parent == "outer"
    assert outer.parent is None
    assert a.depth == b.depth == 1 and outer.depth == 0
    # children fall inside the parent's [ts, ts+dur] window, in order
    assert outer.ts <= a.ts and a.ts + a.dur <= b.ts + 1e-6
    assert b.ts + b.dur <= outer.ts + outer.dur + 1e-6
    assert outer.args["step"] == 1


def test_span_threads_get_distinct_tids():
    tr = tracer_lib.Tracer()
    tracer_lib.install(tr)
    try:
        def work():
            with obs.span("worker"):
                pass
        t = threading.Thread(target=work)
        with obs.span("main"):
            t.start()
            t.join()
    finally:
        tracer_lib.install(None)
    tids = {sp.name: sp.tid for sp in tr.spans}
    assert tids["worker"] != tids["main"]
    # the thread's root span has no parent — stacks are per-thread
    worker = next(sp for sp in tr.spans if sp.name == "worker")
    assert worker.parent is None and worker.depth == 0


def test_disabled_span_is_shared_noop():
    assert tracer_lib.current() is None
    s1 = obs.span("anything", step=9)
    s2 = obs.span("else")
    assert s1 is s2  # one preallocated nullcontext, no per-call garbage
    with s1:
        pass


def test_fence_only_blocks_under_sync():
    import jax.numpy as jnp
    x = jnp.arange(8)
    obs.fence(x)                       # no tracer: must be a no-op
    tr = tracer_lib.Tracer(sync=False)
    tracer_lib.install(tr)
    try:
        obs.fence(x)
        assert tr.n_fences == 0
        assert not obs.sync_active()
    finally:
        tracer_lib.install(None)
    tr = tracer_lib.Tracer(sync=True)
    tracer_lib.install(tr)
    try:
        assert obs.sync_active()
        obs.fence(x, None)             # None leaves are tolerated
        assert tr.n_fences == 1
    finally:
        tracer_lib.install(None)


# ---------------------------------------------------------------------------
# metrics: the single write path
# ---------------------------------------------------------------------------

def test_count_and_set_stat_identical_arithmetic():
    a, b = StepStats(step=1, size=1), StepStats(step=1, size=1)
    reg = metrics_lib.MetricsRegistry()
    metrics_lib.install(reg)
    try:
        obs.count(a, "bytes_to_host", 123)
        obs.count(a, "bytes_to_host", np.int64(7))
        obs.set_stat(a, "n_generated", np.int32(55))
    finally:
        metrics_lib.install(None)
    b.bytes_to_host += 123
    b.bytes_to_host += np.int64(7)
    b.n_generated = np.int32(55)
    assert a.bytes_to_host == b.bytes_to_host
    assert a.n_generated == b.n_generated
    snap = reg.snapshot()
    assert snap["counters"]["bytes_to_host"] == 130
    assert snap["gauges"]["n_generated"] == 55
    # uninstalled: same arithmetic, no registry
    obs.count(a, "bytes_to_host", 1)
    assert a.bytes_to_host == 131


def test_gauge_and_device_memory_guarded():
    reg = metrics_lib.MetricsRegistry()
    metrics_lib.install(reg)
    try:
        obs.gauge("watermark", 10, step=1)
        obs.gauge("watermark", 30, step=2)
        obs.gauge("watermark", 20, step=3)
        mem = metrics_lib.sample_device_memory()   # None on CPU: no crash
        assert mem is None or mem > 0
    finally:
        metrics_lib.install(None)
    snap = reg.snapshot()
    assert snap["gauges"]["watermark"] == 20
    assert snap["gauge_max"]["watermark"] == 30


# ---------------------------------------------------------------------------
# Chrome trace export schema
# ---------------------------------------------------------------------------

def test_chrome_trace_schema_fields():
    tr = tracer_lib.Tracer()
    with tr.span("superstep", step=1):
        with tr.span("expand", step=1):
            pass
    tr.counter("bytes", to_host=10)
    events = obs.chrome_trace_events(tr)
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == 2
    for e in xs:
        for k in ("name", "ph", "ts", "dur", "pid", "tid"):
            assert k in e, f"{e['name']}: missing {k}"
        assert e["dur"] >= 0
    cs = [e for e in events if e["ph"] == "C"]
    assert cs and cs[0]["args"] == {"to_host": 10}
    doc = {"traceEvents": events}
    assert obs.validate_chrome_trace(doc) == []
    # the validator actually rejects malformed docs
    assert obs.validate_chrome_trace({}) != []
    bad = {"traceEvents": [{"ph": "X", "name": "x", "ts": 0}]}
    assert any("dur" in p for p in obs.validate_chrome_trace(bad))


def test_phase_coverage_math():
    def x(name, ts, dur, parent=None):
        e = {"ph": "X", "name": name, "ts": ts, "dur": dur,
             "pid": 1, "tid": 0, "args": {}}
        if parent:
            e["args"]["parent"] = parent
        return e
    doc = {"traceEvents": [
        x("superstep", 0, 100),
        x("expand", 0, 60, "superstep"),
        x("aggregate", 60, 35, "superstep"),
        x("expand", 200, 999),            # wrong parent: not counted
    ]}
    cov = obs.phase_coverage(doc)
    assert cov["total_us"] == 100 and cov["covered_us"] == 95
    assert cov["coverage"] == pytest.approx(0.95)
    assert obs.phase_coverage({"traceEvents": []})["coverage"] == 1.0


# ---------------------------------------------------------------------------
# real runs: identity, zero extra syncs, coverage, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store", ["raw", "odag", "spill"])
@pytest.mark.parametrize("app_name", ["motifs", "cliques", "fsm"])
def test_traced_run_bit_identical(app_name, store, tmp_path):
    g = _graph()
    kw = dict(store="raw", device_budget_bytes=4096) if store == "spill" \
        else dict(store=store)
    ref = SuperstepRuntime(g, APPS[app_name](), RunConfig(**kw)).run()
    traced = SuperstepRuntime(
        g, APPS[app_name](),
        RunConfig(trace=True, trace_dir=str(tmp_path), **kw),
    ).run()
    assert traced.patterns == ref.patterns
    assert ref.trace_path is None and traced.trace_path is not None
    assert len(ref.stats.steps) == len(traced.stats.steps)
    for a, b in zip(ref.stats.steps, traced.stats.steps):
        for k in COUNTER_STATS:
            assert getattr(a, k) == getattr(b, k), (app_name, store, k)
    # trace=False left no tracer behind; trace=True uninstalled after
    assert tracer_lib.current() is None
    assert metrics_lib.current() is None
    doc = json.load(open(traced.trace_path))
    assert obs.validate_chrome_trace(doc) == []
    # ≥0.90 here, not the acceptance gate's 0.95: these warm 40-vertex
    # runs finish supersteps in <1ms, where the fixed span bookkeeping
    # between phases is a visible fraction of the wall. The hard ≥95%
    # gate runs on the real mico_like workload (bench_obs + CI).
    assert obs.phase_coverage(doc)["coverage"] >= 0.90


@pytest.mark.parametrize("backend_kind", ["serial", "shard"])
def test_traced_run_coverage_both_backends(backend_kind, tmp_path):
    import jax
    from repro.core.runtime.shard import ShardMapBackend
    g = _graph()

    def backend():
        if backend_kind == "serial":
            return None
        return ShardMapBackend(jax.make_mesh((1,), ("data",)))

    ref = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig(),
                           backend()).run()
    traced = SuperstepRuntime(
        g, MotifsApp(max_size=3),
        RunConfig(trace=True, trace_dir=str(tmp_path)), backend(),
    ).run()
    assert traced.patterns == ref.patterns
    # zero extra host syncs from tracing, per step
    assert [s.n_host_syncs for s in traced.stats.steps] == \
        [s.n_host_syncs for s in ref.stats.steps]
    doc = json.load(open(traced.trace_path))
    assert obs.validate_chrome_trace(doc) == []
    # relaxed vs the 0.95 acceptance gate — see test_traced_run_bit_identical
    assert obs.phase_coverage(doc)["coverage"] >= 0.90


def test_trace_sync_probes_on_partitioned_runs(tmp_path):
    g = _graph()
    ref = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig()).run()
    cfg = RunConfig(trace=True, trace_dir=str(tmp_path), trace_sync=True,
                    graph_partition=2)
    res = SuperstepRuntime(g, MotifsApp(max_size=3), cfg).run()
    assert res.patterns == ref.patterns
    # the tile-gather probe charged t_gather on at least one superstep
    assert any(s.t_gather > 0 for s in res.stats.steps)
    # untraced / non-sync runs leave the probe timings at zero
    plain = SuperstepRuntime(
        g, MotifsApp(max_size=3), RunConfig(graph_partition=2)
    ).run()
    assert all(s.t_gather == 0 for s in plain.stats.steps)
    assert all(s.t_exchange == 0 for s in plain.stats.steps)


def test_trace_exports_jsonl_and_log(tmp_path, capsys):
    g = _graph()
    cfg = RunConfig(trace=True, trace_dir=str(tmp_path), log_every=1)
    res = SuperstepRuntime(g, MotifsApp(max_size=3), cfg).run()
    out = capsys.readouterr().out
    assert "[obs] step=1" in out and "bytes_to_host=" in out
    jsonl = res.trace_path.replace(".trace.json", ".events.jsonl")
    records = [json.loads(l) for l in open(jsonl)]
    kinds = {r["event"] for r in records}
    assert kinds == {"span", "superstep"}
    steps = [r for r in records if r["event"] == "superstep"]
    assert [r["step"] for r in steps] == [s.step for s in res.stats.steps]
    # otherData carries the run metadata + metrics snapshot
    doc = json.load(open(res.trace_path))
    other = doc["otherData"]
    assert other["backend"] == "serial"
    assert other["metrics"]["counters"]["n_host_syncs"] >= 1


def test_log_every_without_trace(tmp_path, capsys):
    g = _graph()
    res = SuperstepRuntime(
        g, MotifsApp(max_size=3), RunConfig(log_every=1)
    ).run()
    assert res.trace_path is None
    assert "[obs] step=1" in capsys.readouterr().out
    assert tracer_lib.current() is None


def test_observer_uninstalls_on_loop_exception(tmp_path):
    class Boom(MotifsApp):
        def filter(self, g, emb):
            raise RuntimeError("boom")
    g = _graph()
    cfg = RunConfig(trace=True, trace_dir=str(tmp_path))
    with pytest.raises(Exception):
        SuperstepRuntime(g, Boom(max_size=3), cfg).run()
    assert tracer_lib.current() is None
    assert metrics_lib.current() is None


def test_abort_path_flushes_partial_trace(tmp_path):
    """A run killed mid-superstep (§13 injected crash) still exports a
    well-formed partial trace: spans closed by the unwinding, document
    marked aborted, JSONL flushed with a terminal abort record, and
    ``render_trace --check`` accepts it (coverage gate waived — a partial
    superstep cannot meet it; schema validation still applies)."""
    from repro.core.runtime import FaultPlan, FaultSpec

    g = _graph()
    plan = FaultPlan([FaultSpec("expand", 2, "crash")])
    cfg = RunConfig(trace=True, trace_dir=str(tmp_path), faults=plan)
    rt = SuperstepRuntime(g, MotifsApp(max_size=3), cfg)
    with pytest.raises(Exception, match="injected"):
        rt.run()
    # tracer/registry uninstalled despite the abort
    assert tracer_lib.current() is None
    assert metrics_lib.current() is None
    # the partial Chrome trace landed, schema-valid and marked aborted
    path = rt.observer.trace_path
    doc = json.load(open(path))
    assert doc["otherData"]["aborted"] is True
    assert obs.validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
    # the span the fault tripped inside was closed by the unwinding
    assert "expand" in names and "superstep" in names
    # --check passes on the aborted doc, schema problems still rejected
    assert render_trace.check(doc) == []
    assert render_trace.main(["--check", path]) == 0
    # JSONL flushed: the aborted superstep's spans plus a terminal record
    jsonl = path.replace(".trace.json", ".events.jsonl")
    records = [json.loads(l) for l in open(jsonl)]
    assert records[-1]["event"] == "aborted"
    assert any(
        r["event"] == "span" and r["name"] == "expand" for r in records
    )


# ---------------------------------------------------------------------------
# RunStats summary additions + render_trace CLI
# ---------------------------------------------------------------------------

def test_summary_has_bytes_and_phase_walls():
    g = _graph()
    res = SuperstepRuntime(g, MotifsApp(max_size=3), RunConfig()).run()
    s = res.stats.summary()
    assert s["total_bytes_to_host"] == res.stats.total_bytes_to_host > 0
    walls = s["phase_walls_s"]
    assert set(walls) == {
        "t_expand", "t_aggregate", "t_canon", "t_storage", "t_gather",
        "t_exchange", "t_checkpoint",
    }
    assert walls["t_expand"] > 0


def test_render_trace_cli(tmp_path, capsys):
    # --check enforces the hard ≥95% gate, which a warm sub-millisecond
    # unit-test run cannot deterministically meet (the CI --check runs
    # on the real mico_like trace) — so the pass case uses a synthetic
    # trace with perfect coverage, and the real run exercises summary
    # mode only.
    def x(name, ts, dur, parent=None):
        e = {"ph": "X", "name": name, "ts": ts, "dur": dur,
             "pid": 1, "tid": 0, "cat": "host", "args": {"step": 1}}
        if parent:
            e["args"]["parent"] = parent
        return e
    good = tmp_path / "good.trace.json"
    good.write_text(json.dumps({"traceEvents": [
        x("superstep", 0, 100),
        x("materialize", 0, 10, "superstep"),
        x("aggregate", 10, 20, "superstep"),
        x("expand", 30, 60, "superstep"),
        x("seal", 90, 10, "superstep"),
    ]}))
    assert render_trace.main(["--check", str(good)]) == 0
    assert "OK" in capsys.readouterr().out
    # summary mode on a real traced run
    g = _graph()
    cfg = RunConfig(trace=True, trace_dir=str(tmp_path))
    res = SuperstepRuntime(g, MotifsApp(max_size=3), cfg).run()
    assert render_trace.main([res.trace_path]) == 0
    out = capsys.readouterr().out
    assert "superstep" in out and "coverage=" in out
    # a truncated trace fails --check
    bad = tmp_path / "bad.trace.json"
    bad.write_text(json.dumps({"traceEvents": []}))
    assert render_trace.main(["--check", str(bad)]) == 1
