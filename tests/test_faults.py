"""Fault-tolerance subsystem tests (DESIGN.md §13).

Covers the deterministic injection layer (FaultSpec/FaultPlan semantics,
fire budgets shared across retries), checkpoint integrity (SHA-256
payload checksums, corrupt-cut detection and rollback, keep-last-K
retention), the ``run_supervised`` supervisor (bounded retry from the
last valid checkpoint, bit-identical recovery, retries-exhausted
re-raise), every rung of the graceful-degradation ladder, the int32
count-saturation satellite (counts > 2^31 stay exact via the wide
re-fold), and recovery visibility in the obs trace.

The full crash-at-every-phase × backend kill matrix lives in
``tests/test_checkpoint.py`` next to the resume identity tests.

Graphs stay ~40 vertices: every engine run here is sub-second.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FaultPlan,
    FaultSpec,
    RunConfig,
    aggregation,
    graph as G,
    run,
    run_supervised,
)
from repro.core.apps import FSMApp, MotifsApp
from repro.core.runtime import ShardMapBackend, checkpoint as ckpt_lib
from repro.core.runtime import faults as faults_lib
from repro.kernels import aggregate as agg_kernel

SMALL = dict(chunk_size=64, initial_capacity=64)


def _graph():
    return G.random_labeled(40, 90, n_labels=3, seed=3)


_CLEAN = {}


def _clean(max_size=3):
    if max_size not in _CLEAN:
        _CLEAN[max_size] = run(
            _graph(), MotifsApp(max_size=max_size), RunConfig(**SMALL)
        )
    return _CLEAN[max_size]


def _mesh1():
    return jax.make_mesh((1,), ("data",))


# ---------------------------------------------------------------------------
# the injection layer
# ---------------------------------------------------------------------------

def test_fault_spec_validates_phase_and_kind():
    with pytest.raises(ValueError):
        FaultSpec("warp", 1, "crash")
    with pytest.raises(ValueError):
        FaultSpec("expand", 1, "gremlin")
    FaultSpec("halo", 2, "halo")  # the exchange site is a valid phase


def test_fault_plan_fire_budget_and_record():
    plan = FaultPlan([FaultSpec("expand", 2, "crash", times=2)])
    with pytest.raises(faults_lib.InjectedCrash):
        plan.trip("expand", 2)
    plan.trip("expand", 1)          # wrong step: no fire
    plan.trip("seal", 2)            # wrong phase: no fire
    with pytest.raises(faults_lib.InjectedCrash):
        plan.trip("expand", 2)
    plan.trip("expand", 2)          # budget spent: no fire
    assert plan.fired == [("expand", 2, "crash")] * 2
    assert plan.exhausted


def test_fault_plan_benign_take_never_raises_in_trip():
    plan = FaultPlan([("checkpoint", 2, "corrupt"), ("aggregate", 2,
                                                     "saturate")])
    plan.trip("checkpoint", 2)      # benign kinds don't trip lethally
    plan.trip("aggregate", 2)
    assert plan.fired == []
    assert plan.take("checkpoint", 2, "corrupt")
    assert not plan.take("checkpoint", 2, "corrupt")  # consumed
    assert plan.take("aggregate", 2, "saturate")
    with pytest.raises(ValueError):
        plan.take("expand", 2, "crash")  # lethal kinds go through trip()


def test_injected_kinds_raise_their_types():
    plan = FaultPlan([
        FaultSpec("expand", 1, "oom"),
        FaultSpec("halo", 1, "halo"),
    ])
    with pytest.raises(faults_lib.InjectedOOM, match="RESOURCE_EXHAUSTED"):
        plan.trip("expand", 1)
    with pytest.raises(faults_lib.InjectedHaloFailure):
        plan.trip("halo", 1)


def test_classify_failure():
    assert faults_lib.classify_failure(faults_lib.InjectedOOM("x")) == "oom"
    assert faults_lib.classify_failure(
        faults_lib.InjectedHaloFailure("x")) == "halo"
    assert faults_lib.classify_failure(
        RuntimeError("RESOURCE_EXHAUSTED: Out of memory")) == "oom"
    assert faults_lib.classify_failure(
        RuntimeError("cuda OUT OF MEMORY allocating")) == "oom"
    assert faults_lib.classify_failure(RuntimeError("segfault-ish")) == "crash"


# ---------------------------------------------------------------------------
# checkpoint integrity: checksums, corruption, rollback, retention
# ---------------------------------------------------------------------------

def _checkpointed_run(td, **kw):
    cfg = RunConfig(**SMALL, checkpoint_dir=str(td), checkpoint_every=1, **kw)
    return run(_graph(), MotifsApp(max_size=3), cfg)


def test_checksum_rides_the_checkpoint_and_verifies(tmp_path):
    _checkpointed_run(tmp_path)
    paths = ckpt_lib.list_checkpoints(str(tmp_path))
    assert paths
    arrays = ckpt_lib.verify(paths[0])
    assert "checksum" in arrays          # the embedded integrity record
    ckpt_lib.load(paths[0])              # verifies + parses


@pytest.mark.parametrize("mode", ["payload", "truncate"])
def test_corrupt_checkpoint_detected(tmp_path, mode):
    _checkpointed_run(tmp_path)
    newest = ckpt_lib.latest_checkpoint(str(tmp_path))
    faults_lib.corrupt_checkpoint(newest, mode=mode)
    with pytest.raises(ckpt_lib.CheckpointCorruptError):
        ckpt_lib.load(newest)


def test_load_latest_valid_rolls_back_past_corrupt(tmp_path):
    _checkpointed_run(tmp_path)
    paths = ckpt_lib.list_checkpoints(str(tmp_path))
    assert len(paths) >= 2
    faults_lib.corrupt_checkpoint(paths[0])
    state, path, skipped = ckpt_lib.load_latest_valid(
        str(tmp_path), rt_g(), MotifsApp(max_size=3)
    )
    assert path == paths[1] and skipped == [paths[0]]
    assert state is not None
    # every cut corrupt -> no state, all skipped
    for p in paths[1:]:
        faults_lib.corrupt_checkpoint(p)
    state, path, skipped = ckpt_lib.load_latest_valid(
        str(tmp_path), rt_g(), MotifsApp(max_size=3)
    )
    assert state is None and path is None and len(skipped) == len(paths)


def rt_g():
    from repro.core import to_device
    return to_device(_graph())


def test_fingerprint_mismatch_is_fatal_not_corrupt(tmp_path):
    _checkpointed_run(tmp_path)
    with pytest.raises(ValueError, match="different app"):
        ckpt_lib.load_latest_valid(
            str(tmp_path), rt_g(), MotifsApp(max_size=4)
        )


def test_keep_checkpoints_retention(tmp_path):
    res = _checkpointed_run(tmp_path, keep_checkpoints=2)
    assert len(res.stats.steps) >= 3
    paths = ckpt_lib.list_checkpoints(str(tmp_path))
    assert len(paths) == 2               # only the newest K cuts survive
    for p in paths:
        ckpt_lib.verify(p)


# ---------------------------------------------------------------------------
# the supervisor: retry, rollback, ladder
# ---------------------------------------------------------------------------

def test_supervised_recovers_from_crash_bit_identically():
    clean = _clean()
    plan = FaultPlan([FaultSpec("expand", 2, "crash")])
    res = run_supervised(
        _graph(), MotifsApp(max_size=3), RunConfig(**SMALL, faults=plan)
    )
    assert res.patterns == clean.patterns
    assert plan.fired == [("expand", 2, "crash")]
    assert res.recovery["n_retries"] == 1
    assert res.recovery["degradations"] == []
    # the retry attempt stamped its first re-executed step
    marked = [s for s in res.stats.steps if s.n_retries]
    assert len(marked) == 1 and marked[0].step == 2
    assert marked[0].t_recovery > 0


def test_supervised_rolls_back_past_injected_corruption():
    clean = _clean(max_size=4)
    # corrupt the newest cut, then crash: the supervisor must detect the
    # checksum mismatch and resume from the previous valid checkpoint
    plan = FaultPlan([
        FaultSpec("checkpoint", 2, "corrupt"),
        FaultSpec("expand", 3, "crash"),
    ])
    res = run_supervised(
        _graph(), MotifsApp(max_size=4), RunConfig(**SMALL, faults=plan)
    )
    assert res.patterns == clean.patterns
    assert res.recovery["rolled_back"] == 1
    assert res.recovery["resumed_step"] == 2   # the cut BEFORE the corrupt one


def test_supervised_reraises_after_retry_budget():
    plan = FaultPlan([FaultSpec("expand", 2, "crash", times=99)])
    cfg = RunConfig(**SMALL, faults=plan, max_retries=2)
    with pytest.raises(faults_lib.InjectedCrash):
        run_supervised(_graph(), MotifsApp(max_size=3), cfg)
    assert len(plan.fired) == 3          # 1 attempt + 2 retries


def test_ladder_oom_caps_then_halves_budget():
    clean = _clean()
    plan = FaultPlan([FaultSpec("expand", 2, "oom")])
    res = run_supervised(
        _graph(), MotifsApp(max_size=3), RunConfig(**SMALL, faults=plan)
    )
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"] == [
        f"budget_capped:{faults_lib._BUDGET_SEED}"
    ]
    # with a budget already set, OOM halves it
    plan = FaultPlan([FaultSpec("expand", 2, "oom")])
    cfg = RunConfig(**SMALL, faults=plan, device_budget_bytes=1 << 20)
    res = run_supervised(_graph(), MotifsApp(max_size=3), cfg)
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"] == [f"budget_halved:{1 << 19}"]


def test_ladder_repeated_expand_crash_drops_fused_then_pallas():
    clean = _clean()
    plan = FaultPlan([FaultSpec("expand", 2, "crash", times=3)])
    cfg = RunConfig(**SMALL, faults=plan, max_retries=5, use_pallas=True,
                    pallas_interpret=True)
    res = run_supervised(_graph(), MotifsApp(max_size=3), cfg)
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"] == ["fused_off", "pallas_off"]


def test_ladder_repeated_aggregate_crash_goes_host():
    clean = _clean()
    plan = FaultPlan([FaultSpec("aggregate", 2, "crash", times=2)])
    res = run_supervised(
        _graph(), MotifsApp(max_size=3),
        RunConfig(**SMALL, faults=plan, max_retries=4),
    )
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"] == ["host_aggregate"]


def test_ladder_halo_failure_downshifts_to_gather():
    clean = _clean()
    plan = FaultPlan([FaultSpec("halo", 2, "halo")])
    cfg = RunConfig(**SMALL, faults=plan, graph_partition=1)
    res = run_supervised(
        _graph(), MotifsApp(max_size=3), cfg, ShardMapBackend(_mesh1())
    )
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"] == ["halo_gather"]


def test_apply_degradation_rungs_are_pure_config_transforms():
    cfg = RunConfig()
    c1, e1 = faults_lib.apply_degradation(cfg, "expand", "oom")
    assert e1.startswith("budget_capped") and c1.device_budget_bytes
    c2, e2 = faults_lib.apply_degradation(c1, "expand", "oom")
    assert e2.startswith("budget_halved")
    assert c2.device_budget_bytes == c1.device_budget_bytes // 2
    c3, e3 = faults_lib.apply_degradation(cfg, "seal", "crash")
    assert e3 == "fused_off" and c3.async_chunks is False
    c4, e4 = faults_lib.apply_degradation(cfg, "alpha", "crash")
    assert e4 == "host_aggregate" and c4.device_aggregate is False
    c5, e5 = faults_lib.apply_degradation(cfg, "halo", "halo")
    assert e5 == "halo_gather" and c5.resolve_halo() == "gather"
    # checkpoint failures have no rung: retry is the remedy
    c6, e6 = faults_lib.apply_degradation(cfg, "checkpoint", "crash")
    assert e6 is None and c6 is cfg
    # the original config is never mutated (async_chunks default is the
    # tri-state None = cost-modelled, DESIGN.md §14)
    assert cfg.device_budget_bytes is None and cfg.async_chunks is None


def test_saturate_fault_exercises_wide_refold_both_backends():
    clean = _clean()
    plan = FaultPlan([FaultSpec("aggregate", 2, "saturate")])
    res = run(_graph(), MotifsApp(max_size=3), RunConfig(**SMALL,
                                                         faults=plan))
    assert res.patterns == clean.patterns
    assert plan.fired == [("aggregate", 2, "saturate")]
    from repro.core.distributed import run_distributed
    plan = FaultPlan([FaultSpec("aggregate", 2, "saturate")])
    res = run_distributed(
        _graph(), MotifsApp(max_size=3), _mesh1(),
        RunConfig(**SMALL, faults=plan),
    )
    assert res.patterns == clean.patterns
    assert plan.fired == [("aggregate", 2, "saturate")]


def test_supervised_fsm_with_domains_recovers():
    g = _graph()
    app = FSMApp(support=3, max_size=3)
    clean = run(g, app, RunConfig(**SMALL))
    plan = FaultPlan([FaultSpec("aggregate", 2, "crash")])
    res = run_supervised(g, app, RunConfig(**SMALL, faults=plan))
    assert res.patterns == clean.patterns


# ---------------------------------------------------------------------------
# recovery visibility in the trace
# ---------------------------------------------------------------------------

def test_recovery_span_and_degradations_visible_in_trace(tmp_path):
    clean = _clean()
    plan = FaultPlan([FaultSpec("expand", 2, "oom")])
    cfg = RunConfig(**SMALL, faults=plan, trace=True,
                    trace_dir=str(tmp_path))
    res = run_supervised(_graph(), MotifsApp(max_size=3), cfg)
    assert res.patterns == clean.patterns
    doc = json.load(open(res.trace_path))
    rec = [e for e in doc["traceEvents"]
           if e.get("ph") == "X" and e["name"] == "recovery"]
    assert len(rec) == 1
    args = rec[0]["args"]
    assert args["n_retries"] == 1
    assert args["degradations"] == [f"budget_capped:{faults_lib._BUDGET_SEED}"]
    # the crashed attempt exported its own partial trace, marked aborted
    aborted = [
        json.load(open(os.path.join(tmp_path, f)))
        for f in sorted(os.listdir(tmp_path)) if f.endswith(".trace.json")
        if os.path.join(tmp_path, f) != res.trace_path
    ]
    assert any(d["otherData"].get("aborted") for d in aborted)


# ---------------------------------------------------------------------------
# int32 count saturation (satellite): counts > 2^31 stay exact
# ---------------------------------------------------------------------------

BIG = 2 ** 31 + 5


def test_fold_partial_int64_counts_past_2_31_exact():
    lvl1 = aggregation.DeviceLevel1(merge_cap=8)
    uniq = jnp.asarray(
        np.array([[3, 0, 0], [5, 0, 0], [0, 0, 0], [0, 0, 0]], np.int64)
    )
    counts = jnp.asarray(np.array([BIG, 7, 0, 0], np.int64))
    lvl1.fold_partial(uniq, counts, jnp.asarray(2, jnp.int32), 4, rows=10)
    u, c, _ = lvl1.finish()
    assert c.dtype == np.int64           # fit32 kept the drain wide
    assert int(c[0]) == BIG and int(c[1]) == 7


def test_saturated_int32_partial_forces_wide_refold():
    lvl1 = aggregation.DeviceLevel1(merge_cap=8)
    uniq = jnp.asarray(
        np.array([[3, 0, 0], [5, 0, 0], [0, 0, 0], [0, 0, 0]], np.int64)
    )
    sat = jnp.asarray(
        np.array([agg_kernel.I32_SAT, 7, 0, 0], np.int32)
    )
    lvl1.fold_partial(uniq, sat, jnp.asarray(2, jnp.int32), 4, rows=10)
    assert lvl1.finish() is None         # the 7th flag: re-fold wide
    # an unsaturated int32 partial still drains normally
    lvl1 = aggregation.DeviceLevel1(merge_cap=8)
    ok = jnp.asarray(np.array([9, 7, 0, 0], np.int32))
    lvl1.fold_partial(uniq, ok, jnp.asarray(2, jnp.int32), 4, rows=16)
    u, c, _ = lvl1.finish()
    assert c.tolist() == [9, 7]


def test_weighted_bin_rows_past_2_31_exact():
    codes = jnp.asarray(
        np.array([[3, 0, 0], [3, 0, 0], [5, 0, 0]], np.int64)
    )
    w = jnp.asarray(np.array([BIG, BIG, 3], np.int64))
    _, counts, _, n, _ = agg_kernel.bin_rows(
        codes, jnp.ones((3,), bool), 4, weights=w
    )
    assert int(n) == 2
    assert int(counts[0]) == 2 * BIG and int(counts[1]) == 3


# ---------------------------------------------------------------------------
# level-2 placement rung (DESIGN.md §15)
# ---------------------------------------------------------------------------

def test_ladder_canon_placement_downshifts_first():
    # rung 0 of the aggregate/alpha branch: a lifted level-2 placement
    # drops to the synchronous host batch before anything else
    cfg = RunConfig(canonical_placement="device")
    c1, e1 = faults_lib.apply_degradation(cfg, "aggregate", "crash")
    assert e1 == "canon_host" and c1.resolve_canonical_placement() == "host"
    c1b, e1b = faults_lib.apply_degradation(
        RunConfig(canonical_placement="host_async"), "alpha", "crash"
    )
    assert e1b == "canon_host" and c1b.resolve_canonical_placement() == "host"
    # the NEXT failure proceeds down the pre-existing rungs unchanged
    c2, e2 = faults_lib.apply_degradation(c1, "aggregate", "crash")
    assert e2 == "host_aggregate" and c2.device_aggregate is False
    # unresolved knob (None -> "host" pre-calibration) is a no-op rung:
    # default-config ladder sequences keep their exact shape
    c3, e3 = faults_lib.apply_degradation(RunConfig(), "aggregate", "crash")
    assert e3 == "host_aggregate"
    assert cfg.canonical_placement == "device"   # inputs never mutated


def test_supervised_canon_rung_recovers_bit_identically():
    clean = _clean()
    plan = FaultPlan([FaultSpec("aggregate", 2, "crash", times=2)])
    res = run_supervised(
        _graph(), MotifsApp(max_size=3),
        RunConfig(**SMALL, faults=plan, canonical_placement="device",
                  max_retries=3),
    )
    assert res.patterns == clean.patterns
    assert res.recovery["degradations"][0] == "canon_host"
