"""Training substrate: AdamW descends, checkpoints survive restart+reshape,
NaN steps are skipped, straggler watchdog flags outliers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS
from repro.models import build_model
from repro.training import checkpoint as ckpt
from repro.training.data import DataConfig, global_batch, shard_batch
from repro.training.optimizer import AdamWConfig, apply_update, init_opt_state, lr_at
from repro.training.train_step import TrainLoop, make_train_step

CFG = ARCHS["smollm-135m"].reduced()
SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def _setup():
    m = build_model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    dc = DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=4, seed=1)
    return m, params, dc


def test_adamw_descends():
    m, params, dc = _setup()
    loop = TrainLoop(m, AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50))
    batches = [global_batch(dc, s) for s in range(12)]
    _, _, hist = loop.run(params, batches)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.2, (first, last)
    assert not any(h["skipped"] for h in hist)


def test_lr_schedule():
    oc = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_at(oc, jnp.float32(5))) == pytest.approx(0.5)
    assert float(lr_at(oc, jnp.float32(10))) == pytest.approx(1.0)
    assert float(lr_at(oc, jnp.float32(110))) == pytest.approx(0.0, abs=1e-6)


def test_nan_step_skipped():
    m, params, dc = _setup()
    step_fn = jax.jit(make_train_step(m, AdamWConfig(lr=1e-3)))
    state = init_opt_state(params)
    bad = global_batch(dc, 0)
    bad["tokens"] = bad["tokens"].copy()
    p2, s2, metrics = step_fn(params, state, bad)
    # poison params -> NaN loss -> update must be skipped
    poisoned = jax.tree.map(lambda x: x * jnp.nan, params)
    p3, s3, metrics = step_fn(poisoned, state, bad)
    assert int(metrics["skipped"]) == 1
    chex_equal = jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32), equal_nan=True),
        p3, poisoned,
    )
    assert all(jax.tree.leaves(chex_equal))


def test_checkpoint_roundtrip_and_resume(tmp_path):
    m, params, dc = _setup()
    loop = TrainLoop(
        m,
        AdamWConfig(lr=1e-3),
        ckpt_dir=str(tmp_path),
        ckpt_every=5,
    )
    batches = [global_batch(dc, s) for s in range(10)]
    p1, s1, hist1 = loop.run(params, batches)
    assert ckpt.latest_step(str(tmp_path)) == 10

    # resume: a NEW loop continues from step 10 without re-running old steps
    loop2 = TrainLoop(m, AdamWConfig(lr=1e-3), ckpt_dir=str(tmp_path), ckpt_every=5)
    batches2 = [global_batch(dc, s) for s in range(10, 13)]
    p2, s2, hist2 = loop2.run(params, batches2)
    assert hist2[0]["step"] == 10
    assert int(s2.step) == 13


def test_checkpoint_atomicity(tmp_path):
    m, params, _ = _setup()
    state = init_opt_state(params)
    ckpt.save(str(tmp_path), 7, (params, state))
    # a stale .tmp from a crashed writer must be invisible
    import os

    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored_p, restored_s = ckpt.restore(str(tmp_path), 7, (params, state))
    for a, b in zip(jax.tree.leaves(restored_p), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_elastic_reshard_roundtrip(tmp_path):
    """Save, then restore under a different device mesh (1 device here, but
    through the device_put/shardings path used for N devices)."""
    m, params, _ = _setup()
    ckpt.save(str(tmp_path), 1, params)
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    restored = ckpt.restore(str(tmp_path), 1, params, shardings=shardings)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_data_pipeline_determinism_and_sharding():
    dc = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=3)
    b1 = global_batch(dc, 5)
    b2 = global_batch(dc, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards tile the global batch exactly
    parts = [shard_batch(dc, 5, s, 4)["tokens"] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    assert (global_batch(dc, 6)["tokens"] != b1["tokens"]).any()
