"""Frontier-store subsystem tests (DESIGN.md §7): store unit behaviour and
the acceptance contract — ODAGStore / SpillStore engine runs reproduce
RawStore results on motifs, cliques, and FSM for both execution paths."""
import numpy as np
import pytest

from repro.core import EngineConfig, graph as G, run, to_device
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.store import ODAGStore, RawStore, SpillStore, make_store

CFG = dict(chunk_size=2048, initial_capacity=2048)


def _emb_sets(res):
    return {k: set(map(tuple, v.tolist())) for k, v in res.embeddings.items()}


def _assert_same(base, other):
    assert base.patterns == other.patterns
    assert _emb_sets(base) == _emb_sets(other)


# ---------------------------------------------------------------------------
# unit behaviour
# ---------------------------------------------------------------------------

def test_raw_store_roundtrip_and_waves():
    s = RawStore()
    a = np.arange(6, dtype=np.int32).reshape(3, 2)
    b = np.arange(6, 14, dtype=np.int32).reshape(4, 2)
    s.append(a)
    s.append(b, worker=1)      # worker tag is ignored by RawStore
    s.seal(2)
    assert s.n_rows == 7 and s.size == 2
    assert s.raw_bytes == s.stored_bytes == 7 * 2 * 4
    assert (s.materialize() == np.concatenate([a, b])).all()
    waves = list(s.chunks(max_rows=3))
    assert [len(w) for w in waves] == [3, 3, 1]
    assert (np.concatenate(waves) == s.materialize()).all()
    parts = s.worker_parts(3)
    assert (np.concatenate(parts) == s.materialize()).all()
    # re-seal with nothing staged -> empty frontier of the new width
    s.seal(3)
    assert s.n_rows == 0 and list(s.chunks()) == []


def test_spill_store_bounds_wave_rows():
    inner = RawStore()
    inner.append(np.arange(20, dtype=np.int32).reshape(10, 2))
    inner.seal(2)
    s = SpillStore(inner, device_budget_bytes=3 * 2 * 4)   # 3 rows of width 2
    assert s.budget_rows() == 3
    waves = list(s.chunks())
    assert max(len(w) for w in waves) <= 3
    assert (np.concatenate(waves) == inner.materialize()).all()
    with pytest.raises(ValueError):
        SpillStore(RawStore(), 0)


def test_odag_store_seal_and_extract():
    g = G.random_labeled(40, 90, n_labels=1, seed=2)
    dg = to_device(g)
    res = run(g, MotifsApp(max_size=3, collect_embeddings=True),
              EngineConfig(**CFG))
    emb = res.embeddings[3]
    s = ODAGStore(dg)
    half = len(emb) // 2
    s.append(emb[:half])
    s.append(emb[half:])
    s.seal(3)
    assert s.n_rows == len(emb)
    assert 0 < s.stored_bytes < s.raw_bytes      # actually compressed
    want = set(map(tuple, emb.tolist()))
    assert set(map(tuple, s.materialize().tolist())) == want
    # budgeted waves cover the same set, cost-balanced per §5.3, and honour
    # the hard per-wave row bound (hub partitions are sliced)
    budget = max(len(emb) // 3, 1)
    waves = list(s.chunks(max_rows=budget))
    assert len(waves) > 1
    assert max(len(w) for w in waves) <= budget
    got = set(map(tuple, np.concatenate(waves).tolist()))
    assert got == want
    # per-worker slices: disjoint, union exact
    parts = s.worker_parts(4)
    assert sum(len(p) for p in parts) == len(want)
    assert set(map(tuple, np.concatenate(parts).tolist())) == want


def test_odag_store_dense_exchange_merges_workers():
    g = G.random_labeled(40, 90, n_labels=1, seed=4)
    dg = to_device(g)
    res = run(g, MotifsApp(max_size=3, collect_embeddings=True),
              EngineConfig(**CFG))
    emb = res.embeddings[3]
    s = ODAGStore(dg, dense_exchange=True)
    third = len(emb) // 3
    s.append(emb[:third], worker=0)
    s.append(emb[third:], worker=1)
    s.append(emb[:0], worker=2)
    s.seal(3)
    assert set(map(tuple, s.materialize().tolist())) == set(
        map(tuple, emb.tolist())
    )
    # the exchange ships the fixed-shape dense form (what the OR-allreduce
    # collective would move), not the embedding list
    assert s.exchange_bytes > 0


def test_make_store_kinds():
    g = to_device(G.triangle_plus_tail())
    assert isinstance(make_store("raw"), RawStore)
    assert isinstance(make_store("odag", g), ODAGStore)
    spilled = make_store("raw", device_budget_bytes=1024)
    assert isinstance(spilled, SpillStore) and spilled.kind == "raw"
    assert make_store("odag", g, device_budget_bytes=64).kind == "odag"
    with pytest.raises(ValueError):
        make_store("mmap")
    with pytest.raises(ValueError):
        make_store("odag")      # needs the device graph


# ---------------------------------------------------------------------------
# acceptance: engine equivalence across stores
# ---------------------------------------------------------------------------

APP_FACTORIES = [
    ("motifs", lambda: MotifsApp(max_size=4, collect_embeddings=True)),
    ("cliques", lambda: CliquesApp(max_size=4)),
    ("fsm", lambda: FSMApp(support=3, max_size=3, collect_embeddings=True)),
]


@pytest.mark.parametrize("name,mk", APP_FACTORIES, ids=[n for n, _ in APP_FACTORIES])
def test_engine_odag_store_matches_raw(name, mk):
    g = G.random_labeled(40, 90, n_labels=3, seed=1)
    base = run(g, mk(), EngineConfig(**CFG))
    odag = run(g, mk(), EngineConfig(store="odag", **CFG))
    _assert_same(base, odag)
    # the compressed representation is what lived between supersteps
    deep = [s for s in odag.stats.steps if s.size >= 3]
    assert any(s.odag_bytes > 0 for s in deep)


@pytest.mark.parametrize("store", ["raw", "odag"])
def test_engine_spill_budget_smaller_than_peak_matches(store):
    """SpillStore with a device budget below the peak frontier mines in
    waves and still reproduces the RawStore results."""
    g = G.random_labeled(40, 90, n_labels=3, seed=1)
    mk = lambda: MotifsApp(max_size=4, collect_embeddings=True)
    base = run(g, mk(), EngineConfig(**CFG))
    peak = max(s.frontier_bytes for s in base.stats.steps)
    budget = max(peak // 8, 64)
    assert budget < peak
    spilled = run(
        g, mk(),
        EngineConfig(store=store, device_budget_bytes=budget, **CFG),
    )
    _assert_same(base, spilled)


def test_engine_fsm_spill_matches():
    g = G.random_labeled(40, 90, n_labels=3, seed=1)
    mk = lambda: FSMApp(support=3, max_size=3)
    base = run(g, mk(), EngineConfig(**CFG))
    spilled = run(
        g, mk(),
        EngineConfig(store="odag", device_budget_bytes=256, **CFG),
    )
    assert base.patterns == spilled.patterns


# ---------------------------------------------------------------------------
# acceptance: distributed equivalence across stores (1-device mesh; the
# multi-device collective path runs in test_distributed.py under @slow)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,mk", APP_FACTORIES, ids=[n for n, _ in APP_FACTORIES])
def test_distributed_odag_store_matches_serial(name, mk):
    import jax

    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=1)
    ser = run(g, mk(), EngineConfig(**CFG))
    raw = run_distributed(g, mk(), mesh, DistConfig())
    odag = run_distributed(g, mk(), mesh, DistConfig(store="odag"))
    _assert_same(ser, raw)
    _assert_same(ser, odag)
