"""Roofline analysis unit tests: HLO collective parser, term math, and the
scan-body-once behaviour that motivates the depth extrapolation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import hw
from repro.roofline.analysis import Roofline, collective_bytes, _shape_bytes


HLO_SAMPLE = """
  %all-reduce.5 = f32[16,4096]{1,0} all-reduce(%x), replica_groups=[]
  %ag = bf16[256,1024]{1,0} all-gather(%y), dimensions={0}
  %rs = (f32[128]{0}, f32[128]{0}) reduce-scatter(%a, %b), dimensions={0}
  %done = f32[16,4096]{1,0} all-reduce-done(%start)
  %a2a = s32[64,32]{1,0} all-to-all(%z), dimensions={1}
  %cp = bf16[8,128]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %notacoll = f32[999]{0} add(%p, %q)
"""


def test_shape_bytes():
    assert _shape_bytes("f32[16,4096]") == 16 * 4096 * 4
    assert _shape_bytes("bf16[256,1024]") == 256 * 1024 * 2
    assert _shape_bytes("(f32[128], f32[128])") == 2 * 128 * 4
    assert _shape_bytes("pred[]") == 1


def test_collective_parser():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 4096 * 4          # -done skipped
    assert out["all-gather"] == 256 * 1024 * 2
    assert out["reduce-scatter"] == 2 * 128 * 4
    assert out["all-to-all"] == 64 * 32 * 4
    assert out["collective-permute"] == 8 * 128 * 2


def test_roofline_terms_and_bottleneck():
    r = Roofline(
        flops=hw.PEAK_FLOPS_BF16,      # 1 second of compute
        hbm_bytes=hw.HBM_BW * 2,       # 2 seconds of memory
        coll_bytes=hw.ICI_BW * 0.5,    # 0.5 seconds of collectives
        chips=256,
        model_flops=hw.PEAK_FLOPS_BF16 * 256 * 0.5,  # 0.5 s useful / device
    )
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(2.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck == "memory"
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.25)  # 0.5s useful / 2s bound


def test_scan_body_counted_once():
    """The empirical fact behind the dry-run's depth extrapolation."""

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    c = jax.jit(scanned).lower(x, ws).compile()
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    one_body = 2 * 128 * 256 * 256
    assert ca["flops"] < 2 * one_body  # counted once, not x8


def test_unrolled_cost_is_affine_in_depth():
    """cost(L) = a + b*L for unrolled models — the extrapolation's premise."""

    def make(n):
        def f(x, ws):
            for i in range(n):
                x = jnp.tanh(x @ ws[i])
            return x.sum()
        return f

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
    fl = []
    for n in (1, 2, 4):
        c = jax.jit(make(n)).lower(x, ws).compile()
        ca = c.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        fl.append(float(ca["flops"]))
    slope1 = fl[1] - fl[0]
    slope2 = (fl[2] - fl[1]) / 2
    assert slope1 == pytest.approx(slope2, rel=0.05)


def test_model_flops_formula():
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_arch
    from repro.models import build_model
    from repro.roofline.analysis import count_params, model_flops_for

    cfg = get_arch("smollm-135m")
    m = build_model(cfg)
    ps = m.init_shapes(jax.random.PRNGKey(0))
    counts = count_params(ps)
    # ~135M params total (embeddings two-sided: vocab*d*2 = 56.6M)
    assert 100e6 < counts["total"] < 200e6
    mf_train = model_flops_for(cfg, SHAPE_BY_NAME["train_4k"], ps)
    mf_dec = model_flops_for(cfg, SHAPE_BY_NAME["decode_32k"], ps)
    n = counts["total"] - counts["embedding"]
    assert mf_train == pytest.approx(6 * n * 256 * 4096)
    assert mf_dec == pytest.approx(2 * n * 128)


def test_moe_active_fraction():
    from repro.configs.base import SHAPE_BY_NAME
    from repro.configs.registry import get_arch
    from repro.models import build_model
    from repro.roofline.analysis import count_params, model_flops_for

    cfg = get_arch("deepseek-v2-236b")
    m = build_model(cfg)
    ps = m.init_shapes(jax.random.PRNGKey(0))
    counts = count_params(ps)
    assert counts["total"] > 200e9  # ~236B
    mf = model_flops_for(cfg, SHAPE_BY_NAME["train_4k"], ps)
    dense_equiv = 6 * (counts["total"] - counts["embedding"]) * 256 * 4096
    assert mf < dense_equiv * 0.2  # top-6 of 160: only ~5% of experts active
