"""ODAG tests (paper §5.2): exact roundtrip, spurious filtering, merge,
compression, cost-annotated partitioning (§5.3)."""
import numpy as np

from repro.core import EngineConfig, graph as G, run, to_device
from repro.core import odag
from repro.core.apps import FSMApp, MotifsApp


def _frontier(g, app, size):
    res = run(g, app, EngineConfig(chunk_size=2048, initial_capacity=2048))
    return res.embeddings[size]


def test_build_extract_roundtrip_vertex():
    g = G.random_labeled(80, 200, n_labels=2, seed=1)
    emb = _frontier(g, MotifsApp(max_size=4, collect_embeddings=True), 4)
    o = odag.build(emb)
    ext = odag.extract(to_device(g), o)
    assert set(map(tuple, emb.tolist())) == set(map(tuple, ext.tolist()))
    assert len(ext) == len(emb)  # no spurious survivors, no duplicates


def test_build_extract_roundtrip_edge():
    g = G.random_labeled(40, 90, n_labels=2, seed=3)
    emb = _frontier(
        g, FSMApp(support=1, max_size=3, collect_embeddings=True), 3
    )
    o = odag.build(emb)
    ext = odag.extract(to_device(g), o, mode="edge")
    assert set(map(tuple, emb.tolist())) == set(map(tuple, ext.tolist()))


def test_odag_encodes_superset():
    """Figure 6's point: path enumeration without filtering produces
    spurious embeddings."""
    g = G.triangle_plus_tail()
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    o = odag.build(emb)
    assert o.path_upper_bound() >= len(emb)


def test_odag_compresses(tmp_path):
    g = G.random_labeled(100, 300, n_labels=1, seed=2)
    emb = _frontier(g, MotifsApp(max_size=4, collect_embeddings=True), 4)
    o = odag.build(emb)
    assert o.n_bytes < emb.size * 4 / 5  # >5x on this density


def test_merge_equals_joint_build():
    g = G.random_labeled(60, 150, n_labels=1, seed=5)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    half = len(emb) // 2
    merged = odag.merge([odag.build(emb[:half]), odag.build(emb[half:])])
    joint = odag.build(emb)
    assert [d.tolist() for d in merged.domains] == [d.tolist() for d in joint.domains]
    assert all((a == b).all() for a, b in zip(merged.conn, joint.conn))


def test_dense_merge_and_extract():
    g = G.random_labeled(60, 150, n_labels=1, seed=6)
    dg = to_device(g)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    half = len(emb) // 2
    d1 = odag.build_dense(emb[:half], g.n, 3)
    d2 = odag.build_dense(emb[half:], g.n, 3)
    merged = odag.DenseODAG(
        k=3,
        domain_bits=d1.domain_bits | d2.domain_bits,
        conn_bits=d1.conn_bits | d2.conn_bits,
    )
    ext = odag.extract(dg, odag.dense_to_ragged(merged))
    assert set(map(tuple, emb.tolist())) == set(map(tuple, ext.tolist()))


def _star(n_leaves=6):
    """Single-hub graph: vertex 0 adjacent to every leaf — one first-level
    element carries (almost) all extraction cost."""
    return G.Graph(
        n=n_leaves + 1,
        labels=np.zeros(n_leaves + 1, dtype=np.int32),
        edges=np.array([[0, v] for v in range(1, n_leaves + 1)], np.int32),
    )


def test_partition_masks_cover_domain_exactly_once():
    """§5.3: the per-worker masks are a partition of the first-level domain
    — every element claimed by exactly one worker, none dropped."""
    for g, size in [
        (G.random_labeled(80, 250, n_labels=1, seed=7), 3),
        (_star(), 2),
    ]:
        emb = _frontier(g, MotifsApp(max_size=size, collect_embeddings=True), size)
        o = odag.build(emb)
        for n_workers in (1, 2, 3, 8, 64):
            masks = odag.partition_by_cost(o, n_workers)
            assert len(masks) == n_workers
            stacked = np.stack(masks)
            assert (stacked.sum(axis=0) == 1).all()


def test_partition_masks_empty_frontier():
    o = odag.build(np.zeros((0, 3), np.int32), k=3)
    masks = odag.partition_by_cost(o, 4)
    assert len(masks) == 4
    assert all(m.shape == (0,) and m.dtype == bool for m in masks)
    assert len(odag.extract(to_device(_star()), o)) == 0


def test_extract_partition_shards_union_to_extract():
    """Per-worker extractions are disjoint and union to the full extraction."""
    g = G.random_labeled(60, 180, n_labels=2, seed=9)
    dg = to_device(g)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    o = odag.build(emb)
    full = set(map(tuple, odag.extract(dg, o).tolist()))
    for n_workers in (2, 5):
        shards = [
            set(map(tuple, odag.extract_partition(dg, o, m).tolist()))
            for m in odag.partition_by_cost(o, n_workers)
        ]
        assert set().union(*shards) == full
        assert sum(len(s) for s in shards) == len(full)  # pairwise disjoint


def test_extract_partition_single_hub():
    """Star graph: one first-level element exceeds the per-worker cost
    target; it goes to one worker (bounded imbalance, no recursion) and the
    shard union is still exact."""
    g = _star(8)
    dg = to_device(g)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    o = odag.build(emb)
    masks = odag.partition_by_cost(o, 3)
    assert (np.stack(masks).sum(axis=0) == 1).all()
    full = set(map(tuple, odag.extract(dg, o).tolist()))
    shards = [
        set(map(tuple, odag.extract_partition(dg, o, m).tolist()))
        for m in masks
    ]
    assert set().union(*shards) == full
    assert full == set(map(tuple, emb.tolist()))


def test_merge_roundtrips_worker_local_odags():
    """Worker-local ODAGs merge into one whose extraction equals the union
    of the workers' embeddings (the distributed seal path), including a
    worker with an empty share."""
    g = G.random_labeled(60, 150, n_labels=1, seed=5)
    dg = to_device(g)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    third = len(emb) // 3
    shares = [emb[:third], emb[third:], emb[:0]]  # one worker empty
    merged = odag.merge([odag.build(s, k=3) for s in shares])
    ext = odag.extract(dg, merged)
    assert set(map(tuple, ext.tolist())) == set(map(tuple, emb.tolist()))
    assert len(ext) == len(emb)


def test_cost_estimate_partitions_evenly():
    """§5.3: the path-count annotation bounds real extraction work."""
    g = G.random_labeled(80, 250, n_labels=1, seed=7)
    emb = _frontier(g, MotifsApp(max_size=3, collect_embeddings=True), 3)
    o = odag.build(emb)
    ub = o.path_upper_bound()
    assert ub >= len(emb)
    # per-first-element costs sum to the total (the §5.3 partitioning basis)
    cost = np.ones(len(o.domains[-1]), dtype=np.int64)
    for c in reversed(o.conn):
        cost = c @ cost
    assert int(cost.sum()) == ub
