"""Fused superstep pipeline tests (DESIGN.md §8).

Covers the stream-compaction kernel against its jnp contract, the
acceptance-criterion equivalence — ``async_chunks=True`` (fused) vs
``False`` (the PR-2 chunk loop) produce identical pattern dicts and
embedding *sets* for motifs, cliques, and FSM across all three frontier
stores — the O(1)-syncs-per-superstep property, the pow2 bucketing bound
on compiled chunk programs, the lazy device-array store append, and the
fused program under ``shard_map``.

Kernel invocations pin ``interpret=True`` so CPU CI runs the exact kernel
dataflow deterministically. Graphs stay ~40 vertices (engine runs are
seconds each; equivalence matrices multiply fast).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, graph as G, run, to_device
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.store import RawStore
from repro.kernels.compact import stream_compact_pallas, stream_compact_ref


def _emb_sets(res):
    return {k: set(map(tuple, v.tolist())) for k, v in res.embeddings.items()}


# ---------------------------------------------------------------------------
# stream-compaction kernel vs the jnp contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [0, 1, 5, 127, 256, 1000])
@pytest.mark.parametrize("out_cap", [1, 64, 2048])
def test_stream_compact_matches_ref(b, out_cap):
    rng = np.random.default_rng(b + out_cap)
    keep = jnp.asarray(rng.random(b) < 0.3) if b else jnp.zeros((0,), bool)
    idx_k, cnt_k = stream_compact_pallas(keep, out_cap, block=64, interpret=True)
    idx_r, cnt_r = stream_compact_ref(keep, out_cap)
    # count is the UNCLAMPED kept total (host overflow detection relies on
    # it), identical between kernel and jnp route
    assert int(cnt_k) == int(cnt_r) == int(np.asarray(keep).sum())
    valid = min(int(cnt_k), out_cap)
    np.testing.assert_array_equal(
        np.asarray(idx_k[:valid]), np.asarray(idx_r[:valid])
    )
    # pad slots hold the jnp fill value (0)
    assert (np.asarray(idx_k[valid:]) == 0).all()


@pytest.mark.parametrize("keep", [
    np.zeros(100, bool),          # nothing kept
    np.ones(100, bool),           # everything kept
    np.arange(100) % 2 == 0,      # alternating
])
def test_stream_compact_edge_masks(keep):
    idx_k, cnt_k = stream_compact_pallas(
        jnp.asarray(keep), 128, block=32, interpret=True
    )
    idx_r, cnt_r = stream_compact_ref(jnp.asarray(keep), 128)
    assert int(cnt_k) == int(cnt_r)
    np.testing.assert_array_equal(np.asarray(idx_k), np.asarray(idx_r))


def test_compact_routes_through_kernel():
    """explore.compact(use_kernel=True) reproduces the jnp gather exactly
    on a real expansion."""
    from repro.core import explore

    dg = to_device(G.random_labeled(40, 90, n_labels=2, seed=1))
    members = jnp.arange(dg.n, dtype=jnp.int32)[:, None]
    nv = jnp.ones((dg.n,), jnp.int32)
    exp = explore.expand_vertex(dg, members, nv)
    c_ref, n_ref = explore.compact(members, exp, exp.keep, 256)
    c_ker, n_ker = explore.compact(
        members, exp, exp.keep, 256, use_kernel=True, interpret=True
    )
    assert int(n_ref) == int(n_ker)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_ker))


# ---------------------------------------------------------------------------
# acceptance criterion: fused == legacy for all apps x all three stores
# ---------------------------------------------------------------------------

APPS = [
    ("motifs", lambda: MotifsApp(max_size=3, collect_embeddings=True)),
    ("cliques", lambda: CliquesApp(max_size=4, collect_embeddings=True)),
    ("fsm", lambda: FSMApp(support=3, max_size=3, collect_embeddings=True)),
]
STORES = [
    ("raw", dict(store="raw")),
    ("odag", dict(store="odag")),
    ("spill", dict(store="raw", device_budget_bytes=2048)),
]
# small chunks so the fused pipeline actually exercises multi-chunk dispatch
SMALL = dict(chunk_size=64, initial_capacity=64)


@pytest.mark.parametrize("sname,skw", STORES, ids=[s[0] for s in STORES])
@pytest.mark.parametrize("aname,mk", APPS, ids=[a[0] for a in APPS])
def test_fused_matches_legacy(aname, mk, sname, skw):
    g = G.random_labeled(40, 90, n_labels=3, seed=3)
    legacy = run(g, mk(), EngineConfig(async_chunks=False, **SMALL, **skw))
    fused = run(g, mk(), EngineConfig(async_chunks=True, **SMALL, **skw))
    assert legacy.patterns == fused.patterns
    assert _emb_sets(legacy) == _emb_sets(fused)


def test_fused_with_compact_kernel_matches_legacy():
    g = G.random_labeled(40, 90, n_labels=3, seed=5)
    legacy = run(g, MotifsApp(max_size=3), EngineConfig(async_chunks=False))
    fused = run(
        g, MotifsApp(max_size=3),
        EngineConfig(
            async_chunks=True, compact_kernel=True, pallas_interpret=True,
            **SMALL,
        ),
    )
    assert legacy.patterns == fused.patterns


def test_fused_with_pallas_canonicality_matches_legacy():
    """The full kernel stack at once: fused expand_canonical + stream
    compaction inside the fused pipeline."""
    g = G.random_labeled(40, 90, n_labels=3, seed=7)
    legacy = run(g, MotifsApp(max_size=3), EngineConfig(async_chunks=False))
    fused = run(
        g, MotifsApp(max_size=3),
        EngineConfig(
            async_chunks=True, use_pallas=True, fused_expand=True,
            compact_kernel=True, pallas_interpret=True,
        ),
    )
    assert legacy.patterns == fused.patterns


# ---------------------------------------------------------------------------
# sync and compile accounting
# ---------------------------------------------------------------------------

def test_fused_syncs_are_constant_per_step():
    """The tentpole property: host control syncs per superstep are O(1) in
    the fused pipeline vs O(chunks) in the PR-2 loop."""
    g = G.random_labeled(40, 120, n_labels=2, seed=11)
    legacy = run(
        g, MotifsApp(max_size=3), EngineConfig(async_chunks=False, **SMALL)
    )
    fused = run(
        g, MotifsApp(max_size=3), EngineConfig(async_chunks=True, **SMALL)
    )
    assert legacy.patterns == fused.patterns
    for st in fused.stats.steps:
        assert st.n_host_syncs <= 2          # pilot + one drain per superstep
    exp_steps = [s for s in legacy.stats.steps if s.n_chunks > 1]
    assert exp_steps, "graph too small: legacy never ran multi-chunk"
    for st in exp_steps:
        assert st.n_host_syncs >= st.n_chunks   # one sync per chunk (PR-2)


def test_fused_spill_drains_per_wave():
    """With a device budget the fused pipeline drains one budget wave at a
    time (SpillStore's one-resident-wave contract): syncs scale with waves,
    not chunks, and results still match the unbudgeted run."""
    g = G.random_labeled(40, 120, n_labels=2, seed=29)
    base = run(g, MotifsApp(max_size=3), EngineConfig(async_chunks=False))
    budget = 16 * 4 * 3                 # a handful of rows per wave
    res = run(
        g, MotifsApp(max_size=3),
        EngineConfig(
            async_chunks=True, device_budget_bytes=budget,
            chunk_size=8, initial_capacity=32,
        ),
    )
    assert res.patterns == base.patterns
    for st in res.stats.steps:
        if st.n_chunks > 1:
            # <= 2 syncs per wave, and chunks strictly outnumber waves at
            # chunk_size 8 vs 16-row waves
            assert st.n_host_syncs < 2 * st.n_chunks


def test_pow2_bucketing_bounds_compiles():
    """Every dispatched chunk program signature is a (pow2 width, pow2
    capacity) pair and the jit cache grows by at most one entry per
    distinct signature — the recompile bound of DESIGN.md §8."""
    g = G.random_labeled(40, 120, n_labels=2, seed=13)
    res = run(
        g, MotifsApp(max_size=4), EngineConfig(async_chunks=True, **SMALL)
    )
    sigs = res.stats.chunk_signatures
    assert sigs, "no chunk programs dispatched"
    for _, width, cap in sigs:
        assert width & (width - 1) == 0, f"non-pow2 chunk width {width}"
        assert cap & (cap - 1) == 0, f"non-pow2 capacity {cap}"
    assert res.stats.n_compiles <= len(sigs)
    # the signature space itself is logarithmic: widths and caps are pow2
    # buckets, so a frontier of any size compiles O(log) programs per size
    assert len(sigs) <= 4 * len(res.stats.steps) + 4


def test_chunk_program_cache_reused_across_runs():
    """A second run with an equal app config re-traces nothing."""
    g = G.random_labeled(40, 90, n_labels=2, seed=17)
    cfg = dict(async_chunks=True, chunk_size=32, initial_capacity=32)
    run(g, MotifsApp(max_size=3), EngineConfig(**cfg))
    again = run(g, MotifsApp(max_size=3), EngineConfig(**cfg))
    assert again.stats.n_compiles == 0


# ---------------------------------------------------------------------------
# lazy device-array store append
# ---------------------------------------------------------------------------

def test_raw_store_lazy_device_append():
    s = RawStore()
    padded = jnp.asarray(
        np.array([[0, 1], [2, 3], [-1, -1], [-1, -1]], np.int32)
    )
    s.append(padded, count=2)                 # device array, no transfer yet
    s.append(np.array([[4, 5]], np.int32))    # host block, no count
    s.seal(2)
    np.testing.assert_array_equal(
        s.materialize(), np.array([[0, 1], [2, 3], [4, 5]], np.int32)
    )
    assert s.n_rows == 3


def test_raw_store_append_count_zero_is_dropped():
    s = RawStore()
    s.append(jnp.zeros((4, 2), jnp.int32), count=0)
    s.seal(2)
    assert s.n_rows == 0


def test_odag_store_lazy_device_append():
    from repro.core.store import ODAGStore

    g = to_device(G.triangle_plus_tail())
    s = ODAGStore(g, mode="vertex")
    rows = np.array([[0, 1], [0, 2], [1, 2]], np.int32)
    padded = np.concatenate([rows, np.full((2, 2), -1, np.int32)])
    s.append(jnp.asarray(padded), count=3)
    s.seal(2)
    assert s.n_rows == 3
    got = {tuple(r) for r in s.materialize().tolist()}
    assert {tuple(r) for r in rows.tolist()} <= got


# ---------------------------------------------------------------------------
# the fused program under shard_map
# ---------------------------------------------------------------------------

def test_distributed_fused_matches_serial():
    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=19)
    ser = run(g, MotifsApp(max_size=3), EngineConfig(async_chunks=False))
    for store in ("raw", "odag"):
        dist = run_distributed(
            g, MotifsApp(max_size=3), mesh,
            DistConfig(store=store, async_chunks=True),
        )
        assert ser.patterns == dist.patterns
        for st in dist.stats.steps:
            assert st.n_host_syncs <= 2      # one drain (+1 capacity retry)


def test_distributed_fused_fsm_carried_codes():
    """Edge-mode carried codes: FSM's alpha filter consumes codes emitted
    by the previous superstep's sharded expand."""
    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=23)
    ser = run(g, FSMApp(support=3, max_size=3), EngineConfig(async_chunks=False))
    dist = run_distributed(
        g, FSMApp(support=3, max_size=3), mesh, DistConfig(async_chunks=True)
    )
    assert ser.patterns == dist.patterns
