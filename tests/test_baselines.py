"""TLV / TLP paradigm baselines (paper §3.2): cost-model sanity."""
import numpy as np

from repro.core import EngineConfig, graph as G, run
from repro.core.apps import FSMApp, MotifsApp
from repro.core.baselines.bruteforce import enumerate_vertex_embeddings
from repro.core.baselines.tlp import run_tlp_fsm
from repro.core.baselines.tlv import run_tlv


def test_tlv_explores_same_embeddings():
    g = G.random_labeled(40, 90, n_labels=2, seed=1)
    rep = run_tlv(g, max_size=3)
    oracle = enumerate_vertex_embeddings(g, 3)
    expected = sum(len(v) for v in oracle.values())
    assert rep.n_embeddings == expected


def test_tlv_message_blowup():
    """The paper's point: every embedding is replicated to each border
    vertex, so messages >> embeddings, with hot high-degree vertices."""
    g = G.citeseer_like(scale=0.05)
    rep = run_tlv(g, max_size=3)
    assert rep.n_messages > rep.n_embeddings          # duplication
    assert rep.max_vertex_load > 10 * rep.mean_vertex_load  # hotspots


def test_tlp_speedup_bound_saturates():
    """Few hot patterns cap TLP's parallel speedup well below #workers —
    the paper's example: unlabeled motifs at depth 3 have only 2 patterns,
    so throwing workers at patterns cannot scale (Fig. 7 discussion)."""
    g = G.random_labeled(120, 400, n_labels=1, seed=2)  # unlabeled: few patterns
    rep = run_tlp_fsm(g, support=5, max_size=3)
    b5, b20, b80 = (rep.speedup_bound(w) for w in (5, 20, 80))
    assert b5 <= 5.0 + 1e-9 and b20 <= 20.0 + 1e-9
    # skewed few-pattern work: speedup saturates near #patterns
    n_heavy = sum(1 for w in rep.pattern_work.values()
                  if w > 0.01 * sum(rep.pattern_work.values()))
    assert b80 < max(n_heavy * 2, 8)
    assert b80 < 80 * 0.5  # far from linear


def test_tle_vs_tlv_work_ratio():
    """Arabesque (TLE) does strictly less communication-equivalent work:
    its exploration is coordination-free; TLV pays per-border messages."""
    g = G.random_labeled(60, 150, n_labels=2, seed=3)
    res = run(g, MotifsApp(max_size=3), EngineConfig())
    tlv = run_tlv(g, max_size=3)
    assert res.stats.total_embeddings == tlv.n_embeddings
    assert tlv.n_messages > 2 * tlv.n_embeddings
