"""End-to-end correctness: engine results == brute-force oracles (paper's
completeness guarantee, Thm 4) for all three bundled applications."""
import numpy as np
import pytest

from repro.core import EngineConfig, graph as G, run
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.baselines import bruteforce as bf

CFG = EngineConfig(chunk_size=2048, initial_capacity=2048)


@pytest.mark.parametrize("seed,n,m,labels", [(3, 60, 150, 3), (5, 30, 60, 1), (11, 45, 100, 5)])
def test_motifs_match_oracle(seed, n, m, labels):
    g = G.random_labeled(n, m, n_labels=labels, seed=seed)
    res = run(g, MotifsApp(max_size=4), CFG)
    assert res.patterns == bf.motif_counts(g, 4)


@pytest.mark.parametrize("seed", [0, 7])
def test_cliques_match_oracle(seed):
    g = G.random_labeled(50, 180, n_labels=1, seed=seed)
    res = run(g, CliquesApp(max_size=4), CFG)
    oracle = bf.clique_counts(g, 4)
    eng = {s: arr.shape[0] for s, arr in res.embeddings.items()}
    assert eng == {k: v for k, v in oracle.items() if v > 0}
    # every collected embedding really is a clique
    adj = {tuple(sorted((int(u), int(v)))) for u, v in g.edges}
    for size, arr in res.embeddings.items():
        if size < 2:
            continue
        for row in np.asarray(arr)[:50]:
            vs = sorted(int(x) for x in row)
            import itertools

            for a, b in itertools.combinations(vs, 2):
                assert (a, b) in adj


@pytest.mark.parametrize("seed,sup,ms", [(3, 3, 3), (5, 2, 4), (9, 5, 3)])
def test_fsm_match_oracle(seed, sup, ms):
    g = G.random_labeled(40, 90, n_labels=2, seed=seed)
    res = run(g, FSMApp(support=sup, max_size=ms), CFG)
    assert res.patterns == bf.fsm_supports(g, ms, sup)


def test_fsm_antimonotone_counts_decrease():
    g = G.citeseer_like(scale=0.08)
    r_lo = run(g, FSMApp(support=2, max_size=3), CFG)
    r_hi = run(g, FSMApp(support=6, max_size=3), CFG)
    assert set(r_hi.patterns) <= set(r_lo.patterns)
    for k, v in r_hi.patterns.items():
        assert r_lo.patterns[k] == v  # same support values


def test_paper_figure2_single_edge_patterns():
    """Figure 2's example: the three edges of the path share ONE canonical
    single-edge pattern (blue-yellow), whose min-image support is 2 —
    domains are blue:{0,2}, yellow:{1,3} (paper §4.2's domain example)."""
    g = G.paper_figure2()
    res = run(g, FSMApp(support=1, max_size=1), CFG)
    assert len(res.patterns) == 1
    assert list(res.patterns.values()) == [2]
    # embedding *count* for that pattern is 3 (the three edges)
    res2 = run(g, FSMApp(support=1, max_size=1, wants_domains=False), CFG)
    assert list(res2.patterns.values()) == [3]


def test_edge_exploration_exact_sets():
    g = G.random_labeled(30, 60, n_labels=2, seed=5)
    res = run(
        g,
        FSMApp(support=1, max_size=4, collect_embeddings=True),
        CFG,
    )
    oracle = bf.enumerate_edge_embeddings(g, 4)
    for k in range(1, 5):
        eng = res.embeddings.get(k)
        got = (
            {frozenset(int(x) for x in row) for row in np.asarray(eng)}
            if eng is not None
            else set()
        )
        assert got == oracle[k]
        assert eng is None or eng.shape[0] == len(got)  # no duplicates


def test_vertex_exploration_exact_sets():
    g = G.random_labeled(40, 100, n_labels=1, seed=2)
    res = run(g, MotifsApp(max_size=4, collect_embeddings=True), CFG)
    oracle = bf.enumerate_vertex_embeddings(g, 4)
    for k in range(1, 5):
        eng = res.embeddings.get(k)
        got = (
            {frozenset(int(x) for x in row) for row in np.asarray(eng)}
            if eng is not None
            else set()
        )
        assert got == oracle[k]
        assert eng is None or eng.shape[0] == len(got)
