"""Device-resident level-1 aggregation tests (DESIGN.md §10).

Covers the segment-unique/reduce kernel against its jnp contract, the
``bin_rows`` device binning against a numpy oracle (weighted folds,
invalid rows, unclamped overflow counts, empty and single-slot edge
cases), and the acceptance-criterion equivalence: ``device_aggregate=True``
(the default) produces bit-identical patterns / counts / supports to the
host reference path (``aggregation.aggregate_rows``) for motifs, cliques,
and FSM across all three frontier stores and both execution backends —
including the merge-overflow fallback, pattern-granular alpha pruning
(``MiningApp.pattern_filter``), and the automatic host fallback for apps
overriding the per-row ``aggregation_filter``.

Kernel invocations pin ``interpret=True`` so CPU CI runs the exact kernel
dataflow deterministically. Graphs stay ~40 vertices (engine runs are
seconds each; equivalence matrices multiply fast).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, graph as G, run
from repro.core import aggregation
from repro.core.api import MiningApp
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.kernels.aggregate import (
    bin_rows,
    seg_unique_pallas,
    seg_unique_ref,
    sort_codes,
)


def _fake_codes(rng, b, nv=3, n_labels=4):
    """Synthetic quick codes honouring the encoding (words < 2^32)."""
    bits = rng.integers(0, 1 << min(3, 28), b).astype(np.int64)
    w0 = nv | (bits << 4)
    w1 = np.zeros(b, np.int64)
    labels = rng.integers(0, n_labels, (b, min(nv, 4)))
    for i in range(min(nv, 4)):
        w1 |= labels[:, i].astype(np.int64) << (8 * i)
    return np.stack([w0, w1, np.zeros(b, np.int64)], axis=1)


# ---------------------------------------------------------------------------
# segment-unique kernel vs the jnp contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [1, 5, 127, 256, 1000])
@pytest.mark.parametrize("block", [7, 64, 8192])
def test_seg_unique_kernel_matches_ref(b, block):
    rng = np.random.default_rng(b + block)
    codes = _fake_codes(rng, b)
    valid = rng.random(b) < 0.8
    sc, sv, _ = sort_codes(jnp.asarray(codes), jnp.asarray(valid))
    new = sv & jnp.concatenate(
        [jnp.ones((1,), bool), (sc[1:] != sc[:-1]).any(axis=1)]
    )
    cap = 64
    out_k = seg_unique_pallas(new, sv, cap, block=block, interpret=True)
    out_r = seg_unique_ref(new, sv, cap)
    for a, r, name in zip(out_k, out_r, ("src", "counts", "slot", "n")):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(r), err_msg=name
        )


def test_seg_unique_empty():
    for fn in (seg_unique_pallas, seg_unique_ref):
        src, counts, slot, n = fn(
            jnp.zeros((0,), bool), jnp.zeros((0,), bool), 8
        )
        assert int(n) == 0 and slot.shape == (0,)
        assert (np.asarray(counts) == 0).all()


# ---------------------------------------------------------------------------
# bin_rows vs a numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
def test_bin_rows_matches_numpy(use_kernel):
    rng = np.random.default_rng(0)
    codes = _fake_codes(rng, 1000)
    valid = rng.random(1000) < 0.9
    u, c, inv, n, uv = bin_rows(
        jnp.asarray(codes), jnp.asarray(valid), 1024,
        use_kernel=use_kernel, interpret=True,
    )
    ref_u, ref_inv = np.unique(codes[valid], axis=0, return_inverse=True)
    q = len(ref_u)
    assert int(n) == q
    np.testing.assert_array_equal(np.asarray(u)[:q], ref_u)
    np.testing.assert_array_equal(
        np.asarray(c)[:q], np.bincount(ref_inv, minlength=q)
    )
    full = np.full(1000, -1, np.int32)
    full[valid] = ref_inv
    np.testing.assert_array_equal(np.asarray(inv), full)
    np.testing.assert_array_equal(
        np.asarray(uv), np.arange(1024) < q
    )


def test_bin_rows_overflow_count_unclamped():
    """n past the capacity is exact — the re-bin decision is host-side on
    an already-drained value, the compact.py contract."""
    rng = np.random.default_rng(1)
    codes = _fake_codes(rng, 500)
    ref_u = np.unique(codes, axis=0)
    assert len(ref_u) > 8
    u, c, inv, n, uv = bin_rows(
        jnp.asarray(codes), jnp.ones((500,), bool), 8
    )
    assert int(n) == len(ref_u)
    # the first 8 distinct codes (ascending) and their counts are intact
    np.testing.assert_array_equal(np.asarray(u), ref_u[:8])


def test_bin_rows_weighted_fold():
    """Weighted re-binning (the cross-batch merge): counts sum weights."""
    rng = np.random.default_rng(2)
    codes = _fake_codes(rng, 300)
    w = rng.integers(1, 9, 300)
    u, c, inv, n, uv = bin_rows(
        jnp.asarray(codes), jnp.ones((300,), bool), 512,
        weights=jnp.asarray(w),
    )
    ref_u, ref_inv = np.unique(codes, axis=0, return_inverse=True)
    exp = np.zeros(len(ref_u), np.int64)
    np.add.at(exp, ref_inv, w)
    np.testing.assert_array_equal(np.asarray(c)[: len(ref_u)], exp)


def test_bin_rows_single_slot_and_empty():
    one = np.tile(np.array([[3 | (5 << 4), 7, 0]], np.int64), (40, 1))
    u, c, inv, n, uv = bin_rows(jnp.asarray(one), jnp.ones((40,), bool), 16)
    assert int(n) == 1 and int(np.asarray(c)[0]) == 40
    assert (np.asarray(inv) == 0).all()
    u, c, inv, n, uv = bin_rows(
        jnp.zeros((0, 3), jnp.int64), jnp.zeros((0,), bool), 16
    )
    assert int(n) == 0 and inv.shape == (0,)


def test_device_level1_matches_aggregate_rows():
    """DeviceLevel1 over three batches == one host aggregate_rows pass,
    including per-row slot composition through the final merge."""
    rng = np.random.default_rng(3)
    codes = _fake_codes(rng, 900)
    lvl = aggregation.DeviceLevel1(merge_cap=64)
    for lo in range(0, 900, 300):
        lvl.fold_rows(jnp.asarray(codes[lo:lo + 300]))
    uniq, counts, nbytes = lvl.finish()
    ref_u, ref_inv = np.unique(codes, axis=0, return_inverse=True)
    np.testing.assert_array_equal(uniq, ref_u)
    np.testing.assert_array_equal(counts, np.bincount(ref_inv))
    assert nbytes < codes.nbytes / 4        # O(Q), packed
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(lvl.batch_slots(i)), ref_inv[i * 300:(i + 1) * 300]
        )


# ---------------------------------------------------------------------------
# acceptance criterion: device aggregation == host path, all apps x stores
# ---------------------------------------------------------------------------

APPS = [
    ("motifs", lambda: MotifsApp(max_size=3)),
    ("cliques", lambda: CliquesApp(max_size=4)),
    ("fsm", lambda: FSMApp(support=3, max_size=3)),
]
STORES = [
    ("raw", dict(store="raw")),
    ("odag", dict(store="odag")),
    ("spill", dict(store="raw", device_budget_bytes=2048)),
]
SMALL = dict(chunk_size=64, initial_capacity=64)


def _assert_same(host, dev):
    assert host.patterns == dev.patterns
    assert len(host.aggregates) == len(dev.aggregates)
    for a, b in zip(host.aggregates, dev.aggregates):
        np.testing.assert_array_equal(a.canon_codes, b.canon_codes)
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.supports, b.supports)
        assert a.n_quick == b.n_quick
        assert a.n_canonical == b.n_canonical
        assert a.n_iso_checks == b.n_iso_checks


@pytest.mark.parametrize("sname,skw", STORES, ids=[s[0] for s in STORES])
@pytest.mark.parametrize("aname,mk", APPS, ids=[a[0] for a in APPS])
def test_device_aggregate_matches_host(aname, mk, sname, skw):
    g = G.random_labeled(40, 90, n_labels=3, seed=3)
    host = run(g, mk(), EngineConfig(device_aggregate=False, **SMALL, **skw))
    dev = run(g, mk(), EngineConfig(device_aggregate=True, **SMALL, **skw))
    _assert_same(host, dev)


@pytest.mark.parametrize("store", ["raw", "odag"])
@pytest.mark.parametrize("aname,mk", APPS[:1] + APPS[2:],
                         ids=["motifs", "fsm"])
def test_device_aggregate_shard_backend(aname, mk, store):
    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=3, seed=7)
    host = run(g, mk(), EngineConfig(device_aggregate=False, store=store))
    dev = run_distributed(
        g, mk(), mesh, DistConfig(device_aggregate=True, store=store)
    )
    _assert_same(host, dev)
    # the device path must keep the sync contract
    for st in dev.stats.steps:
        assert st.n_host_syncs <= 2


@pytest.mark.slow
def test_device_aggregate_shard_multiworker_raw():
    """The W>1 collective paths on the RAW store (ShardCarried device
    codes, all-gather/psum rank slicing, alpha mask reassembly from the
    per-worker counts) in a subprocess with 4 forced host devices — the
    odag 8-dev test never takes the carried branch."""
    import json
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import json
        import numpy as np
        import jax
        from repro.core import graph as G, run, EngineConfig
        from repro.core.apps import MotifsApp, FSMApp
        from repro.core.distributed import run_distributed, DistConfig

        mesh = jax.make_mesh((4,), ("data",))
        assert len(jax.devices()) == 4
        g = G.random_labeled(40, 90, n_labels=3, seed=3)
        out = {}
        for name, mk in [
            ("motifs", lambda: MotifsApp(max_size=3)),
            ("fsm", lambda: FSMApp(support=3, max_size=3)),
        ]:
            host = run(g, mk(), EngineConfig(device_aggregate=False))
            dist = run_distributed(g, mk(), mesh, DistConfig(store="raw"))
            same_aggs = all(
                np.array_equal(a.counts, b.counts)
                and np.array_equal(a.supports, b.supports)
                and np.array_equal(a.canon_codes, b.canon_codes)
                for a, b in zip(host.aggregates, dist.aggregates)
            )
            out[name] = {
                "match": host.patterns == dist.patterns and same_aggs,
                "syncs_ok": all(
                    s.n_host_syncs <= 2 for s in dist.stats.steps
                ),
            }
        print("RESULT" + json.dumps(out))
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", script],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    for name in ("motifs", "fsm"):
        assert out[name]["match"], name
        assert out[name]["syncs_ok"], name


def test_device_aggregate_is_default_and_knob_respected():
    """device_aggregate resolves on for small graphs (static table);
    False is the host regression path (O(frontier) aggregation bytes
    instead of O(Q)). The raw knob is tri-state since the §14 cost model
    (None = decided at bind time)."""
    from repro.core.runtime.costmodel import static_table

    assert EngineConfig().device_aggregate is None
    assert static_table("serial").device_aggregate is True
    g = G.random_labeled(40, 120, n_labels=2, seed=11)
    dev = run(g, MotifsApp(max_size=3), EngineConfig(**SMALL))
    host = run(
        g, MotifsApp(max_size=3),
        EngineConfig(device_aggregate=False, **SMALL),
    )
    _assert_same(host, dev)
    assert dev.stats.total_bytes_to_host < host.stats.total_bytes_to_host
    big = [s for s in host.stats.steps if s.n_frontier > 100]
    assert big, "graph too small to compare transfer volumes"
    for st in big:
        # host path drains the (B, 3) int64 codes (+ (B, 8) int32 lv)
        assert st.bytes_to_host >= st.n_frontier * 24


def test_merge_overflow_falls_back_bit_identically():
    """agg_qcap far below Q: compaction overflow -> wave re-fold, merge
    overflow -> exact re-merge; results stay bit-identical either way."""
    g = G.random_labeled(40, 90, n_labels=3, seed=13)
    host = run(
        g, MotifsApp(max_size=3),
        EngineConfig(device_aggregate=False, **SMALL),
    )
    for qcap in (1, 2, 7):
        dev = run(
            g, MotifsApp(max_size=3), EngineConfig(agg_qcap=qcap, **SMALL)
        )
        _assert_same(host, dev)


def test_fsm_alpha_prunes_identically_on_device():
    """FSM's support pruning through pattern_filter + device row masks ==
    the old per-row aggregation_filter, embeddings included."""
    g = G.random_labeled(40, 90, n_labels=3, seed=17)
    mk = lambda: FSMApp(support=4, max_size=3, collect_embeddings=True)  # noqa: E731
    host = run(g, mk(), EngineConfig(device_aggregate=False, **SMALL))
    dev = run(g, mk(), EngineConfig(device_aggregate=True, **SMALL))
    _assert_same(host, dev)
    emb = lambda r: {k: set(map(tuple, v.tolist()))  # noqa: E731
                     for k, v in r.embeddings.items()}
    assert emb(host) == emb(dev)


@dataclasses.dataclass
class _PatternPruneApp(MiningApp):
    """Pattern-granular alpha on a domain-free app: exercises the carried
    partial path's alpha fallback (re-bin waves for per-row slots)."""

    mode: str = "vertex"
    max_size: int = 3
    min_count: int = 4

    def pattern_filter(self, agg):
        return np.asarray(agg.counts) >= self.min_count


@dataclasses.dataclass
class _RowAlphaApp(MiningApp):
    """Per-ROW alpha override: the engine must auto-fall back to the host
    aggregation path (device level 1 cannot honour row-granular alpha)."""

    mode: str = "vertex"
    max_size: int = 3

    def aggregation_filter(self, canon_slot, agg):
        keep = np.asarray(agg.counts) >= 4
        return np.where(
            canon_slot >= 0, keep[np.maximum(canon_slot, 0)], False
        )


def test_custom_pattern_filter_app_prunes_on_device():
    g = G.random_labeled(40, 120, n_labels=2, seed=19)
    host = run(
        g, _PatternPruneApp(), EngineConfig(device_aggregate=False, **SMALL)
    )
    dev = run(g, _PatternPruneApp(), EngineConfig(**SMALL))
    _assert_same(host, dev)
    assert host.patterns, "pruning pruned everything — test graph too small"


def test_row_alpha_app_falls_back_to_host_path():
    g = G.random_labeled(40, 120, n_labels=2, seed=19)
    res_row = run(g, _RowAlphaApp(), EngineConfig(**SMALL))
    res_pat = run(g, _PatternPruneApp(), EngineConfig(**SMALL))
    # the two apps encode the same alpha; the row-granular one must take
    # the host path (per-row canon slots) and still agree
    assert res_row.patterns == res_pat.patterns


def test_empty_step_and_single_pattern_edges():
    # support above every pattern's frequency: step-1 aggregation prunes
    # the whole frontier, the run ends with no output
    g = G.random_labeled(40, 90, n_labels=3, seed=23)
    host = run(
        g, FSMApp(support=10**6, max_size=3),
        EngineConfig(device_aggregate=False, **SMALL),
    )
    dev = run(g, FSMApp(support=10**6, max_size=3), EngineConfig(**SMALL))
    _assert_same(host, dev)
    assert dev.patterns == {}
    # a single-edge graph: exactly one pattern per step
    g1 = G.Graph(
        n=2,
        labels=np.array([1, 1], np.int32),
        edges=np.array([[0, 1]], np.int32),
    )
    host = run(
        g1, MotifsApp(max_size=2),
        EngineConfig(device_aggregate=False, **SMALL),
    )
    dev = run(g1, MotifsApp(max_size=2), EngineConfig(**SMALL))
    _assert_same(host, dev)
    assert all(a.n_quick == 1 for a in dev.aggregates)


def test_engine_with_aggregate_kernel_matches_host():
    """The full device path with the Pallas segment kernel (interpreted on
    CPU) inside both the chunk programs and the wave folds."""
    g = G.random_labeled(40, 90, n_labels=3, seed=29)
    host = run(
        g, MotifsApp(max_size=3), EngineConfig(device_aggregate=False)
    )
    for mk in (lambda: MotifsApp(max_size=3),):
        dev = run(
            g, mk(),
            EngineConfig(
                aggregate_kernel=True, pallas_interpret=True, **SMALL
            ),
        )
        assert host.patterns == dev.patterns
    hostf = run(
        g, FSMApp(support=3, max_size=3),
        EngineConfig(device_aggregate=False),
    )
    devf = run(
        g, FSMApp(support=3, max_size=3),
        EngineConfig(aggregate_kernel=True, pallas_interpret=True, **SMALL),
    )
    assert hostf.patterns == devf.patterns
