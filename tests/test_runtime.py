"""Unified superstep runtime tests (DESIGN.md §9): the EngineConfig /
DistConfig deprecation shims resolve identically to the RunConfig they
wrap, the SuperstepRuntime API matches the thin wrappers, and the
duplicated-driver acceptance criterion is grep-checkable (no pilot /
capacity / drain logic left in engine.py or distributed.py)."""
import dataclasses
import pathlib

import numpy as np
import pytest

from repro.core import (
    EngineConfig,
    RunConfig,
    SuperstepRuntime,
    graph as G,
    run,
)
from repro.core.apps import MotifsApp
from repro.core.distributed import DistConfig
from repro.core.runtime import SerialBackend, ShardMapBackend, next_pow2
from repro.core.runtime.costmodel import static_table

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro" / "core"


# ---------------------------------------------------------------------------
# config shims: old names, old kwargs, identical resolution
# ---------------------------------------------------------------------------

def test_configs_are_runconfig_shims():
    assert issubclass(EngineConfig, RunConfig)
    assert issubclass(DistConfig, RunConfig)
    # the shims add NO fields of their own — one config, two legacy names
    assert {f.name for f in dataclasses.fields(EngineConfig)} == {
        f.name for f in dataclasses.fields(RunConfig)
    }
    assert {f.name for f in dataclasses.fields(DistConfig)} == {
        f.name for f in dataclasses.fields(RunConfig)
    }


def test_legacy_engine_kwargs_still_construct():
    cfg = EngineConfig(
        chunk_size=128, initial_capacity=256, max_steps=5, use_pallas=False,
        fused_expand=False, pallas_interpret=True, store="odag",
        device_budget_bytes=4096, async_chunks=False, compact_kernel=False,
    )
    assert cfg.chunk_size == 128 and cfg.store == "odag"
    assert cfg.device_budget_bytes == 4096 and not cfg.async_chunks


def test_legacy_dist_kwargs_still_construct():
    cfg = DistConfig(
        axes=("data",), initial_capacity=1 << 15, max_steps=4, store="odag",
        naive_aggregation=True, use_pallas=False, pallas_interpret=True,
        async_chunks=True, compact_kernel=None,
    )
    assert cfg.axes == ("data",) and cfg.naive_aggregation
    assert cfg.initial_capacity == 1 << 15


@pytest.mark.parametrize("knob", [None, True, False])
def test_shims_resolve_identically_to_runconfig(knob):
    """The deduplicated resolve_use_pallas / resolve_compact_kernel live
    once on RunConfig; the shims inherit them bit-for-bit."""
    for cls in (EngineConfig, DistConfig):
        shim = cls(use_pallas=knob, compact_kernel=knob)
        base = RunConfig(use_pallas=knob, compact_kernel=knob)
        assert shim.resolve_use_pallas() == base.resolve_use_pallas()
        assert shim.resolve_compact_kernel() == base.resolve_compact_kernel()
        if knob is None:
            static = static_table("serial")
            assert shim.resolve_use_pallas() == static.use_pallas
            assert shim.resolve_compact_kernel() == static.compact_kernel
        else:
            assert shim.resolve_use_pallas() is knob
            assert shim.resolve_compact_kernel() is knob


def test_next_pow2_capacity_buckets():
    assert [next_pow2(x) for x in (1, 2, 3, 64, 65)] == [1, 2, 4, 64, 128]


# ---------------------------------------------------------------------------
# the runtime API and the thin wrappers agree
# ---------------------------------------------------------------------------

def test_runtime_matches_engine_run():
    g = G.random_labeled(40, 90, n_labels=2, seed=21)
    via_wrapper = run(g, MotifsApp(max_size=3), EngineConfig())
    via_runtime = SuperstepRuntime(
        g, MotifsApp(max_size=3), RunConfig(), SerialBackend()
    ).run()
    assert via_wrapper.patterns == via_runtime.patterns


def test_runtime_default_backend_is_serial():
    g = G.triangle_plus_tail()
    rt = SuperstepRuntime(g, MotifsApp(max_size=3))
    assert isinstance(rt.backend, SerialBackend)
    assert rt.run().patterns


def test_runtime_shard_backend_matches_serial():
    import jax

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=2, seed=22)
    ser = SuperstepRuntime(g, MotifsApp(max_size=3)).run()
    dist = SuperstepRuntime(
        g, MotifsApp(max_size=3), RunConfig(), ShardMapBackend(mesh)
    ).run()
    assert ser.patterns == dist.patterns


# ---------------------------------------------------------------------------
# acceptance criterion: the wrappers really are thin (grep-checkable)
# ---------------------------------------------------------------------------

def _code_only(text):
    """Source minus docstrings/comments — the grep target is logic, not
    the prose describing where the logic went."""
    import re

    text = re.sub(r'("""|\'\'\')[\s\S]*?\1', "", text)
    return "\n".join(line.split("#")[0] for line in text.splitlines())


def test_no_duplicated_driver_logic_in_wrappers():
    """engine.py and distributed.py must not re-implement the superstep
    driver: no pilot-chunk calibration, no capacity-bucket arithmetic, no
    drain loop, no per-step aggregation plumbing."""
    for name in ("engine.py", "distributed.py"):
        body = _code_only((SRC / name).read_text())
        for needle in (
            "pilot", "_DRAIN_WINDOW", "drain(", "step_cap",
            "n_host_syncs", "aggregation_filter", "termination_filter",
            "store.seal", "worker_parts",
        ):
            assert needle not in body, f"{name} still contains {needle!r}"
    # the driver exists exactly once, in the runtime package
    loop = (SRC / "runtime" / "loop.py").read_text()
    assert "termination_filter" in loop and "store.seal" in loop
