"""Device canonical-refine kernel vs. the host oracle (DESIGN.md §15).

The contract under test: ``kernels/canonical_refine.py`` must be
bit-identical to ``canon_math.canonicalize_one`` (canonical code + sigma,
first-minimal-permutation tie-break) and ``canon_math.automorphism_orbits``
(orbit representative per position, computed on canonical codes) — for
every placement route (jnp fori-loop reference and the Pallas kernel,
pinned to ``interpret=True`` so CI on CPU exercises the exact kernel
dataflow).

Coverage: exhaustive adjacency × label enumeration for nv ≤ 4, seeded
random codes for nv ∈ {5..8}, mixed-nv batches, empty/single-row batches,
and the numpy convenience wrapper the backends and the cost-model probe
call.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import canon_math
from repro.kernels import canonical_refine as cr


def _encode_all(nv: int, labels_pool):
    """Every adjacency mask × every label assignment for ``nv`` vertices."""
    nbits = canon_math.n_pair_bits(nv)
    out = []
    for mask in range(1 << nbits):
        adj = np.zeros((nv, nv), dtype=bool)
        for bb in range(1, nv):
            for aa in range(bb):
                if mask & (1 << canon_math._pair_bit(aa, bb)):
                    adj[aa, bb] = adj[bb, aa] = True
        for labs in itertools.product(labels_pool, repeat=nv):
            out.append(canon_math.encode(nv, adj, np.array(labs)))
    return np.array(out, dtype=np.int64)


def _random_codes(nv: int, n: int, seed: int, n_labels: int = 5):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        adj = np.zeros((nv, nv), dtype=bool)
        for bb in range(1, nv):
            for aa in range(bb):
                if rng.random() < 0.5:
                    adj[aa, bb] = adj[bb, aa] = True
        labs = rng.integers(0, n_labels, size=nv)
        out.append(canon_math.encode(nv, adj, labs))
    return np.array(out, dtype=np.int64)


def _oracle(codes):
    """Host reference: canon + sigma per code, orbits of the CANON code."""
    canon = np.zeros_like(codes)
    sigma = np.zeros((len(codes), 8), np.int32)
    orbits = np.zeros((len(codes), 8), np.int32)
    for i, row in enumerate(codes):
        c, s = canon_math.canonicalize_one(row)
        canon[i] = c
        sigma[i] = s
        orbits[i] = canon_math.automorphism_orbits(np.array(c, np.int64))
    return canon, sigma, orbits


def _refine(codes, nvs, use_kernel):
    canon, sigma, _ = cr.refine_batch(
        jnp.asarray(codes), jnp.ones((len(codes),), bool), nvs,
        use_kernel=use_kernel, interpret=True,
    )
    # orbit pass runs on canonical codes (Aut(canon) != Aut(quick))
    _, _, rep = cr.refine_batch(
        canon, jnp.ones((len(codes),), bool), nvs,
        with_orbits=True, use_kernel=use_kernel, interpret=True,
    )
    return np.asarray(canon), np.asarray(sigma), np.asarray(rep)


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "pallas"])
@pytest.mark.parametrize("nv", [2, 3, 4])
def test_exhaustive_small_nv_matches_oracle(nv, use_kernel):
    codes = _encode_all(nv, labels_pool=(0, 1))
    want_c, want_s, want_o = _oracle(codes)
    got_c, got_s, got_o = _refine(codes, (nv,), use_kernel)
    np.testing.assert_array_equal(got_c, want_c)
    np.testing.assert_array_equal(got_s, want_s)
    np.testing.assert_array_equal(got_o, want_o)


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "pallas"])
@pytest.mark.parametrize("nv", [5, 6, 7, 8])
def test_seeded_large_nv_matches_oracle(nv, use_kernel):
    n = 24 if nv < 7 else 6          # 8! perms per row: keep CI sub-minute
    codes = np.unique(_random_codes(nv, n, seed=nv * 11), axis=0)
    want_c, want_s, want_o = _oracle(codes)
    got_c, got_s, got_o = _refine(codes, (nv,), use_kernel)
    np.testing.assert_array_equal(got_c, want_c)
    np.testing.assert_array_equal(got_s, want_s)
    np.testing.assert_array_equal(got_o, want_o)


@pytest.mark.parametrize("use_kernel", [False, True], ids=["jnp", "pallas"])
def test_mixed_nv_batch(use_kernel):
    codes = np.concatenate([
        _random_codes(2, 8, seed=1),
        _random_codes(3, 16, seed=2),
        _random_codes(4, 16, seed=3),
        _random_codes(5, 8, seed=4),
    ])
    rng = np.random.default_rng(0)
    codes = codes[rng.permutation(len(codes))]
    want_c, want_s, _ = _oracle(codes)
    got_c, got_s, _ = _refine(codes, (2, 3, 4, 5), use_kernel)
    np.testing.assert_array_equal(got_c, want_c)
    np.testing.assert_array_equal(got_s, want_s)


def test_out_of_nvs_and_invalid_rows_pass_through():
    codes = np.concatenate([
        _random_codes(3, 4, seed=9),
        _random_codes(5, 4, seed=9),      # nv outside nvs: untouched
    ])
    valid = np.array([True, True, False, True] + [True] * 4)
    canon, sigma, rep = cr.refine_batch(
        jnp.asarray(codes), jnp.asarray(valid), (3,), interpret=True
    )
    canon, sigma = np.asarray(canon), np.asarray(sigma)
    ident = np.arange(8, dtype=np.int32)
    for i in range(len(codes)):
        nv = int(codes[i, 0]) & 0xF
        if valid[i] and nv == 3:
            want, ws = canon_math.canonicalize_one(codes[i])
            assert tuple(canon[i]) == want
            np.testing.assert_array_equal(sigma[i], ws)
        else:
            np.testing.assert_array_equal(canon[i], codes[i])
            np.testing.assert_array_equal(sigma[i], ident)


def test_empty_and_single_row_batches():
    empty = np.zeros((0, 3), np.int64)
    c, s, r = cr.canonicalize_on_device(empty, interpret=True)
    assert c.shape == (0, 3) and s.shape == (0, 8) and r.shape == (0, 8)
    one = _random_codes(4, 1, seed=42)
    c, s, _ = cr.canonicalize_on_device(one, interpret=True)
    want, ws = canon_math.canonicalize_one(one[0])
    assert tuple(c[0]) == want
    np.testing.assert_array_equal(s[0], ws)
    # nv <= 1 rows pass through with identity sigma (the host contract)
    trivial = np.array([[1, 2, 0], [0, 0, 0]], np.int64)
    c, s, _ = cr.canonicalize_on_device(trivial, interpret=True)
    np.testing.assert_array_equal(c, trivial)
    np.testing.assert_array_equal(
        s, np.tile(np.arange(8, dtype=np.int32), (2, 1))
    )


def test_pallas_route_equals_jnp_route():
    codes = np.unique(np.concatenate([
        _random_codes(3, 40, seed=5),
        _random_codes(4, 40, seed=6),
        _random_codes(6, 10, seed=7),
    ]), axis=0)
    a = _refine(codes, (3, 4, 6), use_kernel=False)
    b = _refine(codes, (3, 4, 6), use_kernel=True)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_first_minimal_permutation_tie_break():
    # a fully symmetric pattern (triangle, uniform labels): every
    # permutation attains the minimum, so sigma must come from the FIRST
    # one in itertools.permutations order — the identity
    adj = np.ones((3, 3), dtype=bool)
    np.fill_diagonal(adj, False)
    code = np.array(canon_math.encode(3, adj, np.array([2, 2, 2])), np.int64)
    for use_kernel in (False, True):
        c, s, rep = _refine(code[None], (3,), use_kernel)
        assert tuple(c[0]) == tuple(code)
        np.testing.assert_array_equal(s[0], np.arange(8, dtype=np.int32))
        # one automorphism orbit: every live position maps to 0
        np.testing.assert_array_equal(rep[0][:3], np.zeros(3, np.int32))


def test_canon_fn_hook_matches_batch_reference():
    codes = np.unique(_random_codes(4, 60, seed=8), axis=0)
    fn = cr.make_canon_fn(interpret=True)
    canon, sigma = fn(codes)
    want_c, want_s = canon_math._canonicalize_batch(codes)
    np.testing.assert_array_equal(canon, want_c)
    np.testing.assert_array_equal(sigma, want_s)
