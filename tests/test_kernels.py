"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracle, assert_allclose per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G, to_device
from repro.kernels.canonical_check import canonical_check
from repro.kernels.canonical_check.ref import canonical_check_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm import rmsnorm
from repro.models.layers import rmsnorm as rmsnorm_oracle


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "bh,sq,sk,d,causal",
    [
        (2, 128, 128, 64, True),
        (2, 256, 256, 64, True),
        (1, 128, 256, 128, False),
        (3, 256, 256, 128, True),
    ],
)
def test_flash_attention_matches_ref(bh, sq, sk, d, causal, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (bh, sq, d), dtype)
    k = jax.random.normal(k2, (bh, sk, d), dtype)
    v = jax.random.normal(k3, (bh, sk, d), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_gqa_wrapper():
    b, s, h, kv, d = 2, 128, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)
    out = flash_attention(q, k, v)
    # oracle: expand kv heads then per-head ref
    kk = jnp.repeat(k, h // kv, axis=2)
    vv = jnp.repeat(v, h // kv, axis=2)
    ref = attention_ref(
        q.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        kk.transpose(0, 2, 1, 3).reshape(b * h, s, d),
        vv.transpose(0, 2, 1, 3).reshape(b * h, s, d),
    ).reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_blocks_dont_matter():
    bh, s, d = 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (bh, s, d), jnp.float32) for kk in ks)
    o1 = flash_attention_bhsd(q, k, v, block_q=128, block_k=128)
    o2 = flash_attention_bhsd(q, k, v, block_q=64, block_k=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# canonical check kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,m,k", [(0, 40, 90, 4), (1, 120, 400, 5), (2, 60, 60, 3)])
def test_canonical_check_matches_engine(seed, n, m, k):
    g = G.random_labeled(n, m, n_labels=2, seed=seed)
    dg = to_device(g)
    rng = np.random.default_rng(seed)
    b = 1000
    members = np.full((b, k), -1, np.int32)
    n_valid = rng.integers(1, k + 1, b).astype(np.int32)
    for i in range(b):
        members[i, : n_valid[i]] = rng.choice(n, size=n_valid[i], replace=False)
    cand = rng.integers(0, n, b).astype(np.int32)

    got = canonical_check(
        dg, jnp.asarray(members), jnp.asarray(n_valid), jnp.asarray(cand), block_b=256
    )
    want = canonical_check_ref(dg, jnp.asarray(members), jnp.asarray(n_valid), jnp.asarray(cand))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_canonical_check_padding_path():
    g = G.random_labeled(30, 60, n_labels=1, seed=3)
    dg = to_device(g)
    members = jnp.asarray([[0, 5, -1], [2, 7, 9]], jnp.int32)
    n_valid = jnp.asarray([2, 3], jnp.int32)
    cand = jnp.asarray([11, 1], jnp.int32)
    got = canonical_check(dg, members, n_valid, cand, block_b=1024)
    want = canonical_check_ref(dg, members, n_valid, cand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# rmsnorm kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 256, 512), (2, 100, 64), (1, 7, 128)])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, shape, dtype)
    scale = (1.0 + 0.1 * jax.random.normal(k2, shape[-1:], jnp.float32)).astype(dtype)
    got = rmsnorm(x, scale)
    want = rmsnorm_oracle(x, scale)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
