"""Cost-model dispatch tests (DESIGN.md §14).

Covers the radix/bucket bin (`kernels/radix_bin.py`) against the
`lax.sort` bin and a numpy oracle — including empty, single-slot,
overflow, weighted and >63-bit wide-key inputs on both the jnp and the
Pallas routes — the forced-decision matrix (every `cost_model` mode
produces bit-identical results across apps × stores × backends), the
calibration-cache persistence/invalidation roundtrip, and the decision
table's observability contract (recorded in `RunStats`, explicit config
knobs override it).

Real calibration probes run once with shrunk probe sizes; the cache
tests stub `calibrate` so the roundtrip is fast and deterministic.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EngineConfig, RunConfig, graph as G, run, to_device
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.core.runtime import costmodel, faults
from repro.kernels import radix_bin
from repro.kernels.aggregate import bin_rows


def _fake_codes(rng, b, nv=3, n_labels=4):
    """Synthetic quick codes honouring the encoding (words < 2^32)."""
    bits = rng.integers(0, 1 << 3, b).astype(np.int64)
    w0 = nv | (bits << 4)
    w1 = np.zeros(b, np.int64)
    labels = rng.integers(0, n_labels, (b, min(nv, 4)))
    for i in range(min(nv, 4)):
        w1 |= labels[:, i].astype(np.int64) << (8 * i)
    return np.stack([w0, w1, np.zeros(b, np.int64)], axis=1)


def _oracle(codes, valid, weights=None):
    """Numpy reference of the full bin_rows contract."""
    cc = codes[valid]
    if len(cc):
        ref_u, ref_inv = np.unique(cc, axis=0, return_inverse=True)
    else:
        ref_u = np.zeros((0, 3), np.int64)
        ref_inv = np.zeros((0,), np.int64)
    q = len(ref_u)
    w = weights[valid] if weights is not None else np.ones(len(cc), np.int64)
    counts = np.zeros(q, np.int64)
    np.add.at(counts, ref_inv, w)
    inv = np.full(len(codes), -1, np.int32)
    inv[valid] = ref_inv
    return ref_u, counts, inv, q


def _check_bin(codes, valid, cap, weights=None, **kw):
    """One bin call (sort vs radix vs oracle), exact on every output."""
    jw = None if weights is None else jnp.asarray(weights)
    got_s = bin_rows(jnp.asarray(codes), jnp.asarray(valid), cap, jw,
                     method="sort", **kw)
    got_r = bin_rows(jnp.asarray(codes), jnp.asarray(valid), cap, jw,
                     method="radix", **kw)
    ref_u, ref_c, ref_inv, q = _oracle(codes, valid, weights)
    for got, name in ((got_s, "sort"), (got_r, "radix")):
        u, c, inv, n, uv = (np.asarray(x) for x in got)
        assert int(n) == q, name                       # unclamped distinct
        k = min(q, cap)
        np.testing.assert_array_equal(u[:k], ref_u[:k], err_msg=name)
        np.testing.assert_array_equal(c[:k], ref_c[:k], err_msg=name)
        np.testing.assert_array_equal(inv, ref_inv, err_msg=name)
        np.testing.assert_array_equal(uv, np.arange(cap) < q, err_msg=name)
        assert (c[k:] == 0).all(), name                # pad slots are empty


# ---------------------------------------------------------------------------
# radix bin vs lax.sort bin vs numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("b,cap,pv", [
    (1000, 1024, 0.9),     # ordinary batch, some invalid rows
    (500, 8, 1.0),         # overflow: far more distinct codes than cap
    (257, 64, 0.5),        # non-pow2 rows, half invalid
])
def test_radix_bin_matches_sort_and_oracle(use_kernel, b, cap, pv):
    rng = np.random.default_rng(b + cap)
    codes = _fake_codes(rng, b)
    valid = rng.random(b) < pv
    _check_bin(codes, valid, cap, use_kernel=use_kernel, interpret=True)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_radix_bin_single_slot_empty_all_invalid(use_kernel):
    kw = dict(use_kernel=use_kernel, interpret=True)
    one = np.tile(np.array([[3 | (5 << 4), 7, 0]], np.int64), (40, 1))
    _check_bin(one, np.ones(40, bool), 16, **kw)
    _check_bin(np.zeros((0, 3), np.int64), np.zeros((0,), bool), 16, **kw)
    rng = np.random.default_rng(9)
    _check_bin(_fake_codes(rng, 64), np.zeros(64, bool), 16, **kw)


def test_radix_bin_weighted_fold():
    rng = np.random.default_rng(2)
    codes = _fake_codes(rng, 300)
    w = rng.integers(1, 9, 300).astype(np.int64)
    valid = rng.random(300) < 0.8
    _check_bin(codes, valid, 512, weights=w)


def test_radix_bin_wide_keys_fall_back_exactly():
    """Words too wide to fuse into one 63-bit key: the in-program
    `lax.cond` slow path must still match the oracle bit for bit."""
    rng = np.random.default_rng(3)
    codes = _fake_codes(rng, 200)
    # widen all three words (still < 2^32 each) so the used bits sum > 63
    codes[:, 0] |= rng.integers(0, 1 << 30, 200).astype(np.int64) << 1
    codes[:, 1] |= rng.integers(0, 1 << 28, 200).astype(np.int64) << 3
    codes[:, 2] |= rng.integers(0, 1 << 28, 200).astype(np.int64) << 2
    valid = rng.random(200) < 0.9
    _check_bin(codes, valid, 64)    # with overflow
    _check_bin(codes, valid, 512)   # without


def test_radix_sort_codes_matches_sort_codes():
    from repro.kernels.aggregate import sort_codes

    rng = np.random.default_rng(4)
    codes = jnp.asarray(_fake_codes(rng, 500))
    valid = jnp.asarray(np.random.default_rng(5).random(500) < 0.7)
    sc, sv, _ = sort_codes(codes, valid)
    rc, rv, order = radix_bin.radix_sort_codes(
        codes, valid, block=128, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(rc), np.asarray(sc))
    np.testing.assert_array_equal(np.asarray(rv), np.asarray(sv))
    # order is a real permutation
    np.testing.assert_array_equal(np.sort(np.asarray(order)), np.arange(500))


# ---------------------------------------------------------------------------
# forced-decision matrix: bit-identical results across every table choice
# ---------------------------------------------------------------------------

_APPS = [
    ("motifs", lambda: MotifsApp(max_size=3)),
    ("cliques", lambda: CliquesApp(max_size=4)),
    ("fsm", lambda: FSMApp(support=2, max_size=3)),
]
_STORES = [
    ("raw", {}),
    ("odag", {"store": "odag"}),
    ("spill", {"device_budget_bytes": 1 << 14}),
]


def _result_key(res):
    """Everything a decision choice must NOT change: patterns and (for
    embedding apps) the exact embedding sets."""
    emb = {
        k: sorted(map(tuple, np.asarray(v).tolist()))
        for k, v in res.embeddings.items()
    }
    return (sorted(res.patterns.items()), emb)


@pytest.mark.parametrize("aname,mk", _APPS)
@pytest.mark.parametrize("sname,skw", _STORES)
def test_forced_modes_bit_identical_serial(aname, mk, sname, skw):
    g = G.random_labeled(40, 90, n_labels=2, seed=11)
    ref = run(g, mk(), EngineConfig(cost_model="off", **skw))
    for mode in ("force_device", "force_host"):
        got = run(g, mk(), EngineConfig(cost_model=mode, **skw))
        assert _result_key(got) == _result_key(ref), (aname, sname, mode)
        assert got.stats.cost_model["source"] == f"forced:{mode}"
    # auto on a tiny graph resolves statically — same results, no pilot
    auto = run(g, mk(), EngineConfig(**skw))
    assert _result_key(auto) == _result_key(ref)
    assert auto.stats.cost_model["source"] == "static"


@pytest.mark.parametrize("mode", ["auto", "force_device", "force_host"])
def test_forced_modes_bit_identical_shard_map(mode):
    from repro.core.distributed import DistConfig, run_distributed

    g = G.random_labeled(40, 90, n_labels=2, seed=12)
    mesh = jax.make_mesh((1,), ("data",))
    ref = run(g, MotifsApp(max_size=3), EngineConfig(cost_model="off"))
    got = run_distributed(
        g, MotifsApp(max_size=3), mesh, DistConfig(cost_model=mode)
    )
    assert got.patterns == ref.patterns
    src = got.stats.cost_model["source"]
    assert src == ("static" if mode == "auto" else f"forced:{mode}")


def test_forced_tables_pin_every_path():
    dev = costmodel.forced_table("force_device", "serial")
    host = costmodel.forced_table("force_host", "serial")
    assert dev.device_aggregate and dev.async_chunks
    assert dev.aggregate_bin == "radix"
    assert not host.device_aggregate and not host.async_chunks
    assert host.aggregate_bin == "sort"
    with pytest.raises(ValueError):
        costmodel.forced_table("force_nothing", "serial")
    with pytest.raises(ValueError):
        costmodel.resolve(
            EngineConfig(cost_model="bogus"),
            to_device(G.random_labeled(10, 20, n_labels=2, seed=0)),
            MotifsApp(max_size=3), "serial",
        )


def test_explicit_knobs_override_table():
    """User-set knobs always win over the table, and the effective table
    reflects the override (observability contract)."""
    g = to_device(G.random_labeled(40, 90, n_labels=2, seed=13))
    cfg = EngineConfig(cost_model="force_device", device_aggregate=False,
                       aggregate_bin="sort")
    resolved, table = costmodel.resolve(cfg, g, MotifsApp(max_size=3), "serial")
    assert resolved.device_aggregate is False
    assert resolved.aggregate_bin == "sort"
    assert table.device_aggregate is False
    assert table.aggregate_bin == "sort"
    assert "override.device_aggregate" in table.timings
    # non-overridden knobs still come from the forced table
    assert resolved.async_chunks is True


def test_decisions_recorded_in_runstats():
    g = G.random_labeled(40, 90, n_labels=2, seed=14)
    r = run(g, MotifsApp(max_size=3), EngineConfig())
    cm = r.stats.cost_model
    for knob in costmodel.DECIDED_KNOBS:
        assert knob in cm and cm[knob] is not None
    assert cm["backend"] == "serial"
    assert cm["platform"] == jax.default_backend()


# ---------------------------------------------------------------------------
# real calibration (shrunk probes) + the cache roundtrip
# ---------------------------------------------------------------------------

def _cal_graph(seed=15):
    return G.random_labeled(120, 600, n_labels=2, seed=seed)


def test_calibration_runs_and_resolves(monkeypatch):
    """A real probe pass: every decided knob concrete, timings populated,
    and the auto run bit-identical to the static config."""
    monkeypatch.setattr(costmodel, "PROBE_CHUNK_ROWS", 32)
    monkeypatch.setattr(costmodel, "PROBE_BIN_ROWS", 2048)
    monkeypatch.setattr(costmodel, "PROBE_OUT_CAP", 1 << 10)
    g = _cal_graph()
    costmodel.clear_cache()
    cfg = EngineConfig(cost_model_min_edges=100)
    ref = run(g, MotifsApp(max_size=3),
              dataclasses.replace(cfg, cost_model="off"))
    auto = run(g, MotifsApp(max_size=3), cfg)
    assert auto.patterns == ref.patterns
    cm = auto.stats.cost_model
    assert cm["source"] == "calibrated", cm
    assert any(k.startswith("expand.") for k in cm["timings"])
    assert any(k.startswith("bin.") for k in cm["timings"])
    for knob in costmodel.DECIDED_KNOBS:
        assert cm[knob] is not None
    # second run in the same process hits the process cache: no re-pilot
    again = run(g, MotifsApp(max_size=3), cfg)
    assert again.stats.cost_model["source"] == "calibrated"
    assert again.patterns == ref.patterns
    costmodel.clear_cache()


def _stub_calibrate(monkeypatch, marker):
    calls = []

    def fake(g, app, config, backend_name):
        calls.append(1)
        t = costmodel.static_table(backend_name, source="calibrated")
        t.timings["stub"] = marker
        return t

    monkeypatch.setattr(costmodel, "calibrate", fake)
    return calls


def test_cache_persistence_roundtrip(tmp_path, monkeypatch):
    """Disk cache: first resolve calibrates and persists; a fresh process
    (simulated by clearing the in-memory cache) loads the table back as
    source="cached" without re-piloting; a graph or config change
    re-pilots."""
    calls = _stub_calibrate(monkeypatch, 42.0)
    g = to_device(_cal_graph(16))
    app = MotifsApp(max_size=3)
    cfg = EngineConfig(cost_model_dir=str(tmp_path), cost_model_min_edges=0)
    costmodel.clear_cache()

    _, t1 = costmodel.resolve(cfg, g, app, "serial")
    assert t1.source == "calibrated" and len(calls) == 1
    assert len(list(tmp_path.glob("costmodel-*.json"))) == 1

    # same key, same process: cache hit, no new pilot
    _, t2 = costmodel.resolve(cfg, g, app, "serial")
    assert len(calls) == 1 and t2.timings["stub"] == 42.0

    # simulate a fresh process: in-memory cache cleared, disk survives
    costmodel.clear_cache()
    _, t3 = costmodel.resolve(cfg, g, app, "serial")
    assert t3.source == "cached" and len(calls) == 1
    assert t3.timings["stub"] == 42.0

    # a different graph re-pilots (new fingerprint, new file)
    costmodel.clear_cache()
    g2 = to_device(_cal_graph(17))
    _, t4 = costmodel.resolve(cfg, g2, app, "serial")
    assert t4.source == "calibrated" and len(calls) == 2
    assert len(list(tmp_path.glob("costmodel-*.json"))) == 2

    # a measurement-relevant config change re-pilots too
    costmodel.clear_cache()
    cfg2 = dataclasses.replace(cfg, chunk_size=cfg.chunk_size * 2)
    _, t5 = costmodel.resolve(cfg2, g, app, "serial")
    assert t5.source == "calibrated" and len(calls) == 3
    costmodel.clear_cache()


def test_cache_rejects_stale_schema(tmp_path, monkeypatch):
    calls = _stub_calibrate(monkeypatch, 7.0)
    g = to_device(_cal_graph(18))
    app = MotifsApp(max_size=3)
    cfg = EngineConfig(cost_model_dir=str(tmp_path), cost_model_min_edges=0)
    costmodel.clear_cache()
    costmodel.resolve(cfg, g, app, "serial")
    (path,) = tmp_path.glob("costmodel-*.json")
    d = json.loads(path.read_text())
    d["schema"] = -1
    path.write_text(json.dumps(d))
    costmodel.clear_cache()
    _, t = costmodel.resolve(cfg, g, app, "serial")
    assert t.source == "calibrated" and len(calls) == 2
    costmodel.clear_cache()


def test_small_graph_skips_pilot(monkeypatch):
    def boom(*a, **k):
        raise AssertionError("pilot must not run below cost_model_min_edges")

    monkeypatch.setattr(costmodel, "calibrate", boom)
    g = to_device(G.random_labeled(20, 40, n_labels=2, seed=19))
    _, t = costmodel.resolve(
        EngineConfig(), g, MotifsApp(max_size=3), "serial"
    )
    assert t.source == "static"


def test_probe_failure_falls_back_static(monkeypatch):
    monkeypatch.setattr(
        costmodel, "_calibrate",
        lambda *a, **k: (_ for _ in ()).throw(RuntimeError("probe boom")),
    )
    g = to_device(_cal_graph(20))
    costmodel.clear_cache()
    _, t = costmodel.resolve(
        EngineConfig(cost_model_min_edges=0), g, MotifsApp(max_size=3),
        "serial",
    )
    assert t.source == "static:probe-error"
    for knob in costmodel.DECIDED_KNOBS:
        assert getattr(t, knob) is not None
    costmodel.clear_cache()


def test_degradation_ladder_handles_tristate_and_radix():
    """The faults ladder downshifts an unresolved (None) knob and turns
    the radix bin off before dropping device aggregation."""
    cfg = RunConfig(aggregate_bin="radix")
    cfg2, event = faults.apply_degradation(cfg, "aggregate", "crash")
    assert event == "radix_bin_off" and cfg2.aggregate_bin == "sort"
    cfg3, event = faults.apply_degradation(cfg2, "aggregate", "crash")
    assert event == "host_aggregate" and cfg3.device_aggregate is False
    cfg4, event = faults.apply_degradation(cfg, "expand", "crash")
    assert event == "fused_off" and cfg4.async_chunks is False
