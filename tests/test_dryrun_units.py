"""Dry-run plumbing unit tests (pure functions — no 512-device mesh)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPE_BY_NAME, SHAPES, cell_is_runnable
from repro.configs.registry import ARCHS
from repro.models.layers import spec_for


MESH_SIZES = {"data": 16, "model": 16}


def test_spec_rules_tensor_parallel_only():
    """ZeRO-1 layout: plain weights are TP-only (perf iteration 2)."""
    s = spec_for("layers/attn/wq/w", (7168, 7168), MESH_SIZES, ("data",))
    assert s == P(None, "model")
    s = spec_for("layers/mlp/w_out/w", (20480, 7168), MESH_SIZES, ("data",))
    assert s == P("model", None)


def test_spec_rules_experts_data_sharded():
    s = spec_for("layers/moe/experts/w_gate", (160, 5120, 1536), MESH_SIZES, ("data",))
    assert s == P("model", ("data",), None)


def test_spec_rules_embed():
    s = spec_for("embed", (102400, 5120), MESH_SIZES, ("data",))
    assert s == P(("data",), "model")
    s = spec_for("unembed", (5120, 102400), MESH_SIZES, ("data",))
    assert s == P(None, "model")


def test_spec_rules_indivisible_fallback():
    # whisper vocab 51865 is not divisible by 16 -> replicated dim
    s = spec_for("unembed", (512, 51865), MESH_SIZES, ("data",))
    assert s == P("model", None) or s == P(None, None)


def test_spec_small_params_replicated():
    s = spec_for("layers/ln1", (64,), MESH_SIZES, ("data",))
    assert s == P(None)


def test_cell_skip_matrix():
    """Exactly the documented skips: long_500k runs only for ssm/hybrid."""
    runnable = {}
    for name, cfg in ARCHS.items():
        for shape in SHAPES:
            ok, why = cell_is_runnable(cfg, shape)
            runnable[(name, shape.name)] = ok
            if not ok:
                assert shape.name == "long_500k"
                assert why
    long_ok = [a for a in ARCHS if runnable[(a, "long_500k")]]
    assert sorted(long_ok) == ["xlstm-1.3b", "zamba2-2.7b"]
    # 40 cells total; 8 documented long_500k skips
    assert sum(runnable.values()) == 32


def test_all_cells_present_in_results():
    """The shipped dryrun_results.json covers every cell on both meshes."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    res = json.load(open(path))
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                key = f"{arch}|{shape.name}|{mesh}"
                assert key in res, key
                ok, _ = cell_is_runnable(ARCHS[arch], shape)
                expect = "ok" if ok else "skipped"
                assert res[key]["status"] == expect, (key, res[key]["status"])
    # headline numbers present for every ok cell
    for k, v in res.items():
        if v.get("status") == "ok" and not k.startswith("mining"):
            r = v["roofline"]
            assert r["flops"] > 0 and r["hbm_bytes"] > 0
            assert r["bottleneck"] in ("compute", "memory", "collective")


def test_mining_cells_present():
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    res = json.load(open(path))
    for key in ("mining|single", "mining|multi"):
        assert res.get(key, {}).get("status") == "ok"
