"""Distributed runtime == serial engine (run in a subprocess with 8
forced host devices so shard_map exercises real collectives)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import json
    import jax
    from repro.core import graph as G, run, EngineConfig
    from repro.core.apps import MotifsApp, FSMApp, CliquesApp
    from repro.core.distributed import run_distributed, DistConfig

    mesh = jax.make_mesh((8,), ("data",))
    assert len(jax.devices()) == 8
    g = G.random_labeled(60, 150, n_labels=3, seed=3)
    out = {}

    for name, mk in [
        ("motifs", lambda: MotifsApp(max_size=4)),
        ("fsm", lambda: FSMApp(support=3, max_size=3)),
    ]:
        ser = run(g, mk(), EngineConfig())
        dist = run_distributed(g, mk(), mesh, DistConfig(store="odag"))
        out[name] = {
            "match": ser.patterns == dist.patterns,
            "n": len(dist.patterns),
            "collective_bytes": [s.collective_bytes for s in dist.stats.steps],
        }
    print("RESULT" + json.dumps(out))
    """
)


@pytest.mark.slow
def test_distributed_matches_serial_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-W", "ignore", "-c", SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert out["motifs"]["match"]
    assert out["fsm"]["match"]
    assert all(b > 0 for b in out["fsm"]["collective_bytes"][:-1])


def test_partition_frontier_even_blocks():
    import numpy as np

    from repro.core.distributed import partition_frontier

    f = np.arange(23 * 3, dtype=np.int32).reshape(23, 3)
    shards, counts = partition_frontier(f, 4)
    assert shards.shape == (4, 6, 3)
    assert counts.tolist() == [6, 6, 6, 5]
    rebuilt = np.concatenate([shards[i, : counts[i]] for i in range(4)])
    assert (rebuilt == f).all()


def test_distributed_single_device_mesh():
    """shard_map path also works on the 1-device CPU mesh."""
    import jax

    from repro.core import graph as G, run, EngineConfig
    from repro.core.apps import MotifsApp
    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=2, seed=1)
    ser = run(g, MotifsApp(max_size=3), EngineConfig())
    dist = run_distributed(g, MotifsApp(max_size=3), mesh, DistConfig())
    assert ser.patterns == dist.patterns
