"""The Pallas canonical-check kernel on the engine hot path.

Covers the dispatch layer (interpret auto-detection, VMEM graph-size
fallback), the batch-shape hardening of the kernel wrappers (empty and
non-power-of-two batches), the fused ``expand_canonical`` kernel against
the jnp expansion, and the acceptance-criterion equivalence: ``engine.run``
with ``use_pallas=True`` and ``False`` produce identical patterns for
motifs, cliques, and FSM on the seed graphs.

All kernel invocations pin ``interpret=True`` so CPU CI runs the exact
kernel dataflow deterministically.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import canonical, explore, graph as G, odag, to_device
from repro.core import run, EngineConfig
from repro.core.apps import CliquesApp, FSMApp, MotifsApp
from repro.kernels import dispatch
from repro.kernels.canonical_check import ops as cc_ops
from repro.kernels.canonical_check.canonical_check import canonical_check_pallas
from repro.kernels.canonical_check.ref import canonical_check_ref


def _random_batch(rng, n, k, b):
    members = np.full((b, k), -1, np.int32)
    n_valid = (
        rng.integers(1, k + 1, b).astype(np.int32) if b else np.zeros(0, np.int32)
    )
    for i in range(b):
        members[i, : n_valid[i]] = rng.choice(n, size=n_valid[i], replace=False)
    cand = (
        rng.integers(0, n, b).astype(np.int32) if b else np.zeros(0, np.int32)
    )
    return jnp.asarray(members), jnp.asarray(n_valid), jnp.asarray(cand)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------

def test_resolve_interpret_explicit_passthrough():
    assert dispatch.resolve_interpret(True) is True
    assert dispatch.resolve_interpret(False) is False


def test_resolve_interpret_auto_matches_backend():
    expected = jax.default_backend() not in dispatch.COMPILED_BACKENDS
    assert dispatch.resolve_interpret(None) is expected
    # the engine-level static default is stricter: kernels default on only
    # where they are validated (TPU); GPU/CPU default to the jnp path
    from repro.core.runtime.costmodel import static_table
    assert static_table("serial").use_pallas is (
        jax.default_backend() == "tpu"
    )


def test_large_graph_falls_back_to_jnp(monkeypatch):
    g = G.random_labeled(50, 120, n_labels=2, seed=5)
    dg = to_device(g)
    m, nv, c = _random_batch(np.random.default_rng(5), 50, 4, 64)
    want = np.asarray(canonical.vertex_check(dg, m, nv, c))
    # force the "bitmap too big for VMEM" branch
    monkeypatch.setattr(cc_ops, "VMEM_BITMAP_LIMIT", 0)
    assert not cc_ops.fits_vmem(dg)
    got = np.asarray(cc_ops.canonical_check(dg, m, nv, c, interpret=True))
    np.testing.assert_array_equal(got, want)


def test_edge_mode_routes_to_jnp_check():
    g = G.triangle_plus_tail()
    dg = to_device(g)
    members = jnp.asarray([[0, 2, -1], [1, -1, -1]], jnp.int32)
    nv = jnp.asarray([2, 1], jnp.int32)
    cand = jnp.asarray([3, 0], jnp.int32)
    got = cc_ops.canonical_check(dg, members, nv, cand, mode="edge", interpret=True)
    want = canonical.edge_check(dg, members, nv, cand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# batch-shape hardening (satellite regression: b in {0, 1, 1023, 1025})
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b", [0, 1, 1023, 1025])
def test_canonical_check_batch_sizes(b):
    g = G.random_labeled(60, 150, n_labels=2, seed=b)
    dg = to_device(g)
    m, nv, c = _random_batch(np.random.default_rng(b), 60, 4, b)
    got = canonical_check_pallas(
        m, nv, c, dg.adj_bits, block_b=256, interpret=True
    )
    assert got.shape == (b,)
    want = canonical_check_ref(dg, m, nv, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b", [0, 1, 1023, 1025])
def test_ops_wrapper_batch_sizes(b):
    g = G.random_labeled(40, 90, n_labels=2, seed=b + 100)
    dg = to_device(g)
    m, nv, c = _random_batch(np.random.default_rng(b + 100), 40, 3, b)
    got = cc_ops.canonical_check(dg, m, nv, c, interpret=True)
    assert got.shape == (b,)
    want = canonical_check_ref(dg, m, nv, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_expand_canonical_empty_frontier():
    dg = to_device(G.triangle_plus_tail())
    cand, valid, keep = cc_ops.expand_canonical(
        dg, jnp.zeros((0, 3), jnp.int32), jnp.zeros((0,), jnp.int32),
        interpret=True,
    )
    assert cand.shape == (0, 3, dg.max_degree)
    assert valid.shape == keep.shape == cand.shape


# ---------------------------------------------------------------------------
# fused expansion kernel vs the jnp expansion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n,m,k", [(0, 30, 70, 2), (1, 50, 140, 3)])
def test_fused_expand_matches_jnp(seed, n, m, k):
    g = G.random_labeled(n, m, n_labels=2, seed=seed)
    dg = to_device(g)
    # grow a real frontier of size-k canonical embeddings via the jnp path
    members = jnp.arange(dg.n, dtype=jnp.int32)[:, None]
    for size in range(1, k):
        nv = jnp.full((members.shape[0],), size, jnp.int32)
        exp = explore.expand_vertex(dg, members, nv)
        children, count = explore.compact(members, exp, exp.keep, 1 << 14)
        members = children[: int(count)]
    nv = jnp.full((members.shape[0],), k, jnp.int32)

    e_jnp = explore.expand_vertex(dg, members, nv)
    e_fused = explore.expand_vertex(
        dg, members, nv, use_pallas=True, fused=True, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(e_jnp.rows), np.asarray(e_fused.rows))
    np.testing.assert_array_equal(np.asarray(e_jnp.cand), np.asarray(e_fused.cand))
    np.testing.assert_array_equal(np.asarray(e_jnp.keep), np.asarray(e_fused.keep))
    assert int(e_jnp.n_generated) == int(e_fused.n_generated)
    assert int(e_jnp.n_canonical) == int(e_fused.n_canonical)


def test_unfused_pallas_expand_matches_jnp():
    g = G.random_labeled(40, 100, n_labels=2, seed=7)
    dg = to_device(g)
    members = jnp.arange(dg.n, dtype=jnp.int32)[:, None]
    nv = jnp.ones((dg.n,), jnp.int32)
    e_jnp = explore.expand_vertex(dg, members, nv)
    e_pal = explore.expand_vertex(
        dg, members, nv, use_pallas=True, interpret=True
    )
    np.testing.assert_array_equal(np.asarray(e_jnp.keep), np.asarray(e_pal.keep))


# ---------------------------------------------------------------------------
# acceptance criterion: engine equivalence for all three example apps
# ---------------------------------------------------------------------------

APPS = [
    ("motifs", lambda: MotifsApp(max_size=3)),
    ("cliques", lambda: CliquesApp(max_size=4)),
    ("fsm", lambda: FSMApp(support=3, max_size=3)),
]


@pytest.mark.parametrize("name,mk", APPS, ids=[a[0] for a in APPS])
def test_engine_pallas_equivalence(name, mk):
    g = G.random_labeled(60, 150, n_labels=3, seed=3)
    base = run(g, mk(), EngineConfig(use_pallas=False))
    pallas = run(
        g, mk(), EngineConfig(use_pallas=True, pallas_interpret=True)
    )
    assert base.patterns == pallas.patterns
    fused = run(
        g, mk(),
        EngineConfig(use_pallas=True, fused_expand=True, pallas_interpret=True),
    )
    assert base.patterns == fused.patterns


def test_engine_pallas_equivalence_paper_graph():
    g = G.paper_figure2()
    base = run(g, MotifsApp(max_size=3), EngineConfig(use_pallas=False))
    pallas = run(
        g, MotifsApp(max_size=3),
        EngineConfig(use_pallas=True, pallas_interpret=True),
    )
    assert base.patterns == pallas.patterns


def test_distributed_pallas_equivalence():
    """pallas_call inside the shard_map worker (needs check_rep=False —
    regression for the _shard_map_pallas_ok dispatch)."""
    from repro.core.distributed import DistConfig, run_distributed

    mesh = jax.make_mesh((1,), ("data",))
    g = G.random_labeled(40, 90, n_labels=2, seed=1)
    ser = run(g, MotifsApp(max_size=3), EngineConfig(use_pallas=False))
    dist = run_distributed(
        g, MotifsApp(max_size=3), mesh,
        DistConfig(use_pallas=True, pallas_interpret=True),
    )
    assert ser.patterns == dist.patterns


# ---------------------------------------------------------------------------
# odag extraction through the kernel dispatch
# ---------------------------------------------------------------------------

def test_odag_extract_pallas_equivalence():
    g = G.random_labeled(40, 100, n_labels=2, seed=9)
    res = run(
        g, MotifsApp(max_size=3, collect_embeddings=True),
        EngineConfig(use_pallas=False),
    )
    emb = res.embeddings[3]
    dg = to_device(g)
    o = odag.build(emb)
    base = odag.extract(dg, o)
    pal = odag.extract(dg, o, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(base, pal)
