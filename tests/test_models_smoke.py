"""Per-arch smoke tests (assignment requirement): reduced same-family
config, one forward/train step on CPU, output shapes + no NaNs; plus
decode-vs-forward consistency for representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS
from repro.models import build_model

SMOKE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_forward_and_decode(name):
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    batch = m.make_batch(SMOKE, RNG)
    loss = m.loss(params, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert float(loss) > 0

    cache = m.init_cache(2, SMOKE.seq_len)
    logits, cache2 = m.decode_step(
        params, cache, jnp.zeros((2, 1), jnp.int32), jnp.int32(0)
    )
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    # cache structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_train_step_decreases_loss(name):
    """One SGD step on repeated data must reduce the loss (checks grads
    flow through every family's block structure)."""
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(RNG)
    batch = m.make_batch(SMOKE, RNG)

    loss0, grads = jax.value_and_grad(m.loss)(params, batch)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{name}: dead grads"
    params2 = jax.tree.map(
        lambda p, g: (p - (0.5 / jnp.maximum(gnorm, 1.0)) * g).astype(p.dtype),
        params,
        grads,
    )
    loss1 = m.loss(params2, batch)
    assert float(loss1) < float(loss0), f"{name}: {loss0} -> {loss1}"


@pytest.mark.parametrize("name", ["stablelm-1.6b", "qwen2.5-14b", "deepseek-v2-236b"])
def test_decode_matches_forward(name):
    """Token-by-token decode with KV cache reproduces the full-sequence
    forward logits. GQA matches tightly; MLA decode uses the absorbed-weight
    formulation (q @ W_uk vs W_uk @ c_kv — different bf16 associativity), so
    per-layer noise ~0.03 compounds over depth and gets a looser bound; the
    single-layer agreement is checked separately below."""
    cfg = dataclasses.replace(ARCHS[name].reduced(), remat=False)
    m = build_model(cfg)
    params = m.init(RNG)
    s = 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    full = np.asarray(m.impl.forward(params, tokens), np.float32)

    cache = m.init_cache(2, s)
    outs = []
    for t in range(s):
        logits, cache = m.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    if ARCHS[name].use_mla:
        # absorbed-weight decode: bf16 associativity noise compounds over
        # depth; require near-perfect logit correlation + argmax agreement
        corr = np.corrcoef(dec.ravel(), full.ravel())[0, 1]
        assert corr > 0.995, corr
        agree = (dec.argmax(-1) == full.argmax(-1)).mean()
        assert agree > 0.9, agree
    else:
        np.testing.assert_allclose(dec, full, rtol=3e-2, atol=3e-2)


def test_mla_decode_matches_forward_single_layer():
    """Absorbed-weight MLA decode == materialised-KV forward within bf16
    noise when depth amplification is excluded."""
    cfg = dataclasses.replace(
        ARCHS["deepseek-v2-236b"].reduced(), remat=False, n_layers=1,
        first_dense_layers=0,
    )
    m = build_model(cfg)
    params = m.init(RNG)
    s = 6
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, s), 0, cfg.vocab)
    full = np.asarray(m.impl.forward(params, tokens), np.float32)
    cache = m.init_cache(2, s)
    outs = []
    for t in range(s):
        logits, cache = m.decode_step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        outs.append(np.asarray(logits[:, 0], np.float32))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=5e-2, atol=5e-2)


def test_decode_matches_forward_ssm():
    """Chunkwise-parallel mLSTM/Mamba2 forward == recurrent decode."""
    for name in ["xlstm-1.3b", "zamba2-2.7b"]:
        cfg = dataclasses.replace(
            ARCHS[name].reduced(), remat=False, ssm_chunk=4, sliding_window_long=10 ** 9
        )
        m = build_model(cfg)
        params = m.init(RNG)
        s = 16
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, s), 0, cfg.vocab)
        full = np.asarray(m.impl.forward(params, tokens), np.float32)
        cache = m.init_cache(2, s)
        outs = []
        for t in range(s):
            logits, cache = m.decode_step(
                params, cache, tokens[:, t : t + 1], jnp.int32(t)
            )
            outs.append(np.asarray(logits[:, 0], np.float32))
        dec = np.stack(outs, axis=1)
        # chunked-parallel (bf16 matmul accum) vs recurrent (f32 state)
        # agree to bf16 noise; check tight correlation + loose elementwise
        corr = np.corrcoef(dec.ravel(), full.ravel())[0, 1]
        assert corr > 0.998, (name, corr)
        diff = np.abs(dec - full)
        assert diff.mean() < 0.05, (name, diff.mean())
        assert np.quantile(diff, 0.99) < 0.25, (name, np.quantile(diff, 0.99))


def test_exact_assigned_configs():
    """The full configs carry the assignment's exact numbers."""
    a = ARCHS["deepseek-v2-236b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.vocab) == (60, 5120, 128, 102400)
    assert (a.n_experts, a.top_k, a.kv_lora) == (160, 6, 512)
    a = ARCHS["yi-34b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff, a.vocab) == (
        60, 7168, 56, 8, 20480, 64000,
    )
    a = ARCHS["qwen2.5-14b"]
    assert a.qkv_bias and a.vocab == 152064
    a = ARCHS["xlstm-1.3b"]
    assert a.d_ff == 0 and a.family == "ssm"
    assert len(ARCHS) == 10
