"""Hypothesis property tests for the paper's Appendix theorems.

Invariants under test:
  * Uniqueness (Thm 3): among all visit orders of a connected vertex set,
    exactly one passes the incremental check at every prefix.
  * The accepted order equals the greedy construction of Thm 3.
  * Extendibility (Thm 2): the canonical automorphism of a child extends the
    canonical parent.
  * Completeness (Thm 4): engine exploration visits exactly the oracle's
    embedding sets (via the set-equality integration test).
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis is an optional test extra (pyproject `[test]`); without it the
# whole module is skipped — the seeded fallback in test_canonical_seeded.py
# keeps the core property covered either way.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import canonical, graph as G, to_device


@st.composite
def small_graph(draw):
    n = draw(st.integers(min_value=2, max_value=9))
    possible = [(i, j) for i in range(n) for j in range(i + 1, n)]
    mask = draw(st.lists(st.booleans(), min_size=len(possible), max_size=len(possible)))
    edges = [e for e, m in zip(possible, mask) if m]
    if not edges:
        edges = [possible[0]]
    labels = draw(
        st.lists(st.integers(0, 3), min_size=n, max_size=n)
    )
    return G.Graph(n=n, labels=np.array(labels), edges=np.array(edges))


def _incremental_accepts(dg, order):
    """Run Alg. 2 over every prefix of a visit order."""
    k = len(order)
    for i in range(1, k):
        members = jnp.full((1, k), -1, jnp.int32)
        members = members.at[0, :i].set(jnp.asarray(order[:i], jnp.int32))
        ok = canonical.vertex_check(
            dg, members, jnp.array([i], jnp.int32), jnp.array([order[i]], jnp.int32)
        )
        if not bool(ok[0]):
            return False
    return True


def _connected_orders(adj_sets, vs):
    """All visit orders of vertex set vs where each vertex attaches to the
    prefix (the only orders exploration can produce)."""
    orders = []
    for perm in itertools.permutations(vs):
        ok = True
        for i in range(1, len(perm)):
            if not any(perm[j] in adj_sets[perm[i]] for j in range(i)):
                ok = False
                break
        if ok:
            orders.append(perm)
    return orders


@settings(max_examples=40, deadline=None)
@given(small_graph(), st.integers(0, 10_000))
def test_uniqueness_thm3(g, pick):
    dg = to_device(g)
    adj = [set() for _ in range(g.n)]
    for u, v in g.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))

    # pick a random connected vertex set by greedy growth
    rng = np.random.default_rng(pick)
    size = int(rng.integers(2, 5))
    emb = {int(rng.integers(0, g.n))}
    for _ in range(size - 1):
        border = set().union(*(adj[v] for v in emb)) - emb
        if not border:
            break
        emb.add(int(rng.choice(sorted(border))))
    if len(emb) < 2:
        return

    orders = _connected_orders(adj, sorted(emb))
    accepted = [o for o in orders if _incremental_accepts(dg, list(o))]
    assert len(accepted) == 1, (emb, accepted)

    # the accepted order is the greedy Thm-3 construction
    ref = canonical.canonical_order_vertices(
        lambda a, b: b in adj[a], emb
    )
    assert list(accepted[0]) == ref


@settings(max_examples=25, deadline=None)
@given(small_graph())
def test_extendibility_thm2(g):
    """For every canonical embedding of size k>=2, dropping its last visited
    vertex that keeps it connected yields... equivalently: the canonical
    order's every prefix is itself canonical (the check is incremental), so
    the canonical child extends a canonical parent."""
    dg = to_device(g)
    adj = [set() for _ in range(g.n)]
    for u, v in g.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))
    from repro.core.baselines.bruteforce import enumerate_vertex_embeddings

    levels = enumerate_vertex_embeddings(g, 4)
    for k in (3, 4):
        for emb in list(levels[k])[:30]:
            order = canonical.canonical_order_vertices(lambda a, b: b in adj[a], emb)
            if order is None:
                continue
            assert _incremental_accepts(dg, order)
            # every prefix is canonical for its own vertex set
            for i in range(2, len(order)):
                prefix_ref = canonical.canonical_order_vertices(
                    lambda a, b: b in adj[a], order[:i]
                )
                assert prefix_ref == order[:i]


@settings(max_examples=20, deadline=None)
@given(small_graph())
def test_edge_canonicality_uniqueness(g):
    """Edge-mode analogue: exactly one attach-connected edge order per edge
    set passes the incremental edge check."""
    dg = to_device(g)
    from repro.core.baselines.bruteforce import enumerate_edge_embeddings

    levels = enumerate_edge_embeddings(g, 3)
    edge_uv = [tuple(int(x) for x in e) for e in g.edges]

    def shares(e1, e2):
        return bool(set(edge_uv[e1]) & set(edge_uv[e2]))

    for k in (2, 3):
        for emb in list(levels[k])[:40]:
            es = sorted(emb)
            accepted = []
            for perm in itertools.permutations(es):
                # attach-connectivity
                ok = all(
                    any(shares(perm[i], perm[j]) for j in range(i))
                    for i in range(1, k)
                )
                if not ok:
                    continue
                passes = True
                for i in range(1, k):
                    members = jnp.full((1, k), -1, jnp.int32)
                    members = members.at[0, :i].set(jnp.asarray(perm[:i], jnp.int32))
                    r = canonical.edge_check(
                        dg,
                        members,
                        jnp.array([i], jnp.int32),
                        jnp.array([perm[i]], jnp.int32),
                    )
                    if not bool(r[0]):
                        passes = False
                        break
                if passes:
                    accepted.append(perm)
            assert len(accepted) == 1, (es, accepted)
            assert list(accepted[0]) == canonical.canonical_order_edges(
                edge_uv, es
            )
