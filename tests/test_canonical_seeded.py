"""Seeded-random fallback for the hypothesis property suite.

``tests/test_property_canonical.py`` needs the optional ``hypothesis``
package and is skipped without it; this module re-checks the central
uniqueness property (paper Appendix Thm 3) with plain seeded randomness so
the Alg.-2 implementation is never silently untested:

  among all attach-connected visit orders of a connected vertex set,
  exactly one passes ``canonical.vertex_check`` at every prefix, and it is
  the greedy ``canonical_order_vertices`` construction.
"""
import itertools

import jax.numpy as jnp
import numpy as np

from repro.core import canonical, graph as G, to_device


def _incremental_accepts(dg, order):
    k = len(order)
    for i in range(1, k):
        members = jnp.full((1, k), -1, jnp.int32)
        members = members.at[0, :i].set(jnp.asarray(order[:i], jnp.int32))
        ok = canonical.vertex_check(
            dg, members, jnp.array([i], jnp.int32), jnp.array([order[i]], jnp.int32)
        )
        if not bool(ok[0]):
            return False
    return True


def _random_connected_set(rng, adj, n, size):
    emb = {int(rng.integers(0, n))}
    for _ in range(size - 1):
        border = set().union(*(adj[v] for v in emb)) - emb
        if not border:
            break
        emb.add(int(rng.choice(sorted(border))))
    return emb


def test_uniqueness_thm3_seeded():
    checked = 0
    for seed in range(12):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(4, 9))
        m = int(rng.integers(n - 1, n * (n - 1) // 2 + 1))
        g = G.random_labeled(n, m, n_labels=3, seed=seed)
        if g.m == 0:
            continue
        dg = to_device(g)
        adj = [set() for _ in range(g.n)]
        for u, v in g.edges:
            adj[int(u)].add(int(v))
            adj[int(v)].add(int(u))

        for _ in range(4):
            emb = _random_connected_set(rng, adj, g.n, int(rng.integers(2, 5)))
            if len(emb) < 2:
                continue
            # all attach-connected visit orders of the set
            orders = []
            for perm in itertools.permutations(sorted(emb)):
                if all(
                    any(perm[j] in adj[perm[i]] for j in range(i))
                    for i in range(1, len(perm))
                ):
                    orders.append(perm)
            accepted = [o for o in orders if _incremental_accepts(dg, list(o))]
            assert len(accepted) == 1, (seed, emb, accepted)
            ref = canonical.canonical_order_vertices(lambda a, b: b in adj[a], emb)
            assert list(accepted[0]) == ref, (seed, emb)
            checked += 1
    assert checked >= 20  # the loop actually exercised the property
