"""Quick/canonical pattern invariants (paper §5.4)."""
import itertools

import jax.numpy as jnp
import numpy as np
import networkx as nx

from repro.core import graph as G, run, EngineConfig, to_device
from repro.core import pattern as pl
from repro.core.apps import MotifsApp


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(50):
        nv = int(rng.integers(1, 8))
        adj = rng.random((nv, nv)) < 0.4
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        labels = rng.integers(0, 200, nv)
        code = pl.encode(nv, adj, labels)
        nv2, adj2, lab2 = pl.decode(code)
        assert nv2 == nv and (adj2 == adj).all() and (lab2 == labels).all()


def test_canonical_code_is_isomorphism_invariant():
    """Permuting a pattern's vertices never changes its canonical code."""
    rng = np.random.default_rng(1)
    for _ in range(25):
        nv = int(rng.integers(2, 6))
        adj = rng.random((nv, nv)) < 0.5
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        labels = rng.integers(0, 3, nv)
        base, _ = pl.canonicalize_one(pl.encode(nv, adj, labels))
        for perm in itertools.permutations(range(nv)):
            perm = np.array(perm)
            c2, _ = pl.canonicalize_one(
                pl.encode(nv, adj[np.ix_(perm, perm)], labels[perm])
            )
            assert c2 == base


def test_canonical_codes_distinguish_nonisomorphic():
    """Canonical equality <-> networkx isomorphism on random small patterns."""
    rng = np.random.default_rng(2)
    pats = []
    for _ in range(30):
        nv = int(rng.integers(2, 5))
        adj = rng.random((nv, nv)) < 0.5
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        labels = rng.integers(0, 2, nv)
        code, _ = pl.canonicalize_one(pl.encode(nv, adj, labels))
        gg = pl.pattern_to_networkx(np.array(code))
        pats.append((code, gg))
    nm = nx.algorithms.isomorphism.categorical_node_match("label", 0)
    for (c1, g1), (c2, g2) in itertools.combinations(pats, 2):
        iso = nx.is_isomorphic(g1, g2, node_match=nm)
        assert iso == (c1 == c2)


def test_quick_pattern_reduction_factor():
    """Table 4's shape: #quick patterns << #embeddings, and #canonical <=
    #quick (measured on a lightly-labeled graph like the paper's motif
    datasets; uniform-random 29-label graphs are the adversarial case)."""
    g = G.random_labeled(300, 3000, n_labels=2, seed=11)
    res = run(g, MotifsApp(max_size=3), EngineConfig(chunk_size=4096, initial_capacity=4096))
    st = res.stats.steps[-1]
    assert st.n_quick_patterns >= st.n_canonical_patterns >= 1
    assert st.n_frontier > 100 * st.n_quick_patterns  # orders-of-magnitude gap
    assert st.n_iso_checks == st.n_quick_patterns


def test_automorphism_orbits_path_and_triangle():
    # path a-b-c: endpoints share an orbit, middle alone
    code = pl.encode(3, np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], bool), np.zeros(3, int))
    orb = pl.automorphism_orbits(code)
    assert orb[0] == orb[2] != orb[1]
    # triangle: single orbit
    code = pl.encode(3, ~np.eye(3, dtype=bool), np.zeros(3, int))
    orb = pl.automorphism_orbits(code)
    assert orb[0] == orb[1] == orb[2]
    # labeled path with distinct end labels: no symmetry
    code = pl.encode(3, np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], bool), np.array([1, 0, 2]))
    orb = pl.automorphism_orbits(code)
    assert len({orb[0], orb[1], orb[2]}) == 3


def test_quick_pattern_vertex_device_matches_host():
    g = G.random_labeled(30, 70, n_labels=4, seed=4)
    dg = to_device(g)
    from repro.core.baselines.bruteforce import enumerate_vertex_embeddings
    from repro.core import canonical

    adj = [set() for _ in range(g.n)]
    for u, v in g.edges:
        adj[int(u)].add(int(v))
        adj[int(v)].add(int(u))

    embs = list(enumerate_vertex_embeddings(g, 3)[3])[:64]
    orders = [
        canonical.canonical_order_vertices(lambda a, b: b in adj[a], e) for e in embs
    ]
    members = jnp.asarray(np.array(orders, np.int32))
    qp = pl.quick_pattern_vertex(dg, members, jnp.full((len(orders),), 3, jnp.int32))
    for i, order in enumerate(orders):
        nv, dadj, dlab = pl.decode(np.asarray(qp.codes[i]))
        assert nv == 3
        assert (dlab == g.labels[np.array(order)]).all()
        for a in range(3):
            for b in range(3):
                if a != b:
                    assert dadj[a, b] == (order[b] in adj[order[a]])
